"""Training substrate units: optimizer math, compression, data pipeline
determinism, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import DataConfig, Prefetcher, SyntheticTokens
from repro.training import compression
from repro.training.optimizer import AdamW, cosine_schedule


class TestAdamW:
    def test_quadratic_convergence(self):
        opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}        # d/dw w^2
            params, state = opt.update(grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_bf16_states_roundtrip(self):
        opt = AdamW(lr=1e-3, state_dtype="bfloat16")
        params = {"w": jnp.ones((8, 8))}
        state = opt.init(params)
        assert state.m["w"].dtype == jnp.bfloat16
        params2, state2 = opt.update({"w": jnp.ones((8, 8))}, state, params)
        assert state2.v["w"].dtype == jnp.bfloat16
        assert bool(jnp.isfinite(params2["w"]).all())

    def test_clipping_bounds_update(self):
        opt = AdamW(lr=1.0, clip_norm=1.0, weight_decay=0.0)
        params = {"w": jnp.zeros(4)}
        state = opt.init(params)
        _, s2 = opt.update({"w": jnp.full(4, 1e6)}, state, params)
        # post-clip first moment magnitude <= (1-b1)*clip
        assert float(jnp.abs(s2.m["w"]).max()) <= 0.11

    def test_decay_only_matrices(self):
        opt = AdamW(lr=1e-2, weight_decay=1.0, clip_norm=0.0)
        params = {"mat": jnp.ones((4, 4)), "vec": jnp.ones((4,))}
        state = opt.init(params)
        zero = {"mat": jnp.zeros((4, 4)), "vec": jnp.zeros((4,))}
        p2, _ = opt.update(zero, state, params)
        assert float(p2["mat"][0, 0]) < 1.0     # decayed
        assert float(p2["vec"][0]) == 1.0       # not decayed

    def test_cosine_schedule_shape(self):
        sched = cosine_schedule(warmup=10, total=100)
        assert float(sched(jnp.int32(0))) == 0.0
        assert abs(float(sched(jnp.int32(10))) - 1.0) < 1e-5
        assert float(sched(jnp.int32(100))) <= 0.11


class TestCompression:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_int8_roundtrip_error_bounded(self, seed):
        g = jax.random.normal(jax.random.PRNGKey(seed), (333,)) * 10
        out = compression.int8_roundtrip({"g": g})["g"]
        err = jnp.abs(out - g).max()
        scale = jnp.abs(g).max() / 127.0
        assert float(err) <= float(scale) * 0.51 + 1e-6

    def test_error_feedback_unbiased_over_time(self):
        """EF-SGD: accumulated compressed updates converge to the true
        sum (residual feedback recovers the quantization loss)."""
        params = {"w": jnp.zeros(64)}
        ef = compression.EFState(params)
        true_g = jax.random.normal(jax.random.PRNGKey(0), (64,)) * 1e-3
        acc = jnp.zeros(64)
        for _ in range(64):
            cg = compression.compress_with_feedback({"w": true_g}, ef)
            acc = acc + cg["w"]
        rel = float(jnp.linalg.norm(acc - 64 * true_g)
                    / jnp.linalg.norm(64 * true_g))
        assert rel < 0.05, rel

    def test_compression_ratio(self):
        g = jax.random.normal(jax.random.PRNGKey(1), (1024,))
        q, scale, shape, pad = compression._quant_block(g)
        wire = q.size * 1 + scale.size * 4
        assert wire < 0.3 * g.size * 4          # > 3.3x compression


class TestDataPipeline:
    def test_deterministic_in_step_and_host(self):
        cfg = DataConfig(vocab=128, seq_len=32, global_batch=8)
        a = SyntheticTokens(cfg, host_id=0, num_hosts=2)
        b = SyntheticTokens(cfg, host_id=0, num_hosts=2)
        np.testing.assert_array_equal(a.batch(17), b.batch(17))
        c = SyntheticTokens(cfg, host_id=1, num_hosts=2)
        assert not np.array_equal(a.batch(17), c.batch(17))

    def test_resume_mid_stream(self):
        cfg = DataConfig(vocab=128, seq_len=16, global_batch=4)
        src = SyntheticTokens(cfg)
        direct = src.batch(42)
        pf = Prefetcher(src, start_step=42)
        step, fetched = pf.next()
        pf.close()
        assert step == 42
        np.testing.assert_array_equal(direct, fetched)

    def test_token_range(self):
        cfg = DataConfig(vocab=100, seq_len=64, global_batch=4)
        b = SyntheticTokens(cfg).batch(0)
        assert b.min() >= 0 and b.max() < 100

    def test_structure_learnable(self):
        """Bigram structure exists: successor entropy < unigram entropy."""
        cfg = DataConfig(vocab=64, seq_len=256, global_batch=16)
        b = SyntheticTokens(cfg).batch(0)
        pairs = {}
        for row in b:
            for x, y in zip(row[:-1], row[1:]):
                pairs.setdefault(int(x), []).append(int(y))
        # most-common-successor accuracy far above chance
        hits = tot = 0
        for ys in pairs.values():
            vals, counts = np.unique(ys, return_counts=True)
            hits += counts.max()
            tot += counts.sum()
        assert hits / tot > 0.2, hits / tot
