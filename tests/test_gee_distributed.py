"""Distributed GEE equivalence on 8 host devices (subprocess so the
device-count flag never leaks into other tests)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow          # fresh-interpreter device sweep

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from repro.graph.generators import erdos_renyi, powerlaw
from repro.graph.edges import make_labels
from repro.core import ref_python as R
from repro.core.distributed import gee_distributed, edge_mesh

out = {"devices": len(jax.devices())}
rng = np.random.default_rng(0)
mesh = edge_mesh()
for name, g in [
    ("er", erdos_renyi(1003, 20007, seed=1, weighted=True)),
    ("skew", powerlaw(512, 8192, seed=2)),
]:
    Y = make_labels(g.n, 7, 0.2, rng)
    Zref = R.gee_numpy(g.u, g.v, g.w, Y, 7, g.n)
    for mode in ["replicated", "reduce_scatter", "a2a", "ring"]:
        Z, dropped = gee_distributed(g, Y, K=7, mode=mode, mesh=mesh)
        out[f"{name}_{mode}_err"] = float(np.abs(Z - Zref).max())
        out[f"{name}_{mode}_dropped"] = dropped
# laplacian through the ring
g = erdos_renyi(500, 6000, seed=3, weighted=True)
Y = make_labels(g.n, 5, 0.3, rng)
from repro.core.gee import gee
Zl_ref = np.asarray(gee(jnp.asarray(g.u), jnp.asarray(g.v),
                        jnp.asarray(g.w), jnp.asarray(Y), K=5, n=g.n,
                        laplacian=True))
Zl, d = gee_distributed(g, Y, K=5, mode="ring", mesh=mesh, laplacian=True)
out["laplacian_ring_err"] = float(np.abs(Zl - Zl_ref).max())
out["laplacian_ring_dropped"] = d
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_runs_on_8_devices(dist_results):
    assert dist_results["devices"] == 8


@pytest.mark.parametrize("graph", ["er", "skew"])
@pytest.mark.parametrize("mode",
                         ["replicated", "reduce_scatter", "a2a", "ring"])
def test_mode_matches_serial(dist_results, graph, mode):
    assert dist_results[f"{graph}_{mode}_err"] < 1e-4
    assert dist_results[f"{graph}_{mode}_dropped"] == 0


def test_laplacian_ring(dist_results):
    assert dist_results["laplacian_ring_err"] < 1e-4
    assert dist_results["laplacian_ring_dropped"] == 0


def test_prebucketed_steady_state():
    """a2a_steady (ingestion-time bucketing, per-iteration sort-free) is
    exact — run in subprocess on 8 devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    script = (
        "import numpy as np, jax, jax.numpy as jnp\n"
        "from repro.graph.generators import powerlaw\n"
        "from repro.graph.edges import make_labels\n"
        "from repro.core import ref_python as R\n"
        "from repro.core.distributed import (edge_mesh, prebucket_host,\n"
        "                                    gee_a2a_steady)\n"
        "mesh = edge_mesh(); p = 8\n"
        "g = powerlaw(512, 8192, seed=2)\n"
        "Y = make_labels(g.n, 7, 0.2, np.random.default_rng(0))\n"
        "Zref = R.gee_numpy(g.u, g.v, g.w, Y, 7, g.n)\n"
        "b_dst, b_src, b_w, n_pad = prebucket_host(g, p)\n"
        "Y_pad = np.full(n_pad, -1, np.int32); Y_pad[:g.n] = Y\n"
        "cap = b_dst.shape[-1]\n"
        "Z, _ = gee_a2a_steady(jnp.asarray(b_dst.reshape(p*p, cap)),\n"
        "                      jnp.asarray(b_src.reshape(p*p, cap)),\n"
        "                      jnp.asarray(b_w.reshape(p*p, cap)),\n"
        "                      jnp.asarray(Y_pad), K=7, n_pad=n_pad,\n"
        "                      mesh=mesh)\n"
        "Z = np.asarray(Z).reshape(n_pad, 7)[:g.n]\n"
        "assert np.abs(Z - Zref).max() < 1e-4\n"
        "print('STEADY_OK')\n")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "STEADY_OK" in r.stdout
