# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see
# the real single CPU device; only launch/dryrun.py (and explicit
# subprocess tests) request 512 placeholder devices.
import os


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running (subprocess compile) tests")
    # Hermetic tests: the encoder's PERSISTENT plan-cache tier would
    # otherwise write to the user's real cache dir and make identity-
    # tier counter assertions order-dependent.  Tests that exercise the
    # persistent tier opt back in with explicit plan_cache dirs (or set
    # the env var themselves in subprocesses).
    os.environ["REPRO_PLAN_CACHE"] = "off"
