# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see
# the real single CPU device; only launch/dryrun.py (and explicit
# subprocess tests) request 512 placeholder devices.
import os
import zlib

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running (subprocess compile / "
        "crash-recovery / fuzz) tests — excluded from the CI fast "
        "lane (`make test-fast`), run by the slow lane")
    # Hermetic tests: the encoder's PERSISTENT plan-cache tier would
    # otherwise write to the user's real cache dir and make identity-
    # tier counter assertions order-dependent.  Tests that exercise the
    # persistent tier opt back in with explicit plan_cache dirs (or set
    # the env var themselves in subprocesses).
    os.environ["REPRO_PLAN_CACHE"] = "off"
    # Same hermeticity for observability: never append test spans to a
    # user's JSONL trace sink (tests that exercise the sink point it at
    # tmp_path via obs.configure).
    os.environ.pop("REPRO_OBS_TRACE", None)


@pytest.fixture
def rng(request):
    """THE test-suite RNG seeding path: a reproducible per-test stream.

    The seed is derived from the test's stable node id (file + class +
    name + params), so every test gets an independent stream that is
    identical across runs and workers — no global seeding, no
    order-dependence, and two tests can never accidentally share a
    stream.  Tests that must replay the *same* stream twice inside one
    test body should fork with ``rng.spawn()`` or draw arrays once and
    reuse them."""
    return np.random.default_rng(
        zlib.adler32(request.node.nodeid.encode()))


def topk_equivalent(idx_a, val_a, idx_b, val_b, atol=1e-5):
    """Assert two top-k answers agree, tie-tolerantly BY SCORE.

    NOTE: the serving kernels themselves are now bit-stable — every
    top-k surface in `repro.serving.queries` breaks score ties by
    ascending global id, so sharded / single-host / IVF answers from
    the SAME Z can (and in the engine tests do) use plain
    `np.array_equal`.  This fixture remains for cross-implementation
    comparisons where the *scores* differ in float low bits (different
    reduction orders: delta-folded vs rebuilt Z, gee vs gee_streaming),
    which can legitimately reorder near-tied candidates:

    * the (row-wise descending) score vectors match everywhere;
    * every slot separated from BOTH neighbors by more than `atol` —
      where the winning candidate is uniquely determined — carries the
      same index (this catches right-score/wrong-id stamping bugs that
      a score-only comparison would miss).

    The LAST slot is never index-checked: it can tie with the (k+1)-th
    candidate, which is invisible in the output."""
    val_a, val_b = np.asarray(val_a), np.asarray(val_b)
    idx_a, idx_b = np.asarray(idx_a), np.asarray(idx_b)
    np.testing.assert_allclose(val_a, val_b, atol=atol)
    with np.errstate(invalid="ignore"):      # -inf pads diff to nan
        gap = (val_a[:, :-1] - val_a[:, 1:]) > atol   # nan -> tied
    no_tie = np.ones(idx_a.shape, bool)
    no_tie[:, 1:] &= gap
    no_tie[:, :-1] &= gap
    no_tie[:, -1] = False
    np.testing.assert_array_equal(idx_a[no_tie], idx_b[no_tie])


@pytest.fixture(name="assert_topk_equivalent")
def _assert_topk_equivalent():
    """The shared tie-tolerant top-k assertion (see `topk_equivalent`)."""
    return topk_equivalent
