# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see
# the real single CPU device; only launch/dryrun.py (and explicit
# subprocess tests) request 512 placeholder devices.


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running (subprocess compile) tests")
