"""Transport-layer fault injection: framing fuzz, RPC robustness, WAL
group commit, replica lag, and the multi-process deployment contract.

The fast half attacks the wire format and RPC loop in-process (mirrors
`test_wal_fuzz`: random truncation and bit-flips must surface as
`FrameError`, never as garbage data or a wedged server), and drives
the WAL's group-commit accounting plus the batcher's deferred-ticket
release at the engine level.

The slow half spawns REAL worker processes: a 2-shard socket engine
must answer `np.array_equal` to the in-process engine (exact and ivf),
a WAL-tail replica must converge and degrade cleanly when killed, and
a shard worker killed mid-workload must error loudly and recover on
reopen with the exact `(version, epoch, fingerprint)` triple."""
import socket
import struct
import threading
import time
import zlib

import numpy as np
import pytest

from repro.graph.edges import make_labels
from repro.graph.generators import erdos_renyi
from repro.serving import GraphStore, ServingEngine
from repro.serving.batcher import MicroBatcher
from repro.serving.wal import WriteAheadLog
from repro.transport import (CallTimeout, FrameError, RemoteCallError,
                             ReplicaLagError, RpcClient, RpcServer,
                             TransportError, pack_obj, recv_msg,
                             send_msg, unpack_obj)
from repro.transport.replica import ReplicaEngine

N, K = 60, 4


def _mkstore(seed=7, n=N):
    g = erdos_renyi(n, 500, seed=seed, weighted=True)
    Y = make_labels(n, K, 0.4, np.random.default_rng(seed))
    return GraphStore(g, Y, K)


# -- codec -------------------------------------------------------------------

def test_codec_roundtrip_preserves_structure_and_dtypes():
    msg = {"id": 3, "method": "class_stats", "none": None,
           "flags": [True, False], "pi": 3.5, "name": "shard-0",
           "raw": b"\x00\xff", "tup": (1, "two", None),
           "args": [np.arange(6, dtype=np.int32).reshape(2, 3),
                    np.linspace(0, 1, 5, dtype=np.float32),
                    np.array([], dtype=np.int64),
                    np.array(7.5, dtype=np.float64)]}
    out = unpack_obj(pack_obj(msg))
    assert out["id"] == 3 and out["none"] is None
    assert out["flags"] == [True, False] and out["tup"] == (1, "two", None)
    assert out["raw"] == b"\x00\xff"
    for a, b in zip(msg["args"], out["args"]):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert np.array_equal(a, b)


def test_codec_rejects_unencodable_and_corrupt():
    with pytest.raises(TypeError):
        pack_obj({"fn": object()})
    with pytest.raises(TypeError):
        pack_obj({1: "non-str key"})
    good = pack_obj({"a": np.arange(4)})
    with pytest.raises(FrameError):
        unpack_obj(good + b"x")          # trailing bytes
    with pytest.raises(FrameError):
        unpack_obj(b"\x7f")              # unknown tag
    with pytest.raises(FrameError):
        unpack_obj(good[:-3])            # truncated payload


def test_codec_fuzz_never_returns_garbage(rng):
    """Random corruption of a valid payload either decodes to SOME
    value (harmless — the RPC layer still checks ids) or raises
    FrameError; it must never raise anything else or hang."""
    base = pack_obj({"id": 1, "method": "rows",
                     "args": [np.arange(32, dtype=np.int32)],
                     "kwargs": {}})
    for _ in range(200):
        blob = bytearray(base)
        if rng.random() < 0.5:
            blob = blob[:int(rng.integers(0, len(blob)))]
        else:
            off = int(rng.integers(0, len(blob)))
            blob[off] ^= 1 << int(rng.integers(0, 8))
        try:
            unpack_obj(bytes(blob))
        except FrameError:
            pass


# -- socket framing ----------------------------------------------------------

def test_frame_roundtrip_and_torn_stream(rng):
    a, b = socket.socketpair()
    try:
        payload = {"z": np.arange(100, dtype=np.float32)}
        send_msg(a, payload)
        assert np.array_equal(recv_msg(b)["z"], payload["z"])
        # torn mid-message: send a truncated frame then close
        frame_bytes = pack_obj(payload)
        cut = int(rng.integers(1, len(frame_bytes) + 8))
        header = struct.pack("<II", len(frame_bytes),
                             zlib.crc32(frame_bytes))
        a.sendall((header + frame_bytes)[:cut])
        a.close()
        with pytest.raises(FrameError):
            recv_msg(b)
    finally:
        b.close()


def test_frame_bitflip_detected(rng):
    payload = pack_obj([1, 2, 3, "four"])
    for _ in range(32):
        a, b = socket.socketpair()
        try:
            blob = bytearray(struct.pack(
                "<II", len(payload), zlib.crc32(payload)) + payload)
            off = int(rng.integers(8, len(blob)))   # corrupt payload
            blob[off] ^= 1 << int(rng.integers(0, 8))
            a.sendall(bytes(blob))
            a.close()
            with pytest.raises(FrameError):
                recv_msg(b)
        finally:
            b.close()


def test_oversized_frame_rejected_without_allocation():
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack("<II", (1 << 31), 0))   # 2 GiB claim
        with pytest.raises(FrameError, match="MAX_FRAME"):
            recv_msg(b)
    finally:
        a.close()
        b.close()


# -- RPC ---------------------------------------------------------------------

class _Handler:
    """Tiny RPC target for protocol tests (no jax anywhere)."""

    def __init__(self):
        self.count = 0

    def add(self, a, b):
        return a + b

    def rows(self, x):
        return np.asarray(x) * 2

    def bump(self):
        self.count += 1
        return self.count

    def bad_index(self):
        raise IndexError("node ids outside [0, 60)")

    def lagging(self):
        raise ReplicaLagError("replica at 3, pinned 7", have=3, want=7)

    def weird(self):
        raise OSError("handler-side disk error")

    def nap(self, seconds):
        time.sleep(seconds)
        return "woke"


@pytest.fixture
def server():
    srv = RpcServer(_Handler()).start()
    yield srv
    srv.close()


def test_rpc_loopback_arrays_and_typed_errors(server):
    c = RpcClient(server.address, timeout_s=5)
    assert c.call("add", 2, 3) == 5
    out = c.call("rows", np.arange(5, dtype=np.int32), idempotent=True)
    assert np.array_equal(out, np.arange(5) * 2)
    with pytest.raises(IndexError, match="outside"):
        c.call("bad_index")
    with pytest.raises(ReplicaLagError):
        c.call("lagging")
    # unmapped remote exception comes back as RemoteCallError with the
    # original type name — deterministic, so never retried
    with pytest.raises(RemoteCallError, match="OSError"):
        c.call("weird", idempotent=True)
    c.close()


def test_rpc_blocks_private_and_unknown_methods(server):
    c = RpcClient(server.address, timeout_s=5)
    with pytest.raises(RemoteCallError, match="AttributeError"):
        c.call("_Handler__count")
    with pytest.raises(RemoteCallError, match="AttributeError"):
        c.call("no_such_method")
    c.close()


def test_rpc_call_timeout_is_transport_error(server):
    c = RpcClient(server.address, timeout_s=5)
    with pytest.raises(CallTimeout):
        c.call("nap", 3.0, timeout_s=0.2)
    c.close()


def test_rpc_torn_connection_isolated_from_other_clients(server):
    good = RpcClient(server.address, timeout_s=5)
    assert good.call("add", 1, 1) == 2
    # a rogue peer sends garbage then a half frame and vanishes — that
    # connection dies alone; the server keeps serving everyone else
    for junk in (b"not a frame at all", b"\xff" * 7):
        rogue = socket.create_connection(
            ("127.0.0.1", int(server.address.rsplit(":", 1)[1])))
        rogue.sendall(junk)
        rogue.close()
    assert good.call("add", 2, 2) == 4
    good.close()


def test_rpc_duplicate_and_interleaved_idempotent_reads(server):
    """Duplicated reads (the retry story) and two clients interleaving
    out of order must all see consistent answers — ids pair each
    response to its own request."""
    c1 = RpcClient(server.address, timeout_s=5)
    c2 = RpcClient(server.address, timeout_s=5)
    x = np.arange(16, dtype=np.int64)
    for i in range(8):
        a = c1.call("rows", x + i, idempotent=True)
        b = c2.call("rows", x + i, idempotent=True)
        again = c1.call("rows", x + i, idempotent=True)   # duplicate
        assert np.array_equal(a, b) and np.array_equal(a, again)
    c1.close()
    c2.close()


def test_rpc_retry_policy_idempotent_reads_only(server, monkeypatch):
    """One injected transport fault: an idempotent read survives via
    bounded retry on a fresh connection; a mutation surfaces the error
    immediately and is never re-sent."""
    c = RpcClient(server.address, timeout_s=5, retries=2,
                  backoff_s=0.01)
    real = c._call_once
    fails = {"left": 1}

    def flaky(method, args, kwargs, timeout):
        if fails["left"]:
            fails["left"] -= 1
            raise TransportError("injected torn stream")
        return real(method, args, kwargs, timeout)

    monkeypatch.setattr(c, "_call_once", flaky)
    out = c.call("rows", np.arange(3), idempotent=True)
    assert np.array_equal(out, [0, 2, 4])
    fails["left"] = 1
    with pytest.raises(TransportError, match="injected"):
        c.call("bump")
    # the failed mutation never reached the handler — no double-apply
    assert server.handler.count == 0
    c.close()


def test_rpc_dead_server_errors_loudly():
    srv = RpcServer(_Handler()).start()
    addr = srv.address
    srv.close()
    c = RpcClient(addr, timeout_s=2, retries=2, backoff_s=0.01)
    with pytest.raises(TransportError):
        c.call("bump")                   # write: one attempt, loud
    with pytest.raises(TransportError):  # read: bounded retries, then
        c.call("rows", np.arange(2), idempotent=True)   # still loud
    c.close()


def test_rpc_client_reconnects_after_server_restart():
    srv = RpcServer(_Handler()).start()
    host, port = srv.addr
    c = RpcClient(srv.address, timeout_s=5)
    assert c.call("add", 1, 2) == 3
    srv.close()
    c.close()                            # connection died with it
    srv2 = RpcServer(_Handler(), host=host, port=port).start()
    try:
        assert c.call("add", 2, 2) == 4  # same client, fresh socket
        assert c.reconnects == 2
    finally:
        c.close()
        srv2.close()


def test_rpc_server_close_wakes_blocked_accept():
    srv = RpcServer(_Handler())
    t = threading.Thread(target=srv.serve_forever)
    t.start()
    time.sleep(0.1)
    srv.close()                          # must wake accept(), not hang
    t.join(timeout=5)
    assert not t.is_alive()


def test_rpc_shutdown_request_stops_server():
    srv = RpcServer(_Handler())
    t = threading.Thread(target=srv.serve_forever)
    t.start()
    c = RpcClient(srv.address, timeout_s=5)
    assert c.call("add", 1, 1) == 2
    c.shutdown_server()
    c.close()
    t.join(timeout=5)
    assert not t.is_alive()


# -- WAL group commit --------------------------------------------------------

def test_wal_group_commit_batches_fsync_barriers(tmp_path, rng):
    wal = WriteAheadLog(str(tmp_path / "g.wal"), fsync=True,
                        group_commit_bytes=1 << 20)
    wal.open()
    u = rng.integers(0, N, 8).astype(np.int32)
    w = rng.random(8).astype(np.float32)
    for i in range(10):
        wal.append_edges(i + 1, u, u, w)
    assert wal.pending_appends == 10 and wal.fsyncs == 0
    assert wal.sync() == 10              # one barrier covers them all
    assert wal.pending_appends == 0 and wal.fsyncs == 1
    assert wal.appends_per_fsync == 10.0
    assert wal.sync() == 0               # nothing pending: no-op
    wal.close()


def test_wal_group_commit_bytes_threshold_auto_syncs(tmp_path, rng):
    wal = WriteAheadLog(str(tmp_path / "g.wal"), fsync=True,
                        group_commit_bytes=64)
    wal.open()
    u = rng.integers(0, N, 16).astype(np.int32)
    wal.append_edges(1, u, u, np.ones(16, np.float32))   # > 64 bytes
    assert wal.fsyncs == 1 and wal.pending_appends == 0
    wal.close()


def test_wal_group_commit_age_threshold(tmp_path, rng):
    wal = WriteAheadLog(str(tmp_path / "g.wal"), fsync=True,
                        group_commit_ms=20.0,
                        group_commit_bytes=1 << 30)
    wal.open()
    u = rng.integers(0, N, 4).astype(np.int32)
    wal.append_edges(1, u, u, np.ones(4, np.float32))
    assert wal.sync_if_due() == 0        # too young
    time.sleep(0.03)
    assert wal.sync_if_due() == 1        # aged past the knob
    wal.close()


def test_wal_close_never_orphans_an_open_group(tmp_path, rng):
    from repro.serving.wal import scan_wal
    path = str(tmp_path / "g.wal")
    wal = WriteAheadLog(path, fsync=True, group_commit_bytes=1 << 30)
    wal.open()
    u = rng.integers(0, N, 4).astype(np.int32)
    for i in range(3):
        wal.append_edges(i + 1, u, u, np.ones(4, np.float32))
    wal.close()                          # implicit final barrier
    assert wal.fsyncs == 1 and wal.appends_covered == 3
    records, _ = scan_wal(path)
    assert len(records) == 3


def test_engine_group_commit_defers_tickets_until_barrier(tmp_path):
    eng = ServingEngine(_mkstore(), data_dir=str(tmp_path / "d"),
                        fsync=True, group_commit_bytes=1 << 20,
                        plan_cache=None)
    assert eng.wal.group_commit
    bat = MicroBatcher(eng, topk=5)
    t1 = bat.submit("insert", (np.array([1], np.int32),
                               np.array([2], np.int32),
                               np.ones(1, np.float32)))
    t2 = bat.submit("insert", (np.array([3], np.int32),
                               np.array([4], np.int32),
                               np.ones(1, np.float32)))
    tr = bat.submit("embed", np.array([0, 1]))
    bat.flush()
    # both writes acknowledged with their APPLY-time versions, covered
    # by ONE fsync barrier (plus the boot snapshot's none)
    assert t1.result() == 1 and t2.result() == 2
    assert (t1.version, t2.version) == (1, 2)
    assert tr.result().shape == (2, K)
    assert eng.wal.pending_appends == 0
    assert eng.wal.fsyncs == 1 and eng.wal.appends_per_fsync == 2.0
    dur = eng.stats()["durability"]
    assert dur["group_commit"] and dur["fsync"]
    assert dur["appends_per_fsync"] == 2.0
    assert dur["pending_appends"] == 0
    assert dur["fsync_seconds"] >= 0.0
    eng.close()


# -- replica engine (in-process: bootstrap, tail, version pinning) -----------

def test_replica_bootstraps_bit_equal_and_tails_the_wal(tmp_path, rng):
    d = str(tmp_path / "dep")
    eng = ServingEngine(_mkstore(), num_shards=2, data_dir=d,
                        plan_cache=None)
    rep = ReplicaEngine(d, start_tail=False, plan_cache=None)
    try:
        assert rep.status()["fingerprint"] == eng.fingerprint()
        nodes = rng.integers(0, N, 16).astype(np.int32)
        assert np.array_equal(rep.embed(nodes),
                              np.asarray(eng.query_embed(nodes)))
        # owner advances: the pinned read must refuse, not lie
        eng.apply_edge_delta(np.array([0], np.int32),
                             np.array([1], np.int32),
                             np.ones(1, np.float32))
        with pytest.raises(ReplicaLagError):
            rep.embed(nodes, min_version=eng.version)
        rep.poll()                       # tail the fresh WAL records
        assert rep.engine.version == eng.version
        assert np.array_equal(rep.embed(nodes, min_version=eng.version),
                              np.asarray(eng.query_embed(nodes)))
        ei, ev = eng.query_topk(nodes, k=5)
        ri, rv = rep.topk(nodes, k=5, min_version=eng.version)
        assert np.array_equal(ei, ri) and np.array_equal(ev, rv)
    finally:
        rep.close()
        eng.close()


def test_replica_ivf_read_before_index_record_is_lag(tmp_path, rng):
    d = str(tmp_path / "dep")
    eng = ServingEngine(_mkstore(), data_dir=d, plan_cache=None)
    rep = ReplicaEngine(d, start_tail=False, plan_cache=None)
    try:
        nodes = rng.integers(0, N, 8).astype(np.int32)
        with pytest.raises(ReplicaLagError):   # no quantizer yet
            rep.topk(nodes, k=5, mode="ivf")
    finally:
        rep.close()
        eng.close()


def test_replica_reloads_on_checkpoint_generation_flip(tmp_path, rng):
    d = str(tmp_path / "dep")
    eng = ServingEngine(_mkstore(), data_dir=d, plan_cache=None)
    rep = ReplicaEngine(d, start_tail=False, plan_cache=None)
    try:
        reloads0 = rep.status()["reloads"]   # the bootstrap load
        eng.apply_edge_delta(np.array([2], np.int32),
                             np.array([3], np.int32),
                             np.ones(1, np.float32))
        eng.checkpoint()                 # new generation, rotated WAL
        rep.poll()
        st = rep.status()
        assert st["generation"] == eng.generation
        assert st["fingerprint"] == eng.fingerprint()
        assert st["reloads"] == reloads0 + 1
    finally:
        rep.close()
        eng.close()


# -- multi-process deployments (spawn real workers) --------------------------

@pytest.mark.slow
def test_socket_engine_answers_equal_inprocess(tmp_path, rng):
    store_a, store_b = _mkstore(seed=11), _mkstore(seed=11)
    local = ServingEngine(store_a, num_shards=2, index="ivf",
                          plan_cache=None)
    sock = ServingEngine(store_b, num_shards=2, index="ivf",
                         transport="socket", plan_cache=None)
    try:
        assert all(s.proc is not None and s.proc.alive()
                   for s in sock.shards)
        nodes = rng.integers(0, N, 32).astype(np.int32)
        assert np.array_equal(np.asarray(local.query_embed(nodes)),
                              np.asarray(sock.query_embed(nodes)))
        for mode, nprobe in (("exact", None), ("ivf", 2)):
            li, lv = local.query_topk(nodes, k=5, mode=mode,
                                      nprobe=nprobe)
            si, sv = sock.query_topk(nodes, k=5, mode=mode,
                                     nprobe=nprobe)
            assert np.array_equal(li, si) and np.array_equal(lv, sv)
        # writes fan out over RPC and stay bit-equal
        b = 16
        du = rng.integers(0, N, b).astype(np.int32)
        dv = rng.integers(0, N, b).astype(np.int32)
        dw = rng.random(b).astype(np.float32) + 0.5
        local.apply_edge_delta(du, dv, dw)
        sock.apply_edge_delta(du, dv, dw)
        local.apply_label_delta(np.array([5, 6]), np.array([1, 2]))
        sock.apply_label_delta(np.array([5, 6]), np.array([1, 2]))
        assert sock.fingerprint() == local.fingerprint()
        assert (sock.version, sock.epoch) == (local.version, local.epoch)
        assert np.array_equal(np.asarray(local.Z), np.asarray(sock.Z))
    finally:
        procs = [s.proc for s in sock.shards]
        sock.close()
        local.close()
        assert all(p is None or not p.alive() for p in procs
                   if p is not None)


@pytest.mark.slow
def test_replica_worker_fallback_and_dead_replica_degrades(tmp_path, rng):
    d = str(tmp_path / "dep")
    eng = ServingEngine(_mkstore(), data_dir=d, replicas=1,
                        plan_cache=None)
    try:
        nodes = rng.integers(0, N, 16).astype(np.int32)
        # served (by replica or owner fallback) and always correct
        assert np.array_equal(np.asarray(eng.query_embed(nodes)),
                              np.asarray(eng.Z)[nodes])
        eng.apply_edge_delta(np.array([0], np.int32),
                             np.array([1], np.int32),
                             np.ones(1, np.float32))
        # immediately after a write the replica may lag — the read must
        # still answer from the CURRENT version via owner fallback
        assert np.array_equal(np.asarray(eng.query_embed(nodes)),
                              np.asarray(eng.Z)[nodes])
        deadline = time.time() + 30
        while time.time() < deadline:
            rows = eng.health()["replicas"]
            if rows and rows[0].get("lag") == 0:
                break
            time.sleep(0.1)
        else:
            pytest.fail(f"replica never converged: {eng.health()}")
        # kill the replica worker: reads fall back, health degrades
        eng._replica_procs[0].kill()
        assert np.array_equal(np.asarray(eng.query_embed(nodes)),
                              np.asarray(eng.Z)[nodes])
        h = eng.health()
        assert h["state"] == "degraded"
        assert "unreachable" in h["reason"]
    finally:
        eng.close()


@pytest.mark.slow
def test_kill_shard_worker_mid_batch_then_reopen_exact(
        tmp_path, rng, assert_topk_equivalent):
    """Kill a shard worker mid-workload: the write in flight errors
    loudly, but append-before-apply means it was already WAL-durable —
    reopening with fresh workers recovers the ORACLE state (every
    batch, including the torn one) with an exact triple."""
    b = 12
    batches = [(rng.integers(0, N, b).astype(np.int32),
                rng.integers(0, N, b).astype(np.int32),
                rng.random(b).astype(np.float32) + 0.5)
               for _ in range(4)]
    # in-process oracle: the same store, every batch applied cleanly
    # (durable too — the gen-0 snapshot boot advances the fingerprint,
    # so only a durable twin chains identically)
    oracle = ServingEngine(_mkstore(seed=13), num_shards=2,
                           data_dir=str(tmp_path / "oracle"),
                           plan_cache=None)
    d = str(tmp_path / "dep")
    eng = ServingEngine(_mkstore(seed=13), num_shards=2, data_dir=d,
                        transport="socket", plan_cache=None)
    try:
        for batch in batches[:3]:
            eng.apply_edge_delta(*batch)
            oracle.apply_edge_delta(*batch)
        # murder shard worker 0: the next write must error loudly (a
        # dead owner never silently drops or re-applies a mutation)
        eng.shards[0].proc.kill()
        with pytest.raises(TransportError):
            eng.apply_edge_delta(*batches[3])
    finally:
        eng.close()                      # tolerates the dead worker
    oracle.apply_edge_delta(*batches[3])
    # reopen with FRESH workers: the torn batch was appended to the
    # WAL before the fan-out died, so it IS part of the durable state
    rec = ServingEngine.open(d, transport="socket", plan_cache=None)
    try:
        assert (rec.version, rec.epoch, rec.fingerprint()) == \
            (oracle.version, oracle.epoch, oracle.fingerprint())
        nodes = rng.integers(0, N, 16).astype(np.int32)
        oi, ov = oracle.query_topk(nodes, k=5)
        ri, rv = rec.query_topk(nodes, k=5)
        # scores to float tolerance only: the oracle's Z is delta-
        # folded, the recovered one rebuilt from the replayed store
        assert_topk_equivalent(oi, ov, ri, rv, atol=1e-4)
    finally:
        rec.close()
        oracle.close()
