"""Serving equivalence: prefill+decode must reproduce teacher-forced
logits for every architecture family (the decode caches, ring buffers,
SSM/xLSTM states and cross-attention caches all get exercised)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import model as M
from repro.models.model import _mask_padded_vocab

KEY = jax.random.PRNGKey(1)


def _dropless(cfg):
    if cfg.moe is not None:
        return dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    return cfg


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_then_decode_matches_forward(arch):
    cfg = _dropless(get_config(arch).reduced())
    params = M.init_params(cfg, KEY)
    S = 16
    toks = jax.random.randint(KEY, (2, S + 2), 0, cfg.vocab)
    frames = (jax.random.normal(KEY, (2, cfg.n_frames, cfg.d_model))
              if cfg.is_encdec else None)

    full, _ = M.forward_logits(cfg, params, toks, frames=frames)
    full = np.asarray(_mask_padded_vocab(cfg, full.astype(jnp.float32)))

    batch = {"tokens": toks[:, :S]}
    if frames is not None:
        batch["frames"] = frames
    pl, cache = M.prefill(cfg, params, batch, max_len=S + 8)
    np.testing.assert_allclose(np.asarray(pl), full[:, S - 1], atol=1e-3)

    logits, cache = M.decode_step(cfg, params, toks[:, S], jnp.int32(S),
                                  cache)
    np.testing.assert_allclose(np.asarray(logits), full[:, S], atol=1e-3)

    logits, cache = M.decode_step(cfg, params, toks[:, S + 1],
                                  jnp.int32(S + 1), cache)
    np.testing.assert_allclose(np.asarray(logits), full[:, S + 1],
                               atol=1e-3)


def test_swa_ring_buffer_equals_full_window():
    """SWA decode through the ring buffer == full attention restricted
    to the window (long sequence, cache smaller than history)."""
    cfg = get_config("h2o-danube-3-4b").reduced()   # window = 32
    params = M.init_params(cfg, KEY)
    S = 64                                          # history 2x window
    toks = jax.random.randint(KEY, (1, S + 1), 0, cfg.vocab)
    full, _ = M.forward_logits(cfg, params, toks)
    full = np.asarray(_mask_padded_vocab(cfg, full.astype(jnp.float32)))
    pl, cache = M.prefill(cfg, params, {"tokens": toks[:, :S]},
                          max_len=S + 8)
    assert cache["k"].shape[2] == cfg.swa_window    # ring, not S
    np.testing.assert_allclose(np.asarray(pl), full[:, S - 1], atol=1e-3)
    logits, _ = M.decode_step(cfg, params, toks[:, S], jnp.int32(S), cache)
    np.testing.assert_allclose(np.asarray(logits), full[:, S], atol=1e-3)


def test_decode_cache_donation_shape_stability():
    """Decode must be jit-able with donated cache (serving hot loop)."""
    cfg = get_config("yi-6b").reduced()
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    _, cache = M.prefill(cfg, params, {"tokens": toks}, max_len=16)
    step = jax.jit(lambda p, t, pos, c: M.decode_step(cfg, p, t, pos, c),
                   donate_argnums=(3,))
    tok = toks[:, -1]
    for i in range(3):
        logits, cache = step(params, tok, jnp.int32(8 + i), cache)
        tok = jnp.argmax(logits, -1)
    assert bool(jnp.isfinite(logits).all())
