"""Cross-backend conformance: every registered backend computes the
same Z through the unified `repro.encoder.Embedder` front door — exact
(float-tolerance) for scatter paths, tolerance-bounded with zero drops
for the capacity-bucketed distributed modes — plus the Embedder
contract itself: plan caching, owned projection weights, exact
partial_fit, refinement.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ref_python import gee_numpy
from repro.encoder import (Embedder, EncoderConfig, NotFittedError,
                           get_backend, list_backends, register_backend)
from repro.graph.edges import Graph, make_labels
from repro.graph.generators import erdos_renyi, sbm

ALL_BACKENDS = list_backends()
# small kernel geometry so pallas exercises multi-tile packing; small
# chunks so streaming exercises multi-chunk accumulation
CFG = dict(tile_n=64, edge_block=128, chunk_size=256)


def _oracle(g, Y, K, laplacian=False):
    w = g.w
    if laplacian:
        deg = g.degrees()
        sc = 1.0 / np.sqrt(np.maximum(deg, 1.0))
        w = (w * sc[g.u] * sc[g.v]).astype(np.float32)
    return gee_numpy(g.u, g.v, w, Y, K, g.n)


def _cases():
    """Weighted/directed/self-loop/partially-labeled graph zoo."""
    rng = np.random.default_rng(0)
    cases = {}
    g = erdos_renyi(130, 700, seed=2, weighted=True)     # weighted digraph
    cases["weighted_directed"] = (g, make_labels(130, 5, 0.4, rng))
    loops = Graph(np.arange(40, dtype=np.int32),
                  np.arange(40, dtype=np.int32),
                  rng.random(40, dtype=np.float32) + 0.5, 40)
    mixed = erdos_renyi(40, 160, seed=3, weighted=True)
    g2 = Graph(np.concatenate([mixed.u, loops.u]),
               np.concatenate([mixed.v, loops.v]),
               np.concatenate([mixed.w, loops.w]), 40)   # self-loops
    cases["self_loops"] = (g2, make_labels(40, 4, 0.5, rng))
    g3 = erdos_renyi(90, 400, seed=4, weighted=True)
    Y3 = np.full(90, -1, np.int32)                       # 3 labeled nodes
    Y3[[0, 7, 31]] = [0, 1, 2]
    cases["sparsely_labeled"] = (g3, Y3)
    return cases


class TestConformance:
    @pytest.mark.parametrize("backend", ALL_BACKENDS)
    @pytest.mark.parametrize("case", sorted(_cases()))
    def test_all_backends_match_oracle(self, backend, case):
        g, Y = _cases()[case]
        K = int(Y.max()) + 1 if Y.max() >= 0 else 3
        emb = Embedder(EncoderConfig(K=K, **CFG), backend=backend)
        emb.fit(g, Y)
        atol = 1e-5 if emb.backend.exact else 1e-4
        np.testing.assert_allclose(emb.transform(), _oracle(g, Y, K),
                                   atol=atol)
        assert emb.last_info_.get("dropped", 0) == 0

    @pytest.mark.parametrize("backend", ALL_BACKENDS)
    def test_laplacian_conformance(self, backend):
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5, laplacian=True, **CFG),
                       backend=backend)
        emb.fit(g, Y)
        np.testing.assert_allclose(
            emb.transform(), _oracle(g, Y, 5, laplacian=True), atol=1e-4)

    @pytest.mark.parametrize("backend", ALL_BACKENDS)
    def test_empty_graph(self, backend):
        g = Graph(np.zeros(0, np.int32), np.zeros(0, np.int32),
                  np.zeros(0, np.float32), 16)
        Y = make_labels(16, 3, 0.5, np.random.default_rng(1))
        emb = Embedder(EncoderConfig(K=3, **CFG), backend=backend)
        emb.fit(g, Y)
        assert emb.transform().shape == (16, 3)
        assert np.all(emb.transform() == 0)


class TestPartialFit:
    def test_delta_then_delete_roundtrip(self):
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5), backend="xla").fit(g, Y)
        Z0 = emb.transform()
        rng = np.random.default_rng(9)
        d = Graph(rng.integers(0, g.n, 60).astype(np.int32),
                  rng.integers(0, g.n, 60).astype(np.int32),
                  rng.random(60, dtype=np.float32) + 0.5, g.n)
        emb.partial_fit(d)
        # live multiset = g ++ d
        both = Graph(np.concatenate([g.u, d.u]), np.concatenate([g.v, d.v]),
                     np.concatenate([g.w, d.w]), g.n)
        np.testing.assert_allclose(emb.transform(), _oracle(both, Y, 5),
                                   atol=1e-4)
        emb.partial_fit(d, sign=-1.0)
        np.testing.assert_allclose(emb.transform(), Z0, atol=1e-4)

    def test_empty_delta_is_noop(self):
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5), backend="xla").fit(g, Y)
        Z0 = emb.transform()
        emb.partial_fit(Graph(np.zeros(0, np.int32), np.zeros(0, np.int32),
                              np.zeros(0, np.float32), g.n))
        np.testing.assert_array_equal(emb.transform(), Z0)

    def test_owned_weights_ignore_caller_label_drift(self):
        """The old `gee_apply_delta(Wv=...)` footgun: deltas must use the
        weights Z was BUILT with, even if the caller's labels moved."""
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5), backend="xla").fit(g, Y)
        Y_drifted = Y.copy()
        Y_drifted[:20] = (Y_drifted[:20] + 1) % 5      # caller-side churn
        d = Graph(np.array([1, 2], np.int32), np.array([3, 4], np.int32),
                  np.ones(2, np.float32), g.n)
        emb.partial_fit(d)                  # uses owned (labels_, Wv_)
        both = Graph(np.concatenate([g.u, d.u]), np.concatenate([g.v, d.v]),
                     np.concatenate([g.w, d.w]), g.n)
        np.testing.assert_allclose(emb.transform(), _oracle(both, Y, 5),
                                   atol=1e-4)

    def test_laplacian_partial_fit_rejected(self):
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5, laplacian=True),
                       backend="xla").fit(g, Y)
        with pytest.raises(ValueError, match="laplacian"):
            emb.partial_fit(Graph(np.array([0], np.int32),
                                  np.array([1], np.int32),
                                  np.ones(1, np.float32), g.n))

    def test_refit_after_partial_fit_rejected(self):
        """refit re-embeds the plan's ORIGINAL multiset; after deltas
        that would silently discard them — it must refuse."""
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5), backend="xla").fit(g, Y)
        emb.partial_fit(Graph(np.array([0], np.int32),
                              np.array([1], np.int32),
                              np.ones(1, np.float32), g.n))
        with pytest.raises(RuntimeError, match="discard"):
            emb.refit(Y)
        with pytest.raises(RuntimeError, match="discard"):
            emb.refine()
        # a fresh fit on the live graph clears the guard
        live = Graph(np.concatenate([g.u, [0]]).astype(np.int32),
                     np.concatenate([g.v, [1]]).astype(np.int32),
                     np.concatenate([g.w, [1.0]]).astype(np.float32), g.n)
        emb.fit(live, Y)
        emb.refit(Y)                       # allowed again
        np.testing.assert_allclose(emb.transform(), _oracle(live, Y, 5),
                                   atol=1e-5)

    def test_wrong_n_rejected(self):
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5), backend="xla").fit(g, Y)
        with pytest.raises(ValueError, match="n="):
            emb.partial_fit(Graph(np.array([0], np.int32),
                                  np.array([1], np.int32),
                                  np.ones(1, np.float32), g.n + 5))


class TestPlanCache:
    @pytest.mark.parametrize("backend",
                             ["xla", "pallas", "distributed:ring"])
    def test_same_arrays_hit_cache(self, backend):
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5, **CFG), backend=backend)
        emb.fit(g, Y)
        emb.fit(g, Y)
        emb.refit(Y)
        assert emb.plan_stats == {"built": 1, "hits": 2,
                                  "disk_hits": 0, "disk_stores": 0}

    def test_new_arrays_rebuild_plan(self):
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5), backend="xla").fit(g, Y)
        g2 = Graph(g.u.copy(), g.v.copy(), g.w.copy(), g.n)
        emb.fit(g2, Y)                    # same content, new arrays
        assert emb.plan_stats["built"] == 2

    def test_plan_swap_invalidates_fitted_state(self):
        """plan() on a different graph must not leave refit/transform
        serving the old fit against the new plan."""
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5), backend="xla").fit(g, Y)
        g2 = Graph(g.u.copy(), g.v.copy(), g.w.copy(), g.n)
        emb.plan(g2)
        with pytest.raises(NotFittedError):
            emb.refit(Y)
        with pytest.raises(NotFittedError):
            emb.transform()
        emb.fit(g2, Y)                     # fitting again recovers
        np.testing.assert_allclose(emb.transform(), _oracle(g, Y, 5),
                                   atol=1e-5)

    def test_refit_with_new_labels_skips_packing(self):
        """The load-bearing property: label churn (refinement rounds,
        serving epochs) must not re-run host-side packing."""
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5, **CFG), backend="pallas")
        emb.fit(g, Y)
        Y2 = make_labels(g.n, 5, 0.7, np.random.default_rng(42))
        emb.refit(Y2)
        assert emb.plan_stats == {"built": 1, "hits": 1,
                                  "disk_hits": 0, "disk_stores": 0}
        np.testing.assert_allclose(emb.transform(), _oracle(g, Y2, 5),
                                   atol=1e-5)


class TestPersistentPlanCache:
    """Tier 2 (content-addressed, on-disk) of the plan cache; the
    cross-PROCESS acceptance tests live in tests/test_plan_cache.py —
    here we prove in-process that disk-loaded plans compute the same Z
    for every persistable backend."""

    @pytest.mark.parametrize("backend", ALL_BACKENDS)
    @pytest.mark.parametrize("laplacian", [False, True])
    def test_z_agreement_from_disk_plans(self, backend, laplacian,
                                         tmp_path):
        g, Y = _cases()["weighted_directed"]
        cfg = EncoderConfig(K=5, laplacian=laplacian, **CFG)
        warm = Embedder(cfg, backend=backend, plan_cache=tmp_path)
        warm.fit(g, Y)
        assert warm.plan_stats["disk_stores"] == 1
        # a fresh Embedder has an empty identity tier: the plan can only
        # come from disk
        cold = Embedder(cfg, backend=backend, plan_cache=tmp_path)
        cold.fit(g, Y)
        assert cold.plan_stats == {"built": 0, "hits": 0,
                                   "disk_hits": 1, "disk_stores": 0}
        np.testing.assert_allclose(
            cold.transform(), _oracle(g, Y, 5, laplacian=laplacian),
            atol=1e-4)
        assert cold.last_info_.get("dropped", 0) == 0

    def test_config_and_content_key_the_entry(self, tmp_path):
        g, Y = _cases()["weighted_directed"]
        Embedder(EncoderConfig(K=5), backend="xla",
                 plan_cache=tmp_path).fit(g, Y)
        # different config (laplacian changes w_eff) must MISS
        other = Embedder(EncoderConfig(K=5, laplacian=True),
                         backend="xla", plan_cache=tmp_path)
        other.fit(g, Y)
        assert other.plan_stats["disk_hits"] == 0
        assert other.plan_stats["built"] == 1
        # different content must MISS
        g2 = Graph(g.u.copy(), g.v.copy(),
                   (g.w + 1.0).astype(np.float32), g.n)
        third = Embedder(EncoderConfig(K=5), backend="xla",
                         plan_cache=tmp_path)
        third.fit(g2, Y)
        assert third.plan_stats["disk_hits"] == 0
        # same content in NEW arrays must HIT (content identity, not
        # array identity — the whole point of tier 2)
        fourth = Embedder(EncoderConfig(K=5), backend="xla",
                          plan_cache=tmp_path)
        fourth.fit(Graph(g.u.copy(), g.v.copy(), g.w.copy(), g.n), Y)
        assert fourth.plan_stats == {"built": 0, "hits": 0,
                                     "disk_hits": 1, "disk_stores": 0}


class TestOwnedRows:
    """The owned-rows accumulate path (`EncoderConfig.row_partition`):
    each partitioned Embedder allocates only its (hi - lo, K) slice,
    and the slices concatenate to the unsharded Z — for full-graph
    input AND for the routed sub-multiset a serving shard receives."""

    OWNED_BACKENDS = ["numpy", "xla", "streaming", "pallas"]

    @pytest.mark.parametrize("backend", OWNED_BACKENDS)
    def test_owned_slices_concat_to_full_z(self, backend):
        from repro.graph.partition import RowPartition
        g, Y = _cases()["weighted_directed"]
        part = RowPartition(g.n, 3)
        ref = _oracle(g, Y, 5)
        routed = dict(part.route_graph(g))
        for lo, hi in part.slices():
            emb = Embedder(EncoderConfig(K=5, chunk_size=64,
                                         row_partition=(lo, hi)),
                           backend=backend)
            emb.fit(g, Y)
            assert emb.Z_.shape == (hi - lo, 5)       # O(n/p), not O(n)
            np.testing.assert_allclose(emb.transform(), ref[lo:hi],
                                       atol=1e-5)
        for i, (lo, hi) in enumerate(part.slices()):
            emb = Embedder(EncoderConfig(K=5, chunk_size=64,
                                         row_partition=(lo, hi)),
                           backend=backend)
            emb.fit(routed[i], Y)          # what a serving shard gets
            np.testing.assert_allclose(emb.transform(), ref[lo:hi],
                                       atol=1e-5)

    def test_owned_laplacian_from_full_graph(self):
        """Laplacian degrees come from the graph as passed — the FULL
        unpadded graph keeps the normalizer exact per slice."""
        g, Y = _cases()["weighted_directed"]
        ref = _oracle(g, Y, 5, laplacian=True)
        emb = Embedder(EncoderConfig(K=5, laplacian=True,
                                     row_partition=(30, 100)),
                       backend="xla").fit(g, Y)
        np.testing.assert_allclose(emb.transform(), ref[30:100],
                                   atol=1e-4)

    def test_owned_partial_fit_roundtrip(self):
        g, Y = _cases()["weighted_directed"]
        rng = np.random.default_rng(17)
        emb = Embedder(EncoderConfig(K=5, row_partition=(40, 90)),
                       backend="xla").fit(g, Y)
        Z0 = emb.transform().copy()
        d = Graph(rng.integers(0, g.n, 50).astype(np.int32),
                  rng.integers(0, g.n, 50).astype(np.int32),
                  rng.random(50, dtype=np.float32) + 0.5, g.n)
        emb.partial_fit(d)
        both = Graph(np.concatenate([g.u, d.u]),
                     np.concatenate([g.v, d.v]),
                     np.concatenate([g.w, d.w]), g.n)
        np.testing.assert_allclose(emb.transform(),
                                   _oracle(both, Y, 5)[40:90], atol=1e-4)
        emb.partial_fit(d, sign=-1.0)
        np.testing.assert_allclose(emb.transform(), Z0, atol=1e-4)
        # a delta with no contribution into [lo, hi) is an exact no-op
        out = Graph(np.array([0, 1], np.int32), np.array([2, 3], np.int32),
                    np.ones(2, np.float32), g.n)
        emb.partial_fit(out)
        np.testing.assert_allclose(emb.transform(), Z0, atol=1e-4)

    def test_global_node_ids_and_bounds(self):
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5, row_partition=(40, 90)),
                       backend="xla").fit(g, Y)
        ref = _oracle(g, Y, 5)
        np.testing.assert_allclose(
            emb.transform(np.array([40, 60, 89])),
            ref[[40, 60, 89]], atol=1e-5)
        with pytest.raises(IndexError, match="owned"):
            emb.transform(np.array([39]))
        with pytest.raises(IndexError, match="owned"):
            emb.predict(np.array([90]))

    def test_unsupported_backends_and_configs_rejected(self):
        g, Y = _cases()["weighted_directed"]
        # only the distributed collective modes lack the owned-rows
        # path; the rejection must name the offender AND the
        # partition-aware alternatives
        emb = Embedder(EncoderConfig(K=5, row_partition=(0, 10),
                                     **CFG), backend="distributed:ring")
        with pytest.raises(ValueError, match="owned-rows") as ei:
            emb.plan(g)
        msg = str(ei.value)
        assert "distributed:ring" in msg
        for name in ("numpy", "xla", "streaming", "pallas"):
            assert name in msg
        with pytest.raises(ValueError, match="row_partition"):
            EncoderConfig(K=5, row_partition=(10, 10))
        with pytest.raises(ValueError, match="row_partition"):
            EncoderConfig(K=5, row_partition=(-1, 10))
        with pytest.raises(ValueError, match="exceeds"):
            Embedder(EncoderConfig(K=5, row_partition=(0, g.n + 1)),
                     backend="xla").plan(g)

    def test_full_embedding_surfaces_guarded(self):
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5, row_partition=(0, 65)),
                       backend="xla").fit(g, Y)
        with pytest.raises(RuntimeError, match="owns only rows"):
            emb.refine()
        with pytest.raises(RuntimeError, match="owns only rows"):
            emb.to_features(16)

    def test_row_partition_keys_the_persistent_cache(self, tmp_path):
        """Resharding must never hit a stale plan: the partition is
        part of the tier-2 key, and same-partition replicas share."""
        g, Y = _cases()["weighted_directed"]
        a = Embedder(EncoderConfig(K=5, row_partition=(0, 65)),
                     backend="xla", plan_cache=tmp_path)
        a.fit(g, Y)
        assert a.plan_stats["disk_stores"] == 1
        b = Embedder(EncoderConfig(K=5, row_partition=(65, 130)),
                     backend="xla", plan_cache=tmp_path)
        b.fit(g, Y)                        # resharded: different key
        assert b.plan_stats["disk_hits"] == 0
        assert b.plan_stats["built"] == 1
        c = Embedder(EncoderConfig(K=5, row_partition=(0, 65)),
                     backend="xla", plan_cache=tmp_path)
        c.fit(g, Y)                        # same partition: shared
        assert c.plan_stats == {"built": 0, "hits": 0,
                                "disk_hits": 1, "disk_stores": 0}
        np.testing.assert_allclose(c.transform(),
                                   _oracle(g, Y, 5)[:65], atol=1e-5)


class TestAutoBackend:
    def test_policy_table_resolution(self):
        from repro.encoder import resolve_auto
        assert resolve_auto(100, 50, device_kind="cpu",
                            device_count=1) == "xla"
        assert resolve_auto(100, 50, device_kind="tpu",
                            device_count=1) == "pallas"
        assert (resolve_auto(100, 50, device_kind="cpu", device_count=8)
                == "distributed:reduce_scatter")
        assert resolve_auto(10, 1 << 40, device_kind="cpu",
                            device_count=1) == "streaming"

    def test_policy_table_is_overridable(self):
        from repro.encoder import AUTO_POLICY, resolve_auto
        AUTO_POLICY.insert(0, ("pin", lambda n, s, k, c: "numpy"))
        try:
            assert resolve_auto(100, 50, device_kind="tpu",
                                device_count=8) == "numpy"
        finally:
            AUTO_POLICY.pop(0)

    def test_auto_fit_resolves_and_matches_oracle(self):
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5, **CFG))    # backend="auto"
        assert emb.backend is None                   # deferred to plan()
        emb.fit(g, Y)
        assert emb.backend.name == "xla"             # 1 CPU, small s
        np.testing.assert_allclose(emb.transform(), _oracle(g, Y, 5),
                                   atol=1e-5)
        emb.refit(Y)                                 # identity tier holds
        assert emb.plan_stats["hits"] == 1

    def test_auto_shares_cache_entries_with_explicit_name(self, tmp_path):
        """auto->xla and backend="xla" must address the SAME persistent
        entry (the resolved name keys the cache, not the spec)."""
        g, Y = _cases()["weighted_directed"]
        cfg = EncoderConfig(K=5, **CFG)
        Embedder(cfg, backend="xla", plan_cache=tmp_path).fit(g, Y)
        auto = Embedder(cfg, plan_cache=tmp_path)
        auto.fit(g, Y)
        assert auto.plan_stats["disk_hits"] == 1

    def test_graph_source_front_door(self):
        """fit/plan accept a GraphSource anywhere a Graph is accepted."""
        from repro.graph.sources import SyntheticSource
        src = SyntheticSource("erdos_renyi", n=130, s=700, seed=2,
                              weighted=True)
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5), backend="xla").fit(src, Y)
        np.testing.assert_allclose(emb.transform(), _oracle(g, Y, 5),
                                   atol=1e-5)
        with pytest.raises(TypeError, match="GraphSource"):
            Embedder(EncoderConfig(K=5), backend="xla").fit(object(), Y)


class TestEmbedderContract:
    def test_not_fitted_errors(self):
        emb = Embedder(EncoderConfig(K=3))
        for call in (lambda: emb.transform(), lambda: emb.predict(),
                     lambda: emb.refit(), lambda: emb.refine()):
            with pytest.raises(NotFittedError):
                call()

    def test_unknown_backend(self):
        with pytest.raises(KeyError, match="registered"):
            Embedder(EncoderConfig(K=3), backend="tpu-v9")

    def test_label_out_of_range_rejected(self):
        g, _ = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=3), backend="xla")
        with pytest.raises(ValueError, match=">= K"):
            emb.fit(g, np.full(g.n, 4, np.int32))

    def test_predict_and_transform_slices(self):
        g, truth = sbm(300, 4, 5000, p_in=0.9, seed=5)
        Y = make_labels(300, 4, 0.2, np.random.default_rng(5),
                        true_labels=truth)
        emb = Embedder(EncoderConfig(K=4), backend="xla").fit(g, Y)
        nodes = np.array([4, 8, 15], np.int32)
        np.testing.assert_array_equal(emb.transform(nodes),
                                      emb.transform()[nodes])
        mask = Y < 0
        acc = (emb.predict()[mask] == truth[mask]).mean()
        assert acc > 0.85, acc

    def test_dtype_config(self):
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5, dtype="bfloat16"),
                       backend="xla").fit(g, Y)
        assert emb.transform().dtype == jnp.bfloat16

    def test_refine_recovers_sbm(self):
        g, truth = sbm(200, 3, 4000, p_in=0.95, seed=8)
        emb = Embedder(EncoderConfig(K=3, refine_iters=8), backend="xla")
        emb.fit(g, np.full(200, -1, np.int32))
        emb.refine(jax.random.PRNGKey(1))
        import itertools
        best = max((emb.labels_ == np.array(p)[truth]).mean()
                   for p in itertools.permutations(range(3)))
        assert best > 0.85, best

    def test_out_of_range_nodes_rejected(self):
        """jnp gather silently clamps; the front door must raise."""
        g, Y = _cases()["weighted_directed"]
        emb = Embedder(EncoderConfig(K=5), backend="xla").fit(g, Y)
        with pytest.raises(IndexError, match="node ids"):
            emb.transform(np.array([g.n]))
        with pytest.raises(IndexError, match="node ids"):
            emb.predict(np.array([-1]))

    def test_refine_twice_rebootstraps(self):
        """refine() must pin only the FIT-time supervised labels — a
        second refine with a new key re-bootstraps the unknowns instead
        of freezing on round one's clustering."""
        g = erdos_renyi(90, 400, seed=4, weighted=True)  # no communities
        emb = Embedder(EncoderConfig(K=4, refine_iters=3), backend="xla")
        emb.fit(g, np.full(90, -1, np.int32))
        L1 = emb.refine(jax.random.PRNGKey(1)).labels_.copy()
        L2 = emb.refine(jax.random.PRNGKey(2)).labels_.copy()
        assert (L1 != L2).any()            # unknowns were re-bootstrapped
        # supervised pins survive repeated refines
        Y = np.full(90, -1, np.int32)
        Y[[0, 5, 9, 14]] = [0, 1, 2, 3]
        emb2 = Embedder(EncoderConfig(K=4, refine_iters=3), backend="xla")
        emb2.fit(g, Y).refine(jax.random.PRNGKey(3))
        emb2.refine(jax.random.PRNGKey(4))
        np.testing.assert_array_equal(emb2.labels_[Y >= 0], Y[Y >= 0])

    def test_register_custom_backend(self):
        """New execution strategies plug in without touching call sites."""
        @register_backend("test:negated")
        class NegatedXla(get_backend("xla").__class__):
            pass
        try:
            g, Y = _cases()["weighted_directed"]
            emb = Embedder(EncoderConfig(K=5), backend="test:negated")
            emb.fit(g, Y)
            np.testing.assert_allclose(emb.transform(), _oracle(g, Y, 5),
                                       atol=1e-5)
        finally:
            from repro.encoder import backends as B
            del B._REGISTRY["test:negated"]


class TestServiceOnEmbedder:
    def test_service_runs_on_partial_fit(self):
        """serving.EmbeddingService delta path == Embedder.partial_fit;
        its delta-vs-rebuild self-check holds through mixed traffic."""
        from repro.serving import EmbeddingService, GraphStore
        rng = np.random.default_rng(3)
        g, truth = sbm(150, 4, 2000, p_in=0.9, seed=3)
        Y = make_labels(150, 4, 0.3, rng, true_labels=truth)
        svc = EmbeddingService(GraphStore(g, Y, 4))
        assert svc.embedder.backend.name == "streaming"
        for _ in range(4):
            b = int(rng.integers(1, 60))
            svc.apply_edge_delta(rng.integers(0, 150, b).astype(np.int32),
                                 rng.integers(0, 150, b).astype(np.int32),
                                 rng.random(b).astype(np.float32))
        live = svc.store.edges()
        np.testing.assert_allclose(np.asarray(svc.Z),
                                   _oracle(live, svc.Y_epoch, 4),
                                   atol=1e-4)
        # quiet store -> rebuilds reuse the same base arrays -> plan hits
        svc.compact()
        svc.refresh()
        assert svc.embedder.plan_stats["hits"] >= 1
