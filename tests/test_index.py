"""IVF-GEE index (`repro.index`): bit-stable tie-breaking in the query
kernels, inverted-list quantization, delta maintenance == rebuild under
a fixed quantizer, engine integration (`query_topk(mode="ivf")` ==
exact at nprobe=K for every shard count, bit-for-bit), recall on a
well-separated SBM, churn-gated re-quantization, and WAL/recovery
determinism of the index quantizer.

The exact-equality assertions here are the point of the tie-breaking
contract in `repro.serving.queries`: candidates order lexicographically
by (-score, ascending global id), so `np.array_equal` — not the
tie-tolerant fixture — is the right comparison whenever both sides
score the SAME Z.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.graph.edges import make_labels
from repro.graph.generators import erdos_renyi, sbm
from repro.index import DEFAULT_NPROBE, IVFIndex
from repro.serving import GraphStore, MicroBatcher, ServingEngine
from repro.serving import queries as Q
from repro.serving import wal as W

K = 5


def _store(seed=0, n=240, s=2400, k=K, frac=0.4):
    g = erdos_renyi(n, s, seed=seed, weighted=True)
    Y = make_labels(n, k, frac, np.random.default_rng(seed))
    return GraphStore(g, Y, k)


def _normalized(Z):
    return Q.normalize_rows(jnp.asarray(np.asarray(Z, np.float32)))


class TestTieBreaking:
    """Satellite: score ties break by ascending global id everywhere."""

    def test_duplicate_rows_tie_to_ascending_id(self):
        # identical rows -> identical scores; ids must come back sorted
        Zn = _normalized(np.ones((7, K)))
        idx, val = Q.topk_cosine(Zn, np.array([0], np.int32), k=4,
                                 pre_normalized=True)
        assert idx[0].tolist() == [1, 2, 3, 4]
        assert np.allclose(val, 1.0)

    def test_merge_topk_is_part_order_invariant_under_ties(self):
        p1 = (np.array([[5, 3]], np.int32),
              np.array([[1.0, 0.5]], np.float32))
        p2 = (np.array([[2, 9]], np.int32),
              np.array([[1.0, 0.5]], np.float32))
        a = Q.merge_topk([p1[0], p2[0]], [p1[1], p2[1]], k=3)
        b = Q.merge_topk([p2[0], p1[0]], [p2[1], p1[1]], k=3)
        # ties at 1.0 -> ids 2 then 5; tie at 0.5 -> id 3
        assert a[0].tolist() == [[2, 5, 3]]
        assert np.array_equal(a[0], b[0])
        assert np.array_equal(a[1], b[1])

    def test_topk_cosine_ids_matches_contiguous_scan(self, rng):
        # gathering rows by explicit sorted ids must be bitwise equal
        # to scanning them in place
        Z = rng.normal(size=(64, K)).astype(np.float32)
        Zn = _normalized(Z)
        nodes = np.arange(8, dtype=np.int32)
        q = Zn[jnp.asarray(nodes)]
        a = Q.topk_cosine_q(Zn, q, nodes, k=6)
        ids = np.arange(64, dtype=np.int32)
        b = Q.topk_cosine_ids(Zn, ids, q, nodes, k=6)
        assert np.array_equal(a[0], b[0])
        assert np.array_equal(a[1], b[1])

    @pytest.mark.parametrize("p", [1, 2, 4])
    def test_sharded_results_bitwise_stable(self, p, rng):
        # duplicate-heavy Z maximizes ties; results must not depend on
        # the shard count at all (not merely tie-tolerantly)
        eng1 = ServingEngine(_store(seed=9), num_shards=1)
        engp = ServingEngine(_store(seed=9), num_shards=p)
        nodes = rng.integers(0, 240, 32).astype(np.int32)
        a = eng1.query_topk(nodes, k=10)
        b = engp.query_topk(nodes, k=10)
        assert np.array_equal(a[0], b[0])
        assert np.array_equal(a[1], b[1])


class TestEdgeCases:
    """Satellite: k-clamping and empty-cell hardening."""

    def test_k_exceeds_candidates_clamps(self):
        Zn = _normalized(np.eye(3, K))
        idx, val = Q.topk_cosine_q(Zn, Zn[:2], np.array([0, 1], np.int32),
                                   k=5)
        # 3 rows, self excluded -> 2 real candidates per query
        assert (idx[:, 2:] == -1).all()
        assert np.isneginf(val[:, 2:]).all()
        assert (idx[:, :2] >= 0).all()

    def test_index_k_exceeds_probed_rows_clamps(self, rng):
        Z = rng.normal(size=(30, K)).astype(np.float32)
        Zn = _normalized(Z)
        ix = IVFIndex(K=K)
        ix.build(Zn, rng.normal(size=(K, K)).astype(np.float32))
        # probe only each query's single nearest cell with a huge k
        nodes = np.arange(4, dtype=np.int32)
        q = Zn[jnp.asarray(nodes)]
        probe = ix._assign_cells(q)[:, None]
        idx, val, scanned = ix.topk(Zn, q, nodes, probe, k=25)
        assert scanned < 30 * 4
        pad = idx == -1
        assert pad.any()                      # cells hold < 25 rows
        assert np.isneginf(val[pad]).all()
        assert (val[~pad] > -np.inf).all()

    def test_empty_cell_no_nan_and_skipped(self, rng):
        Z = rng.normal(size=(40, K)).astype(np.float32)
        Zn = _normalized(Z)
        cent = rng.normal(size=(K, K)).astype(np.float32)
        # an unlabeled class produces an all-zero centroid: it must
        # normalize to zero (never NaN) and may legitimately win no rows
        cent[2] = 0.0
        ix = IVFIndex(K=K)
        ix.build(Zn, cent)
        assert not np.isnan(np.asarray(ix._cn)).any()
        assert int(ix.cell_sizes().sum()) == 40
        # force-probe ONLY a cell we empty out by hand
        ix._members[2] = np.zeros(0, np.int64)
        nodes = np.arange(3, dtype=np.int32)
        idx, val, scanned = ix.topk(Zn, Zn[jnp.asarray(nodes)], nodes,
                                    np.full((3, 1), 2, np.int32), k=4)
        assert scanned == 0
        assert (idx == -1).all()
        assert np.isneginf(val).all()
        assert not np.isnan(val).any()

    def test_invalid_mode_raises(self):
        eng = ServingEngine(_store())
        with pytest.raises(ValueError, match="mode"):
            eng.query_topk(np.array([0]), mode="lsh")
        with pytest.raises(ValueError, match="index mode"):
            ServingEngine(_store(), index="hnsw")


class TestIVFIndex:
    """The index data structure in isolation."""

    def test_build_partitions_all_rows(self, rng):
        Z = rng.normal(size=(100, K)).astype(np.float32)
        Zn = _normalized(Z)
        ix = IVFIndex(K=K)
        ix.build(Zn, rng.normal(size=(K, K)).astype(np.float32))
        sizes = ix.cell_sizes()
        assert int(sizes.sum()) == 100
        seen = np.concatenate(ix._members)
        assert np.array_equal(np.sort(seen), np.arange(100))
        for m in ix._members:                 # sorted: the tie contract
            assert np.array_equal(m, np.sort(m))

    def test_full_probe_equals_exact_scan_bitwise(self, rng):
        Z = rng.normal(size=(300, K)).astype(np.float32)
        Zn = _normalized(Z)
        ix = IVFIndex(K=K)
        ix.build(Zn, rng.normal(size=(K, K)).astype(np.float32))
        nodes = rng.integers(0, 300, 20).astype(np.int32)
        q = Zn[jnp.asarray(nodes)]
        probe = np.tile(np.arange(K, dtype=np.int32), (20, 1))
        ii, iv, scanned = ix.topk(Zn, q, nodes, probe, k=10)
        ei, ev = Q.topk_cosine_q(Zn, q, nodes, k=10)
        assert np.array_equal(ei, ii)
        assert np.array_equal(ev, iv)

    def test_delta_maintenance_equals_rebuild(self, rng):
        """Property (satellite): update_rows against the FIXED
        build-time centroids == a fresh build under the same centroids
        — memberships and answers both."""
        Z = rng.normal(size=(200, K)).astype(np.float32)
        cent = rng.normal(size=(K, K)).astype(np.float32)
        ix = IVFIndex(K=K)
        ix.build(_normalized(Z), cent)
        for _ in range(3):                   # several delta rounds
            touched = rng.choice(200, size=30, replace=False)
            Z[touched] += rng.normal(size=(30, K)).astype(np.float32)
            Zn = _normalized(Z)
            ix.update_rows(Zn, touched)
        fresh = IVFIndex(K=K)
        fresh.build(Zn, cent)
        assert np.array_equal(ix.assign, fresh.assign)
        for a, b in zip(ix._members, fresh._members):
            assert np.array_equal(a, b)
        nodes = rng.integers(0, 200, 16).astype(np.int32)
        q = Zn[jnp.asarray(nodes)]
        probe = np.tile(np.arange(K, dtype=np.int32), (16, 1))
        a = ix.topk(Zn, q, nodes, probe, k=8)
        b = fresh.topk(Zn, q, nodes, probe, k=8)
        assert np.array_equal(a[0], b[0])
        assert np.array_equal(a[1], b[1])

    def test_update_rows_counts_moves_and_bounds_check(self, rng):
        Z = rng.normal(size=(50, K)).astype(np.float32)
        Zn = _normalized(Z)
        ix = IVFIndex(K=K)
        ix.build(Zn, rng.normal(size=(K, K)).astype(np.float32))
        assert ix.update_rows(Zn, np.arange(10)) == 0   # nothing moved
        assert ix.churn == 0.0
        with pytest.raises(IndexError):
            ix.update_rows(Zn, np.array([50]))
        with pytest.raises(RuntimeError):
            IVFIndex(K=K).update_rows(Zn, np.array([0]))

    def test_row_offset_stamps_global_ids(self, rng):
        Z = rng.normal(size=(40, K)).astype(np.float32)
        Zn = _normalized(Z)
        ix = IVFIndex(K=K, row_offset=1000)
        ix.build(Zn, rng.normal(size=(K, K)).astype(np.float32))
        nodes = np.array([1005, 1007], np.int32)
        probe = np.tile(np.arange(K, dtype=np.int32), (2, 1))
        idx, val, _ = ix.topk(Zn, Zn[jnp.asarray([5, 7])], nodes, probe,
                              k=5)
        real = idx[idx >= 0]
        assert ((real >= 1000) & (real < 1040)).all()
        assert 1005 not in idx[0] and 1007 not in idx[1]  # self-excluded


class TestEngineIVF:
    """query_topk(mode="ivf") through the sharded engine."""

    @pytest.mark.parametrize("p", [1, 2, 4])
    def test_nprobe_K_equals_exact_bitwise(self, p, rng):
        eng = ServingEngine(_store(seed=4), num_shards=p, index="ivf")
        u = rng.integers(0, 240, 300).astype(np.int32)
        v = rng.integers(0, 240, 300).astype(np.int32)
        w = rng.random(300, dtype=np.float32) + 0.5
        eng.apply_edge_delta(u, v, w)        # exercise delta maintenance
        nodes = rng.integers(0, 240, 40).astype(np.int32)
        ei, ev = eng.query_topk(nodes, k=10, mode="exact")
        ii, iv = eng.query_topk(nodes, k=10, mode="ivf", nprobe=K)
        assert np.array_equal(ei, ii)
        assert np.array_equal(ev, iv)

    def test_lazy_enable_on_first_ivf_query(self):
        eng = ServingEngine(_store())
        assert eng.index_mode is None
        eng.query_topk(np.array([0, 1], np.int32), mode="ivf")
        assert eng.index_mode == "ivf"
        assert eng.shards[0].index is not None

    def test_engine_delta_maintenance_equals_rebuild(self, rng):
        """Property (satellite), engine level: after deltas, the
        delta-maintained per-shard indexes answer exactly like a full
        rebuild under the SAME quantizer centroids."""
        eng = ServingEngine(_store(seed=6), num_shards=2, index="ivf")
        cent = eng._index_centroids.copy()
        for _ in range(2):
            u = rng.integers(0, 240, 150).astype(np.int32)
            v = rng.integers(0, 240, 150).astype(np.int32)
            w = rng.random(150, dtype=np.float32) + 0.5
            eng.apply_edge_delta(u, v, w)
        nodes = rng.integers(0, 240, 24).astype(np.int32)
        maintained = eng.query_topk(nodes, k=10, mode="ivf", nprobe=2)
        eng._build_index(cent, record=False)   # force the rebuild path
        rebuilt = eng.query_topk(nodes, k=10, mode="ivf", nprobe=2)
        assert np.array_equal(maintained[0], rebuilt[0])
        assert np.array_equal(maintained[1], rebuilt[1])

    def test_churn_gate_triggers_requantize(self):
        eng = ServingEngine(_store(), index="ivf", index_churn=0.25)
        eng._index_moved = eng.n             # saturate the drift signal
        before = eng.requantizes
        eng.apply_edge_delta(np.array([0], np.int32),
                             np.array([1], np.int32),
                             np.ones(1, np.float32))
        assert eng.requantizes == before + 1
        assert eng._index_moved == 0         # counter reset by rebuild

    def test_label_churn_rebuild_requantizes(self, rng):
        eng = ServingEngine(_store(), index="ivf", rebuild_churn=0.0)
        before = eng.requantizes
        nodes = rng.integers(0, 240, 30).astype(np.int64)
        eng.apply_label_delta(nodes, np.full(30, 2, np.int32))
        assert eng.rebuilds >= 1
        assert eng.requantizes == before + 1   # epoch rebuild re-quantizes

    def test_recall_on_separated_sbm(self, rng):
        """Satellite: recall@10 == 1.0 probing all cells; >= 0.9 at
        nprobe=2 when communities are well separated."""
        n, k = 1200, 4
        g, truth = sbm(n, k, 18_000, p_in=0.95, seed=11)
        Y = make_labels(n, k, 0.5, rng, true_labels=truth)
        eng = ServingEngine(GraphStore(g, Y, k), index="ivf")
        nodes = rng.integers(0, n, 64).astype(np.int32)
        ei, ev = eng.query_topk(nodes, k=10, mode="exact")
        fi, fv = eng.query_topk(nodes, k=10, mode="ivf", nprobe=k)
        assert np.array_equal(ei, fi)        # full probe == exact
        assert np.array_equal(ev, fv)
        ii, _ = eng.query_topk(nodes, k=10, mode="ivf", nprobe=2)
        recall = np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
                          for a, b in zip(ei, ii)])
        assert recall >= 0.9

    def test_nprobe_clamped_to_valid_range(self, rng):
        eng = ServingEngine(_store(), index="ivf")
        nodes = rng.integers(0, 240, 8).astype(np.int32)
        hi = eng.query_topk(nodes, k=5, mode="ivf", nprobe=999)
        ex = eng.query_topk(nodes, k=5, mode="exact")
        assert np.array_equal(hi[0], ex[0])  # clamped to K == full scan
        lo = eng.query_topk(nodes, k=5, mode="ivf", nprobe=0)
        assert lo[0].shape == (8, 5)         # clamped to 1: still valid

    def test_stats_index_section_and_metrics(self, rng):
        from repro import obs
        obs.reset()
        eng = ServingEngine(_store(), num_shards=2, index="ivf")
        nodes = rng.integers(0, 240, 16).astype(np.int32)
        eng.query_topk(nodes, k=10, mode="ivf")
        s = eng.stats()["index"]
        assert s["mode"] == "ivf"
        assert s["nprobe"] == DEFAULT_NPROBE
        assert s["requantizes"] == 0
        assert len(s["cell_sizes"]) == 2
        assert sum(sum(c) for c in s["cell_sizes"]) == eng.n
        snap = obs.snapshot(prefix="repro_index")
        counters = {c.split("{")[0] for c in snap["counters"]}
        assert "repro_index_builds_total" in counters
        assert "repro_index_queries_total" in counters
        assert "repro_index_rows_scanned_total" in counters

    def test_batcher_routes_ivf_mode(self, rng):
        eng = ServingEngine(_store(), index="ivf")
        b = MicroBatcher(eng, topk=10, topk_mode="ivf", topk_nprobe=K)
        nodes = rng.integers(0, 240, 12).astype(np.int32)
        t = b.submit("topk", nodes)
        b.flush()
        idx, val = t.result(timeout=10)
        ei, ev = eng.query_topk(nodes, k=10, mode="exact")
        assert np.array_equal(idx, ei)       # nprobe=K == exact, bitwise
        assert np.array_equal(val, ev)


class TestIndexDurability:
    """WAL INDEX records and recovery determinism."""

    def test_wal_index_record_roundtrip(self, tmp_path):
        path = str(tmp_path / "wal.log")
        cent = np.arange(K * K, dtype=np.float32).reshape(K, K)
        w = W.WriteAheadLog(path)
        w.open()
        w.append_index(7, cent)
        w.close()
        recs = list(W.read_wal(path))
        assert len(recs) == 1
        assert recs[0].kind == W.INDEX
        assert recs[0].version == 7
        assert np.array_equal(
            np.asarray(recs[0].a).reshape(K, K), cent)

    @pytest.mark.slow
    def test_recovery_answers_identically(self, tmp_path, rng):
        """Acceptance: post-crash recovery rebuilds an index that
        answers bit-identically (pre-crash Z made deterministic by a
        refresh — the recovery contract rebuilds Z fresh)."""
        d = str(tmp_path / "dep")
        eng = ServingEngine(_store(seed=13), data_dir=d, num_shards=2,
                            index="ivf", nprobe=2)
        for _ in range(3):
            u = rng.integers(0, 240, 150).astype(np.int32)
            v = rng.integers(0, 240, 150).astype(np.int32)
            w = rng.random(150, dtype=np.float32) + 0.5
            eng.apply_edge_delta(u, v, w)
        eng.refresh()                        # deterministic pre-crash Z
        nodes = rng.integers(0, 240, 32).astype(np.int32)
        pre = eng.query_topk(nodes, k=10, mode="ivf")
        pre_cent = eng._index_centroids.copy()
        # crash: no close(); reopen from disk
        rec = ServingEngine.open(d, num_shards=2)
        assert rec.index_mode == "ivf"
        assert rec.nprobe == 2
        assert np.array_equal(rec._index_centroids, pre_cent)
        post = rec.query_topk(nodes, k=10, mode="ivf")
        assert np.array_equal(pre[0], post[0])
        assert np.array_equal(pre[1], post[1])

    @pytest.mark.slow
    def test_live_requantize_survives_recovery(self, tmp_path, rng):
        """A churn-triggered re-quantization appends an INDEX record;
        replay must restore the re-quantized centroids, not the boot
        ones."""
        d = str(tmp_path / "dep")
        eng = ServingEngine(_store(seed=17), data_dir=d, index="ivf")
        boot_cent = eng._index_centroids.copy()
        eng._index_moved = eng.n             # force the churn gate
        u = rng.integers(0, 240, 100).astype(np.int32)
        v = rng.integers(0, 240, 100).astype(np.int32)
        eng.apply_edge_delta(u, v, np.ones(100, np.float32))
        assert eng.requantizes == 1
        assert not np.array_equal(eng._index_centroids, boot_cent)
        rec = ServingEngine.open(d)
        assert np.array_equal(rec._index_centroids,
                              eng._index_centroids)
        assert rec.requantizes == 0          # counters restart; answers
        nodes = rng.integers(0, 240, 16).astype(np.int32)   # don't
        a = eng.query_topk(nodes, k=10, mode="ivf", nprobe=K)
        b = rec.query_topk(nodes, k=10, mode="ivf", nprobe=K)
        assert np.array_equal(a[0], b[0])    # nprobe=K: exact under
        # both engines' own Z — and both exact scans agree on ids
        # because refresh-free recovery rebuilds the same multiset

    def test_checkpoint_persists_index_meta(self, tmp_path, rng):
        d = str(tmp_path / "dep")
        eng = ServingEngine(_store(seed=19), data_dir=d, index="ivf",
                            nprobe=3, index_churn=0.5)
        eng.checkpoint()
        rec = ServingEngine.open(d)
        assert rec.index_mode == "ivf"
        assert rec.nprobe == 3
        assert rec.index_churn == 0.5
        assert np.array_equal(rec._index_centroids,
                              eng._index_centroids)
