"""Checkpointing + fault tolerance: atomicity, resume, async writer,
crash recovery (subprocess kill), straggler monitor, elastic remesh."""
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as CK
from repro.training.fault_tolerance import (ElasticMeshManager, Heartbeat,
                                            StragglerMonitor,
                                            simulate_failure)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(7), "c": jnp.float32(3.5)}}


class TestAtomicCheckpoint:
    def test_roundtrip(self, tmp_path):
        t = _tree()
        CK.save_checkpoint(str(tmp_path), 5, t)
        restored, step = CK.restore_checkpoint(str(tmp_path), t)
        assert step == 5
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_points_to_newest(self, tmp_path):
        CK.save_checkpoint(str(tmp_path), 1, _tree(1))
        CK.save_checkpoint(str(tmp_path), 7, _tree(7))
        assert CK.latest_step(str(tmp_path)) == 7

    def test_prune_keeps_latest(self, tmp_path):
        for s in (1, 2, 3, 4, 5):
            CK.save_checkpoint(str(tmp_path), s, _tree(s))
        CK.prune_old(str(tmp_path), keep=2)
        assert CK.latest_step(str(tmp_path)) == 5
        restored, _ = CK.restore_checkpoint(str(tmp_path), _tree())
        assert restored is not None

    def test_shape_mismatch_rejected(self, tmp_path):
        CK.save_checkpoint(str(tmp_path), 1, _tree())
        bad = {"a": jnp.zeros((3, 3)),
               "nested": {"b": jnp.arange(7), "c": jnp.float32(0)}}
        with pytest.raises(AssertionError):
            CK.restore_checkpoint(str(tmp_path), bad)

    def test_async_writer(self, tmp_path):
        ck = CK.AsyncCheckpointer(str(tmp_path), keep=2)
        for s in (10, 20, 30):
            ck.save(s, _tree(s))
        ck.close()
        assert CK.latest_step(str(tmp_path)) == 30


@pytest.mark.slow
class TestCrashRecovery:
    def test_kill_mid_training_then_resume(self, tmp_path):
        """SIGKILL a trainer subprocess mid-run; a fresh run must resume
        from the last complete checkpoint, not corrupt state."""
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        base = [sys.executable, "-m", "repro.launch.train",
                "--arch", "yi-6b", "--reduced",
                "--batch", "2", "--seq", "32",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
                "--log-every", "1"]
        args = base + ["--steps", "200"]
        p = subprocess.Popen(args, env=env, stdout=subprocess.PIPE,
                             stderr=subprocess.STDOUT, text=True)
        # wait for a couple of checkpoints then kill hard
        deadline = time.time() + 300
        while time.time() < deadline:
            if CK.latest_step(str(tmp_path)) and \
                    CK.latest_step(str(tmp_path)) >= 10:
                break
            time.sleep(1.0)
            if p.poll() is not None:
                break
        p.kill()
        p.wait()
        ck1 = CK.latest_step(str(tmp_path))
        assert ck1 is not None and ck1 >= 5

        r = subprocess.run(base + ["--steps", str(ck1 + 5)],
                           env=env, capture_output=True, text=True,
                           timeout=300)
        out = r.stdout
        assert r.returncode == 0, out[-2000:]
        assert "resumed from step" in out


class TestStragglerAndElastic:
    def test_straggler_detection(self):
        mon = StragglerMonitor(deadline_factor=2.0)
        for i in range(20):
            mon.record(i, 0.1)
        assert mon.record(20, 0.5)        # 5x median
        assert not mon.record(21, 0.11)
        assert len(mon.straggler_steps) == 1

    def test_heartbeat_liveness(self, tmp_path):
        hb = Heartbeat(str(tmp_path), host_id=3, interval_s=0.0)
        hb.beat(step=7)
        assert Heartbeat.dead_hosts(str(tmp_path), timeout_s=60) == []
        assert Heartbeat.dead_hosts(str(tmp_path), timeout_s=-1) == [3]

    def test_elastic_remesh_rebuilds_step(self):
        built = []

        def build_step(mesh):
            built.append(mesh.shape)
            return lambda x: x + 1

        mgr = ElasticMeshManager(build_step, model_axis_size=1)
        devs = jax.devices()
        mesh, step, gen = mgr.remesh(devs)
        assert step(1) == 2 and gen == 1
        survivors = simulate_failure(devs, kill=0)
        mesh2, step2, gen2 = mgr.remesh(survivors)
        assert gen2 == 2 and len(built) == 2
