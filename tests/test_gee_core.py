"""GEE correctness: paper claim C1 (parallel == serial, bit-exact algo)
plus property-based invariants of the embedding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import gee as G
from repro.core import ref_python as R
from repro.graph.edges import Graph, make_labels
from repro.graph.generators import erdos_renyi, powerlaw, sbm

RNG = np.random.default_rng(0)


def _graph_and_labels(n=200, s=1500, K=6, seed=1, frac=0.3):
    g = erdos_renyi(n, s, seed=seed, weighted=True)
    Y = make_labels(n, K, frac, np.random.default_rng(seed))
    return g, Y


def _jax_gee(g, Y, K, **kw):
    return np.asarray(G.gee(jnp.asarray(g.u), jnp.asarray(g.v),
                            jnp.asarray(g.w), jnp.asarray(Y),
                            K=K, n=g.n, **kw))


class TestAgainstPaperAlgorithm:
    def test_jax_matches_python_loop(self):
        g, Y = _graph_and_labels()
        Zp = R.gee_python(g.u, g.v, g.w, Y, 6, g.n)
        np.testing.assert_allclose(_jax_gee(g, Y, 6), Zp, atol=1e-5)

    def test_numpy_matches_python_loop(self):
        g, Y = _graph_and_labels(seed=3)
        Zp = R.gee_python(g.u, g.v, g.w, Y, 6, g.n)
        np.testing.assert_allclose(R.gee_numpy(g.u, g.v, g.w, Y, 6, g.n),
                                   Zp, atol=1e-5)

    def test_dense_oracle(self):
        g, Y = _graph_and_labels(n=60, s=300, seed=4)
        Zd = np.asarray(G.gee_dense_oracle(
            jnp.asarray(g.u), jnp.asarray(g.v), jnp.asarray(g.w),
            jnp.asarray(Y), 6, g.n))
        Zp = R.gee_python(g.u, g.v, g.w, Y, 6, g.n)
        np.testing.assert_allclose(Zd, Zp, atol=1e-5)

    def test_powerlaw_skew(self):
        g = powerlaw(300, 4000, seed=5)
        Y = make_labels(300, 8, 0.2, np.random.default_rng(5))
        Zp = R.gee_numpy(g.u, g.v, g.w, Y, 8, g.n)
        np.testing.assert_allclose(_jax_gee(g, Y, 8), Zp, atol=1e-5)

    def test_laplacian_variant(self):
        g, Y = _graph_and_labels(seed=6)
        Z = _jax_gee(g, Y, 6, laplacian=True)
        # manual laplacian scaling then plain gee
        deg = g.degrees()
        sc = 1.0 / np.sqrt(np.maximum(deg, 1.0))
        g2 = Graph(g.u, g.v, (g.w * sc[g.u] * sc[g.v]).astype(np.float32),
                   g.n)
        Zp = R.gee_numpy(g2.u, g2.v, g2.w, Y, 6, g2.n)
        np.testing.assert_allclose(Z, Zp, atol=1e-5)


class TestProperties:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_edge_order_invariance(self, seed):
        """Scatter-add is order-independent (the paper's atomics argument
        made deterministic)."""
        g, Y = _graph_and_labels(n=50, s=200, seed=seed % 97)
        Z1 = _jax_gee(g, Y, 6)
        perm = np.random.default_rng(seed).permutation(g.s)
        g2 = Graph(g.u[perm], g.v[perm], g.w[perm], g.n)
        Z2 = _jax_gee(g2, Y, 6)
        np.testing.assert_allclose(Z1, Z2, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), alpha=st.floats(0.1, 10.0))
    def test_linearity_in_weights(self, seed, alpha):
        """Z(alpha*w) == alpha*Z(w) — GEE is linear in edge weights."""
        g, Y = _graph_and_labels(n=50, s=200, seed=seed % 89)
        Z1 = _jax_gee(g, Y, 6)
        g2 = Graph(g.u, g.v, (g.w * alpha).astype(np.float32), g.n)
        Z2 = _jax_gee(g2, Y, 6)
        np.testing.assert_allclose(Z2, alpha * Z1, rtol=1e-4, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_additivity_in_edges(self, seed):
        """Z(E1 ++ E2) == Z(E1) + Z(E2) — single-pass streaming validity
        (what makes sharded accumulation correct)."""
        g, Y = _graph_and_labels(n=50, s=300, seed=seed % 83)
        cut = g.s // 3
        g1 = Graph(g.u[:cut], g.v[:cut], g.w[:cut], g.n)
        g2 = Graph(g.u[cut:], g.v[cut:], g.w[cut:], g.n)
        np.testing.assert_allclose(
            _jax_gee(g, Y, 6), _jax_gee(g1, Y, 6) + _jax_gee(g2, Y, 6),
            atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_unlabeled_contribute_nothing(self, seed):
        """Edges from fully-unlabeled sources leave Z untouched."""
        g = erdos_renyi(40, 150, seed=seed % 79)
        Y = np.full(40, -1, np.int32)      # nobody labeled
        Z = _jax_gee(g, Y, 5)
        assert np.all(Z == 0)

    def test_row_scale_is_class_frequency(self):
        """Each labeled node's W row sums to 1/|class| (paper's W)."""
        Y = np.array([0, 0, 1, -1, 2, 2, 2], np.int32)
        Wv = np.asarray(G.make_w(jnp.asarray(Y), 3))
        np.testing.assert_allclose(
            Wv, [0.5, 0.5, 1.0, 0.0, 1 / 3, 1 / 3, 1 / 3], atol=1e-6)


class TestEmbeddingQuality:
    def test_sbm_communities_recovered_semisupervised(self):
        g, labels = sbm(400, 4, 6000, p_in=0.9, seed=7)
        Y = make_labels(400, 4, 0.15, np.random.default_rng(7),
                        true_labels=labels)
        Z = _jax_gee(g, Y, 4)
        pred = Z.argmax(1)
        mask = Y < 0               # evaluate only on unlabeled nodes
        acc = (pred[mask] == labels[mask]).mean()
        assert acc > 0.9, acc

    def test_refinement_unsupervised(self):
        g, labels = sbm(300, 3, 5000, p_in=0.95, seed=8)
        Y0 = jnp.full((300,), -1, jnp.int32)
        Z, pred = G.gee_refine(jnp.asarray(g.u), jnp.asarray(g.v),
                               jnp.asarray(g.w), Y0,
                               jax.random.PRNGKey(1), K=3, n=300, iters=8)
        pred = np.asarray(pred)
        # purity under best permutation (3! = 6 candidates)
        import itertools
        best = max(
            (pred == np.array(p)[labels]).mean()
            for p in itertools.permutations(range(3)))
        assert best > 0.85, best


class TestStreaming:
    def test_incremental_equals_batch(self):
        """Beyond-paper: dynamic-graph updates are exact (additivity)."""
        import jax.numpy as jnp
        from repro.core.gee import gee_apply_delta, gee_streaming, make_w
        g, Y = _graph_and_labels(n=80, s=400, seed=21)
        Yj = jnp.asarray(Y)
        full = _jax_gee(g, Y, 6)
        cut = g.s // 2
        chunks = [(jnp.asarray(g.u[:cut]), jnp.asarray(g.v[:cut]),
                   jnp.asarray(g.w[:cut])),
                  (jnp.asarray(g.u[cut:]), jnp.asarray(g.v[cut:]),
                   jnp.asarray(g.w[cut:]))]
        Z = gee_streaming(chunks, Yj, K=6, n=g.n)
        np.testing.assert_allclose(np.asarray(Z), full, atol=1e-5)
        # delete the second half again -> equals first-half embedding
        Wv = make_w(Yj, 6)
        Z2 = gee_apply_delta(Z, *chunks[1], Yj, Wv, K=6, sign=-1.0)
        first = _jax_gee(
            Graph(g.u[:cut], g.v[:cut], g.w[:cut], g.n), Y, 6)
        np.testing.assert_allclose(np.asarray(Z2), first, atol=1e-4)
