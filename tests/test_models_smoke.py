"""Per-arch smoke tests: reduced (family-preserving) config, one
forward + one train step on CPU, asserting shapes and no NaNs —
exactly the contract in the brief.  The FULL configs are exercised only
by the dry-run (launch/dryrun.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import model as M
from repro.training.optimizer import AdamW
from repro.training.train_loop import make_train_step

ARCHS = list_archs()
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if cfg.is_encdec:
        b["frames"] = jax.random.normal(KEY, (B, cfg.n_frames, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = M.forward_logits(cfg, params, batch["tokens"],
                                   frames=batch.get("frames"))
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_nothing_nan(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, KEY)
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    batch = _batch(cfg)
    p1, o1, m1 = step(params, opt_state, batch)
    p2, o2, m2 = step(p1, o1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert np.isfinite(float(m2["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_specs_consistent(arch):
    """Full config: spec tree builds, analytic count is positive, and
    abstract params carry the right dtypes (no allocation)."""
    cfg = get_config(arch)
    specs = M.abstract_params(cfg)
    n = M.count_params_analytic(cfg)
    assert n > 1e8      # every assigned arch is >= 0.8B params
    leaves = jax.tree_util.tree_leaves(specs)
    assert all(hasattr(leaf, "shape") for leaf in leaves)
    total = sum(int(np.prod(leaf.shape)) for leaf in leaves)
    assert total == n


def test_gradient_accumulation_equivalence():
    """accum_steps=2 must match the single big batch (same loss path)."""
    cfg = get_config("yi-6b").reduced()
    cfg = dataclasses.replace(cfg, remat=False)
    params = M.init_params(cfg, KEY)
    opt = AdamW(lr=0.0, clip_norm=0.0)     # lr 0: compare grads via metrics
    b = _batch(cfg, B=4, S=16)
    s1 = make_train_step(cfg, opt, accum_steps=1)
    s2 = make_train_step(cfg, opt, accum_steps=2)
    _, _, m1 = s1(params, opt.init(params), b)
    _, _, m2 = s2(params, opt.init(params), b)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    assert abs(float(m1["grad_norm"]) - float(m2["grad_norm"])) < 1e-3


def test_moe_capacity_drops_counted():
    """Tiny capacity must change outputs (drops) but never NaN."""
    cfg = get_config("qwen2-moe-a2.7b").reduced()
    lo = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.1))
    hi = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe,
                                     capacity_factor=float(cfg.moe.num_experts)))
    params = M.init_params(hi, KEY)
    b = _batch(hi, B=2, S=32)
    l_lo, _ = M.forward_train(lo, params, b)
    l_hi, _ = M.forward_train(hi, params, b)
    assert np.isfinite(float(l_lo)) and np.isfinite(float(l_hi))
    assert abs(float(l_lo) - float(l_hi)) > 1e-6
