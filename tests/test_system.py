"""End-to-end behaviour tests for the paper's system."""
import numpy as np


def test_training_loss_decreases():
    """Full production loop (sharded step, optimizer, data pipeline) on a
    reduced dense arch: loss must drop materially on structured data."""
    from repro.launch.train import main
    losses = main(["--arch", "yi-6b", "--reduced", "--steps", "120",
                   "--batch", "8", "--seq", "64", "--lr", "2e-3",
                   "--log-every", "1000"])
    first = float(np.mean(losses[:5]))
    last = float(np.mean(losses[-10:]))
    assert last < first - 0.4, (first, last)


def test_gee_end_to_end_pipeline():
    """Paper workload end-to-end: generate graph -> labels -> embed ->
    classify unlabeled nodes by argmax — the GEE use-case."""
    import jax.numpy as jnp
    from repro.core.gee import gee
    from repro.graph.edges import make_labels
    from repro.graph.generators import sbm

    g, truth = sbm(600, 5, 12000, p_in=0.9, seed=11)
    Y = make_labels(600, 5, 0.1, np.random.default_rng(11),
                    true_labels=truth)
    Z = np.asarray(gee(jnp.asarray(g.u), jnp.asarray(g.v),
                       jnp.asarray(g.w), jnp.asarray(Y), K=5, n=g.n))
    pred = Z.argmax(1)
    mask = Y < 0
    acc = (pred[mask] == truth[mask]).mean()
    assert acc > 0.85, acc


def test_serve_driver_end_to_end():
    from repro.launch.serve import main
    gen = main(["--arch", "yi-6b", "--reduced", "--batch", "2",
                "--prompt-len", "16", "--gen", "4"])
    assert gen.shape == (2, 4)
    assert gen.dtype.kind in "iu"


def test_gee_embedding_init_shapes():
    """The GEE<->LM bridge produces a well-scaled init table."""
    from repro.core.embed_init import gee_embedding_init
    rng = np.random.default_rng(0)
    stream = rng.integers(0, 128, size=20_000).astype(np.int32)
    table = gee_embedding_init(stream, vocab=128, d_model=32, K=8,
                               refine_iters=3)
    assert table.shape == (128, 32)
    assert np.isfinite(table).all()
    # scale comparable to 1/sqrt(d) init
    assert 0.01 < np.abs(table).std() < 1.0
