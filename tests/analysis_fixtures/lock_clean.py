"""lock-discipline clean: every guarded touch locked, annotated, or
carrying a justified waiver."""
import threading


class Counter:
    def __init__(self):
        self._mu = threading.Lock()
        self.count = 0                   # guarded by: _mu
        self.peak = 0                    # guarded by: _mu
        self.limit = 10                  # not guarded: set once

    def bump(self):
        with self._mu:
            self.count += 1
            if self.count > self.peak:
                self.peak = self.count

    # holds: _mu — only called from bump-like locked paths
    def _reset(self):
        self.count = 0
        self.peak = 0

    def snapshot(self):
        with self._mu, open("/dev/null") as f:   # multi-item with
            f.read(0)
            return (self.count, self.peak)

    def racy_hint(self):
        # repro: allow(lock-discipline) — monotone hint read; staleness is acceptable for display
        return self.count

    def unguarded(self):
        return self.limit                # not annotated: no finding
