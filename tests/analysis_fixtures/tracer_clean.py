"""tracer-safety clean: static args, structural tests, shape reads,
pallas partial-bound statics — all legal under trace."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@functools.partial(jax.jit, static_argnames=("K", "laplacian"))
def clean_static(w, K, laplacian, deg=None):
    if laplacian:                        # static arg: fine
        w = w * 2.0
    if deg is None:                      # structural test: fine
        deg = jnp.zeros((K,), jnp.float32)
    n = w.shape[0]                       # shape read: fine
    if n > 4:                            # shape-derived int: fine
        w = w[:4]
    return jnp.where(w > 0, w, deg[:1])


def _kernel(x_ref, o_ref, *, tile_n):
    o_ref[...] = x_ref[...] * tile_n     # ref stores are params: fine


def run_pallas(x, tile_n):
    return pl.pallas_call(
        functools.partial(_kernel, tile_n=tile_n),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)


def eager_numpy(x):
    import numpy as np
    return np.asarray(x)                 # not jitted: fine


@jax.jit
def scan_body_closure(xs):
    def body(carry, x):
        carry = carry + x                # scan-local rebinding: fine
        return carry, carry
    return jax.lax.scan(body, jnp.float32(0.0), xs)
