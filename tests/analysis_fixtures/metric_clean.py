"""metric-name clean: scheme-conforming names, checked f-prefix."""


def emit(obs, component, name):
    obs.counter("repro_serving_requests_total")
    obs.gauge("repro_serving_queue_depth", 3)
    obs.observe("repro_transport_client_seconds", 0.1)
    obs.histogram("repro_wal_fsync_seconds", 0.2)
    with obs.span("serving.rebuild",
                  metric="repro_serving_rebuild_seconds"):
        pass
    obs.gauge(f"repro_{component}_health_state", 1)   # literal prefix
    obs.counter(name)                    # fully dynamic: skipped
