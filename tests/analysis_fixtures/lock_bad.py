"""lock-discipline violations: unlocked touches of guarded state."""
import threading


class Counter:
    def __init__(self):
        self._mu = threading.Lock()
        self.count = 0                   # guarded by: _mu
        self.peak = 0                    # guarded by: _mu

    def bump(self):
        self.count += 1                  # VIOLATION: no lock held

    def read(self):
        return self.count                # VIOLATION: unlocked read

    def deferred(self):
        with self._mu:
            # the closure may run after the lock is released, so the
            # lexical `with` above must NOT cover it
            return lambda: self.peak + 1   # VIOLATION: closure escape

    def reasonless(self):
        # repro: allow(lock-discipline)
        return self.peak                 # VIOLATION: waiver w/o reason
