"""wal-exhaustive violation: pickle on the wire."""
import pickle                                # VIOLATION


def load(blob):
    return pickle.loads(blob)
