"""wal-exhaustive violations: a kind with no replay arm."""

EDGES, LABELS, SNAPSHOT = 1, 2, 3


def _replay(store, rec):
    if rec.kind == EDGES:
        store.apply_edges(rec.a, rec.b)
    elif rec.kind == LABELS:                 # VIOLATION: no SNAPSHOT
        store.apply_labels(rec.a)
