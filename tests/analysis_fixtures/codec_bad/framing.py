"""wal-exhaustive violations: one-directional codec tags."""

_T_INT, _T_STR = b"i", b"s"
_T_BLOB = b"b"


def pack_obj(out, obj):
    if isinstance(obj, int):
        out += _T_INT                        # _T_STR never packed
    else:
        out += _T_BLOB
    return out


def unpack_obj(tag, body):
    if tag == _T_INT:
        return int(body)
    if tag == _T_STR:                        # _T_BLOB never unpacked
        return body.decode()
    raise ValueError(tag)
