"""retry-safety violations: retried mutations and twin drift."""


class Shard:
    def build(self, graph, Y):
        pass

    def rows(self, nodes, *, norm=False):
        return nodes


def retried_mutation(client):
    client.call("apply_delta", idempotent=True)      # VIOLATION


def computed_flag(client, flag):
    client.call("ping", idempotent=flag)             # VIOLATION


def dynamic_method(client, name):
    client.call(name, idempotent=True)               # VIOLATION


# repro: twin-of Shard; extra: address
class BadProxy:
    def build(self, graph, Y, token):                # VIOLATION:
        pass                                         # required extra

    def rows(self, nodes):                           # VIOLATION:
        return nodes                                 # drops norm=

    def stats(self):                                 # VIOLATION:
        return {}                                    # no counterpart
