"""retry-safety clean: allowlisted retries, compatible twin."""


class Engine:
    def embed(self, nodes, min_version=0):
        return nodes

    def status(self):
        return {}


def reads(client):
    client.call("ping", idempotent=True)
    client.call("status", idempotent=True)
    client.call("apply_delta")           # mutation, not retried: fine
    client.call("build", idempotent=False)


# repro: twin-of Engine; extra: ping, address
class GoodProxy:
    def embed(self, nodes, *, min_version=0, timeout_s=None):
        return nodes                     # optional extra kwarg: fine

    def status(self):
        return {}

    def ping(self):                      # declared extra
        return {}

    def _call(self, method):             # private: not checked
        return method
