"""metric-name violations: off-scheme literals and f-strings."""


def emit(obs, who):
    obs.counter("serving_requests")              # VIOLATION: no repro_
    obs.gauge("repro_Serving_Depth", 3)          # VIOLATION: case
    obs.observe("repro_latency", 0.1)            # VIOLATION: 2 segs
    with obs.span("serving", metric="lat"):      # 2 VIOLATIONS
        pass
    obs.counter(f"{who}_requests_total")         # VIOLATION: f-string
