"""wal-exhaustive clean: a second replayer, qualified-name arms."""
from . import wal as W


def _apply_live(engine, rec):
    if rec.kind == W.EDGES:
        engine.apply_edge_delta(rec.a, rec.b)
    elif rec.kind == W.LABELS:
        engine.apply_label_delta(rec.a)
    elif rec.kind == W.SNAPSHOT:
        engine.compact()
