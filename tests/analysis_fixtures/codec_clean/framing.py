"""wal-exhaustive clean: every tag packed and unpacked."""

_T_INT, _T_STR = b"i", b"s"


def pack_obj(out, obj):
    if isinstance(obj, int):
        out += _T_INT
    else:
        out += _T_STR
    return out


def unpack_obj(tag, body):
    if tag == _T_INT:
        return int(body)
    if tag == _T_STR:
        return body.decode()
    raise ValueError(tag)
