"""wal-exhaustive clean: every kind has a replay arm."""

EDGES, LABELS, SNAPSHOT = 1, 2, 3
_MARKERS = (SNAPSHOT,)


def _replay(store, rec):
    if rec.kind == EDGES:
        store.apply_edges(rec.a, rec.b)
    elif rec.kind == LABELS:
        store.apply_labels(rec.a)
    elif rec.kind == SNAPSHOT:
        store.compact()
    else:
        raise ValueError(rec.kind)
