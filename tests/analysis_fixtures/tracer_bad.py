"""tracer-safety violations: numpy, control flow, and closed-over
mutation inside traced functions."""
import functools

import jax
import numpy as np

_cache = {}


@jax.jit
def bad_numpy(x, y):
    return np.dot(x, y)                  # VIOLATION: numpy on tracers


@jax.jit
def bad_branch(x, thresh):
    if thresh > 0:                       # VIOLATION: if on tracer
        return x * 2
    return x


@functools.partial(jax.jit, static_argnames=("k",))
def bad_loop(x, k, limit):
    acc = x
    while acc.sum() < limit:             # VIOLATION: while on derived
        acc = acc * 2
    return acc


@jax.jit
def bad_closure(x):
    _cache["last"] = x                   # VIOLATION: closed-over store
    return x


def make_counter():
    n = 0

    @jax.jit
    def bad_nonlocal(x):
        nonlocal n                       # VIOLATION: nonlocal write
        n += 1
        return x

    return bad_nonlocal
