"""Owned-rows pallas scatter + fused query kernels (ISSUE 10).

Conformance contracts under test:

* partitioned ``backend="pallas"`` vs the `ref_python.gee_numpy_owned`
  oracle across random RowPartitions x K x tile geometries, including
  tail tiles and empty partition slices — and bit-identical across
  runs;
* the fused normalize+cosine+top-k kernel is ``np.array_equal`` (NOT
  tie-tolerant) to the jitted blocked scan for every tested shard
  count, per-slice and after the cross-shard merge;
* the fused delta-apply+renormalize kernel matches partial_fit +
  normalize_rows;
* ``interpret="auto"`` resolution is recorded in plan metadata and the
  embed info dict.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ref_python import gee_numpy_owned
from repro.encoder import Embedder, EncoderConfig
from repro.encoder.plan import effective_weights, owned_contributions
from repro.graph.edges import Graph, make_labels
from repro.graph.generators import erdos_renyi, powerlaw
from repro.graph.partition import RowPartition
from repro.kernels.gee_scatter import resolve_interpret
from repro.serving import queries as Q
from repro.serving.engine import GraphStore, ServingEngine


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _graph_labels(n=220, s=1800, K=5, seed=3):
    g = erdos_renyi(n, s, seed=seed, weighted=True)
    Y = make_labels(n, K, 0.3, np.random.default_rng(seed))
    return g, Y


def _owned_oracle(g, Y, K, lo, hi):
    from repro.core.gee import make_w
    w_eff = effective_weights(g, EncoderConfig(K=K))
    rows, src, w = owned_contributions(g, w_eff, lo, hi)
    Wv = np.asarray(make_w(jnp.asarray(Y), K))
    return gee_numpy_owned(rows, src, w, np.asarray(Y), Wv, K, hi - lo)


class TestOwnedRowsPallas:
    """Partitioned pallas plans pack owned contributions over local
    rows [0, hi - lo) and accumulate O(n/p), not O(n)."""

    @pytest.mark.parametrize("K", [3, 8])
    @pytest.mark.parametrize("tile_n,edge_block", [(64, 128), (32, 64)])
    @pytest.mark.parametrize("parts", [2, 3])
    def test_matches_owned_oracle_across_partitions(self, K, tile_n,
                                                    edge_block, parts):
        g, Y = _graph_labels(K=K, seed=K + parts)
        for lo, hi in RowPartition(g.n, parts).slices():
            emb = Embedder(EncoderConfig(K=K, tile_n=tile_n,
                                         edge_block=edge_block,
                                         row_partition=(lo, hi)),
                           backend="pallas", plan_cache=None).fit(g, Y)
            assert emb.Z_.shape == (hi - lo, K)       # O(n/p), not O(n)
            np.testing.assert_allclose(
                np.asarray(emb.Z_), _owned_oracle(g, Y, K, lo, hi),
                atol=1e-5)

    def test_tail_tile_partition(self):
        """n_local deliberately NOT a tile multiple: the kernel's tail
        tile must accumulate exactly and slice back to (hi - lo, K)."""
        g, Y = _graph_labels(n=200, s=1500)
        lo, hi = 37, 150                               # n_local = 113
        emb = Embedder(EncoderConfig(K=5, tile_n=64, edge_block=128,
                                     row_partition=(lo, hi)),
                       backend="pallas", plan_cache=None).fit(g, Y)
        assert emb.Z_.shape == (113, 5)
        np.testing.assert_allclose(np.asarray(emb.Z_),
                                   _owned_oracle(g, Y, 5, lo, hi),
                                   atol=1e-5)

    def test_empty_partition_slice(self):
        """A slice no edge lands in packs an empty contribution set and
        embeds to zeros (not an error, not garbage)."""
        rng = np.random.default_rng(5)
        u = rng.integers(0, 10, 80).astype(np.int32)
        v = rng.integers(0, 10, 80).astype(np.int32)
        g = Graph(u, v, np.ones(80, np.float32), 100)
        Y = make_labels(100, 4, 0.5, rng)
        emb = Embedder(EncoderConfig(K=4, tile_n=32, edge_block=64,
                                     row_partition=(50, 60)),
                       backend="pallas", plan_cache=None).fit(g, Y)
        assert emb.Z_.shape == (10, 4)
        assert np.all(np.asarray(emb.Z_) == 0)

    def test_skewed_destinations_partitioned(self):
        """Power-law graphs stress per-tile bucket padding inside a
        partition slice too."""
        g = powerlaw(300, 5000, seed=9)
        Y = make_labels(300, 8, 0.25, np.random.default_rng(9))
        emb = Embedder(EncoderConfig(K=8, tile_n=64, edge_block=128,
                                     row_partition=(0, 120)),
                       backend="pallas", plan_cache=None).fit(g, Y)
        np.testing.assert_allclose(np.asarray(emb.Z_),
                                   _owned_oracle(g, Y, 8, 0, 120),
                                   atol=1e-5)

    def test_bit_identical_across_runs(self):
        g, Y = _graph_labels()
        cfg = EncoderConfig(K=5, tile_n=64, edge_block=128,
                            row_partition=(40, 173))
        Z1 = Embedder(cfg, backend="pallas", plan_cache=None).fit(g, Y).Z_
        Z2 = Embedder(cfg, backend="pallas", plan_cache=None).fit(g, Y).Z_
        assert np.array_equal(np.asarray(Z1), np.asarray(Z2))

    def test_packed_blocks_are_the_tier2_artifact(self, tmp_path):
        """A second partitioned pallas Embedder hits the persisted
        packed blocks; a different partition misses (keyed on it)."""
        g, Y = _graph_labels()
        cfg = EncoderConfig(K=5, tile_n=64, edge_block=128,
                            row_partition=(0, 110))
        a = Embedder(cfg, backend="pallas", plan_cache=tmp_path)
        a.fit(g, Y)
        assert a.plan_stats["disk_stores"] == 1
        b = Embedder(cfg, backend="pallas", plan_cache=tmp_path)
        b.fit(Graph(g.u.copy(), g.v.copy(), g.w.copy(), g.n), Y)
        assert b.plan_stats == {"built": 0, "hits": 0,
                                "disk_hits": 1, "disk_stores": 0}
        assert np.array_equal(np.asarray(a.Z_), np.asarray(b.Z_))
        c = Embedder(EncoderConfig(K=5, tile_n=64, edge_block=128,
                                   row_partition=(110, 220)),
                     backend="pallas", plan_cache=tmp_path)
        c.fit(g, Y)
        assert c.plan_stats["disk_hits"] == 0
        assert c.plan_stats["built"] == 1


class TestFusedTopK:
    """The fused kernel must be np.array_equal — not tie-tolerant — to
    the jitted blocked scan, per shard slice and after the merge."""

    K, M, NQ, TOPK = 6, 160, 12, 9

    def _fixture(self, rng, duplicates=True):
        base = rng.normal(size=(self.M // 4, self.K)).astype(np.float32)
        # duplicate-heavy rows maximize score ties: the id-order tie
        # contract is what the equality below actually exercises
        Z = np.repeat(base, 4, axis=0) if duplicates else \
            rng.normal(size=(self.M, self.K)).astype(np.float32)
        Zn = Q.normalize_rows(jnp.asarray(Z))
        qnodes = rng.integers(0, self.M, self.NQ).astype(np.int32)
        q = Zn[jnp.asarray(qnodes)]
        return Z, Zn, q, qnodes

    @pytest.mark.parametrize("p", [1, 2, 4])
    @pytest.mark.parametrize("block_rows", [16, 64, 1 << 14])
    def test_bitwise_equal_per_slice_and_merged(self, p, block_rows,
                                                rng):
        Z, Zn, q, qnodes = self._fixture(rng)
        bounds = np.linspace(0, self.M, p + 1).astype(int)
        ref_parts, fus_parts = [], []
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            ref = Q.topk_cosine_q(Zn[lo:hi], q, qnodes, k=self.TOPK,
                                  block_rows=block_rows, row_offset=lo)
            fus = Q.topk_cosine_fused(Zn[lo:hi], q, qnodes, k=self.TOPK,
                                      block_rows=block_rows,
                                      row_offset=lo)
            assert np.array_equal(ref[0], fus[0])
            assert np.array_equal(ref[1], fus[1])
            ref_parts.append(ref)
            fus_parts.append(fus)
        mr = Q.merge_topk([r[0] for r in ref_parts],
                          [r[1] for r in ref_parts], k=self.TOPK)
        mf = Q.merge_topk([f[0] for f in fus_parts],
                          [f[1] for f in fus_parts], k=self.TOPK)
        assert np.array_equal(mr[0], mf[0])
        assert np.array_equal(mr[1], mf[1])

    def test_norm_mode_matches_separate_passes(self, rng):
        """Fused normalize+scan == normalize_rows -> blocked scan, and
        the emitted Zn is bit-identical to normalize_rows."""
        Z, Zn, q, qnodes = self._fixture(rng)
        ref = Q.topk_cosine_q(Zn, q, qnodes, k=self.TOPK, block_rows=32)
        fi, fv, Zn2 = Q.topk_cosine_fused_norm(
            jnp.asarray(Z), q, qnodes, k=self.TOPK, block_rows=32)
        assert np.array_equal(ref[0], fi)
        assert np.array_equal(ref[1], fv)
        assert np.array_equal(np.asarray(Zn), np.asarray(Zn2))

    def test_k_exceeds_candidates_clamps(self, rng):
        Z, Zn, q, qnodes = self._fixture(rng)
        few = Zn[:3]
        ref = Q.topk_cosine_q(few, q, qnodes, k=8, block_rows=16)
        fus = Q.topk_cosine_fused(few, q, qnodes, k=8, block_rows=16)
        assert np.array_equal(ref[0], fus[0])
        assert np.array_equal(ref[1], fus[1])
        assert (fus[0] == -1).any()                  # clamped tail

    def test_exclude_self_off(self, rng):
        Z, Zn, q, qnodes = self._fixture(rng)
        ref = Q.topk_cosine_q(Zn, q, qnodes, k=self.TOPK,
                              block_rows=64, exclude_self=False)
        fus = Q.topk_cosine_fused(Zn, q, qnodes, k=self.TOPK,
                                  block_rows=64, exclude_self=False)
        assert np.array_equal(ref[0], fus[0])
        assert np.array_equal(ref[1], fus[1])


class TestFusedDelta:
    """partial_fit_norm: one pass == partial_fit + normalize_rows."""

    def _fitted(self, **cfg_kw):
        g, Y = _graph_labels()
        cfg = EncoderConfig(K=5, tile_n=64, edge_block=128, **cfg_kw)
        return (Embedder(cfg, backend="pallas", plan_cache=None)
                .fit(g, Y), g)

    @pytest.mark.parametrize("rp", [None, (40, 173)])
    def test_matches_partial_fit_then_normalize(self, rp, rng):
        kw = {} if rp is None else {"row_partition": rp}
        e1, g = self._fitted(**kw)
        e2, _ = self._fitted(**kw)
        d = Graph(rng.integers(0, g.n, 40).astype(np.int32),
                  rng.integers(0, g.n, 40).astype(np.int32),
                  rng.random(40, dtype=np.float32) + 0.5, g.n)
        Zn = e1.partial_fit_norm(d)
        e2.partial_fit(d)
        np.testing.assert_allclose(np.asarray(e1.Z_), np.asarray(e2.Z_),
                                   atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(Zn), np.asarray(Q.normalize_rows(e1.Z_)),
            atol=1e-6)

    def test_deterministic_and_sign_roundtrip(self, rng):
        e1, g = self._fitted(row_partition=(40, 173))
        e2, _ = self._fitted(row_partition=(40, 173))
        Z0 = np.asarray(e1.Z_).copy()
        d = Graph(rng.integers(0, g.n, 30).astype(np.int32),
                  rng.integers(0, g.n, 30).astype(np.int32),
                  rng.random(30, dtype=np.float32) + 0.5, g.n)
        Zn1 = e1.partial_fit_norm(d)
        Zn2 = e2.partial_fit_norm(d)
        assert np.array_equal(np.asarray(e1.Z_), np.asarray(e2.Z_))
        assert np.array_equal(np.asarray(Zn1), np.asarray(Zn2))
        e1.partial_fit_norm(d, sign=-1.0)            # exact inverse
        np.testing.assert_allclose(np.asarray(e1.Z_), Z0, atol=1e-4)

    def test_guards_mirror_partial_fit(self, rng):
        g, Y = _graph_labels()
        emb = Embedder(EncoderConfig(K=5, tile_n=64, edge_block=128),
                       backend="pallas", plan_cache=None)
        from repro.encoder.embedder import NotFittedError
        d = Graph(np.array([0], np.int32), np.array([1], np.int32),
                  np.ones(1, np.float32), g.n)
        with pytest.raises(NotFittedError):
            emb.partial_fit_norm(d)
        emb.fit(g, Y)
        emb.partial_fit_norm(d)
        with pytest.raises(RuntimeError, match="partial_fit"):
            emb.refit(Y)                 # deltas pending, like partial_fit


class TestPallasServing:
    """End-to-end: a pallas-backed engine serves through the fused
    kernels.  Cross-BACKEND comparisons are allclose (streaming and
    pallas accumulate Z in different orders); the fused-vs-blocked
    bitwise contract on a FIXED Zn is covered in TestFusedTopK, and
    here the cold (normalize-in-kernel) and warm (cached Zn) fused
    paths must answer bit-identically."""

    def _store(self, seed=4):
        g = erdos_renyi(240, 2400, seed=seed, weighted=True)
        Y = make_labels(240, 6, 0.4, np.random.default_rng(seed))
        return GraphStore(g, Y, 6)

    @pytest.mark.parametrize("p", [1, 2, 4])
    def test_engine_matches_streaming(self, p, rng):
        ref = ServingEngine(self._store(), num_shards=p)
        pal = ServingEngine(self._store(), num_shards=p,
                            backend="pallas")
        np.testing.assert_allclose(np.asarray(pal.Z), np.asarray(ref.Z),
                                   atol=1e-5)
        nodes = rng.integers(0, 240, 32).astype(np.int32)
        a = ref.query_topk(nodes, k=10)
        b = pal.query_topk(nodes, k=10)   # cold: fused normalize+scan
        c = pal.query_topk(nodes, k=10)   # warm: fused scan of cached Zn
        assert np.array_equal(b[0], c[0])
        assert np.array_equal(b[1], c[1])
        np.testing.assert_allclose(b[1], a[1], atol=1e-5)

    @pytest.mark.parametrize("p", [2, 4])
    def test_engine_after_delta(self, p, rng):
        ref = ServingEngine(self._store(), num_shards=p)
        pal = ServingEngine(self._store(), num_shards=p,
                            backend="pallas")
        u = rng.integers(0, 240, 100).astype(np.int32)
        v = rng.integers(0, 240, 100).astype(np.int32)
        w = rng.random(100, dtype=np.float32) + 0.5
        ref.apply_edge_delta(u, v, w)
        pal.apply_edge_delta(u, v, w)    # fused apply+renorm path
        np.testing.assert_allclose(np.asarray(pal.Z), np.asarray(ref.Z),
                                   atol=1e-5)
        nodes = rng.integers(0, 240, 24).astype(np.int32)
        a = ref.query_topk(nodes, k=8)
        b = pal.query_topk(nodes, k=8)
        np.testing.assert_allclose(b[1], a[1], atol=1e-5)
        # determinism of the fused path itself
        c = pal.query_topk(nodes, k=8)
        assert np.array_equal(b[0], c[0])
        assert np.array_equal(b[1], c[1])


class TestInterpretResolution:
    def test_resolve_semantics(self):
        assert resolve_interpret(True) is True
        assert resolve_interpret(False) is False
        expect = jax.default_backend() not in ("tpu", "gpu")
        assert resolve_interpret("auto") is expect
        assert resolve_interpret(None) is expect

    def test_recorded_in_plan_and_info(self):
        g, Y = _graph_labels()
        emb = Embedder(EncoderConfig(K=5, tile_n=64, edge_block=128),
                       backend="pallas", plan_cache=None).fit(g, Y)
        expect = jax.default_backend() not in ("tpu", "gpu")
        assert emb._plan.data["interpret"] is expect
        assert emb.last_info_["interpret"] is expect
        # never persisted: the host half holds only the packed blocks
        assert "interpret" not in emb._plan.host

    def test_config_rejects_junk(self):
        with pytest.raises(ValueError, match="interpret"):
            EncoderConfig(K=3, interpret="yes")
