"""The static-analysis suite (`repro.analysis`) — checker by checker.

Each checker must (a) fire on every planted violation in its
`tests/analysis_fixtures/` bad input, with the exact rule and line,
and (b) stay silent on the clean counterpart.  The waiver machinery,
the JSON/CLI surface, and the repo gate itself (`python -m
repro.analysis src` exits 0) are covered here too.  Everything is
stdlib-only and fast-lane: the suite never imports the code it
analyzes, so none of these tests touch jax.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

from repro.analysis import Module, run_checks
from repro.analysis.checkers import default_checkers
from repro.analysis.lock_discipline import LockDiscipline
from repro.analysis.metric_names import MetricNames
from repro.analysis.retry_safety import RetrySafety
from repro.analysis.tracer_safety import TracerSafety
from repro.analysis.wal_exhaustive import WalExhaustive

FIXTURES = Path(__file__).parent / "analysis_fixtures"
REPO = Path(__file__).parent.parent


def _hits(path, checker):
    report = run_checks([str(path)], checkers=[checker])
    return [(f.rule, f.line) for f in report.findings]


# -- lock discipline -------------------------------------------------------

def test_lock_discipline_fires_on_violations():
    hits = _hits(FIXTURES / "lock_bad.py", LockDiscipline())
    assert ("lock-discipline", 12) in hits    # unlocked write
    assert ("lock-discipline", 15) in hits    # unlocked read
    assert ("lock-discipline", 21) in hits    # closure escapes `with`
    assert ("lock-discipline", 25) in hits    # reasonless waiver: kept
    assert ("waiver", 24) in hits             # ...and flagged itself


def test_lock_discipline_quiet_on_clean():
    report = run_checks([str(FIXTURES / "lock_clean.py")],
                        checkers=[LockDiscipline()])
    assert report.findings == []
    assert report.waived == 1                 # the justified waiver


# -- retry safety / twins --------------------------------------------------

def test_retry_safety_fires_on_violations():
    hits = _hits(FIXTURES / "retry_bad.py", RetrySafety())
    assert ("retry-safety", 13) in hits       # retried mutation
    assert ("retry-safety", 17) in hits       # computed flag
    assert ("retry-safety", 21) in hits       # dynamic method name
    assert ("retry-safety", 26) in hits       # required proxy-only arg
    assert ("retry-safety", 29) in hits       # dropped twin kwarg
    assert ("retry-safety", 32) in hits       # no twin counterpart


def test_retry_safety_quiet_on_clean():
    assert _hits(FIXTURES / "retry_clean.py", RetrySafety()) == []


def test_twin_check_skips_when_twin_absent():
    mod = Module("proxy.py", (
        "# repro: twin-of SomewhereElse\n"
        "class P:\n"
        "    def extra_method(self):\n"
        "        return 1\n"))
    assert list(RetrySafety().check([mod])) == []


def test_allowlist_is_read_only_names():
    from repro.analysis.retry_safety import READ_ONLY_RPC_METHODS
    for mutation in ("build", "apply_delta", "update_index",
                     "build_index", "__shutdown__"):
        assert mutation not in READ_ONLY_RPC_METHODS


# -- metric / span names ---------------------------------------------------

def test_metric_names_fire_on_violations():
    hits = _hits(FIXTURES / "metric_bad.py", MetricNames())
    assert hits.count(("metric-name", 8)) == 2   # span name + metric=
    for line in (5, 6, 7, 10):
        assert ("metric-name", line) in hits
    assert len(hits) == 6


def test_metric_names_quiet_on_clean():
    assert _hits(FIXTURES / "metric_clean.py", MetricNames()) == []


# -- tracer safety ---------------------------------------------------------

def test_tracer_safety_fires_on_violations():
    hits = _hits(FIXTURES / "tracer_bad.py", TracerSafety())
    assert ("tracer-safety", 13) in hits      # np on tracers
    assert ("tracer-safety", 18) in hits      # if on tracer
    assert ("tracer-safety", 26) in hits      # while on derived value
    assert ("tracer-safety", 33) in hits      # closed-over store
    assert ("tracer-safety", 42) in hits      # nonlocal write
    assert len(hits) == 5


def test_tracer_safety_quiet_on_clean():
    assert _hits(FIXTURES / "tracer_clean.py", TracerSafety()) == []


# -- WAL / codec exhaustiveness --------------------------------------------

def test_wal_exhaustive_fires_on_violations():
    report = run_checks([str(FIXTURES / "codec_bad")],
                        checkers=[WalExhaustive()])
    got = {(os.path.basename(f.path), f.line) for f in report.findings}
    assert ("wal.py", 6) in got               # missing replay arm
    assert ("framing.py", 3) in got           # tag never packed
    assert ("framing.py", 4) in got           # tag never unpacked
    assert ("legacy.py", 2) in got            # pickle import
    assert len(report.findings) == 4


def test_wal_exhaustive_quiet_on_clean():
    report = run_checks([str(FIXTURES / "codec_clean")],
                        checkers=[WalExhaustive()])
    assert report.findings == []


# -- framework: waivers, CLI, and the repo gate ----------------------------

def test_waiver_requires_matching_rule():
    mod_src = (
        "import threading\n"
        "class C:\n"
        "    def __init__(self):\n"
        "        self._mu = threading.Lock()\n"
        "        self.x = 0  # guarded by: _mu\n"
        "    def f(self):\n"
        "        # repro: allow(metric-name) — wrong rule\n"
        "        return self.x\n")
    path = FIXTURES / "_tmp_wrong_rule.py"
    path.write_text(mod_src)
    try:
        hits = _hits(path, LockDiscipline())
        assert ("lock-discipline", 8) in hits    # waiver didn't apply
    finally:
        path.unlink()


def test_default_suite_has_five_checkers():
    names = {c.name for c in default_checkers()}
    assert names == {"lock-discipline", "retry-safety", "metric-name",
                     "tracer-safety", "wal-exhaustive"}


def _run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=str(REPO), env=env, capture_output=True, text=True,
        timeout=120)


def test_cli_json_reports_findings_and_exits_nonzero():
    proc = _run_cli("--json", str(FIXTURES / "metric_bad.py"))
    assert proc.returncode == 1
    report = json.loads(proc.stdout)
    assert report["ok"] is False
    assert report["files"] == 1
    assert {f["rule"] for f in report["findings"]} == {"metric-name"}


def test_repo_tree_is_clean():
    """THE gate: the shipped source passes its own analysis suite."""
    proc = _run_cli("src")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout
