"""Pallas kernels vs. pure-jnp oracles (interpret mode): shape/dtype
sweeps per the brief."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph.edges import make_labels
from repro.graph.generators import erdos_renyi, powerlaw
from repro.kernels import ops, ref


class TestGeeScatterKernel:
    @pytest.mark.parametrize("n,s,K", [
        (100, 500, 5), (1000, 8000, 12), (257, 1999, 50), (64, 64, 3),
    ])
    @pytest.mark.parametrize("tile_n,edge_block", [(128, 128), (64, 256)])
    def test_matches_oracle(self, n, s, K, tile_n, edge_block):
        g = erdos_renyi(n, s, seed=n + s, weighted=True)
        Y = make_labels(n, K, 0.3, np.random.default_rng(n))
        Z = ops.gee_pallas(g.u, g.v, g.w, jnp.asarray(Y), K=K, n=n,
                           tile_n=tile_n, edge_block=edge_block)
        Zr = ref.gee_ref(jnp.asarray(g.u), jnp.asarray(g.v),
                         jnp.asarray(g.w), jnp.asarray(Y), n, K)
        np.testing.assert_allclose(np.asarray(Z), np.asarray(Zr),
                                   atol=1e-5)

    def test_skewed_destinations(self):
        """Power-law graphs stress the per-tile bucket padding."""
        g = powerlaw(300, 5000, seed=9)
        Y = make_labels(300, 8, 0.25, np.random.default_rng(9))
        Z = ops.gee_pallas(g.u, g.v, g.w, jnp.asarray(Y), K=8, n=300,
                           tile_n=64, edge_block=128)
        Zr = ref.gee_ref(jnp.asarray(g.u), jnp.asarray(g.v),
                         jnp.asarray(g.w), jnp.asarray(Y), 300, 8)
        np.testing.assert_allclose(np.asarray(Z), np.asarray(Zr),
                                   atol=1e-5)

    def test_all_unlabeled_zero(self):
        g = erdos_renyi(64, 256, seed=1)
        Y = jnp.full((64,), -1, jnp.int32)
        Z = ops.gee_pallas(g.u, g.v, g.w, Y, K=4, n=64,
                           tile_n=64, edge_block=64)
        assert np.all(np.asarray(Z) == 0)


class TestPackEdges:
    """Host-side packing edge cases (ISSUE 2): the packed blocks must
    round-trip to exactly the XLA scatter result."""

    @staticmethod
    def _scatter_oracle(dst, cls, val, n, K):
        Z = np.zeros((n, K), np.float32)
        np.add.at(Z, (np.asarray(dst), np.asarray(cls)), np.asarray(val))
        return Z

    @staticmethod
    def _unpack_scatter(rows, clsb, valb, T, tile_n, n, K):
        """Replay the packed blocks on the host: tile-local rows become
        global rows; padded slots carry val = 0 and cancel out."""
        Z = np.zeros((T * tile_n, K), np.float32)
        for t in range(T):
            r = rows[t].reshape(-1) + t * tile_n
            c = clsb[t].reshape(-1)
            x = valb[t].reshape(-1)
            np.add.at(Z, (r, c), x)
        return Z[:n]

    def _roundtrip(self, dst, cls, val, n, K, tile_n=64, edge_block=32):
        rows, clsb, valb, T = ops.pack_edges(dst, cls, val, n,
                                             tile_n, edge_block)
        assert rows.shape == clsb.shape == valb.shape
        assert rows.shape[0] == T and rows.shape[2] == edge_block
        got = self._unpack_scatter(rows, clsb, valb, T, tile_n, n, K)
        np.testing.assert_allclose(
            got, self._scatter_oracle(dst, cls, val, n, K), atol=1e-6)

    def test_empty_edge_list(self):
        dst = np.zeros(0, np.int32)
        self._roundtrip(dst, dst.copy(), np.zeros(0, np.float32),
                        n=100, K=4)

    def test_all_edges_one_destination_tile(self):
        rng = np.random.default_rng(11)
        dst = rng.integers(0, 64, 500).astype(np.int32)   # tile 0 only
        cls = rng.integers(0, 4, 500).astype(np.int32)
        val = rng.random(500, dtype=np.float32)
        self._roundtrip(dst, cls, val, n=1000, K=4)

    def test_n_not_multiple_of_tile(self):
        rng = np.random.default_rng(13)
        n = 257                                           # 257 % 64 != 0
        dst = rng.integers(0, n, 900).astype(np.int32)
        cls = rng.integers(0, 5, 900).astype(np.int32)
        val = rng.random(900, dtype=np.float32)
        self._roundtrip(dst, cls, val, n=n, K=5)

    def test_empty_graph_through_pallas_kernel(self):
        """pack_edges empty case end-to-end through gee_pallas."""
        Z = ops.gee_pallas(np.zeros(0, np.int32), np.zeros(0, np.int32),
                           np.zeros(0, np.float32),
                           jnp.zeros(64, jnp.int32), K=4, n=64,
                           tile_n=64, edge_block=64)
        assert np.all(np.asarray(Z) == 0) and Z.shape == (64, 4)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("B,H,KV,S,D", [
        (1, 2, 2, 64, 16),      # MHA
        (2, 4, 2, 128, 32),     # GQA 2:1
        (1, 8, 1, 128, 16),     # MQA
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, B, H, KV, S, D, dtype):
        ks = jax.random.split(jax.random.PRNGKey(B * 100 + S), 3)
        q = jax.random.normal(ks[0], (B, H, S, D), dtype)
        k = jax.random.normal(ks[1], (B, KV, S, D), dtype)
        v = jax.random.normal(ks[2], (B, KV, S, D), dtype)
        o = ops.flash_attention(q, k, v, bq=32, bk=32)
        orf = ref.flash_attention_ref(q, k, v)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(orf, np.float32),
            atol=tol, rtol=tol)

    @pytest.mark.parametrize("bq,bk", [(16, 64), (64, 16), (128, 128)])
    def test_block_shape_sweep(self, bq, bk):
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q = jax.random.normal(ks[0], (1, 4, 128, 32))
        k = jax.random.normal(ks[1], (1, 2, 128, 32))
        v = jax.random.normal(ks[2], (1, 2, 128, 32))
        o = ops.flash_attention(q, k, v, bq=bq, bk=bk)
        orf = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                                   atol=2e-5, rtol=2e-5)

    def test_matches_model_chunked_attention(self):
        """The Pallas kernel and the model's lax.scan flash path are the
        same math — cross-validate them against each other."""
        from repro.models.attention import attn_flash
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        B, H, KV, S, D = 2, 4, 4, 128, 16
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, KV, D))
        v = jax.random.normal(ks[2], (B, S, KV, D))
        pos = jnp.arange(S)
        o_model = attn_flash(q, k, v, pos, pos, causal=True,
                             q_chunk=32, kv_chunk=32)
        o_kernel = ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), bq=32, bk=32).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(o_model),
                                   np.asarray(o_kernel), atol=2e-5)
