"""Sharding rule unit tests (no multi-device needed: PartitionSpecs are
pure functions of mesh shape + logical axes)."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.models import model as M
from repro.models.layers import tree_map_specs
from repro.sharding import make_rules


class FakeMesh:
    """Shape-only stand-in (rules never touch devices)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def mesh16x16():
    return FakeMesh({"data": 16, "model": 16})


def mesh2x16x16():
    return FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_weight_specs_basic():
    r = make_rules(mesh16x16())
    assert r.weight_spec((4096, 11008), ("embed", "mlp")) == \
        P("data", "model")
    assert r.weight_spec((64000, 4096), ("vocab", "embed")) == \
        P("model", "data")
    # stacked layer dim replicated
    assert r.weight_spec((48, 4096, 11008), ("layers", "embed", "mlp")) == \
        P(None, "data", "model")


def test_multipod_adds_pod_to_fsdp():
    r = make_rules(mesh2x16x16())
    assert r.weight_spec((4096, 11008), ("embed", "mlp")) == \
        P(("pod", "data"), "model")


def test_divisibility_fallback():
    r = make_rules(mesh16x16())
    # 4 heads cannot shard over 16 -> replicated (xlstm case)
    assert r.weight_spec((2048, 4, 512), ("embed", "heads", None)) == \
        P("data")
    # vocab not divisible by 16 -> replicated
    assert r.weight_spec((51865, 1024), ("vocab", "embed")) == \
        P(None, "data")


def test_axis_reuse_guard():
    r = make_rules(mesh16x16())
    # both dims map to "model"-able names: only the first gets it
    spec = r.weight_spec((4096, 4096), ("mlp", "vocab"))
    assert spec == P("model")      # second dim falls back to None


def test_activation_rules():
    r = make_rules(mesh16x16())
    assert r.act_spec((256, 4096, 4096), ("batch", "seq", "embed")) == \
        P("data")
    assert r.act_spec((256, 4096, 32, 128),
                      ("batch", "seq", "heads", None)) == \
        P("data", None, "model")


def test_seq_parallel_option():
    r = make_rules(mesh16x16(), seq_shard_acts=True)
    assert r.act_spec((256, 4096, 4096), ("batch", "seq", "embed")) == \
        P("data", "model")


def test_fsdp_off():
    r = make_rules(mesh16x16(), fsdp=False)
    assert r.weight_spec((4096, 11008), ("embed", "mlp")) == \
        P(None, "model")


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mesh_fn", [mesh16x16, mesh2x16x16])
def test_every_param_spec_resolves(arch, mesh_fn):
    """Every parameter of every arch must map to a valid PartitionSpec
    with no duplicate mesh axes and correct rank."""
    cfg = get_config(arch)
    rules = make_rules(mesh_fn())
    specs = M.param_specs(cfg)

    def check(s):
        ps = rules.weight_spec(s.shape, s.logical)
        flat = []
        for part in ps:
            if part is None:
                continue
            flat.extend(part if isinstance(part, tuple) else (part,))
        assert len(flat) == len(set(flat)), (s.shape, s.logical, ps)
        assert len(ps) <= len(s.shape)
        # sharded dims must divide
        for dim, part in zip(s.shape, tuple(ps) + (None,) * 10):
            if part is None:
                continue
            size = int(np.prod([mesh_fn().shape[a] for a in
                                (part if isinstance(part, tuple)
                                 else (part,))]))
            assert dim % size == 0, (s.shape, ps)
        return ps

    tree_map_specs(check, specs)


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "grok-1-314b"])
def test_big_models_fit_when_sharded(arch):
    """Param + optimizer-state bytes per chip under the weight rules must
    fit the 16 GB HBM budget (the memory-side scale contract)."""
    cfg = get_config(arch)
    rules = make_rules(mesh16x16())
    specs = M.param_specs(cfg)
    import jax.numpy as jnp
    pbytes = jnp.dtype(cfg.param_dtype).itemsize
    sbytes = jnp.dtype(cfg.state_dtype).itemsize

    total = 0.0

    def acc(s):
        nonlocal total
        ps = rules.weight_spec(s.shape, s.logical)
        shards = 1
        for part in ps:
            if part is None:
                continue
            for a in (part if isinstance(part, tuple) else (part,)):
                shards *= rules.mesh.shape[a]
        n = int(np.prod(s.shape)) / shards
        total += n * (pbytes + 2 * sbytes + 4)   # p + m + v + f32 grad
        return s

    tree_map_specs(acc, specs)
    assert total < 16e9, f"{arch}: {total/1e9:.1f} GB/chip"
