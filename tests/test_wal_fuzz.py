"""WAL fault-injection fuzz: corrupt a real deployment's log at random
offsets — truncation and bit-flips — and require that
`ServingEngine.open()` always recovers an EXACT prefix of the states
the crashed process went through (or cleanly truncates back to the
snapshot), never crashes, and never applies a corrupt record.

The oracle: every accepted mutation appends exactly one WAL record, so
after each mutation we snapshot the (version, epoch, fingerprint)
triple and the live Z.  Any corruption makes replay stop at the first
bad record (length/CRC framing), which must land the recovered engine
on one of those recorded states — anything else means a torn or
bit-flipped record leaked into the store."""
import os

import numpy as np
import pytest

from repro.graph.edges import make_labels
from repro.graph.generators import erdos_renyi
from repro.serving import GraphStore, ServingEngine

pytestmark = pytest.mark.slow

N, K = 60, 4
N_TRIALS = 12
_MAGIC_LEN = len(b"REPROWAL1\n")


def _mkstore(seed):
    g = erdos_renyi(N, 500, seed=seed, weighted=True)
    Y = make_labels(N, K, 0.4, np.random.default_rng(seed))
    return GraphStore(g, Y, K)


def _build_deployment(d, rng):
    """Drive a durable 2-shard engine through mixed traffic; return
    every prefix state the WAL could legally replay to:
    {(version, epoch, fingerprint): Z}."""
    eng = ServingEngine(_mkstore(seed=7), num_shards=2, data_dir=d,
                        rebuild_churn=0.2)
    states = {}

    def snap():
        states[(eng.version, eng.epoch, eng.fingerprint())] = \
            np.asarray(eng.Z)

    snap()                               # the snapshot-only state
    inserted = []
    for step in range(10):
        if step == 4:
            eng.compact()                # COMPACT marker mid-log
        elif step == 7:
            eng.refresh()                # REBUILD marker mid-log
        elif step % 3 == 2 and inserted:
            eng.apply_edge_delta(*inserted.pop(), delete=True)
        elif step % 5 == 3:
            nodes = rng.choice(N, int(rng.integers(1, N // 2)),
                               replace=False)
            eng.apply_label_delta(
                nodes, rng.integers(-1, K, nodes.shape[0]
                                    ).astype(np.int32))
        else:
            b = int(rng.integers(1, 40))
            batch = (rng.integers(0, N, b).astype(np.int32),
                     rng.integers(0, N, b).astype(np.int32),
                     (rng.random(b, dtype=np.float32) + 0.5))
            eng.apply_edge_delta(*batch)
            inserted.append(batch)
        snap()
    eng.close()
    return states


@pytest.mark.parametrize("mode", ["truncate", "bitflip"])
def test_corrupted_wal_recovers_exact_prefix_state(tmp_path, rng, mode):
    d = str(tmp_path / "dep")
    states = _build_deployment(d, rng)
    assert len(states) >= 8              # distinct replayable prefixes
    wal_path = os.path.join(d, "wal-0.log")
    with open(wal_path, "rb") as f:
        pristine = f.read()
    assert len(pristine) > _MAGIC_LEN
    for _ in range(N_TRIALS):
        if mode == "truncate":
            # anywhere, including inside the file magic (reads as an
            # empty log -> clean truncation back to the snapshot)
            cut = int(rng.integers(0, len(pristine) + 1))
            blob = pristine[:cut]
        else:
            # a disk error inside the record region; the file magic is
            # config, not data — a corrupted magic is "not a WAL" and
            # correctly refuses rather than guessing
            off = int(rng.integers(_MAGIC_LEN, len(pristine)))
            blob = bytearray(pristine)
            blob[off] ^= 1 << int(rng.integers(0, 8))
            blob = bytes(blob)
        with open(wal_path, "wb") as f:
            f.write(blob)
        rec = ServingEngine.open(d)      # must never raise
        try:
            triple = (rec.version, rec.epoch, rec.fingerprint())
            assert triple in states, \
                f"recovered {triple} is not a valid prefix state"
            np.testing.assert_allclose(np.asarray(rec.Z),
                                       states[triple], atol=1e-3)
            # the corrupt suffix was truncated away: the recovered log
            # must accept appends again
            rec.apply_edge_delta(np.array([0], np.int32),
                                 np.array([1], np.int32),
                                 np.ones(1, np.float32))
        finally:
            rec.close()
