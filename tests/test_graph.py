"""Graph substrate: generators, IO, partition planning."""
import numpy as np
import pytest

from repro.graph.edges import Graph, make_labels
from repro.graph.generators import erdos_renyi, powerlaw, sbm
from repro.graph.io import ShardedEdgeReader, load_graph, save_graph
from repro.graph.partition import owner_histogram, plan_capacity, \
    shuffle_edges


def test_generator_shapes_and_ranges():
    g = erdos_renyi(100, 1000, seed=0)
    g.validate()
    assert g.s == 1000 and g.n == 100
    gp = powerlaw(100, 1000, seed=0)
    gp.validate()
    gs, labels = sbm(100, 4, 1000, seed=0)
    gs.validate()
    assert labels.shape == (100,) and labels.max() < 4


def test_sbm_is_assortative():
    g, labels = sbm(500, 5, 20000, p_in=0.9, seed=1)
    same = (labels[g.u] == labels[g.v]).mean()
    assert same > 0.8       # ~p_in + chance
    g2, _ = sbm(500, 5, 20000, p_in=0.2, seed=1)


def test_symmetrize_doubles_edges():
    g = erdos_renyi(50, 200, seed=2)
    gs = g.symmetrize()
    assert gs.s == 400
    d1 = g.degrees()
    np.testing.assert_allclose(gs.degrees(), 2 * d1)


def test_pad_is_noop_for_gee():
    import jax.numpy as jnp
    from repro.core.gee import gee
    g = erdos_renyi(60, 123, seed=3, weighted=True)
    Y = make_labels(60, 4, 0.5, np.random.default_rng(3))
    Z1 = np.asarray(gee(jnp.asarray(g.u), jnp.asarray(g.v),
                        jnp.asarray(g.w), jnp.asarray(Y), K=4, n=60))
    gp = g.pad_to(160)
    Z2 = np.asarray(gee(jnp.asarray(gp.u), jnp.asarray(gp.v),
                        jnp.asarray(gp.w), jnp.asarray(Y), K=4, n=60))
    np.testing.assert_allclose(Z1, Z2, atol=1e-6)


def test_pad_preserves_laplacian_degrees():
    """Regression (ISSUE 2): padded zero-weight self-loops on node 0
    must not perturb `degrees()` or the Laplacian deg precompute —
    including when node 0 is isolated (deg 0) and would be most
    sensitive to a phantom self-loop."""
    import jax.numpy as jnp
    from repro.core.gee import gee
    g = erdos_renyi(60, 123, seed=7, weighted=True)
    # isolate node 0 so any phantom degree contribution is visible
    keep = (g.u != 0) & (g.v != 0)
    g = Graph(g.u[keep], g.v[keep], g.w[keep], g.n)
    Y = make_labels(60, 4, 0.5, np.random.default_rng(7))
    gp = g.pad_to(256)
    assert gp.n == g.n and gp.s == 256
    np.testing.assert_array_equal(g.degrees(), gp.degrees())
    assert gp.degrees()[0] == 0.0
    Z1 = np.asarray(gee(jnp.asarray(g.u), jnp.asarray(g.v),
                        jnp.asarray(g.w), jnp.asarray(Y), K=4, n=60,
                        laplacian=True))
    Z2 = np.asarray(gee(jnp.asarray(gp.u), jnp.asarray(gp.v),
                        jnp.asarray(gp.w), jnp.asarray(Y), K=4, n=60,
                        laplacian=True))
    np.testing.assert_allclose(Z1, Z2, atol=1e-6)
    # same invariant through the unified API's deg precompute (the
    # encoder plans degrees from the unpadded graph by construction)
    from repro.encoder import Embedder, EncoderConfig
    Zp = Embedder(EncoderConfig(K=4, laplacian=True),
                  backend="xla").fit(gp, Y).transform()
    np.testing.assert_allclose(Z1, Zp, atol=1e-6)


def test_pad_empty_graph_rejected():
    g = Graph(np.zeros(0, np.int32), np.zeros(0, np.int32),
              np.zeros(0, np.float32), 0)
    with pytest.raises(AssertionError, match="no nodes"):
        g.pad_to(8)


def test_io_roundtrip_and_sharded_reader(tmp_path):
    g = erdos_renyi(100, 999, seed=4, weighted=True)
    path = str(tmp_path / "g.npz")
    save_graph(path, g)
    g2 = load_graph(path)
    np.testing.assert_array_equal(g.u, g2.u)
    np.testing.assert_allclose(g.w, g2.w)

    # two hosts stream disjoint slices covering everything
    seen = []
    for host in (0, 1):
        for chunk in ShardedEdgeReader(path, host, 2, chunk_size=100):
            seen.append(chunk.u)
    assert sum(len(x) for x in seen) == g.s
    np.testing.assert_array_equal(np.concatenate(seen), g.u)


def test_sbm_deterministic_per_seed():
    g1, l1 = sbm(300, 4, 3000, p_in=0.9, seed=11)
    g2, l2 = sbm(300, 4, 3000, p_in=0.9, seed=11)
    np.testing.assert_array_equal(g1.u, g2.u)
    np.testing.assert_array_equal(g1.v, g2.v)
    np.testing.assert_array_equal(l1, l2)
    assert g1.fingerprint() == g2.fingerprint()
    g3, _ = sbm(300, 4, 3000, p_in=0.9, seed=12)
    assert g1.fingerprint() != g3.fingerprint()


def test_sbm_empty_block_handling():
    """With n << K some blocks get no members; the intra-edge sampler
    (sorted-by-label indexing) must still confine intra edges to the
    source's own block and never index out of range."""
    found = False
    for seed in range(40):
        g, labels = sbm(8, 6, 400, p_in=1.0, seed=seed)
        g.validate()
        # p_in=1.0 -> EVERY edge is intra: endpoints share a block
        np.testing.assert_array_equal(labels[g.u], labels[g.v])
        if np.bincount(labels, minlength=6).min() == 0:
            found = True                    # an actually-empty block
    assert found, "no seed produced an empty block; weaken n or raise K"


def test_powerlaw_degree_skew():
    n, s = 1000, 50_000
    g = powerlaw(n, s, alpha=1.5, seed=3)
    g.validate()
    out = np.bincount(g.u, minlength=n)
    mean = s / n
    # rank-1 node dominates: far above mean, and the top 1% of sources
    # carry a disproportionate share of all edges (Zipf endpoints)
    assert out[0] > 10 * mean
    top = np.sort(out)[::-1][: n // 100].sum()
    assert top / s > 0.3
    # destinations stay uniform-ish (only sources are skewed)
    indeg = np.bincount(g.v, minlength=n)
    assert indeg.max() < 5 * mean


def test_weighted_erdos_renyi_roundtrip(tmp_path):
    g = erdos_renyi(120, 800, seed=9, weighted=True)
    assert g.w.dtype == np.float32 and (g.w >= 0.5).all()
    assert not np.allclose(g.w, 1.0)           # actually weighted
    path = str(tmp_path / "w.npz")
    save_graph(path, g)
    g2 = load_graph(path)
    np.testing.assert_array_equal(g.u, g2.u)
    np.testing.assert_array_equal(g.v, g2.v)
    np.testing.assert_array_equal(g.w, g2.w)
    assert g2.n == g.n
    assert g.fingerprint() == g2.fingerprint()


def test_mmap_fast_path_matches_streaming(tmp_path):
    """ROADMAP satellite: uncompressed snapshots take the mmap path;
    chunks must be identical to the streaming decode, per host slice."""
    from repro.graph.io import is_mmapable
    g = erdos_renyi(100, 999, seed=4, weighted=True)
    comp = str(tmp_path / "c.npz")
    stored = str(tmp_path / "u.npz")
    save_graph(comp, g)
    save_graph(stored, g, compressed=False)
    assert not is_mmapable(comp) and is_mmapable(stored)

    for host in (0, 1, 2):
        mm = list(ShardedEdgeReader(stored, host, 3, chunk_size=100,
                                    mmap=True))
        st = list(ShardedEdgeReader(stored, host, 3, chunk_size=100,
                                    mmap=False))
        assert len(mm) == len(st)
        for a, b in zip(mm, st):
            np.testing.assert_array_equal(np.asarray(a.u), b.u)
            np.testing.assert_array_equal(np.asarray(a.v), b.v)
            np.testing.assert_array_equal(np.asarray(a.w), b.w)
            assert a.n == b.n

    # auto-detection: stored file maps, compressed file streams; forcing
    # mmap on a compressed file is a loud error, not silent decode
    assert ShardedEdgeReader(stored, 0, 1).mmap
    assert not ShardedEdgeReader(comp, 0, 1).mmap
    with pytest.raises(ValueError, match="compressed"):
        list(ShardedEdgeReader(comp, 0, 1, mmap=True))
    # and the mmap'd chunks are zero-copy views of the file
    first = next(iter(ShardedEdgeReader(stored, 0, 1, mmap=True)))
    assert isinstance(first.u, np.memmap)


def test_shuffle_balances_owners():
    g = powerlaw(1024, 32768, seed=5)     # skewed sources
    gs = shuffle_edges(g, seed=1)
    hist = owner_histogram(gs, p=8)
    per_shard = hist.sum(1)
    assert per_shard.max() / per_shard.min() < 1.05


def test_capacity_plan_reasonable():
    cf = plan_capacity(s=1_000_000, n=100_000, p=64)
    assert 1.0 < cf < 3.0
