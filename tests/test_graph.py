"""Graph substrate: generators, IO, partition planning."""
import numpy as np
import pytest

from repro.graph.edges import Graph, make_labels
from repro.graph.generators import erdos_renyi, powerlaw, sbm
from repro.graph.io import ShardedEdgeReader, load_graph, save_graph
from repro.graph.partition import owner_histogram, plan_capacity, \
    shuffle_edges


def test_generator_shapes_and_ranges():
    g = erdos_renyi(100, 1000, seed=0)
    g.validate()
    assert g.s == 1000 and g.n == 100
    gp = powerlaw(100, 1000, seed=0)
    gp.validate()
    gs, labels = sbm(100, 4, 1000, seed=0)
    gs.validate()
    assert labels.shape == (100,) and labels.max() < 4


def test_sbm_is_assortative():
    g, labels = sbm(500, 5, 20000, p_in=0.9, seed=1)
    same = (labels[g.u] == labels[g.v]).mean()
    assert same > 0.8       # ~p_in + chance
    g2, _ = sbm(500, 5, 20000, p_in=0.2, seed=1)


def test_symmetrize_doubles_edges():
    g = erdos_renyi(50, 200, seed=2)
    gs = g.symmetrize()
    assert gs.s == 400
    d1 = g.degrees()
    np.testing.assert_allclose(gs.degrees(), 2 * d1)


def test_pad_is_noop_for_gee():
    import jax.numpy as jnp
    from repro.core.gee import gee
    g = erdos_renyi(60, 123, seed=3, weighted=True)
    Y = make_labels(60, 4, 0.5, np.random.default_rng(3))
    Z1 = np.asarray(gee(jnp.asarray(g.u), jnp.asarray(g.v),
                        jnp.asarray(g.w), jnp.asarray(Y), K=4, n=60))
    gp = g.pad_to(160)
    Z2 = np.asarray(gee(jnp.asarray(gp.u), jnp.asarray(gp.v),
                        jnp.asarray(gp.w), jnp.asarray(Y), K=4, n=60))
    np.testing.assert_allclose(Z1, Z2, atol=1e-6)


def test_pad_preserves_laplacian_degrees():
    """Regression (ISSUE 2): padded zero-weight self-loops on node 0
    must not perturb `degrees()` or the Laplacian deg precompute —
    including when node 0 is isolated (deg 0) and would be most
    sensitive to a phantom self-loop."""
    import jax.numpy as jnp
    from repro.core.gee import gee
    g = erdos_renyi(60, 123, seed=7, weighted=True)
    # isolate node 0 so any phantom degree contribution is visible
    keep = (g.u != 0) & (g.v != 0)
    g = Graph(g.u[keep], g.v[keep], g.w[keep], g.n)
    Y = make_labels(60, 4, 0.5, np.random.default_rng(7))
    gp = g.pad_to(256)
    assert gp.n == g.n and gp.s == 256
    np.testing.assert_array_equal(g.degrees(), gp.degrees())
    assert gp.degrees()[0] == 0.0
    Z1 = np.asarray(gee(jnp.asarray(g.u), jnp.asarray(g.v),
                        jnp.asarray(g.w), jnp.asarray(Y), K=4, n=60,
                        laplacian=True))
    Z2 = np.asarray(gee(jnp.asarray(gp.u), jnp.asarray(gp.v),
                        jnp.asarray(gp.w), jnp.asarray(Y), K=4, n=60,
                        laplacian=True))
    np.testing.assert_allclose(Z1, Z2, atol=1e-6)
    # same invariant through the unified API's deg precompute (the
    # encoder plans degrees from the unpadded graph by construction)
    from repro.encoder import Embedder, EncoderConfig
    Zp = Embedder(EncoderConfig(K=4, laplacian=True),
                  backend="xla").fit(gp, Y).transform()
    np.testing.assert_allclose(Z1, Zp, atol=1e-6)


def test_pad_empty_graph_rejected():
    g = Graph(np.zeros(0, np.int32), np.zeros(0, np.int32),
              np.zeros(0, np.float32), 0)
    with pytest.raises(AssertionError, match="no nodes"):
        g.pad_to(8)


def test_io_roundtrip_and_sharded_reader(tmp_path):
    g = erdos_renyi(100, 999, seed=4, weighted=True)
    path = str(tmp_path / "g.npz")
    save_graph(path, g)
    g2 = load_graph(path)
    np.testing.assert_array_equal(g.u, g2.u)
    np.testing.assert_allclose(g.w, g2.w)

    # two hosts stream disjoint slices covering everything
    seen = []
    for host in (0, 1):
        for chunk in ShardedEdgeReader(path, host, 2, chunk_size=100):
            seen.append(chunk.u)
    assert sum(len(x) for x in seen) == g.s
    np.testing.assert_array_equal(np.concatenate(seen), g.u)


def test_shuffle_balances_owners():
    g = powerlaw(1024, 32768, seed=5)     # skewed sources
    gs = shuffle_edges(g, seed=1)
    hist = owner_histogram(gs, p=8)
    per_shard = hist.sum(1)
    assert per_shard.max() / per_shard.min() < 1.05


def test_capacity_plan_reasonable():
    cf = plan_capacity(s=1_000_000, n=100_000, p=64)
    assert 1.0 < cf < 3.0
