"""Persistent plan-cache tier: the cross-process acceptance criteria.

  * a SECOND PROCESS embedding the same graph gets a persistent hit —
    no host repacking — asserted via the Embedder's cache counters from
    real subprocesses;
  * a corrupted cache entry falls back to a correct rebuild (and is
    replaced);
  * a stale entry (older format / plan_version) reads as a miss;
  * writes are atomic and keyed entries verify their full metadata.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

pytestmark = pytest.mark.slow          # persistent plan-cache tier (subprocess hits)

import repro
from repro.core.ref_python import gee_numpy
from repro.encoder import Embedder, EncoderConfig, get_backend
from repro.encoder.plan_cache import PlanDiskCache, default_cache
from repro.graph.edges import make_labels
from repro.graph.generators import erdos_renyi
from repro.graph.io import save_graph

CFG = dict(tile_n=64, edge_block=128)

# The child embeds a snapshot through the SnapshotSource front door with
# the persistent cache pointed at argv's dir (via REPRO_PLAN_CACHE, so
# the env-resolution path is covered too), then reports its plan
# counters and a Z checksum on stdout.
CHILD = r"""
import json, sys
import numpy as np
from repro.encoder import Embedder, EncoderConfig
from repro.graph.edges import make_labels
from repro.graph.sources import SnapshotSource

src = SnapshotSource(sys.argv[1])
g = src.graph()
Y = make_labels(g.n, 5, 0.4, np.random.default_rng(0))
emb = Embedder(EncoderConfig(K=5, tile_n=64, edge_block=128),
               backend="pallas")
emb.fit(src, Y)
print(json.dumps({"stats": emb.plan_stats,
                  "zsum": float(np.abs(emb.transform()).sum())}))
"""


def _run_child(snapshot: str, cache_dir: str) -> dict:
    env = dict(os.environ)
    # repro is a namespace package: resolve its root from __path__
    src_root = str(Path(next(iter(repro.__path__))).resolve().parent)
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_PLAN_CACHE"] = cache_dir
    out = subprocess.run([sys.executable, "-c", CHILD, snapshot],
                         env=env, capture_output=True, text=True,
                         timeout=300)
    assert out.returncode == 0, out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_second_process_gets_persistent_hit(tmp_path):
    g = erdos_renyi(130, 700, seed=2, weighted=True)
    snap = str(tmp_path / "g.npz")
    save_graph(snap, g)
    cache = str(tmp_path / "plans")

    first = _run_child(snap, cache)
    assert first["stats"] == {"built": 1, "hits": 0,
                              "disk_hits": 0, "disk_stores": 1}
    second = _run_child(snap, cache)
    # the load-bearing claim: a fresh process never repacked — the plan
    # came off disk
    assert second["stats"] == {"built": 0, "hits": 0,
                               "disk_hits": 1, "disk_stores": 0}
    assert second["zsum"] == pytest.approx(first["zsum"], rel=1e-6)


def _fit(tmp_path, g, Y, **kw):
    emb = Embedder(EncoderConfig(K=5, **CFG), backend="pallas",
                   plan_cache=tmp_path, **kw)
    emb.fit(g, Y)
    return emb


def test_corrupt_entry_falls_back_to_rebuild(tmp_path):
    g = erdos_renyi(90, 400, seed=4, weighted=True)
    Y = make_labels(90, 5, 0.4, np.random.default_rng(1))
    _fit(tmp_path, g, Y)
    [entry] = list(Path(tmp_path).glob("*.npz"))
    entry.write_bytes(b"not an npz at all")

    emb = _fit(tmp_path, g, Y)                 # must not crash
    assert emb.plan_stats == {"built": 1, "hits": 0,
                              "disk_hits": 0, "disk_stores": 1}
    np.testing.assert_allclose(emb.transform(),
                               gee_numpy(g.u, g.v, g.w, Y, 5, g.n),
                               atol=1e-5)
    # the rebuild REPLACED the corrupt entry: next process hits again
    emb2 = _fit(tmp_path, g, Y)
    assert emb2.plan_stats["disk_hits"] == 1


def test_stale_entry_is_a_miss(tmp_path):
    """An entry written by an older plan format (simulated by doctoring
    the stored metadata) must read as a miss, never as a wrong plan."""
    g = erdos_renyi(90, 400, seed=4, weighted=True)
    Y = make_labels(90, 5, 0.4, np.random.default_rng(1))
    _fit(tmp_path, g, Y)

    cache = PlanDiskCache(tmp_path)
    cfg = EncoderConfig(K=5, **CFG)
    meta = cache.describe(g.fingerprint(), get_backend("pallas"), cfg)
    path = cache.path(meta)
    with np.load(path, allow_pickle=False) as d:
        host = {k: d[k] for k in d.files if k != "__meta__"}
    doctored = dict(meta, plan_version=meta["plan_version"] + 1)
    with open(path, "wb") as f:
        np.savez(f, __meta__=np.asarray(json.dumps(doctored)), **host)

    assert cache.load(meta) is None            # stale -> miss
    emb = _fit(tmp_path, g, Y)                 # -> correct rebuild
    assert emb.plan_stats["built"] == 1
    np.testing.assert_allclose(emb.transform(),
                               gee_numpy(g.u, g.v, g.w, Y, 5, g.n),
                               atol=1e-5)


def test_atomic_writes_leave_no_tmp_droppings(tmp_path):
    g = erdos_renyi(60, 200, seed=1)
    Y = make_labels(60, 3, 0.5, np.random.default_rng(0))
    emb = Embedder(EncoderConfig(K=3, **CFG), backend="pallas",
                   plan_cache=tmp_path)
    emb.fit(g, Y)
    names = [p.name for p in Path(tmp_path).iterdir()]
    assert len(names) == 1 and not any(".tmp" in x for x in names)


def test_unwritable_cache_never_breaks_embedding(tmp_path):
    target = tmp_path / "blocked"
    target.write_text("a file where the cache dir should go")
    g = erdos_renyi(60, 200, seed=1)
    Y = make_labels(60, 3, 0.5, np.random.default_rng(0))
    emb = Embedder(EncoderConfig(K=3, **CFG), backend="pallas",
                   plan_cache=target)           # mkdir will fail
    emb.fit(g, Y)                               # still embeds
    assert emb.plan_stats["built"] == 1
    assert emb.plan_stats["disk_stores"] == 0


def test_clear_and_entries(tmp_path):
    g = erdos_renyi(60, 200, seed=1)
    Y = make_labels(60, 3, 0.5, np.random.default_rng(0))
    _ = Embedder(EncoderConfig(K=3, **CFG), backend="pallas",
                 plan_cache=tmp_path).fit(g, Y)
    _ = Embedder(EncoderConfig(K=4, **CFG), backend="pallas",
                 plan_cache=tmp_path).fit(g, Y)
    cache = PlanDiskCache(tmp_path)
    assert len(cache.entries()) == 2
    assert cache.clear() == 2
    assert cache.entries() == []


def test_default_cache_env_resolution(monkeypatch, tmp_path):
    for off in ("off", "0", "", "none", "DISABLED"):
        monkeypatch.setenv("REPRO_PLAN_CACHE", off)
        assert default_cache() is None
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "p"))
    cache = default_cache()
    assert cache is not None and cache.root == tmp_path / "p"
    monkeypatch.delenv("REPRO_PLAN_CACHE")
    monkeypatch.setenv("XDG_CACHE_HOME", str(tmp_path / "xdg"))
    assert default_cache().root == tmp_path / "xdg" / "repro-gee" / "plans"


def _fake_entry(cache: PlanDiskCache, i: int, mtime: float,
                nbytes: int = 64) -> Path:
    """A raw npz entry with a controlled last_used time and size."""
    cache.root.mkdir(parents=True, exist_ok=True)
    path = cache.root / f"{i:032x}.npz"
    np.savez(path, blob=np.zeros(max(1, nbytes // 8), np.int64))
    os.utime(path, (mtime, mtime))
    return path


class TestLruEviction:
    def test_max_entries_evicts_least_recently_used(self, tmp_path):
        cache = PlanDiskCache(tmp_path, max_entries=2)
        paths = [_fake_entry(cache, i, mtime=1000.0 + i)
                 for i in range(4)]
        assert cache.evict() == 2
        assert not paths[0].exists() and not paths[1].exists()
        assert paths[2].exists() and paths[3].exists()

    def test_max_bytes_evicts_until_under_budget(self, tmp_path):
        cache = PlanDiskCache(tmp_path, max_bytes=1)
        a = _fake_entry(cache, 0, mtime=1000.0)
        b = _fake_entry(cache, 1, mtime=2000.0)
        assert cache.evict() >= 1
        assert not a.exists()           # oldest went first
        # a single entry can still exceed a tiny budget — it goes too
        assert cache.evict() == (1 if b.exists() else 0)

    def test_store_triggers_eviction_and_hits_touch(self, tmp_path):
        g = erdos_renyi(60, 300, seed=0, weighted=True)
        Y = make_labels(60, 3, 0.4, np.random.default_rng(0))
        cache = PlanDiskCache(tmp_path, max_entries=2)
        # three distinct configs -> three entries through the real
        # store path; the cap holds after every store
        for K in (3, 4, 5):
            Embedder(EncoderConfig(K=K, **CFG), backend="pallas",
                     plan_cache=cache).fit(
                         g, np.minimum(Y, K - 1).astype(np.int32))
            assert len(cache.entries()) <= 2
        # a LOAD refreshes recency: back-date both survivors, hit one,
        # then overflow — the un-hit entry is the eviction victim
        survivors = cache.entries()
        for p in survivors:
            os.utime(p, (1000.0, 1000.0))
        emb = Embedder(EncoderConfig(K=5, **CFG), backend="pallas",
                       plan_cache=cache)
        emb.fit(g, np.minimum(Y, 4).astype(np.int32))
        assert emb.plan_stats["disk_hits"] == 1
        hit_path = [p for p in cache.entries()
                    if p.stat().st_mtime > 1500.0]
        assert len(hit_path) == 1
        _fake_entry(cache, 99, mtime=3000.0)
        cache.evict()
        assert hit_path[0].exists()     # recently used: kept

    def test_unbounded_cache_never_evicts(self, tmp_path):
        cache = PlanDiskCache(tmp_path)
        for i in range(5):
            _fake_entry(cache, i, mtime=1000.0 + i)
        assert cache.evict() == 0
        assert len(cache.entries()) == 5

    def test_default_cache_reads_limit_env(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path))
        monkeypatch.setenv("REPRO_PLAN_CACHE_MAX_ENTRIES", "7")
        monkeypatch.setenv("REPRO_PLAN_CACHE_MAX_BYTES", "1048576")
        cache = default_cache()
        assert (cache.max_entries, cache.max_bytes) == (7, 1048576)
        monkeypatch.setenv("REPRO_PLAN_CACHE_MAX_ENTRIES", "junk")
        monkeypatch.setenv("REPRO_PLAN_CACHE_MAX_BYTES", "0")
        cache = default_cache()
        assert (cache.max_entries, cache.max_bytes) == (None, None)


class TestCli:
    def test_stats_and_clear(self, tmp_path, capsys):
        from repro.encoder.plan_cache import main
        cache = PlanDiskCache(tmp_path)
        for i in range(3):
            _fake_entry(cache, i, mtime=1000.0 + i)
        assert main(["--dir", str(tmp_path), "--stats"]) == 0
        out = capsys.readouterr().out
        assert "entries:     3" in out
        assert main(["--dir", str(tmp_path), "--clear"]) == 0
        out = capsys.readouterr().out
        assert "cleared 3" in out
        assert cache.entries() == []

    def test_disabled_cache_reports_and_fails(self, monkeypatch, capsys):
        from repro.encoder.plan_cache import main
        monkeypatch.setenv("REPRO_PLAN_CACHE", "off")
        assert main(["--stats"]) == 1
        assert "disabled" in capsys.readouterr().out

    def test_module_entrypoint_runs(self, tmp_path):
        env = dict(os.environ)
        src_root = str(Path(next(iter(repro.__path__))).resolve().parent)
        env["PYTHONPATH"] = (src_root + os.pathsep
                             + env.get("PYTHONPATH", ""))
        out = subprocess.run(
            [sys.executable, "-m", "repro.encoder.plan_cache",
             "--dir", str(tmp_path), "--stats"],
            env=env, capture_output=True, text=True, timeout=120)
        assert out.returncode == 0, out.stderr
        assert "entries:     0" in out.stdout
