"""Dry-run machinery tests.

The full 40-cell x 2-mesh sweep runs via ``python -m repro.launch.dryrun
--all [--multi-pod]`` (results in artifacts/ and EXPERIMENTS.md); here we
verify the machinery itself: one real 256-chip cell end-to-end in a
subprocess (cheap arch), mesh construction, collective parsing, and the
depth-probe extrapolation math.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_production_mesh_shapes():
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512'\n"
        "from repro.launch.mesh import make_production_mesh, make_gee_mesh\n"
        "m1 = make_production_mesh()\n"
        "m2 = make_production_mesh(multi_pod=True)\n"
        "m3 = make_gee_mesh(multi_pod=True)\n"
        "assert dict(m1.shape) == {'data': 16, 'model': 16}, m1.shape\n"
        "assert dict(m2.shape) == {'pod': 2, 'data': 16, 'model': 16}\n"
        "assert dict(m3.shape) == {'edges': 512}\n"
        "print('MESH_OK')\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "MESH_OK" in r.stdout


@pytest.mark.slow
def test_one_cell_lowers_and_compiles_256_chips(tmp_path):
    """h2o-danube long_500k: the cheapest real cell; proves lower +
    compile + memory/cost analysis + probe extrapolation end-to-end."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "h2o-danube-3-4b", "--shape", "long_500k"],
        env=env, capture_output=True, text=True, timeout=580,
        cwd=str(tmp_path))
    assert r.returncode == 0, (r.stdout + r.stderr)[-3000:]
    assert "[dryrun] OK" in r.stdout


def test_collective_parsing():
    from repro.launch.roofline import parse_collectives, shape_bytes
    assert shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert shape_bytes("(f32[2,2], bf16[4])") == 24
    hlo = """
  %all-reduce.5 = f32[16,128]{1,0} all-reduce(%x), replica_groups=[2,4]<=[8]
  %ag = bf16[32,64]{1,0} all-gather(%y), dimensions={0}
  %cp.2 = f32[8]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %other = f32[4]{0} add(%a, %b)
"""
    c = parse_collectives(hlo)
    assert c["all-reduce"]["count"] == 1
    assert c["all-reduce"]["wire_bytes"] == 2 * 16 * 128 * 4
    assert c["all-gather"]["bytes"] == 32 * 64 * 2
    assert c["collective-permute"]["count"] == 1


def test_probe_extrapolation_math():
    from repro.launch.analytic import extrapolate
    u = {"flops": 110.0}     # const 10 + 100/unit
    u2 = {"flops": 210.0}
    out = extrapolate(u, u2, n_units=48, tail_units=0.0)
    assert abs(out["flops"] - (10 + 100 * 48)) < 1e-9


def test_probe_units_cover_all_archs():
    from repro.configs import get_config, list_archs
    from repro.launch.analytic import probe_unit
    for arch in list_archs():
        cfg = get_config(arch)
        u, u2, n_units, tail = probe_unit(cfg)
        assert u.n_layers * 2 == u2.n_layers
        # extrapolation must cover every layer of the real config
        if cfg.is_encdec:
            assert n_units == cfg.enc_layers
        elif cfg.xlstm is not None:
            assert n_units * cfg.xlstm.slstm_every == cfg.n_layers
        elif cfg.attn_every:
            per = cfg.attn_every
            assert n_units * per + tail * (per + 1) == cfg.n_layers
        else:
            assert n_units == cfg.n_layers


def test_all_cells_enumerated():
    """40 total cells; long_500k only for sub-quadratic archs."""
    from repro.configs import all_cells, get_config, list_archs
    cells = all_cells()
    assert len(cells) == 33       # 10*3 + 3 long_500k (xlstm/danube/zamba)
    skipped = [(a, s) for a in list_archs()
               for s in get_config(a).skipped_shapes()]
    assert len(cells) + len(skipped) == 40
