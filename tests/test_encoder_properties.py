"""Hypothesis property suite for the encoder: random small graphs x
the in-process single-host backends.

Properties:

* **permutation invariance** — Z depends on the edge MULTISET, not the
  edge order (plans and packings may differ; the answer may not);
* **partial_fit(delta) == fit(base ++ delta)** — GEE linearity, the
  serving delta path's exactness contract (plus sign=-1 as the exact
  inverse);
* **owned-rows concatenation** — fitting each slice of a random
  `RowPartition` with `row_partition=(lo, hi)` and concatenating the
  owned accumulators reproduces the unsharded Z, both from the full
  graph and from the routed sub-multisets a serving shard receives.

Runs only where hypothesis is installed (a dev dependency,
requirements.txt); skipped otherwise, like tests/test_gee_core.py.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, assume, given, settings
from hypothesis import strategies as st

from repro.encoder import Embedder, EncoderConfig
from repro.graph.edges import Graph
from repro.graph.partition import RowPartition

#: the in-process single-host backends — the owned-rows-capable set and
#: the serving hot paths (pallas/distributed conformance lives in
#: test_encoder.py with device-shaped fixed cases)
BACKENDS = ("numpy", "xla", "streaming")

#: tiny graphs, few examples: each example pays a jit compile per new
#: (n, s, K) shape, so the budget goes to case diversity, not repeats
SETTINGS = settings(max_examples=12, deadline=None,
                    suppress_health_check=[HealthCheck.too_slow])


@st.composite
def graph_cases(draw):
    """(Graph, Y, K): tiny random weighted digraph + partial labels
    (self-loops, parallel edges, negative weights, unlabeled nodes all
    reachable).  Sizes are drawn through hypothesis so shrinking
    reduces n/s; array CONTENT comes from a drawn numpy seed —
    hypothesis still controls reproducibility, numpy keeps generation
    fast."""
    n = draw(st.integers(min_value=2, max_value=24))
    s = draw(st.integers(min_value=0, max_value=80))
    K = draw(st.integers(min_value=1, max_value=5))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    r = np.random.default_rng(seed)
    w = (r.uniform(0.25, 2.0, s)
         * r.choice([1.0, -1.0], s, p=[0.85, 0.15]))
    g = Graph(r.integers(0, n, s).astype(np.int32),
              r.integers(0, n, s).astype(np.int32),
              w.astype(np.float32), n)
    Y = r.integers(-1, K, n).astype(np.int32)
    return g, Y, K


def _fit_Z(g, Y, K, backend, row_partition=None):
    emb = Embedder(EncoderConfig(K=K, chunk_size=64,
                                 row_partition=row_partition),
                   backend=backend, plan_cache=None)
    return np.asarray(emb.fit(g, Y).transform())


@given(case=graph_cases(), backend=st.sampled_from(BACKENDS),
       perm_seed=st.integers(0, 2**31 - 1))
@SETTINGS
def test_edge_multiset_permutation_invariance(case, backend, perm_seed):
    g, Y, K = case
    gp = g.permuted(np.random.default_rng(perm_seed))
    np.testing.assert_allclose(_fit_Z(gp, Y, K, backend),
                               _fit_Z(g, Y, K, backend), atol=1e-4)


@given(case=graph_cases(), backend=st.sampled_from(BACKENDS),
       cut_frac=st.floats(0.0, 1.0))
@SETTINGS
def test_partial_fit_equals_full_fit(case, backend, cut_frac):
    g, Y, K = case
    cut = int(round(cut_frac * g.s))
    base = Graph(g.u[:cut], g.v[:cut], g.w[:cut], g.n)
    delta = Graph(g.u[cut:], g.v[cut:], g.w[cut:], g.n)
    emb = Embedder(EncoderConfig(K=K, chunk_size=64), backend=backend,
                   plan_cache=None).fit(base, Y)
    Z_base = np.asarray(emb.transform()).copy()
    emb.partial_fit(delta)
    np.testing.assert_allclose(np.asarray(emb.transform()),
                               _fit_Z(g, Y, K, backend), atol=1e-4)
    emb.partial_fit(delta, sign=-1.0)    # deletion: the exact inverse
    np.testing.assert_allclose(np.asarray(emb.transform()), Z_base,
                               atol=1e-4)


@given(case=graph_cases(), backend=st.sampled_from(BACKENDS),
       p=st.integers(1, 5), routed=st.booleans())
@SETTINGS
def test_owned_rows_concat_equals_unsharded(case, backend, p, routed):
    g, Y, K = case
    try:
        part = RowPartition(g.n, min(p, g.n))
    except ValueError:       # ceil-stride layout empties the last shard
        assume(False)
    full = _fit_Z(g, Y, K, backend)
    subs = dict(part.route_graph(g)) if routed else None
    parts = []
    for i, (lo, hi) in enumerate(part.slices()):
        sub = g if subs is None else subs.get(
            i, Graph(np.zeros(0, np.int32), np.zeros(0, np.int32),
                     np.zeros(0, np.float32), g.n))
        Z = _fit_Z(sub, Y, K, backend, row_partition=(lo, hi))
        assert Z.shape == (hi - lo, K)
        parts.append(Z)
    np.testing.assert_allclose(np.concatenate(parts, 0), full,
                               atol=1e-4)
