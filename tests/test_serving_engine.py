"""Sharded serving engine: row-partition routing, WAL durability and
crash recovery (the acceptance contract: replaying WAL onto the last
snapshot reconstructs the exact (version, epoch, fingerprint) state and
a Z equal to a fresh `gee_streaming` rebuild), sharded scatter/gather
query equivalence for N in {1, 2, 4, 8} over owned-rows-only shard
accumulators, and the async flush loop.  RNG comes from conftest's
`rng` fixture; top-k comparisons use the shared tie-tolerant
`assert_topk_equivalent`."""
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gee import gee_streaming
from repro.graph.edges import make_labels
from repro.graph.generators import erdos_renyi
from repro.graph.partition import RowPartition
from repro.serving import (GraphStore, MicroBatcher, ServingEngine,
                           WriteAheadLog)
from repro.serving import wal as W

SHARD_COUNTS = (1, 2, 4, 8)


def _mkstore(n=240, s=2400, K=5, seed=0, frac=0.4):
    g = erdos_renyi(n, s, seed=seed, weighted=True)
    Y = make_labels(n, K, frac, np.random.default_rng(seed))
    return GraphStore(g, Y, K)


def _rand_batch(rng, n, b):
    return (rng.integers(0, n, b).astype(np.int32),
            rng.integers(0, n, b).astype(np.int32),
            (rng.random(b, dtype=np.float32) + 0.5))


class TestRowPartition:
    @pytest.mark.parametrize("n,p", [(10, 1), (10, 3), (100, 4),
                                     (101, 4), (7, 7)])
    def test_slices_cover_and_agree_with_shard_of(self, n, p):
        part = RowPartition(n, p)
        seen = np.zeros(n, bool)
        for shard in range(p):
            lo, hi = part.slice(shard)
            assert not seen[lo:hi].any()
            seen[lo:hi] = True
            if hi > lo:
                ids = np.arange(lo, hi)
                np.testing.assert_array_equal(part.shard_of(ids), shard)
        assert seen.all()

    def test_invalid_partitions_raise(self):
        with pytest.raises(ValueError):
            RowPartition(10, 0)
        with pytest.raises(ValueError):
            RowPartition(3, 5)
        with pytest.raises(ValueError):   # ceil stride empties shard 4
            RowPartition(8, 5)

    def test_route_edges_fans_out_to_owners_once(self):
        rng = np.random.default_rng(1)
        n, s, p = 50, 400, 3
        u = rng.integers(0, n, s).astype(np.int32)
        v = rng.integers(0, n, s).astype(np.int32)
        w = rng.random(s).astype(np.float32)
        part = RowPartition(n, p)
        su, sv = part.shard_of(u), part.shard_of(v)
        routed = dict(part.route_edges(u, v, w))
        # shard i holds exactly the edges with an endpoint in its rows
        for i in range(p):
            want = (su == i) | (sv == i)
            got = routed.get(i)
            assert got is not None and got[0].shape[0] == want.sum()
            np.testing.assert_array_equal(got[0], u[want])  # order kept
            np.testing.assert_array_equal(got[2], w[want])
        # total copies = 1 for intra-shard edges, 2 for crossing ones
        total = sum(g[0].shape[0] for g in routed.values())
        assert total == s + (su != sv).sum()

    def test_route_nodes_reassembles_in_request_order(self):
        part = RowPartition(30, 3)
        nodes = np.array([29, 0, 15, 1, 29, 10], np.int32)
        out = np.full(nodes.shape[0], -1, np.int64)
        for shard, idx in part.route_nodes(nodes):
            lo, hi = part.slice(shard)
            assert ((nodes[idx] >= lo) & (nodes[idx] < hi)).all()
            out[idx] = nodes[idx]
        np.testing.assert_array_equal(out, nodes)


class TestWal:
    def test_roundtrip(self, tmp_path):
        path = str(tmp_path / "w.log")
        wal = WriteAheadLog(path)
        assert wal.open() == []
        wal.append_edges(1, np.array([1, 2], np.int32),
                         np.array([3, 4], np.int32),
                         np.array([0.5, -1.5], np.float32))
        wal.append_labels(2, np.array([7], np.int64),
                          np.array([0], np.int32))
        wal.append_marker(W.COMPACT, 2)
        wal.append_edges(3, np.zeros(0, np.int32), np.zeros(0, np.int32),
                         np.zeros(0, np.float32))   # empty batches legal
        wal.close()
        recs = list(W.read_wal(path))
        assert [r.kind for r in recs] == [W.EDGES, W.LABELS, W.COMPACT,
                                          W.EDGES]
        assert [r.version for r in recs] == [1, 2, 2, 3]
        np.testing.assert_array_equal(recs[0].a, [1, 2])
        np.testing.assert_allclose(recs[0].c, [0.5, -1.5])
        np.testing.assert_array_equal(recs[1].a, [7])
        assert recs[3].a.shape == (0,)

    def test_torn_tail_is_truncated_and_appendable(self, tmp_path):
        path = str(tmp_path / "w.log")
        wal = WriteAheadLog(path)
        wal.open()
        wal.append_marker(W.REBUILD, 1)
        wal.append_marker(W.REBUILD, 2)
        wal.close()
        good_size = os.path.getsize(path)
        with open(path, "ab") as f:      # crash mid-append
            f.write(b"\x13\x00\x00\x00garbage")
        wal2 = WriteAheadLog(path)
        recs = wal2.open()
        assert [r.version for r in recs] == [1, 2]
        assert os.path.getsize(path) == good_size
        wal2.append_marker(W.REBUILD, 3)
        wal2.close()
        assert [r.version for r in W.read_wal(path)] == [1, 2, 3]

    def test_corrupt_record_stops_replay(self, tmp_path):
        path = str(tmp_path / "w.log")
        wal = WriteAheadLog(path)
        wal.open()
        wal.append_edges(1, np.arange(8, dtype=np.int32),
                         np.arange(8, dtype=np.int32),
                         np.ones(8, np.float32))
        first_end = wal.bytes_written
        wal.append_marker(W.REBUILD, 2)
        wal.close()
        with open(path, "r+b") as f:     # flip a byte inside record 1
            f.seek(first_end - 5)
            b = f.read(1)
            f.seek(first_end - 5)
            f.write(bytes([b[0] ^ 0xFF]))
        recs = list(W.read_wal(path))
        assert recs == []                # CRC catches it; tail dropped

    def test_not_a_wal_raises(self, tmp_path):
        path = tmp_path / "w.log"
        path.write_bytes(b"definitely-not-a-wal-file-here")
        with pytest.raises(ValueError):
            WriteAheadLog(str(path)).open()


class TestShardedEquivalence:
    """Acceptance: sharded scatter/gather answers for N in {1, 2, 4, 8}
    equal the single-shard answers on randomized graphs — with every
    proper sub-range shard holding an owned-rows-only accumulator."""

    @pytest.mark.parametrize("seed", [0, 1])
    def test_queries_match_single_shard(self, seed, rng,
                                        assert_topk_equivalent):
        engines = {p: ServingEngine(_mkstore(seed=seed), num_shards=p)
                   for p in SHARD_COUNTS}
        # mutate every deployment identically: inserts, deletes, labels
        for step in range(4):
            batch = _rand_batch(rng, 240, 60 + step)
            for e in engines.values():
                e.apply_edge_delta(*batch)
            if step == 2:
                for e in engines.values():
                    e.apply_edge_delta(*batch, delete=True)
        nodes = rng.integers(0, 240, 50).astype(np.int32)
        ref = engines[1]
        rows_ref = ref.query_embed(nodes)
        pred_ref, score_ref = ref.query_predict(nodes)
        idx_ref, val_ref = ref.query_topk(nodes, k=7, block_rows=32)
        for p in SHARD_COUNTS[1:]:
            e = engines[p]
            assert e.stats()["num_shards"] == p
            np.testing.assert_allclose(e.query_embed(nodes), rows_ref,
                                       atol=1e-5)
            np.testing.assert_allclose(np.asarray(e.Z),
                                       np.asarray(ref.Z), atol=1e-5)
            pred, score = e.query_predict(nodes)
            np.testing.assert_array_equal(pred, pred_ref)
            np.testing.assert_allclose(score, score_ref, atol=1e-5)
            idx, val = e.query_topk(nodes, k=7, block_rows=32)
            assert_topk_equivalent(idx, val, idx_ref, val_ref)

    def test_shard_accumulators_are_owned_rows_only(self):
        """The tentpole memory contract: a p-shard engine's per-shard
        accumulator is (n_k, K) — O(n/p) — not the full (n, K), and
        stats() reports the bytes so the bench can chart it."""
        for p in SHARD_COUNTS:
            eng = ServingEngine(_mkstore(seed=2), num_shards=p)
            for shard in eng.shards:
                lo, hi = shard.lo, shard.hi
                assert shard.owned_only == (p > 1)
                want_rows = (hi - lo) if p > 1 else 240
                assert shard.embedder.Z_.shape == (want_rows, 5)
            stats = eng.stats()
            peak = stats["peak_shard_accumulator_bytes"]
            assert peak == max(stats["shard_accumulator_bytes"])
            assert peak == -(-240 // p) * 5 * 4     # ceil(n/p)*K*4
            assert eng.Z.shape == (240, 5)

    def test_rebuild_on_label_churn_stays_equivalent(self, rng):
        truth = rng.integers(0, 5, 240, dtype=np.int32)
        engines = {p: ServingEngine(_mkstore(seed=3), num_shards=p,
                                    rebuild_churn=0.1)
                   for p in SHARD_COUNTS}
        many = np.arange(240 // 3)
        for e in engines.values():
            e.apply_label_delta(many, truth[many])
            assert e.epoch == 2           # threshold crossed everywhere
        ref = np.asarray(engines[1].Z)
        for p in SHARD_COUNTS[1:]:
            np.testing.assert_allclose(np.asarray(engines[p].Z), ref,
                                       atol=1e-5)

    def test_topk_self_exclusion_across_shards(self):
        eng = ServingEngine(_mkstore(seed=5), num_shards=4)
        nodes = np.arange(0, 240, 17, dtype=np.int32)
        idx, _ = eng.query_topk(nodes, k=6, block_rows=64)
        for i, q in enumerate(nodes):
            assert q not in idx[i]

    def test_batcher_runs_over_sharded_engine(self, rng):
        eng = ServingEngine(_mkstore(seed=11), num_shards=3)
        mb = MicroBatcher(eng, topk=4, topk_block_rows=64)
        pre = mb.submit("embed", rng.integers(0, 240, 8))
        wt = mb.submit("insert", _rand_batch(rng, 240, 20))
        post = mb.submit("embed", rng.integers(0, 240, 8))
        assert mb.flush() == 3
        assert pre.version == 0 and wt.result() == 1
        assert post.version == 1
        np.testing.assert_allclose(
            post.result(), np.asarray(eng.Z)[np.asarray(post.payload)],
            atol=1e-6)


@pytest.mark.slow
class TestCrashRecovery:
    """Acceptance: kill an engine mid-stream after K applied deltas,
    restart from WAL+snapshot, and the recovered Z equals a fresh
    `gee_streaming` rebuild of the same edge multiset, with exact
    (version, epoch, fingerprint) match."""

    @pytest.mark.parametrize("num_shards", [1, 2])
    def test_recovery_reconstructs_exact_state(self, tmp_path, rng,
                                               num_shards):
        truth = rng.integers(0, 5, 240, dtype=np.int32)
        eng = ServingEngine(_mkstore(seed=8), num_shards=num_shards,
                            data_dir=str(tmp_path / "dep"),
                            rebuild_churn=0.1)
        inserted = []
        for step in range(8):            # K applied deltas mid-stream
            if step % 3 == 2 and inserted:
                eng.apply_edge_delta(*inserted.pop(), delete=True)
            else:
                batch = _rand_batch(rng, 240, int(rng.integers(1, 90)))
                eng.apply_edge_delta(*batch)
                inserted.append(batch)
        few = rng.choice(240, 10, replace=False)     # below threshold
        eng.apply_label_delta(few, truth[few])
        many = rng.choice(240, 120, replace=False)   # forces a rebuild
        eng.apply_label_delta(many, truth[many])
        assert eng.epoch > 1 and eng.stale_labels >= 0
        triple = (eng.version, eng.epoch, eng.fingerprint())
        Z_live = np.asarray(eng.Z)
        # crash: the engine object is abandoned without close/checkpoint
        rec = ServingEngine.open(str(tmp_path / "dep"))
        assert rec.num_shards == num_shards
        assert (rec.version, rec.epoch, rec.fingerprint()) == triple
        np.testing.assert_array_equal(rec.Y_epoch, eng.Y_epoch)
        np.testing.assert_array_equal(rec.store.Y, eng.store.Y)
        # recovered Z == fresh gee_streaming rebuild of the multiset
        g = rec.store.edges()
        Z_ref = gee_streaming([(jnp.asarray(g.u), jnp.asarray(g.v),
                                jnp.asarray(g.w))],
                              jnp.asarray(rec.Y_epoch), K=5, n=g.n)
        np.testing.assert_allclose(np.asarray(rec.Z), np.asarray(Z_ref),
                                   atol=1e-5)
        # ... and tracks the crashed process's delta-maintained Z
        np.testing.assert_allclose(np.asarray(rec.Z), Z_live, atol=1e-3)
        rec.close()

    def test_checkpoint_rotates_generation_and_recovers(self, tmp_path,
                                                       rng):
        d = str(tmp_path / "dep")
        eng = ServingEngine(_mkstore(seed=9), num_shards=2, data_dir=d)
        eng.apply_edge_delta(*_rand_batch(rng, 240, 50))
        info = eng.checkpoint()
        assert info["generation"] == 1 and eng.checkpoints == 1
        assert eng.wal.records_appended == 0     # rotated
        assert not os.path.exists(os.path.join(d, "wal-0.log"))
        eng.apply_edge_delta(*_rand_batch(rng, 240, 30))
        triple = (eng.version, eng.epoch, eng.fingerprint())
        rec = ServingEngine.open(d)              # crash after checkpoint
        assert rec.generation == 1 and rec.checkpoints == 1
        assert (rec.version, rec.epoch, rec.fingerprint()) == triple
        rec.close()

    def test_compact_and_refresh_markers_replay(self, tmp_path, rng):
        d = str(tmp_path / "dep")
        eng = ServingEngine(_mkstore(seed=13), data_dir=d)
        eng.apply_edge_delta(*_rand_batch(rng, 240, 40))
        eng.compact()                    # volatile compaction, marker
        eng.refresh()                    # explicit rebuild, marker
        eng.apply_edge_delta(*_rand_batch(rng, 240, 20))
        triple = (eng.version, eng.epoch, eng.fingerprint())
        rec = ServingEngine.open(d)
        assert (rec.version, rec.epoch, rec.fingerprint()) == triple
        assert rec.rebuilds == eng.rebuilds
        rec.close()

    def test_torn_wal_tail_recovers_prefix(self, tmp_path, rng):
        d = str(tmp_path / "dep")
        eng = ServingEngine(_mkstore(seed=21), data_dir=d)
        eng.apply_edge_delta(*_rand_batch(rng, 240, 25))
        triple = (eng.version, eng.epoch, eng.fingerprint())
        wal_path = os.path.join(d, "wal-0.log")
        with open(wal_path, "ab") as f:  # crash mid-append of the next
            f.write(b"\xff\xff\x00\x00torn")
        rec = ServingEngine.open(d)
        assert (rec.version, rec.epoch, rec.fingerprint()) == triple
        rec.close()

    def test_existing_deployment_refuses_fresh_init(self, tmp_path):
        d = str(tmp_path / "dep")
        ServingEngine(_mkstore(), data_dir=d).close()
        with pytest.raises(FileExistsError):
            ServingEngine(_mkstore(), data_dir=d)

    def test_open_missing_deployment_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            ServingEngine.open(str(tmp_path / "nope"))

    def test_recovered_replica_shares_plan_cache(self, tmp_path, rng):
        """A recovered sharded engine's rebuild must be a persistent
        plan-cache hit: the chained per-shard fingerprints replay to
        the same values the crashed process stored under."""
        d = str(tmp_path / "dep")
        cache = str(tmp_path / "plans")
        eng = ServingEngine(_mkstore(seed=31), num_shards=2,
                            data_dir=d, plan_cache=cache)
        eng.apply_edge_delta(*_rand_batch(rng, 240, 30))
        eng.refresh()                    # store entries for the live
        stats = eng.stats()["plan_stats"]   # multiset's routed halves
        assert stats["disk_stores"] >= 2
        rec = ServingEngine.open(d, plan_cache=cache)
        rstats = rec.stats()["plan_stats"]
        assert rstats["disk_hits"] == 2 and rstats["built"] == 0
        np.testing.assert_allclose(np.asarray(rec.Z),
                                   np.asarray(eng.Z), atol=1e-5)
        rec.close()


class TestAsyncLoop:
    def test_background_flush_serves_submitters(self, rng):
        eng = ServingEngine(_mkstore(seed=55), num_shards=2)
        mb = eng.start(interval=1e-3)
        try:
            tickets = []
            for i in range(6):
                tickets.append(mb.submit("embed",
                                         rng.integers(0, 240, 8)))
                if i == 2:
                    tickets.append(mb.submit(
                        "insert", _rand_batch(rng, 240, 16)))
            values = [t.result(timeout=30) for t in tickets]
            assert all(v is not None for v in values)
            # barrier still holds through the background consumer
            versions = [t.version for t in tickets]
            assert versions == sorted(versions)
        finally:
            eng.stop()
        assert mb.pending() == 0
        with pytest.raises(RuntimeError):   # double-start guarded
            eng.start()
            eng.start()
        eng.stop()

    def test_auto_checkpoint_when_wal_outgrows_budget(self, tmp_path,
                                                      rng):
        eng = ServingEngine(_mkstore(seed=66), data_dir=str(tmp_path),
                            num_shards=2)
        mb = eng.start(interval=1e-3, checkpoint_bytes=64)
        try:
            t = mb.submit("insert", _rand_batch(rng, 240, 32))
            t.result(timeout=30)
            deadline = time.time() + 30
            while eng.generation == 0 and time.time() < deadline:
                time.sleep(0.01)
            assert eng.generation >= 1 and eng.checkpoints >= 1
        finally:
            eng.close()

    def test_checkpoint_requires_durability(self):
        eng = ServingEngine(_mkstore(seed=1))
        with pytest.raises(RuntimeError):
            eng.checkpoint()

    def test_loop_survives_checkpoint_failure(self, tmp_path, rng,
                                              monkeypatch):
        """An engine-level failure in the background consumer (e.g. a
        checkpoint hitting a full disk) must not kill the thread: the
        error is recorded, auto-checkpointing stops, and submitters
        keep being served."""
        eng = ServingEngine(_mkstore(seed=88), data_dir=str(tmp_path))
        boom = OSError("disk full")

        def failing_checkpoint():
            raise boom
        monkeypatch.setattr(eng, "checkpoint", failing_checkpoint)
        mb = eng.start(interval=1e-3, checkpoint_bytes=16)
        try:
            mb.submit("insert", _rand_batch(rng, 240, 8)).result(
                timeout=30)
            deadline = time.time() + 30
            while eng.loop_error is None and time.time() < deadline:
                time.sleep(0.01)
            assert eng.loop_error is boom
            assert "loop_error" in eng.stats()
            # the consumer is still alive and serving
            out = mb.submit("embed", np.array([1, 2])).result(timeout=30)
            assert out.shape == (2, 5)
        finally:
            eng.close()
