"""Observability layer: registry, spans, health, and the instrumented
hot paths' integration with them.

Covers the unit surface (`repro.obs.registry` / `trace` / `health`),
the export surfaces (snapshot, Prometheus text, CLI), the no-op
contract when disabled, and the serving-engine integration: health
state transitions (starting -> serving -> degraded -> serving),
recovery timing after a torn-tail WAL open, per-kind batcher latency
distributions (write barriers INCLUDED — the bug this PR fixed), and
`stats()` atomicity under a concurrent writer.
"""
from __future__ import annotations

import json
import os
import re
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs.registry import bucket_index, bucket_upper
from repro.obs.health import (DEGRADED, SERVING, STARTING, STATE_VALUES,
                              HealthTracker)
from repro.obs.trace import render_tree
from repro.graph.edges import make_labels
from repro.graph.generators import sbm
from repro.serving.batcher import MicroBatcher
from repro.serving.engine import ServingEngine
from repro.serving.store import GraphStore


@pytest.fixture
def clean_obs():
    """Enabled layer with empty registry/ring; restores defaults."""
    obs.configure(enabled=True, trace_path="")
    obs.reset()
    yield
    obs.configure(enabled=True, trace_path="")
    obs.reset()


def _small_engine(rng, *, shards=2, n=60, data_dir=None, **kw):
    g, truth = sbm(n, 3, 600, p_in=0.85, seed=int(rng.integers(1 << 31)))
    Y = make_labels(n, 3, 0.5, rng, true_labels=truth)
    eng = ServingEngine(GraphStore(g, Y, 3), num_shards=shards,
                        data_dir=data_dir, **kw)
    return eng, truth


# -- registry ----------------------------------------------------------------

class TestRegistry:
    def test_counter_gauge_roundtrip(self, clean_obs):
        r = obs.registry()
        r.counter("repro_test_events_total", 2, kind="a")
        r.counter("repro_test_events_total", kind="a")
        r.counter("repro_test_events_total", kind="b")
        r.gauge("repro_test_rate_value", 7.5)
        assert r.counter_value("repro_test_events_total", kind="a") == 3
        assert r.counter_value("repro_test_events_total", kind="b") == 1
        assert r.counter_value("repro_test_events_total", kind="zz") == 0
        assert r.gauge_value("repro_test_rate_value") == 7.5
        snap = r.snapshot()
        assert snap["counters"]['repro_test_events_total{kind="a"}'] == 3
        assert snap["gauges"]["repro_test_rate_value"] == 7.5

    def test_name_scheme_enforced(self, clean_obs):
        r = obs.registry()
        for bad in ("plain", "repro_single", "Repro_x_y", "repro_x_Y",
                    "repro_x-y_z", "other_sub_metric"):
            assert not obs.valid_metric_name(bad)
            with pytest.raises(ValueError):
                r.counter(bad)
        assert obs.valid_metric_name("repro_serving_wal_append_seconds")

    def test_histogram_summary(self, clean_obs):
        r = obs.registry()
        vals = [0.001] * 98 + [0.5, 1.0]
        for v in vals:
            r.observe("repro_test_lat_seconds", v)
        s = r.hist_summary("repro_test_lat_seconds")
        assert s["count"] == 100
        assert s["sum"] == pytest.approx(sum(vals))
        assert s["min"] == pytest.approx(0.001)
        assert s["max"] == pytest.approx(1.0)
        # log2 buckets over-estimate by at most 2x, clamped to max
        assert 0.001 <= s["p50"] <= 0.002
        assert 0.5 <= s["p99"] <= 1.0            # within the 2x bound

    def test_bucket_layout(self):
        assert bucket_index(0.0) == 0
        assert bucket_index(1e-6) == 0
        assert bucket_index(1e300) == 63
        for v in (1e-5, 3e-3, 0.7, 42.0):
            i = bucket_index(v)
            assert v <= bucket_upper(i)
            assert i == 0 or v > bucket_upper(i - 1)

    def test_thread_safety_exact_counts(self, clean_obs):
        r = obs.registry()

        def hammer():
            for _ in range(1000):
                r.counter("repro_test_race_total")
                r.observe("repro_test_race_seconds", 1e-3)
        threads = [threading.Thread(target=hammer) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert r.counter_value("repro_test_race_total") == 8000
        assert r.hist_summary("repro_test_race_seconds")["count"] == 8000

    def test_prometheus_rendering(self, clean_obs):
        r = obs.registry()
        r.counter("repro_test_events_total", 3, kind="x")
        r.gauge("repro_test_rate_value", 2.0)
        for v in (1e-4, 1e-4, 0.3):
            r.observe("repro_test_lat_seconds", v)
        text = r.render_prometheus()
        assert "# TYPE repro_test_events_total counter" in text
        assert 'repro_test_events_total{kind="x"} 3' in text
        assert "# TYPE repro_test_lat_seconds histogram" in text
        assert 'repro_test_lat_seconds_bucket{le="+Inf"} 3' in text
        assert "repro_test_lat_seconds_count 3" in text
        # every sample line parses as  name{labels}? value
        sample = re.compile(
            r'^[a-z0-9_]+(\{[a-z0-9_]+="[^"]*"'
            r'(,[a-z0-9_]+="[^"]*")*\})? \S+$')
        for line in text.strip().splitlines():
            if line.startswith("#"):
                continue
            assert sample.match(line), line
        # cumulative bucket counts are non-decreasing and end at count
        cum = [int(ln.rsplit(" ", 1)[1])
               for ln in text.splitlines()
               if ln.startswith("repro_test_lat_seconds_bucket")]
        assert cum == sorted(cum) and cum[-1] == 3

    def test_summarize_pretty(self, clean_obs):
        obs.counter("repro_test_events_total")
        obs.observe("repro_test_lat_seconds", 0.01)
        out = obs.summarize(obs.snapshot())
        assert "repro_test_events_total" in out
        assert "p95" in out


# -- spans / tracing ---------------------------------------------------------

class TestSpans:
    def test_parent_links_and_attrs(self, clean_obs):
        with obs.span("outer", job="x") as so:
            with obs.span("inner") as si:
                si.set(rows=4)
        events = obs.trace_events()
        assert [e["name"] for e in events] == ["inner", "outer"]
        inner, outer = events
        assert inner["parent"] == outer["id"]
        assert outer["parent"] is None
        assert outer["attrs"] == {"job": "x"}
        assert inner["attrs"] == {"rows": 4}
        assert so.duration >= si.duration >= 0.0

    def test_error_capture(self, clean_obs):
        with pytest.raises(RuntimeError):
            with obs.span("boom"):
                raise RuntimeError("nope")
        (event,) = obs.trace_events()
        assert "nope" in event["error"]

    def test_metric_mirror(self, clean_obs):
        with obs.span("timed", metric="repro_test_span_seconds",
                      mlabels={"backend": "b"}):
            pass
        s = obs.registry().hist_summary("repro_test_span_seconds",
                                        backend="b")
        assert s["count"] == 1

    def test_ring_bounded(self, clean_obs):
        obs.configure(ring=8)
        try:
            for i in range(50):
                with obs.span(f"s{i}"):
                    pass
            events = obs.trace_events()
            assert len(events) == 8
            assert events[-1]["name"] == "s49"   # newest wins
        finally:
            obs.configure(ring=4096)

    def test_jsonl_sink_and_replay(self, clean_obs, tmp_path):
        path = str(tmp_path / "trace.jsonl")
        obs.configure(trace_path=path)
        with obs.span("parent"):
            with obs.span("child", shard=1):
                pass
        obs.configure(trace_path="")
        events = obs.load_jsonl(path)
        assert len(events) == 2
        for line in open(path):
            json.loads(line)                     # every line valid JSON
        tree = render_tree(events)
        lines = tree.splitlines()
        assert lines[0].startswith("- parent")
        assert lines[1].startswith("  - child")  # indented under parent
        assert "shard=1" in lines[1]

    def test_orphan_renders_as_root(self, clean_obs):
        tree = render_tree([{"name": "lost", "id": 7, "parent": 99,
                             "t0": 1.0, "dur_s": 0.0}])
        assert tree.startswith("- lost")


# -- the disabled path -------------------------------------------------------

class TestDisabled:
    def test_true_noop(self, clean_obs):
        obs.configure(enabled=False)
        assert obs.tick() == 0.0 and obs.tock(0.0) == 0.0
        obs.counter("repro_test_events_total")
        obs.gauge("repro_test_rate_value", 1)
        obs.observe("repro_test_lat_seconds", 1)
        sp = obs.span("nothing", metric="repro_test_span_seconds")
        with sp as s:
            assert s.fence(123) == 123           # passes through, no block
        assert sp.duration == 0.0
        assert not obs.registry().series_names()
        assert not obs.trace_events()
        assert obs.snapshot()["enabled"] is False

    def test_fit_emits_nothing_when_off(self, clean_obs, rng):
        obs.configure(enabled=False)
        eng, _ = _small_engine(rng, shards=1)
        eng.query_embed([0, 1])
        eng.close()
        assert not obs.registry().series_names()


# -- health state machine ----------------------------------------------------

class TestHealth:
    def test_transitions_and_export(self, clean_obs):
        h = HealthTracker("test")
        assert h.state == STARTING
        assert obs.registry().gauge_value("repro_test_health_state") \
            == STATE_VALUES[STARTING]
        assert h.to(SERVING) is True
        assert h.to(SERVING) is False            # idempotent
        assert h.to(DEGRADED, reason="disk") is True
        assert h.as_dict()["reason"] == "disk"
        assert obs.registry().counter_value(
            "repro_test_health_transitions_total", to=DEGRADED) == 1
        assert obs.registry().gauge_value("repro_test_health_state") \
            == STATE_VALUES[DEGRADED]
        h.to(SERVING)
        assert "reason" not in h.as_dict()

    def test_engine_serving_on_boot(self, clean_obs, rng):
        eng, _ = _small_engine(rng)
        assert eng.health()["state"] == SERVING
        eng.close()

    def test_engine_degrades_on_loop_error_and_recovers(self, clean_obs,
                                                        rng):
        eng, _ = _small_engine(rng)
        eng.loop_error = RuntimeError("checkpoint failed")
        h = eng.health()
        assert h["state"] == DEGRADED
        assert "checkpoint failed" in h["reason"]
        assert eng.stats()["health"]["state"] == DEGRADED
        eng.loop_error = None                    # fault cleared
        assert eng.health()["state"] == SERVING  # re-evaluated, not latched
        eng.close()

    def test_engine_degrades_on_slow_wal_append(self, clean_obs, rng,
                                                tmp_path):
        eng, _ = _small_engine(rng, data_dir=str(tmp_path / "d"),
                               degraded_append_s=0.05)
        eng.apply_edge_delta(np.array([0], np.int32),
                             np.array([1], np.int32),
                             np.ones(1, np.float32))
        assert eng.health()["state"] == SERVING  # a local append is fast
        eng.wal.last_append_seconds = 0.2        # simulated slow disk
        h = eng.health()
        assert h["state"] == DEGRADED and "wal append" in h["reason"]
        eng.wal.last_append_seconds = 1e-4
        assert eng.health()["state"] == SERVING
        eng.close()


# -- recovery timing (torn-tail WAL, as in test_wal_fuzz) --------------------

@pytest.mark.slow
def test_recovery_timed_after_torn_tail(clean_obs, rng, tmp_path):
    d = str(tmp_path / "dep")
    eng, _ = _small_engine(rng, data_dir=d)
    for _ in range(4):
        b = int(rng.integers(2, 20))
        eng.apply_edge_delta(rng.integers(0, 60, b).astype(np.int32),
                             rng.integers(0, 60, b).astype(np.int32),
                             rng.random(b).astype(np.float32) + 0.5)
    eng.close()
    wal_path = os.path.join(d, "wal-0.log")
    blob = open(wal_path, "rb").read()
    with open(wal_path, "wb") as f:              # crash mid-append
        f.write(blob[:len(blob) - 3])
    obs.reset()
    rec = ServingEngine.open(d)
    try:
        assert rec.health()["state"] == SERVING
        s = obs.registry().hist_summary("repro_serving_recovery_seconds")
        assert s["count"] == 1 and s["sum"] > 0.0
        assert obs.registry().counter_value(
            "repro_serving_recovery_replayed_total") == 3  # 4 - torn one
        names = [e["name"] for e in obs.trace_events()]
        assert "serving.recovery" in names
        assert "serving.rebuild" in names        # nested child ran
    finally:
        rec.close()


# -- batcher latency accounting (the satellite fix) --------------------------

class TestBatcherAccounting:
    def test_every_kind_lands_in_latency_histogram(self, clean_obs, rng):
        eng, truth = _small_engine(rng)
        mb = MicroBatcher(eng, topk=3)
        counts = {"embed": 3, "predict": 2, "topk": 2, "insert": 2,
                  "delete": 1, "labels": 1}
        for _ in range(counts["embed"]):
            mb.submit("embed", rng.integers(0, 60, 5))
        for _ in range(counts["predict"]):
            mb.submit("predict", rng.integers(0, 60, 5))
        for _ in range(counts["topk"]):
            mb.submit("topk", rng.integers(0, 60, 5))
        batch = (np.array([1, 2], np.int32), np.array([3, 4], np.int32),
                 np.ones(2, np.float32))
        for _ in range(counts["insert"]):
            mb.submit("insert", batch)
        mb.submit("delete", batch)
        mb.submit("labels", (np.arange(5), truth[:5]))
        served = mb.flush()
        assert served == sum(counts.values())
        # the distribution's count equals the submit count PER KIND —
        # write barriers are first-class citizens of the latency stats
        for kind, want in counts.items():
            s = obs.registry().hist_summary(
                "repro_serving_batcher_ticket_seconds", kind=kind)
            assert s["count"] == want, kind
            assert obs.registry().counter_value(
                "repro_serving_batcher_requests_total", kind=kind) == want
        eng.close()

    def test_failed_tickets_still_counted(self, clean_obs, rng):
        eng, _ = _small_engine(rng)
        mb = MicroBatcher(eng)
        t_bad = mb.submit("embed", np.array([10_000]))   # out of range
        t_ok = mb.submit("embed", np.array([0]))
        mb.flush()
        with pytest.raises(IndexError):
            t_bad.result(timeout=5)
        t_ok.result(timeout=5)
        s = obs.registry().hist_summary(
            "repro_serving_batcher_ticket_seconds", kind="embed")
        assert s["count"] == 2                   # errors are latencies too
        assert obs.registry().counter_value(
            "repro_serving_batcher_errors_total", kind="embed") == 1
        eng.close()


# -- stats() atomicity -------------------------------------------------------

def test_stats_atomic_under_concurrent_writes(clean_obs, rng):
    eng, truth = _small_engine(rng, shards=2)
    stop = threading.Event()
    errors = []

    def writer():
        try:
            i = 0
            while not stop.is_set():
                b = 4
                eng.apply_edge_delta(
                    np.arange(b, dtype=np.int32) % 60,
                    (np.arange(b, dtype=np.int32) + 1) % 60,
                    np.ones(b, np.float32))
                if i % 7 == 0:
                    eng.refresh()                # epoch also moves
                i += 1
        except Exception as e:                   # pragma: no cover
            errors.append(e)

    th = threading.Thread(target=writer)
    th.start()
    try:
        last_version = last_epoch = -1
        for _ in range(60):
            st = eng.stats()
            # lock-consistent snapshot: monotone counters, never torn
            assert st["version"] >= last_version
            assert st["epoch"] >= last_epoch
            assert st["deltas_applied"] >= 0
            assert st["health"]["state"] == SERVING
            assert st["metrics"]["enabled"] is True
            last_version, last_epoch = st["version"], st["epoch"]
    finally:
        stop.set()
        th.join()
        eng.close()
    assert not errors


# -- registry-backed engine stats / plan-cache counters ----------------------

def test_engine_stats_mirror_registry(clean_obs, rng, tmp_path):
    eng, truth = _small_engine(rng, data_dir=str(tmp_path / "d"))
    eng.apply_edge_delta(np.array([5], np.int32), np.array([6], np.int32),
                         np.ones(1, np.float32))
    eng.apply_label_delta(np.arange(3), truth[:3])
    eng.query_embed([0, 1, 2])
    eng.query_predict([3])
    eng.query_topk([4], k=2)
    eng.checkpoint()
    st = eng.stats()
    m = st["metrics"]
    assert m["counters"]["repro_serving_wal_records_total"
                         '{kind="edges"}'] == 1
    assert m["counters"]["repro_serving_delta_edges_total"] == 1
    assert m["counters"]['repro_serving_queries_total{kind="topk"}'] == 1
    assert m["counters"]["repro_serving_checkpoints_total"] == 1
    assert m["histograms"]["repro_serving_checkpoint_seconds"]["count"] \
        == 1
    # every shard reported its accumulator gauge (the owned-rows
    # memory contract as a live series)
    shard_gauges = [k for k in m["gauges"]
                    if k.startswith("repro_serving_shard_accumulator")]
    assert len(shard_gauges) == eng.num_shards
    # plan-cache events mirror the shards' identity-tier counters
    hits = obs.registry().counter_value(
        "repro_encoder_plan_cache_total", event="tier1_hit")
    built = obs.registry().counter_value(
        "repro_encoder_plan_cache_total", event="built")
    shard_plan = st["plan_stats"]
    assert built == shard_plan["built"] > 0
    assert hits == shard_plan["hits"]
    eng.close()


# -- CLI ---------------------------------------------------------------------

@pytest.mark.slow
def test_cli_snapshot_and_trace_replay(tmp_path):
    src_root = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "src")
    env = dict(os.environ,
               PYTHONPATH=src_root + os.pathsep + os.environ.get(
                   "PYTHONPATH", ""))
    trace = str(tmp_path / "demo.jsonl")
    out = subprocess.run(
        [sys.executable, "-m", "repro.obs", "--snapshot", "--json",
         "--trace-out", trace],
        capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr
    snap = json.loads(out.stdout)
    series = (list(snap["counters"]) + list(snap["gauges"])
              + list(snap["histograms"]))
    for family in ("repro_serving_wal_", "repro_encoder_plan_cache",
                   "repro_serving_shard_", "repro_serving_batcher_",
                   "repro_kernel_"):
        assert any(family in s for s in series), family
    # replay the JSONL trace the demo wrote: parent-linked span tree
    replay = subprocess.run(
        [sys.executable, "-m", "repro.obs", "--trace", trace],
        capture_output=True, text=True, env=env, timeout=120)
    assert replay.returncode == 0, replay.stderr
    assert "- obs.demo" in replay.stdout
    assert "  - serving.rebuild" in replay.stdout   # indented child
