"""Online serving subsystem: the first stateful correctness surface —
delta-maintained Z must track a from-scratch rebuild through arbitrary
insert/delete/compaction histories (GEE linearity made load-bearing),
plus query kernels and microbatcher semantics."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gee import gee, gee_apply_delta, gee_streaming, make_w
from repro.graph.edges import make_labels
from repro.graph.generators import erdos_renyi, sbm
from repro.serving.batcher import MicroBatcher
from repro.serving.queries import (class_centroids, gather_embeddings,
                                   predict_labels, topk_cosine)
from repro.serving.service import EmbeddingService
from repro.serving.store import GraphStore


def _jax_gee(g, Y, K):
    return np.asarray(gee(jnp.asarray(g.u), jnp.asarray(g.v),
                          jnp.asarray(g.w), jnp.asarray(Y), K=K, n=g.n))


def _setup(n=120, s=600, K=5, seed=0, frac=0.4):
    g = erdos_renyi(n, s, seed=seed, weighted=True)
    Y = make_labels(n, K, frac, np.random.default_rng(seed))
    return g, Y


def _rand_batch(rng, n, b):
    return (rng.integers(0, n, b).astype(np.int32),
            rng.integers(0, n, b).astype(np.int32),
            (rng.random(b, dtype=np.float32) + 0.5))


class TestIncrementalEquivalence:
    @pytest.mark.parametrize("seed", range(5))
    def test_streaming_over_chunks_equals_oneshot(self, seed):
        """Property: gee_streaming over arbitrary chunkings == gee."""
        rng = np.random.default_rng(seed)
        g, Y = _setup(seed=seed, s=int(rng.integers(200, 800)))
        Yj = jnp.asarray(Y)
        cuts = np.sort(rng.integers(0, g.s, size=3))
        bounds = [0, *cuts.tolist(), g.s]
        chunks = [(jnp.asarray(g.u[a:b]), jnp.asarray(g.v[a:b]),
                   jnp.asarray(g.w[a:b]))
                  for a, b in zip(bounds[:-1], bounds[1:])]
        Z = gee_streaming(chunks, Yj, K=5, n=g.n)
        np.testing.assert_allclose(np.asarray(Z), _jax_gee(g, Y, 5),
                                   atol=1e-5)

    @pytest.mark.parametrize("seed", range(5))
    def test_insert_then_delete_roundtrips(self, seed):
        """Property: applying a delta with sign=+1 then sign=-1 restores
        the original Z (random weighted digraphs)."""
        rng = np.random.default_rng(100 + seed)
        g, Y = _setup(seed=seed)
        Yj = jnp.asarray(Y)
        Wv = make_w(Yj, 5)
        Z0 = jnp.asarray(_jax_gee(g, Y, 5))
        du, dv, dw = _rand_batch(rng, g.n, int(rng.integers(1, 200)))
        du, dv, dw = jnp.asarray(du), jnp.asarray(dv), jnp.asarray(dw)
        Z1 = gee_apply_delta(Z0, du, dv, dw, Yj, Wv, K=5)
        assert float(jnp.abs(Z1 - Z0).max()) > 0    # delta did something
        Z2 = gee_apply_delta(Z1, du, dv, dw, Yj, Wv, K=5, sign=-1.0)
        np.testing.assert_allclose(np.asarray(Z2), np.asarray(Z0),
                                   atol=1e-4)

    def test_randomized_ops_match_scratch_rebuild(self):
        """Acceptance: after a randomized sequence of edge inserts,
        deletes, and a mid-sequence compaction, the delta-maintained Z
        equals a from-scratch gee over the live multiset."""
        rng = np.random.default_rng(7)
        g, Y = _setup(seed=7)
        service = EmbeddingService(GraphStore(g, Y, 5))
        inserted = []
        versions = [service.version]
        for step in range(14):
            op = rng.random()
            if op < 0.55 or not inserted:
                batch = _rand_batch(rng, g.n, int(rng.integers(0, 120)))
                service.apply_edge_delta(*batch)
                inserted.append(batch)
            else:
                batch = inserted.pop(int(rng.integers(0, len(inserted))))
                service.apply_edge_delta(*batch, delete=True)
            versions.append(service.version)
            if step == 6:
                service.compact()
                assert service.store.log_edges == 0
        assert versions == sorted(versions) and len(set(versions)) == 15
        live = service.store.edges()
        np.testing.assert_allclose(
            np.asarray(service.Z), _jax_gee(live, service.Y_epoch, 5),
            atol=1e-4)

    def test_empty_delta_batches_are_legal(self):
        g, Y = _setup(seed=3)
        service = EmbeddingService(GraphStore(g, Y, 5))
        Z0 = np.asarray(service.Z)
        empty = (np.zeros(0, np.int32), np.zeros(0, np.int32),
                 np.zeros(0, np.float32))
        v1 = service.apply_edge_delta(*empty)
        v2 = service.apply_edge_delta(*empty, delete=True)
        assert (v1, v2) == (1, 2)
        np.testing.assert_array_equal(np.asarray(service.Z), Z0)


class TestEpochPolicy:
    def test_label_churn_threshold_gates_rebuild(self):
        g, Y = _setup(seed=11, frac=0.5)
        truth = np.random.default_rng(11).integers(0, 5, g.n,
                                                   dtype=np.int32)
        service = EmbeddingService(GraphStore(g, Y, 5),
                                   rebuild_churn=0.10)
        assert service.epoch == 1
        # flip 2% of nodes: below threshold -> same epoch, Z untouched
        few = np.arange(2)
        Z0 = np.asarray(service.Z)
        service.apply_label_delta(few, (Y[few] + 1) % 5)
        assert service.epoch == 1 and service.stale_labels > 0
        np.testing.assert_array_equal(np.asarray(service.Z), Z0)
        # flip 20%: rebuild under current labels, fresh epoch, no staleness
        many = np.arange(g.n // 5)
        service.apply_label_delta(many, truth[many])
        assert service.epoch == 2 and service.stale_labels == 0
        np.testing.assert_allclose(
            np.asarray(service.Z), _jax_gee(g, service.store.Y, 5),
            atol=1e-5)

    def test_compaction_coalesces_and_preserves_embedding(self):
        g, Y = _setup(seed=13)
        service = EmbeddingService(GraphStore(g, Y, 5))
        dup = (g.u[:50], g.v[:50], g.w[:50])
        service.apply_edge_delta(*dup)              # parallel duplicates
        service.apply_edge_delta(*dup, delete=True)  # ...and cancel them
        Z_before = np.asarray(service.Z)
        info = service.compact()
        assert info["edges_after"] <= info["edges_before"]
        base = service.store.base
        assert np.abs(base.w).min() > 0             # no ~zero survivors
        # coalesced: (u, v) keys unique
        key = base.u.astype(np.int64) * base.n + base.v
        assert np.unique(key).shape[0] == key.shape[0]
        np.testing.assert_allclose(np.asarray(service.Z), Z_before,
                                   atol=1e-4)

    def test_snapshot_roundtrip(self, tmp_path):
        rng = np.random.default_rng(17)
        g, Y = _setup(seed=17)
        service = EmbeddingService(GraphStore(g, Y, 5))
        service.apply_edge_delta(*_rand_batch(rng, g.n, 80))
        prefix = str(tmp_path / "snap")
        service.store.snapshot(prefix)
        store2 = GraphStore.load(prefix)
        assert store2.version == service.store.version
        assert store2.K == 5 and store2.log_edges == 0
        np.testing.assert_array_equal(store2.Y, service.store.Y)
        service2 = EmbeddingService(store2)
        np.testing.assert_allclose(np.asarray(service2.Z),
                                   np.asarray(service.Z), atol=1e-4)


class TestQueries:
    def test_topk_cosine_matches_dense(self):
        g, Y = _setup(n=90, s=700, seed=19, frac=0.6)
        Z = jnp.asarray(_jax_gee(g, Y, 5))
        q = np.array([3, 10, 40, 77], np.int32)
        # small block_rows forces multi-block merging + tail padding
        idx, val = topk_cosine(Z, q, k=6, block_rows=32)
        Zn = np.asarray(Z)
        Zn = Zn / np.maximum(np.linalg.norm(Zn, axis=1, keepdims=True),
                             1e-9)
        sims = Zn[q] @ Zn.T
        sims[np.arange(len(q)), q] = -np.inf        # exclude_self
        for i in range(len(q)):
            assert q[i] not in idx[i]
            ref = np.sort(sims[i])[::-1][:6]
            np.testing.assert_allclose(np.sort(val[i])[::-1], ref,
                                       atol=1e-5)
            np.testing.assert_allclose(sims[i][idx[i]], val[i], atol=1e-5)

    def test_centroid_prediction_recovers_sbm_blocks(self):
        g, truth = sbm(300, 4, 6000, p_in=0.9, seed=23)
        Y = make_labels(300, 4, 0.2, np.random.default_rng(23),
                        true_labels=truth)
        Z = jnp.asarray(_jax_gee(g, Y, 4))
        cent = class_centroids(Z, jnp.asarray(Y), K=4)
        nodes = np.arange(300, dtype=np.int32)
        pred, score = predict_labels(Z, cent, jnp.asarray(nodes))
        acc = (np.asarray(pred) == truth).mean()
        assert acc > 0.8, acc
        assert np.asarray(score).max() <= 1.0 + 1e-5

    def test_gather(self):
        g, Y = _setup(seed=29)
        Z = jnp.asarray(_jax_gee(g, Y, 5))
        nodes = jnp.asarray(np.array([5, 5, 0, 119], np.int32))
        out = np.asarray(gather_embeddings(Z, nodes))
        np.testing.assert_array_equal(out, np.asarray(Z)[[5, 5, 0, 119]])


class TestBatcher:
    def test_reads_coalesce_and_writes_are_barriers(self):
        rng = np.random.default_rng(31)
        g, Y = _setup(seed=31)
        service = EmbeddingService(GraphStore(g, Y, 5))
        batcher = MicroBatcher(service, topk=4)
        pre = [batcher.submit("embed", rng.integers(0, g.n, 8))
               for _ in range(3)]
        wt = batcher.submit("insert", _rand_batch(rng, g.n, 30))
        post = [batcher.submit("embed", rng.integers(0, g.n, 8))
                for _ in range(2)]
        served = batcher.flush()
        assert served == 6
        # barrier semantics: pre-write reads saw version 0, the write
        # bumped it to 1, post-write reads saw 1
        assert {t.version for t in pre} == {0}
        assert wt.result() == 1 and wt.version == 1
        assert {t.version for t in post} == {1}
        # coalescing: 5 embed requests served in exactly 2 kernel batches
        st = batcher.stats()
        assert st["embed"]["requests"] == 5
        assert st["embed"]["batches"] == 2
        assert st["embed"]["items"] == 40
        # results correct per-ticket (post-write tickets see updated Z)
        Z = np.asarray(service.Z)
        for t in post:
            np.testing.assert_allclose(
                t.result(), Z[np.asarray(t.payload)], atol=1e-6)

    def test_mixed_read_kinds_one_batch_each(self):
        g, truth = sbm(200, 4, 3000, p_in=0.9, seed=37)
        Y = make_labels(200, 4, 0.3, np.random.default_rng(37),
                        true_labels=truth)
        service = EmbeddingService(GraphStore(g, Y, 4))
        batcher = MicroBatcher(service, topk=3, topk_block_rows=64)
        te = batcher.submit("embed", np.array([1, 2, 3]))
        tp = batcher.submit("predict", np.array([4, 5]))
        tt = batcher.submit("topk", np.array([6]))
        tl = batcher.submit("labels",
                            (np.array([0, 1]), truth[:2]))
        batcher.flush()
        assert te.result().shape == (3, 4)
        pred, score = tp.result()
        assert pred.shape == (2,) and score.shape == (2,)
        idx, val = tt.result()
        assert idx.shape == (1, 3) and 6 not in idx[0]
        assert tl.result() == service.version
        st = batcher.stats()
        assert all(st[k]["batches"] == 1
                   for k in ("embed", "predict", "topk", "labels"))

    def test_bad_requests_fail_their_ticket_not_the_queue(self):
        """A poisoned request must not hang or poison the flush: the
        error lands on its own ticket, everything else is served."""
        rng = np.random.default_rng(41)
        g, Y = _setup(seed=41)
        service = EmbeddingService(GraphStore(g, Y, 5))
        batcher = MicroBatcher(service)
        bad_read = batcher.submit("embed", np.array([g.n + 7]))
        good_read = batcher.submit("embed", np.array([1, 2]))
        bad_write = batcher.submit(
            "insert", (np.array([g.n + 1], np.int32),
                       np.array([0], np.int32), np.ones(1, np.float32)))
        good_write = batcher.submit("insert", _rand_batch(rng, g.n, 10))
        tail_read = batcher.submit("embed", np.array([3]))
        served = batcher.flush()
        assert served == 5 and batcher.pending() == 0
        with pytest.raises(IndexError):
            bad_read.result(timeout=1)
        with pytest.raises(AssertionError):
            bad_write.result(timeout=1)
        # out-of-range reads were rejected, not clamped to row n-1
        np.testing.assert_allclose(
            good_read.result(timeout=1),
            np.asarray(service.Z)[[1, 2]], atol=1e-6)
        # the failed write did not bump the version; the good one did
        assert good_write.result(timeout=1) == 1
        assert tail_read.result(timeout=1).shape == (1, 5)
        st = batcher.stats()
        assert st["embed"]["errors"] == 1
        assert st["insert"]["errors"] == 1
        assert st["embed"]["items_per_s"] > 0

    def test_interleaved_kinds_keep_barrier_order(self):
        """Barrier ordering with every read kind interleaved between
        writes: each read window observes exactly the version produced
        by the writes before it, and writes apply in submission order."""
        rng = np.random.default_rng(43)
        g, truth = sbm(200, 4, 3000, p_in=0.9, seed=43)
        Y = make_labels(200, 4, 0.3, np.random.default_rng(43),
                        true_labels=truth)
        service = EmbeddingService(GraphStore(g, Y, 4))
        batcher = MicroBatcher(service, topk=3, topk_block_rows=64)
        r0 = batcher.submit("embed", np.array([1, 2]))
        w0 = batcher.submit("insert", _rand_batch(rng, 200, 10))
        r1a = batcher.submit("predict", np.array([3]))
        r1b = batcher.submit("topk", np.array([4]))
        w1 = batcher.submit("labels", (np.array([0]), truth[:1]))
        w2 = batcher.submit("delete", _rand_batch(rng, 200, 5))
        r3 = batcher.submit("embed", np.array([5]))
        assert batcher.flush() == 7
        assert r0.version == 0
        assert w0.result() == 1
        assert {r1a.version, r1b.version} == {1}
        assert (w1.result(), w2.result()) == (2, 3)
        assert r3.version == 3
        # reads between two writes form ONE window: one batch per kind
        st = batcher.stats()
        assert st["predict"]["batches"] == 1
        assert st["topk"]["batches"] == 1
        assert st["embed"]["batches"] == 2      # split by the barrier

    def test_empty_flush_is_a_noop(self):
        g, Y = _setup(seed=47)
        batcher = MicroBatcher(EmbeddingService(GraphStore(g, Y, 5)))
        assert batcher.flush() == 0
        assert batcher.pending() == 0
        assert batcher.stats() == {}            # no phantom kinds

    def test_stats_after_exception_in_read_handler(self, monkeypatch):
        """A kernel-side failure (not a bad request) fails every ticket
        in the coalesced batch, counts one batch with zero items, and
        leaves the batcher serviceable."""
        g, Y = _setup(seed=53)
        service = EmbeddingService(GraphStore(g, Y, 5))
        batcher = MicroBatcher(service)
        boom = RuntimeError("kernel exploded")

        def broken(nodes, **kw):
            raise boom
        monkeypatch.setattr(service, "query_topk", broken)
        t1 = batcher.submit("topk", np.array([1]))
        t2 = batcher.submit("topk", np.array([2, 3]))
        ok = batcher.submit("embed", np.array([4]))
        assert batcher.flush() == 3
        for t in (t1, t2):
            with pytest.raises(RuntimeError):
                t.result(timeout=1)
        assert ok.result(timeout=1).shape == (1, 5)
        st = batcher.stats()
        assert st["topk"]["errors"] == 2
        assert st["topk"]["batches"] == 1
        assert st["topk"]["items"] == 0
        assert st["topk"]["items_per_s"] == 0.0
        assert st["embed"]["errors"] == 0
        # the failure poisoned nothing: the next flush serves normally
        monkeypatch.undo()
        t3 = batcher.submit("topk", np.array([1]))
        batcher.flush()
        idx, val = t3.result(timeout=1)
        assert idx.shape == (1, batcher.topk) and 1 not in idx[0]
        assert batcher.stats()["topk"]["batches"] == 2
