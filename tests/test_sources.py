"""GraphSource protocol + registry: every ingestion path yields the
same Graph contract plus a content fingerprint with the cheapness
guarantee each source advertises (param hash for synthetic, chained
O(batch) maintenance for the serving store)."""
import numpy as np
import pytest

from repro.graph.edges import (Graph, edge_fingerprint,
                               extend_fingerprint, make_labels)
from repro.graph.generators import erdos_renyi
from repro.graph.io import save_graph
from repro.graph.sources import (ShardedSource, SnapshotSource,
                                 StoreSource, SyntheticSource, as_graph,
                                 get_source, list_sources,
                                 register_source)


class TestFingerprint:
    def test_content_identity_not_array_identity(self):
        g = erdos_renyi(50, 200, seed=1, weighted=True)
        same = Graph(g.u.copy(), g.v.copy(), g.w.copy(), g.n)
        assert g.fingerprint() == same.fingerprint()
        other = Graph(g.u, g.v, (g.w + 1).astype(np.float32), g.n)
        assert g.fingerprint() != other.fingerprint()
        # n is part of the content (isolated trailing nodes matter)
        bigger = Graph(g.u, g.v, g.w, g.n + 1)
        assert g.fingerprint() != bigger.fingerprint()

    def test_dtype_canonicalization(self):
        g = erdos_renyi(30, 90, seed=2)
        g64 = Graph(g.u.astype(np.int64), g.v.astype(np.int64),
                    g.w.astype(np.float64), g.n)
        assert g.fingerprint() == g64.fingerprint()

    def test_order_sensitivity(self):
        """Plan artifacts depend on edge order, so a permuted multiset
        must read as different content."""
        g = erdos_renyi(30, 90, seed=2)
        p = np.random.default_rng(0).permutation(g.s)
        gp = Graph(g.u[p], g.v[p], g.w[p], g.n)
        assert g.fingerprint() != gp.fingerprint()

    def test_extend_matches_replay(self):
        """Chained fingerprints are replayable: any process applying the
        same base + delta sequence reaches the same value."""
        g = erdos_renyi(30, 90, seed=3)
        du = np.array([1, 2], np.int32)
        dv = np.array([3, 4], np.int32)
        dw = np.ones(2, np.float32)
        a = extend_fingerprint(g.fingerprint(), du, dv, dw)
        b = extend_fingerprint(
            edge_fingerprint(g.n, g.u, g.v, g.w), du, dv, dw)
        assert a == b
        assert a != g.fingerprint()


class TestRegistry:
    def test_builtin_sources_registered(self):
        assert {"synthetic", "snapshot", "sharded",
                "store"} <= set(list_sources())

    def test_get_source_and_unknown(self):
        src = get_source("synthetic", kind="erdos_renyi", n=10, s=20,
                         seed=0)
        assert isinstance(src, SyntheticSource)
        with pytest.raises(KeyError, match="registered"):
            get_source("csv")

    def test_register_custom_source(self):
        @register_source("test:const")
        class ConstSource(SyntheticSource):
            pass
        try:
            assert "test:const" in list_sources()
        finally:
            from repro.graph import sources as S
            del S._SOURCES["test:const"]

    def test_as_graph(self):
        g = erdos_renyi(10, 20, seed=0)
        assert as_graph(g) is g
        src = SyntheticSource("erdos_renyi", n=10, s=20, seed=0)
        assert isinstance(as_graph(src), Graph)
        with pytest.raises(TypeError):
            as_graph(42)


class TestSyntheticSource:
    def test_fingerprint_is_param_hash_no_materialization(self):
        a = SyntheticSource("erdos_renyi", n=100, s=400, seed=7)
        b = SyntheticSource("erdos_renyi", n=100, s=400, seed=7)
        c = SyntheticSource("erdos_renyi", n=100, s=400, seed=8)
        assert a.fingerprint() == b.fingerprint() != c.fingerprint()
        assert a._graph is None            # identity cost: zero arrays

    def test_graph_is_stamped_and_cached(self):
        src = SyntheticSource("erdos_renyi", n=100, s=400, seed=7)
        g = src.graph()
        assert g.fingerprint() == src.fingerprint()
        assert src.graph() is g

    def test_sbm_exposes_labels(self):
        src = SyntheticSource("sbm", n=60, K=3, s=500, seed=0)
        g = src.graph()
        assert g.n == 60 and src.labels.shape == (60,)

    def test_unknown_generator(self):
        with pytest.raises(KeyError, match="generator"):
            SyntheticSource("petersen")


class TestSnapshotSource:
    def test_fingerprint_stable_across_resaves(self, tmp_path):
        g = erdos_renyi(80, 300, seed=5, weighted=True)
        p1, p2 = str(tmp_path / "a.npz"), str(tmp_path / "b.npz")
        save_graph(p1, g)
        save_graph(p2, g, compressed=False)   # different bytes on disk
        s1, s2 = SnapshotSource(p1), SnapshotSource(p2)
        assert s1.fingerprint() == s2.fingerprint() == g.fingerprint()
        np.testing.assert_array_equal(s1.graph().u, g.u)


class TestShardedSource:
    def test_slice_assembly_and_fingerprint(self, tmp_path):
        g = erdos_renyi(100, 999, seed=4, weighted=True)
        path = str(tmp_path / "g.npz")
        save_graph(path, g)
        full = ShardedSource(path, 0, 1, chunk_size=100)
        gf = full.graph()
        np.testing.assert_array_equal(gf.u, g.u)
        np.testing.assert_array_equal(gf.w, g.w)
        # fingerprint is CONTENT identity: independent of chunk size
        # (a reader tuning knob) and equal to the snapshot's own
        # fingerprint for the full slice — replicas with different
        # reader settings share plan-cache entries
        other_chunks = ShardedSource(path, 0, 1, chunk_size=512)
        assert other_chunks.fingerprint() == full.fingerprint()
        assert full.fingerprint() == g.fingerprint()
        assert full.fingerprint() == SnapshotSource(path).fingerprint()
        # a different slice is different content
        half = ShardedSource(path, 0, 2, chunk_size=100)
        assert half.fingerprint() != full.fingerprint()
        assert half.graph().s < g.s

    def test_chunks_stream(self, tmp_path):
        g = erdos_renyi(50, 500, seed=4)
        path = str(tmp_path / "g.npz")
        save_graph(path, g)
        src = ShardedSource(path, 0, 1, chunk_size=128)
        sizes = [c.s for c in src.chunks()]
        assert sum(sizes) == g.s and max(sizes) <= 128


class TestStoreSource:
    def _store(self):
        from repro.serving.store import GraphStore
        g = erdos_renyi(60, 300, seed=6, weighted=True)
        Y = make_labels(60, 4, 0.5, np.random.default_rng(0))
        return GraphStore(g, Y, 4)

    def test_incremental_maintenance_matches_replay(self):
        s1, s2 = self._store(), self._store()
        assert s1.fingerprint() == s2.fingerprint()
        u = np.array([1, 2], np.int32)
        v = np.array([3, 4], np.int32)
        w = np.ones(2, np.float32)
        s1.apply_edges(u, v, w)
        assert s1.fingerprint() != s2.fingerprint()
        s2.apply_edges(u, v, w)                 # same history -> same fp
        assert s1.fingerprint() == s2.fingerprint()
        # deletes are content too (negated weights)
        s1.apply_edges(u, v, w, delete=True)
        s2.apply_edges(u, v, w)
        assert s1.fingerprint() != s2.fingerprint()

    def test_edges_stamped_and_labels_neutral(self):
        store = self._store()
        src = StoreSource(store)
        assert src.graph().fingerprint() == store.fingerprint()
        fp = store.fingerprint()
        store.apply_labels(np.array([0]), np.array([1]))
        assert store.fingerprint() == fp        # labels aren't edges
        store.apply_edges(np.array([5], np.int32), np.array([6], np.int32),
                          np.ones(1, np.float32))
        assert src.graph().fingerprint() == store.fingerprint() != fp

    def test_compaction_rehashes(self):
        store = self._store()
        u = np.array([1], np.int32)
        v = np.array([2], np.int32)
        store.apply_edges(u, v, np.ones(1, np.float32))
        before = store.fingerprint()
        store.compact()
        after = store.fingerprint()
        assert after != before                  # arrays were rewritten
        # and the new value is the plain content hash of the new base
        assert after == Graph(store.base.u, store.base.v, store.base.w,
                              store.base.n).fingerprint()

    def test_service_cold_start_hits_persistent_cache(self, tmp_path):
        """A 'replica' (second service over an identically-replayed
        store) must find the first replica's plan on disk."""
        from repro.serving.service import EmbeddingService
        a = EmbeddingService(self._store(), plan_cache=tmp_path)
        assert a.embedder.plan_stats["disk_stores"] == 1
        b = EmbeddingService(self._store(), plan_cache=tmp_path)
        assert b.embedder.plan_stats == {"built": 0, "hits": 0,
                                         "disk_hits": 1, "disk_stores": 0}
        np.testing.assert_allclose(np.asarray(a.Z), np.asarray(b.Z),
                                   atol=1e-6)
