"""Logical-axis sharding rules -> PartitionSpec, divisibility-aware.

Two rule tables, because the same logical name means different things on
weights and activations:

  * weight rules — "embed" shards over the data axes (ZeRO-3/FSDP:
    weights gathered just-in-time per layer under the scan), "mlp",
    "heads", "vocab" shard over the model axis (TP).
  * activation rules — "batch" over (pod, data); head/mlp/vocab dims
    over model; "embed" replicated (activations are batch-sharded, not
    feature-sharded, except where SP is enabled).

Every rule application checks divisibility and axis-reuse: a dim that
doesn't divide (e.g. xlstm's 4 heads on a 16-way model axis) silently
falls back to replication — per-arch correctness beats a crash, and the
roofline table makes the cost of the fallback visible.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer as tfm
from repro.models.layers import set_activation_sharder, tree_map_specs

DATA_AXES = ("pod", "data")      # FSDP/DP axes (pod present on multi-pod)
MODEL_AXIS = "model"


def _present(mesh: Mesh, names) -> tuple:
    return tuple(a for a in names if a in mesh.axis_names)


def default_weight_rules(mesh: Mesh) -> dict:
    fsdp = _present(mesh, DATA_AXES)
    return {
        "embed": fsdp,
        "mlp": MODEL_AXIS,
        "heads": MODEL_AXIS,
        "kv_heads": MODEL_AXIS,
        "vocab": MODEL_AXIS,
        "experts": None,
        "layers": None,
        "inner": None,
        "embed_out": None,
        # state/cache logical names that can appear in spec trees
        "batch": fsdp,
        "kv_seq": MODEL_AXIS,
        "seq": None,
    }


def default_act_rules(mesh: Mesh) -> dict:
    batch = _present(mesh, DATA_AXES)
    return {
        "batch": batch,
        "seq": None,
        "embed": None,
        "heads": MODEL_AXIS,
        "kv_heads": MODEL_AXIS,
        "mlp": MODEL_AXIS,
        "vocab": MODEL_AXIS,
        "experts": None,
        "kv_seq": MODEL_AXIS,
    }


@dataclass
class ShardingRules:
    mesh: Mesh
    weight: dict
    act: dict

    def spec(self, shape, logical, table) -> P:
        used: set = set()
        parts = []
        for dim, name in zip(shape, logical):
            axes = table.get(name) if name is not None else None
            if axes is None:
                parts.append(None)
                continue
            if isinstance(axes, str):
                axes = (axes,)
            axes = tuple(a for a in axes if a in self.mesh.axis_names
                         and a not in used)
            size = 1
            for a in axes:
                size *= self.mesh.shape[a]
            if not axes or size == 1 or dim % size != 0:
                parts.append(None)
                continue
            used.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def weight_spec(self, shape, logical) -> P:
        return self.spec(shape, logical, self.weight)

    def act_spec(self, shape, logical) -> P:
        return self.spec(shape, logical, self.act)

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def make_rules(mesh: Mesh, *, seq_shard_acts: bool = False,
               fsdp: bool = True) -> ShardingRules:
    w = default_weight_rules(mesh)
    a = default_act_rules(mesh)
    if not fsdp:
        w["embed"] = None
        w["batch"] = _present(mesh, DATA_AXES)
    if seq_shard_acts:                       # sequence parallelism (§Perf)
        a["seq"] = MODEL_AXIS
    return ShardingRules(mesh, w, a)


# ---------------------------------------------------------------------------
# Tree helpers
# ---------------------------------------------------------------------------


def spec_tree_shardings(rules: ShardingRules, spec_tree):
    """ParamSpec tree -> NamedSharding tree (weight rules)."""
    return tree_map_specs(
        lambda s: rules.named(rules.weight_spec(s.shape, s.logical)),
        spec_tree)


def spec_tree_pspecs(rules: ShardingRules, spec_tree):
    return tree_map_specs(
        lambda s: rules.weight_spec(s.shape, s.logical), spec_tree)


# ---------------------------------------------------------------------------
# Activation-constraint context
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: Optional[ShardingRules] = None):
    """Within this context, models' ashard() calls emit
    with_sharding_constraint and decode dispatch sees the mesh."""
    rules = rules or make_rules(mesh)

    def shard_fn(x, logical):
        spec = rules.act_spec(x.shape, logical)
        return jax.lax.with_sharding_constraint(x, rules.named(spec))

    set_activation_sharder(shard_fn)
    tfm.set_current_mesh(mesh)
    try:
        yield rules
    finally:
        set_activation_sharder(None)
        tfm.set_current_mesh(None)
