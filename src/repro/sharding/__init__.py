from repro.sharding.rules import (ShardingRules, default_act_rules,
                                  default_weight_rules, make_rules,
                                  spec_tree_pspecs, spec_tree_shardings,
                                  use_sharding)

__all__ = ["ShardingRules", "default_act_rules", "default_weight_rules",
           "make_rules", "spec_tree_pspecs", "spec_tree_shardings",
           "use_sharding"]
