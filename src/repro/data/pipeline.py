"""Deterministic synthetic token pipeline (host-sharded, resumable).

Production shape: each host materializes only its slice of the global
batch (``host_id``/``num_hosts``), the stream is a pure function of
(seed, step) so restarts resume exactly (the checkpoint stores `step`),
and a background prefetch thread hides generation latency.

The synthetic distribution is a mixture of Zipf unigrams and a Markov
bigram chain — enough structure that a 100M-param model's loss drops
well below the unigram entropy (examples/train_lm.py demonstrates), so
training-loop correctness is visible in the curve.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    markov_order: bool = True


class SyntheticTokens:
    """Deterministic (seed, step) -> batch generator."""

    def __init__(self, cfg: DataConfig, host_id: int = 0,
                 num_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        assert cfg.global_batch % num_hosts == 0
        self.local_batch = cfg.global_batch // num_hosts
        root = np.random.default_rng(cfg.seed)
        # fixed unigram (Zipf) and a sparse "grammar" bigram table
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        self.unigram = ranks ** (-cfg.zipf_a)
        self.unigram /= self.unigram.sum()
        self.next_tok = root.integers(
            0, cfg.vocab, size=(cfg.vocab, 4)).astype(np.int32)

    def batch(self, step: int) -> np.ndarray:
        """(local_batch, seq_len) int32, deterministic in (step, host)."""
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed, step, self.host_id, 0xD0E))
        B, S = self.local_batch, c.seq_len
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.choice(c.vocab, size=B, p=self.unigram)
        follow = rng.random((B, S)) < 0.75
        branch = rng.integers(0, 4, size=(B, S))
        fresh = rng.choice(c.vocab, size=(B, S), p=self.unigram)
        for t in range(1, S):
            nxt = self.next_tok[toks[:, t - 1], branch[:, t]]
            toks[:, t] = np.where(follow[:, t], nxt, fresh[:, t])
        return toks

    def __iter__(self) -> Iterator[np.ndarray]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (depth-bounded)."""

    def __init__(self, source: SyntheticTokens, start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            b = self.source.batch(s)
            try:
                self._q.put((s, b), timeout=1.0)
                s += 1
            except queue.Full:
                continue

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()


def unigram_entropy(cfg: DataConfig) -> float:
    ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
    p = ranks ** (-cfg.zipf_a)
    p /= p.sum()
    return float(-(p * np.log(p)).sum())
