"""Data pipeline: deterministic host-sharded synthetic streams."""
