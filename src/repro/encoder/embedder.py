"""Embedder: the one front door for GEE.

    cfg = EncoderConfig(K=5)
    emb = Embedder(cfg, backend="xla").fit(graph, Y)
    Z   = emb.transform()                 # (n, K)
    emb.partial_fit(delta_graph)          # O(batch) exact update
    emb.refit(Y_new)                      # reuse the cached plan

Design rules:

* **Backend is configuration.**  Every execution strategy registered in
  `backends.py` is reachable by name; call sites never import a
  strategy-specific function again.
* **plan() is cached.**  The label-free host preprocessing (Laplacian
  degrees, padding, Pallas destination packing, distributed capacity
  measurement) runs once per edge multiset; `refit` and repeated `fit`
  on the same arrays skip it (`plan_stats` proves it, the encoder
  benchmark measures it).
* **The Embedder owns the projection weights.**  `make_w(Y, K)` is
  computed at fit time and used by every subsequent `partial_fit`, so
  the raw `gee_apply_delta` contract — "Wv must be the weights Z was
  built with" — can no longer be violated by a caller holding a stale
  or foreign Wv.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

import functools

from repro.core.gee import (gee_apply_delta, kmeans_refine_round, make_w)
from repro.encoder.backends import Backend, get_backend
from repro.encoder.config import EncoderConfig
from repro.encoder.plan import Plan
from repro.graph.edges import Graph, bucket_size


class NotFittedError(RuntimeError):
    pass


@functools.partial(jax.jit, static_argnames=("K", "kmeans_iters"))
def _kmeans_reassign(Z, labels, Y0, *, K: int, kmeans_iters: int):
    """Jitted wrapper over the shared `core.gee.kmeans_refine_round`."""
    return kmeans_refine_round(Z, labels, Y0, K, kmeans_iters)


class Embedder:
    """Unified GEE embedding API over pluggable backends.

    Fitted state (sklearn-style trailing underscore):
      Z_        (n, K) float32 embedding (device array).
      labels_   the labels Z was built under (int32, -1 = unknown).
      Wv_       per-node projection weights Z was built with.
    """

    def __init__(self, config: EncoderConfig, *, backend: str = "xla",
                 mesh=None):
        self.config = config
        self.backend: Backend = get_backend(backend)
        self.mesh = mesh
        self._plan: Optional[Plan] = None
        self._deltas_applied = 0       # partial_fits since last _embed
        self._Yj = self._Yfit = None
        self.Z_: Optional[jnp.ndarray] = None
        self.labels_: Optional[np.ndarray] = None
        self.Wv_: Optional[jnp.ndarray] = None
        self.last_info_: dict = {}
        self.plan_stats = {"built": 0, "hits": 0}

    # -- planning ----------------------------------------------------------

    def plan(self, graph: Graph) -> Plan:
        """Build (or reuse) the label-free preprocessing for `graph`.

        Cache hits are O(1): the plan matches iff it was built against
        the very same edge arrays — a changed multiset means new arrays
        and a rebuild, same arrays (refinement rounds, serving rebuilds
        off a quiet store, benchmark repeats) skip all host packing.
        """
        if self._plan is not None and self._plan.matches(
                graph, self.backend.name, self.config):
            self.plan_stats["hits"] += 1
            return self._plan
        graph.validate()
        if self.Z_ is not None:
            # the fitted state belonged to the OLD plan's graph; keeping
            # it would let refit()/transform() serve stale or mismatched
            # results against the new plan
            self.Z_ = self.labels_ = self.Wv_ = None
            self._Yj = self._Yfit = None
            self._deltas_applied = 0
            self.last_info_ = {}
        self._plan = self.backend.plan(graph, self.config, mesh=self.mesh)
        self.plan_stats["built"] += 1
        return self._plan

    # -- fitting -----------------------------------------------------------

    def fit(self, graph: Graph, Y) -> "Embedder":
        """Embed `graph` under labels `Y` (int, -1 = unknown)."""
        plan = self.plan(graph)
        return self._embed(plan, Y)

    def refit(self, Y=None) -> "Embedder":
        """Re-embed under new labels, reusing the cached plan (no host
        packing).  Y=None re-runs with the current labels.

        Refuses to run after `partial_fit`: the cached plan holds the
        ORIGINAL edge multiset, so a refit would silently drop every
        applied delta — fit() on the live graph instead (serving does
        exactly that on rebuild)."""
        if self._plan is None or self.Z_ is None:
            raise NotFittedError(
                "refit() requires a fitted state for the cached plan "
                "(fit() first; a plan() on a different graph clears it)")
        self._check_no_pending_deltas("refit")
        self.plan_stats["hits"] += 1
        return self._embed(self._plan, self.labels_ if Y is None else Y)

    def _check_no_pending_deltas(self, what: str) -> None:
        if self._deltas_applied:
            raise RuntimeError(
                f"{what}() after {self._deltas_applied} partial_fit(s) "
                "would re-embed the plan's ORIGINAL edge multiset and "
                "silently discard the applied deltas; fit() on the "
                "live graph instead")

    def _embed(self, plan: Plan, Y) -> "Embedder":
        Y = np.asarray(Y, np.int32)
        if Y.shape != (plan.n,):
            raise ValueError(f"Y shape {Y.shape} != ({plan.n},)")
        if Y.size and Y.max() >= self.config.K:
            raise ValueError(f"label {Y.max()} >= K={self.config.K}")
        self.labels_ = Y.copy()
        self._Yj = jnp.asarray(Y)
        self._Yfit = self._Yj       # supervised set: pinned by refine()
        self.Wv_ = make_w(self._Yj, self.config.K)
        self.Z_, self.last_info_ = self.backend.embed(plan, self._Yj,
                                                      self.Wv_)
        self._deltas_applied = 0
        return self

    def partial_fit(self, delta: Graph, *, sign: float = 1.0
                    ) -> "Embedder":
        """Fold an edge delta into Z exactly (GEE is linear in the edge
        multiset).  sign=+1 inserts, sign=-1 deletes.  Uses the OWNED
        (labels_, Wv_) pair, so the Wv-mismatch footgun of calling
        `gee_apply_delta` directly cannot occur.  Batches are padded to
        power-of-two buckets: one jit compile per bucket size."""
        if self.Z_ is None:
            raise NotFittedError("partial_fit() before fit()")
        if self.config.laplacian:
            raise ValueError(
                "partial_fit is exact only for laplacian=False: degree "
                "scaling makes Z nonlinear in the edge multiset — refit "
                "on the updated graph instead")
        if delta.n != self.n_:
            raise ValueError(f"delta graph has n={delta.n}, fitted "
                             f"n={self.n_}")
        delta.validate()
        if delta.s == 0:
            return self
        padded = delta.pad_to(bucket_size(delta.s))
        self.Z_ = gee_apply_delta(
            self.Z_, jnp.asarray(padded.u), jnp.asarray(padded.v),
            jnp.asarray(padded.w), self._Yj, self.Wv_,
            K=self.config.K, sign=sign)
        self._deltas_applied += 1
        return self

    # -- refinement --------------------------------------------------------

    def refine(self, key=None) -> "Embedder":
        """Unsupervised GEE clustering (embed -> k-means -> reassign,
        `config.refine_iters` rounds).  Known labels in `labels_` stay
        pinned; unknowns bootstrap randomly.  Updates Z_ and labels_.

        Each round's embed dispatches through the CONFIGURED backend
        against the cached plan (labels are the only thing that changes
        round to round — exactly the plan/embed split), so refinement
        keeps the backend's memory/placement properties instead of
        falling back to a single-device full-graph pass."""
        if self._plan is None or self._Yfit is None:
            raise NotFittedError("refine() before fit()")
        self._check_no_pending_deltas("refine")
        key = jax.random.PRNGKey(0) if key is None else key
        cfg = self.config
        # pin only the labels SUPERVISED at fit time — not a previous
        # refine()'s assignments, so repeated refines re-bootstrap the
        # unknowns instead of freezing on round one's clustering
        Y0 = self._Yfit
        rand = jax.random.randint(key, (self._plan.n,), 0, cfg.K,
                                  jnp.int32)
        labels = jnp.where(Y0 >= 0, Y0, rand)
        for _ in range(cfg.refine_iters):
            Z, _ = self.backend.embed(self._plan, labels,
                                      make_w(labels, cfg.K))
            labels = _kmeans_reassign(Z, labels, Y0, K=cfg.K,
                                      kmeans_iters=cfg.kmeans_iters)
        self.labels_ = np.asarray(labels)
        self._Yj = labels
        self.Wv_ = make_w(labels, cfg.K)
        self.Z_, self.last_info_ = self.backend.embed(self._plan, labels,
                                                      self.Wv_)
        return self

    # -- queries -----------------------------------------------------------

    @property
    def n_(self) -> int:
        if self._plan is None:
            raise NotFittedError("not fitted")
        return self._plan.n

    def _rows(self, nodes):
        """Z rows for `nodes`, bounds-checked (jnp gather would silently
        CLAMP out-of-range ids — a stale node id must raise, not return
        a plausible wrong row)."""
        if self.Z_ is None:
            raise NotFittedError("not fitted")
        if nodes is None:
            return self.Z_
        nodes = np.asarray(nodes)
        if nodes.size and (nodes.min() < 0 or nodes.max() >= self.n_):
            raise IndexError(f"node ids must be in [0, {self.n_}), got "
                             f"range [{nodes.min()}, {nodes.max()}]")
        return self.Z_[jnp.asarray(nodes)]

    def transform(self, nodes=None) -> np.ndarray:
        """Z rows for `nodes` (all rows if None), in config.dtype."""
        Z = self._rows(nodes)
        return np.asarray(Z.astype(jnp.dtype(self.config.dtype)))

    def predict(self, nodes=None) -> np.ndarray:
        """argmax-Z class prediction for `nodes` (all nodes if None)."""
        Z = self._rows(nodes)
        return np.asarray(jnp.argmax(Z, axis=1).astype(jnp.int32))
