"""Embedder: the one front door for GEE.

    cfg = EncoderConfig(K=5)                  # backend="auto" resolves
    emb = Embedder(cfg).fit(source, Y)        # Graph or GraphSource
    Z   = emb.transform()                 # (n, K)
    emb.partial_fit(delta_graph)          # O(batch) exact update
    emb.refit(Y_new)                      # reuse the cached plan

Design rules:

* **Backend is configuration.**  Every execution strategy registered in
  `backends.py` is reachable by name; call sites never import a
  strategy-specific function again.  `backend="auto"` (the config
  default) resolves at plan time from (n, s, device kind, device count)
  via the `AUTO_POLICY` table.
* **plan() is a two-tier cache.**  Tier 1: O(1) array-identity match —
  refits and repeated fits on the same arrays skip all host work.
  Tier 2: a persistent on-disk cache keyed on the graph's CONTENT
  fingerprint (`repro.encoder.plan_cache`), so a fresh process
  (restart, CI rerun, new serving replica) embedding the same graph
  skips host packing too and only re-runs cheap device placement
  (`plan_stats` counts built / hits / disk_hits / disk_stores; the
  encoder benchmark measures both tiers).
* **The Embedder owns the projection weights.**  `make_w(Y, K)` is
  computed at fit time and used by every subsequent `partial_fit`, so
  the raw `gee_apply_delta` contract — "Wv must be the weights Z was
  built with" — can no longer be violated by a caller holding a stale
  or foreign Wv.
"""
from __future__ import annotations

import os
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

import functools

from repro import obs
from repro.core.gee import (gee_apply_delta, gee_apply_delta_owned,
                            kmeans_refine_round, make_w)
from repro.encoder.backends import Backend, get_backend, resolve_auto
from repro.encoder.config import EncoderConfig
from repro.encoder.plan import Plan, owned_contributions
from repro.encoder.plan_cache import PlanDiskCache, default_cache
from repro.graph.edges import Graph, bucket_size
from repro.graph.sources import as_graph


class NotFittedError(RuntimeError):
    pass


@functools.partial(jax.jit, static_argnames=("K", "kmeans_iters"))
def _kmeans_reassign(Z, labels, Y0, *, K: int, kmeans_iters: int):
    """Jitted wrapper over the shared `core.gee.kmeans_refine_round`."""
    return kmeans_refine_round(Z, labels, Y0, K, kmeans_iters)


class Embedder:
    """Unified GEE embedding API over pluggable backends.

    Fitted state (sklearn-style trailing underscore):
      Z_        (n, K) float32 embedding (device array).
      labels_   the labels Z was built under (int32, -1 = unknown).
      Wv_       per-node projection weights Z was built with.
    """

    def __init__(self, config: EncoderConfig, *,
                 backend: Optional[str] = None, mesh=None,
                 plan_cache: Union[str, PlanDiskCache, None] = "auto"):
        self.config = config
        spec = backend if backend is not None else config.backend
        self._backend_spec = spec
        #: resolved Backend; None until first plan() when spec="auto"
        self.backend: Optional[Backend] = (
            None if spec == "auto" else get_backend(spec))
        self.mesh = mesh
        if plan_cache == "auto":
            self.plan_cache = default_cache()
        elif plan_cache is None or plan_cache is False:
            self.plan_cache = None
        elif isinstance(plan_cache, (str, os.PathLike)):
            self.plan_cache = PlanDiskCache(plan_cache)
        else:
            self.plan_cache = plan_cache
        self._plan: Optional[Plan] = None
        self._deltas_applied = 0       # partial_fits since last _embed
        self._Yj = self._Yfit = None
        self.Z_: Optional[jnp.ndarray] = None
        self.labels_: Optional[np.ndarray] = None
        self.Wv_: Optional[jnp.ndarray] = None
        self.last_info_: dict = {}
        self.plan_stats = {"built": 0, "hits": 0,
                           "disk_hits": 0, "disk_stores": 0}

    def _bump_plan_stat(self, key: str) -> None:
        """plan_stats increment, mirrored into the process registry
        (`repro_encoder_plan_cache_total{event=...}`) so every
        Embedder's cache behavior lands in one observable series."""
        self.plan_stats[key] += 1
        obs.counter("repro_encoder_plan_cache_total",
                    event={"hits": "tier1_hit", "built": "built",
                           "disk_hits": "disk_hit",
                           "disk_stores": "disk_store"}[key])

    # -- planning ----------------------------------------------------------

    def _resolve_backend(self, graph: Graph) -> Backend:
        if self._backend_spec == "auto":
            name = resolve_auto(graph.n, graph.s, mesh=self.mesh)
            if self.backend is None or self.backend.name != name:
                self.backend = get_backend(name)
        return self.backend

    def plan(self, graph) -> Plan:
        """Build (or reuse) the label-free preprocessing for `graph`
        (a Graph or a GraphSource).

        Tier 1 hits are O(1): the plan matches iff it was built against
        the very same edge arrays — a changed multiset means new arrays
        and a rebuild, same arrays (refinement rounds, serving rebuilds
        off a quiet store, benchmark repeats) skip all host packing.

        Tier 2 is content-addressed and survives the process: on a tier
        1 miss, the graph's fingerprint + resolved backend + config key
        a persistent entry holding the plan's host half — a hit skips
        `plan_host` (packing, capacity measurement, Laplacian degrees)
        and only re-runs device placement.  Stale or corrupt entries
        fall back to a full rebuild; `plan_cache=None` disables the
        tier (or set REPRO_PLAN_CACHE=off process-wide)."""
        graph = as_graph(graph)
        backend = self._resolve_backend(graph)
        rp = self.config.row_partition
        if rp is not None:
            if not backend.supports_row_partition:
                from repro.encoder.backends import partition_backends
                raise ValueError(
                    f"backend {backend.name!r} has no owned-rows "
                    "accumulate path (row_partition) — only the "
                    "distributed:* collective modes lack one (they "
                    "shard internally across the device mesh instead); "
                    "use one of the partition-aware backends: "
                    f"{', '.join(partition_backends())}")
            if rp[1] > graph.n:
                raise ValueError(
                    f"row_partition {rp} exceeds graph n={graph.n}")
        if self._plan is not None and self._plan.matches(
                graph, backend.name, self.config):
            self._bump_plan_stat("hits")
            return self._plan
        graph.validate()
        if self.Z_ is not None:
            # the fitted state belonged to the OLD plan's graph; keeping
            # it would let refit()/transform() serve stale or mismatched
            # results against the new plan
            self.Z_ = self.labels_ = self.Wv_ = None
            self._Yj = self._Yfit = None
            self._deltas_applied = 0
            self.last_info_ = {}
        with obs.span("encoder.plan", backend=backend.name,
                      n=graph.n, s=graph.s) as sp:
            meta = host = None
            cache = self.plan_cache if backend.persistable else None
            if cache is not None:
                meta = cache.describe(graph.fingerprint(), backend,
                                      self.config, mesh=self.mesh)
                host = cache.load(meta)
            if host is not None:
                self._bump_plan_stat("disk_hits")
                self._plan = backend.plan(graph, self.config,
                                          mesh=self.mesh, host=host)
                source = "disk"
            else:
                self._plan = backend.plan(graph, self.config,
                                          mesh=self.mesh)
                self._bump_plan_stat("built")
                if meta is not None and cache.store(meta,
                                                    self._plan.host):
                    self._bump_plan_stat("disk_stores")
                source = "built"
            sp.set(source=source)
        if obs.enabled():
            obs.observe("repro_encoder_plan_seconds", sp.duration,
                        backend=backend.name, source=source)
        return self._plan

    # -- fitting -----------------------------------------------------------

    def fit(self, graph, Y) -> "Embedder":
        """Embed `graph` (a Graph or GraphSource) under labels `Y`
        (int, -1 = unknown)."""
        plan = self.plan(graph)
        return self._embed(plan, Y)

    def refit(self, Y=None) -> "Embedder":
        """Re-embed under new labels, reusing the cached plan (no host
        packing).  Y=None re-runs with the current labels.

        Refuses to run after `partial_fit`: the cached plan holds the
        ORIGINAL edge multiset, so a refit would silently drop every
        applied delta — fit() on the live graph instead (serving does
        exactly that on rebuild)."""
        if self._plan is None or self.Z_ is None:
            raise NotFittedError(
                "refit() requires a fitted state for the cached plan "
                "(fit() first; a plan() on a different graph clears it)")
        self._check_no_pending_deltas("refit")
        self._bump_plan_stat("hits")
        return self._embed(self._plan, self.labels_ if Y is None else Y)

    def _check_no_pending_deltas(self, what: str) -> None:
        if self._deltas_applied:
            raise RuntimeError(
                f"{what}() after {self._deltas_applied} partial_fit(s) "
                "would re-embed the plan's ORIGINAL edge multiset and "
                "silently discard the applied deltas; fit() on the "
                "live graph instead")

    def _embed(self, plan: Plan, Y) -> "Embedder":
        Y = np.asarray(Y, np.int32)
        if Y.shape != (plan.n,):
            raise ValueError(f"Y shape {Y.shape} != ({plan.n},)")
        if Y.size and Y.max() >= self.config.K:
            raise ValueError(f"label {Y.max()} >= K={self.config.K}")
        self.labels_ = Y.copy()
        with obs.span("encoder.fit",
                      metric="repro_encoder_fit_seconds",
                      mlabels={"backend": self.backend.name},
                      backend=self.backend.name, n=plan.n,
                      s=plan.s) as sp:
            self._Yj = jnp.asarray(Y)
            self._Yfit = self._Yj   # supervised set: pinned by refine()
            self.Wv_ = make_w(self._Yj, self.config.K)
            self.Z_, self.last_info_ = self.backend.embed(plan, self._Yj,
                                                          self.Wv_)
            sp.fence(self.Z_)       # bill the async scatter to the fit
        if obs.enabled() and plan.s and sp.duration > 0:
            obs.gauge("repro_encoder_fit_edges_per_s",
                      plan.s / sp.duration, backend=self.backend.name)
        self._deltas_applied = 0
        return self

    def partial_fit(self, delta: Graph, *, sign: float = 1.0
                    ) -> "Embedder":
        """Fold an edge delta into Z exactly (GEE is linear in the edge
        multiset).  sign=+1 inserts, sign=-1 deletes.  Uses the OWNED
        (labels_, Wv_) pair, so the Wv-mismatch footgun of calling
        `gee_apply_delta` directly cannot occur.  Batches are padded to
        power-of-two buckets: one jit compile per bucket size."""
        if self.Z_ is None:
            raise NotFittedError("partial_fit() before fit()")
        if self.config.laplacian:
            raise ValueError(
                "partial_fit is exact only for laplacian=False: degree "
                "scaling makes Z nonlinear in the edge multiset — refit "
                "on the updated graph instead")
        if delta.n != self.n_:
            raise ValueError(f"delta graph has n={delta.n}, fitted "
                             f"n={self.n_}")
        delta.validate()
        if delta.s == 0:
            return self
        t0 = obs.tick()
        rp = self.config.row_partition
        if rp is not None:
            # owned-rows path: bucket the delta by owned destination on
            # the host (O(batch)), scatter into the (n_local, K) slice.
            # Contributions landing outside [lo, hi) never touch owned
            # rows (laplacian is rejected above, so Z is linear and
            # non-incident edges are exact no-ops here).
            rows, src, w = owned_contributions(delta, delta.w, *rp)
            if rows.shape[0] == 0:
                return self
            pad = bucket_size(rows.shape[0]) - rows.shape[0]
            if pad:
                rows = np.concatenate([rows, np.zeros(pad, np.int32)])
                src = np.concatenate([src, np.zeros(pad, np.int32)])
                w = np.concatenate([w, np.zeros(pad, np.float32)])
            self.Z_ = gee_apply_delta_owned(
                self.Z_, jnp.asarray(rows), jnp.asarray(src),
                jnp.asarray(w), self._Yj, self.Wv_, K=self.config.K,
                sign=sign)
            self._deltas_applied += 1
            self._record_partial_fit(t0, delta.s)
            return self
        padded = delta.pad_to(bucket_size(delta.s))
        self.Z_ = gee_apply_delta(
            self.Z_, jnp.asarray(padded.u), jnp.asarray(padded.v),
            jnp.asarray(padded.w), self._Yj, self.Wv_,
            K=self.config.K, sign=sign)
        self._deltas_applied += 1
        self._record_partial_fit(t0, delta.s)
        return self

    def partial_fit_norm(self, delta: Graph, *, sign: float = 1.0
                         ) -> jnp.ndarray:
        """`partial_fit` fused with renormalization: fold the delta
        into Z AND produce the row-normalized slice in one pallas pass
        (`kernels.query_fused.gee_delta_renorm`) — the serving
        partial_fit-then-query turnaround, where the normalized rows
        are needed immediately and a separate normalize pass would
        re-read all of Z from HBM.  Same exactness contract as
        `partial_fit` (linear updates only); classes/values resolve on
        the host from the fitted (labels_, Wv_) pair and pack by
        destination tile like the fit-path kernel.  Returns Zn — the
        unit-normalized fitted rows (the shard's query cache)."""
        if self.Z_ is None:
            raise NotFittedError("partial_fit_norm() before fit()")
        if self.config.laplacian:
            raise ValueError(
                "partial_fit_norm is exact only for laplacian=False: "
                "degree scaling makes Z nonlinear in the edge multiset "
                "— refit on the updated graph instead")
        if delta.n != self.n_:
            raise ValueError(f"delta graph has n={delta.n}, fitted "
                             f"n={self.n_}")
        delta.validate()
        from repro.kernels.ops import pack_edges
        from repro.kernels.query_fused import gee_delta_renorm
        t0 = obs.tick()
        rp = self.config.row_partition
        if delta.s == 0:
            rows = src = np.zeros(0, np.int32)
            w = np.zeros(0, np.float32)
        elif rp is not None:
            rows, src, w = owned_contributions(delta, delta.w, *rp)
        else:
            u, v = np.asarray(delta.u), np.asarray(delta.v)
            rows = np.concatenate([u, v]).astype(np.int32)
            src = np.concatenate([v, u]).astype(np.int32)
            w = np.concatenate([delta.w, delta.w]).astype(np.float32)
        Ys = self.labels_[src]
        clsv = np.maximum(Ys, 0).astype(np.int32)
        Wvh = np.asarray(self.Wv_)
        val = np.where(Ys >= 0, Wvh[src] * w,
                       np.float32(0)) * np.float32(sign)
        n_local = int(self.Z_.shape[0])
        rb, cb, vb, _ = pack_edges(rows, clsv, val.astype(np.float32),
                                   n_local, self.config.tile_n,
                                   self.config.edge_block)
        self.Z_, Zn = gee_delta_renorm(
            self.Z_, rb, cb, vb, tile_n=self.config.tile_n,
            interpret=self.config.interpret)
        if rows.shape[0]:
            self._deltas_applied += 1
        self._record_partial_fit(t0, delta.s)
        return Zn

    def _record_partial_fit(self, t0: float, s: int) -> None:
        """Registry metrics for one applied delta (obs-on only: the
        fence synchronizes device work so the latency is real)."""
        if not obs.enabled():
            return
        jax.block_until_ready(self.Z_)
        obs.observe("repro_encoder_partial_fit_seconds", obs.tock(t0),
                    backend=self.backend.name)
        obs.counter("repro_encoder_delta_edges_total", s)

    # -- refinement --------------------------------------------------------

    def refine(self, key=None) -> "Embedder":
        """Unsupervised GEE clustering (embed -> k-means -> reassign,
        `config.refine_iters` rounds).  Known labels in `labels_` stay
        pinned; unknowns bootstrap randomly.  Updates Z_ and labels_.

        Each round's embed dispatches through the CONFIGURED backend
        against the cached plan (labels are the only thing that changes
        round to round — exactly the plan/embed split), so refinement
        keeps the backend's memory/placement properties instead of
        falling back to a single-device full-graph pass."""
        if self._plan is None or self._Yfit is None:
            raise NotFittedError("refine() before fit()")
        self._require_full_rows("refine")
        self._check_no_pending_deltas("refine")
        key = jax.random.PRNGKey(0) if key is None else key
        cfg = self.config
        with obs.span("encoder.refine",
                      metric="repro_encoder_refine_seconds",
                      backend=self.backend.name,
                      iters=cfg.refine_iters) as sp:
            # pin only the labels SUPERVISED at fit time — not a
            # previous refine()'s assignments, so repeated refines
            # re-bootstrap the unknowns instead of freezing on round
            # one's clustering
            Y0 = self._Yfit
            rand = jax.random.randint(key, (self._plan.n,), 0, cfg.K,
                                      jnp.int32)
            labels = jnp.where(Y0 >= 0, Y0, rand)
            for _ in range(cfg.refine_iters):
                Z, _ = self.backend.embed(self._plan, labels,
                                          make_w(labels, cfg.K))
                labels = _kmeans_reassign(Z, labels, Y0, K=cfg.K,
                                          kmeans_iters=cfg.kmeans_iters)
            self.labels_ = np.asarray(labels)
            self._Yj = labels
            self.Wv_ = make_w(labels, cfg.K)
            self.Z_, self.last_info_ = self.backend.embed(
                self._plan, labels, self.Wv_)
            sp.fence(self.Z_)
        return self

    # -- queries -----------------------------------------------------------

    @property
    def n_(self) -> int:
        if self._plan is None:
            raise NotFittedError("not fitted")
        return self._plan.n

    def _require_full_rows(self, what: str) -> None:
        if self.config.row_partition is not None:
            raise RuntimeError(
                f"{what}() needs the full embedding, but this Embedder "
                f"owns only rows {self.config.row_partition} "
                "(row_partition) — run it on an unpartitioned Embedder")

    def _rows(self, nodes):
        """Z rows for `nodes` (GLOBAL ids, also under a row partition),
        bounds-checked (jnp gather would silently CLAMP out-of-range
        ids — a stale or unowned node id must raise, not return a
        plausible wrong row)."""
        if self.Z_ is None:
            raise NotFittedError("not fitted")
        if nodes is None:
            return self.Z_
        nodes = np.asarray(nodes)
        lo, hi = self.config.row_partition or (0, self.n_)
        if nodes.size and (nodes.min() < lo or nodes.max() >= hi):
            owned = " owned" if self.config.row_partition else ""
            raise IndexError(f"node ids must be in{owned} [{lo}, {hi}), "
                             f"got range [{nodes.min()}, {nodes.max()}]")
        return self.Z_[jnp.asarray(nodes - lo)]

    def transform(self, nodes=None) -> np.ndarray:
        """Z rows for `nodes` (all fitted rows if None — the owned
        block under a row partition), in config.dtype.  Node ids are
        always GLOBAL."""
        t0 = obs.tick()
        Z = self._rows(nodes)
        out = np.asarray(Z.astype(jnp.dtype(self.config.dtype)))
        if obs.enabled():
            obs.observe("repro_encoder_transform_seconds",
                        obs.tock(t0))
        return out

    def predict(self, nodes=None) -> np.ndarray:
        """argmax-Z class prediction for `nodes` (all fitted nodes if
        None; global ids)."""
        Z = self._rows(nodes)
        return np.asarray(jnp.argmax(Z, axis=1).astype(jnp.int32))

    def to_features(self, d_model: int, *, key=None,
                    blend: float = 0.5) -> np.ndarray:
        """Project the fitted Z into an (n, d_model) feature table —
        the GEE -> LM bridge (embedding-table initialization).

        Rows of Z are unit-normalized, rotated K -> d_model with a
        fixed random near-isometry, and blended with scaled Gaussian
        noise; the result matches a standard 1/sqrt(d) init in scale
        but starts topic-structured (nodes GEE places together get
        similar feature rows).  ``blend`` in [0, 1]: 1 = pure
        structure, 0 = pure noise."""
        if self.Z_ is None:
            raise NotFittedError("to_features() before fit()")
        self._require_full_rows("to_features")
        key = jax.random.PRNGKey(0) if key is None else key
        k_rot, k_noise = jax.random.split(key)
        Z = self.Z_ / jnp.maximum(
            jnp.linalg.norm(self.Z_, axis=1, keepdims=True), 1e-9)
        K = self.config.K
        R = jax.random.normal(k_rot, (K, d_model),
                              jnp.float32) / np.sqrt(K)
        base = Z @ R
        noise = jax.random.normal(k_noise, (self.n_, d_model),
                                  jnp.float32)
        scale = 1.0 / np.sqrt(d_model)
        table = scale * (blend * base * np.sqrt(d_model)
                         + (1 - blend) * noise)
        return np.asarray(table, np.float32)
