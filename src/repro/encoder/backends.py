"""Backend registry: every execution strategy behind one interface.

A backend turns a `Plan` plus the *current* labels into Z.  All of them
compute the same mathematical object (conformance-tested); they differ
in where the scatter runs and how contributions move:

  numpy           `ref_python.gee_numpy` — the compiled-serial oracle.
  xla             `core.gee` — jitted XLA scatter-add (CPU/GPU/TPU).
  pallas          `kernels.gee_scatter` — destination-tiled one-hot
                  matmul; edges packed ONCE at plan time by destination
                  tile with their *source node* (not class), so label
                  changes re-resolve on device and never re-pack.
  streaming       chunked accumulate (O(chunk) device memory) —
                  the out-of-core / serving-rebuild path.
  distributed:M   `core.distributed.gee_sharded` for M in
                  {replicated, reduce_scatter, a2a, ring} — SPMD
                  collectives; the plan pads edges/rows to the mesh and
                  measures the exact zero-drop capacity factor once.

Register new strategies with ``@register_backend("name")``; callers
select them by name through ``Embedder(..., backend="name")`` without
touching any call site.  ``backend="auto"`` (the `EncoderConfig`
default) picks a strategy at plan time from (n, s, device kind, device
count) via the overridable `AUTO_POLICY` table below.

Every backend's plan is built in two halves:

  plan_host      expensive, label-free, DEVICE-FREE artifacts (numpy
                 arrays / scalars) — persistable by the cross-process
                 plan cache (`repro.encoder.plan_cache`);
  plan_finalize  cheap per-process work: device uploads, mesh
                 placement, chunk views — always re-run.

A cache hit hands plan() the stored host dict and skips plan_host
entirely; that is the whole point of the persistent tier.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.encoder.config import EncoderConfig
from repro.encoder.plan import Plan, effective_weights, owned_contributions
from repro.graph.edges import Graph

_REGISTRY: Dict[str, Type["Backend"]] = {}


def register_backend(name: str):
    """Class decorator: make a Backend constructible by name."""
    def deco(cls: Type["Backend"]) -> Type["Backend"]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_backend(name: str) -> "Backend":
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; registered: "
                       f"{', '.join(sorted(_REGISTRY))}") from None


def list_backends() -> list[str]:
    return sorted(_REGISTRY)


def partition_backends() -> list[str]:
    """Registered backends implementing the owned-rows accumulate path
    (`EncoderConfig.row_partition`) — the suggestion list for the
    plan-time rejection of a partition-unaware backend."""
    return sorted(n for n, c in _REGISTRY.items()
                  if c.supports_row_partition)


class Backend:
    """One execution strategy: label-free `plan`, label-dependent `embed`."""

    name: str = "?"
    #: scatter-path backends reproduce the oracle to float tolerance;
    #: bucketed collective modes additionally depend on capacity padding.
    exact: bool = True
    #: bump when the plan_host artifact layout changes — stale disk
    #: entries from older code then read as misses, not wrong plans
    plan_version: int = 1
    #: whether plan_host output may be persisted cross-process
    persistable: bool = True
    #: whether this backend implements the owned-rows accumulate path
    #: (`EncoderConfig.row_partition`): an (n_local, K) accumulator over
    #: contributions pre-bucketed by owned destination
    supports_row_partition: bool = False

    def cache_context(self, *, mesh=None) -> str:
        """Runtime context baked into the persistent-cache key (e.g.
        device count, which distributed capacity factors depend on)."""
        return ""

    def plan_host(self, graph: Graph, config: EncoderConfig,
                  w_eff: np.ndarray, *, mesh=None) -> Dict:
        """Backend-specific expensive host artifacts (numpy arrays /
        scalars only; "w_eff" is added by `plan`)."""
        return {}

    def plan_finalize(self, plan: Plan, graph: Graph, *,
                      mesh=None) -> None:
        """Populate plan.data from (graph, plan.host): device uploads,
        mesh placement, chunk views — cheap, re-run every process."""
        raise NotImplementedError

    def plan(self, graph: Graph, config: EncoderConfig, *, mesh=None,
             host: Optional[Dict] = None) -> Plan:
        """Build the plan; `host` (from the persistent cache) skips the
        expensive half.

        w_eff only rides the host dict (and hence disk) when Laplacian
        scaling makes it a real O(s) artifact; unscaled it IS graph.w,
        so persisting it would bloat every cache entry with a full
        per-edge copy that costs more to load than to recompute.
        (Partitioned plans fold w_eff into the owned contribution
        arrays, so they never persist the full-length copy either.)"""
        built = host is None
        if built:
            w_eff = effective_weights(graph, config)
            keep_w = config.laplacian and config.row_partition is None
            host = {**({"w_eff": w_eff} if keep_w else {}),
                    **self.plan_host(graph, config, w_eff, mesh=mesh)}
        if config.row_partition is not None:
            # owned plans folded the scaling into o_w: don't retain (or,
            # on a cache hit, rebuild) a second full-length copy that no
            # partitioned finalize/embed path ever reads
            w_eff = graph.w
        elif not built:
            w_eff = (host["w_eff"] if "w_eff" in host
                     else effective_weights(graph, config))
        p = Plan(backend=self.name, config=config, n=graph.n, s=graph.s,
                 w_eff=np.asarray(w_eff, np.float32), host=host,
                 **Plan.anchors(graph))
        self.plan_finalize(p, graph, mesh=mesh)
        return p

    def embed(self, plan: Plan, Yj: jnp.ndarray, Wv: jnp.ndarray
              ) -> Tuple[jnp.ndarray, dict]:
        """Return (Z (n, K) float32, info dict)."""
        raise NotImplementedError

    def _record_kernel(self, plan: Plan, Z, t0: float) -> None:
        """Kernel-level throughput telemetry (obs-on only): fence the
        result so the async dispatch is billed here, then export
        achieved edges/s — the paper's own unit — as a gauge plus the
        wall-time histogram."""
        jax.block_until_ready(Z)
        dt = obs.tock(t0)
        obs.observe("repro_kernel_embed_seconds", dt,
                    backend=self.name)
        if plan.s and dt > 0:
            obs.gauge("repro_kernel_edges_per_s", plan.s / dt,
                      backend=self.name)


def _owned_plan_host(graph: Graph, config: EncoderConfig,
                     w_eff: np.ndarray) -> Dict:
    """Shared host half of a partitioned plan: contributions bucketed
    by owned destination, destination rows remapped to [0, n_local)."""
    rows, src, w = owned_contributions(graph, w_eff,
                                       *config.row_partition)
    return {"o_rows": rows, "o_src": src, "o_w": w}


@register_backend("numpy")
class NumpyBackend(Backend):
    """`ref_python.gee_numpy`: the host-side oracle every other backend
    is conformance-checked against."""

    supports_row_partition = True

    def plan_host(self, graph, config, w_eff, *, mesh=None):
        if config.row_partition is None:
            return {}
        return _owned_plan_host(graph, config, w_eff)

    def plan_finalize(self, p, graph, *, mesh=None):
        if p.config.row_partition is None:
            p.data = {"u": np.asarray(graph.u), "v": np.asarray(graph.v)}
        else:
            h = p.host
            p.data = {"rows": np.asarray(h["o_rows"], np.int32),
                      "src": np.asarray(h["o_src"], np.int32),
                      "w": np.asarray(h["o_w"], np.float32)}

    def embed(self, plan, Yj, Wv):
        from repro.core.ref_python import gee_numpy, gee_numpy_owned
        Y = np.asarray(Yj)
        d = plan.data
        if plan.config.row_partition is not None:
            Z = gee_numpy_owned(d["rows"], d["src"], d["w"], Y,
                                np.asarray(Wv), plan.config.K,
                                plan.n_local)
            return jnp.asarray(Z), {}
        Z = gee_numpy(d["u"], d["v"], plan.w_eff, Y,
                      plan.config.K, plan.n)
        return jnp.asarray(Z), {}


@register_backend("xla")
class XlaBackend(Backend):
    """`core.gee` (jitted XLA scatter-add) — the single-device hot
    path.  Passes the Embedder-owned Wv through `gee`'s precompute
    parameter instead of re-deriving it from Y.  Under a row partition
    it scatters the pre-bucketed owned contributions into an
    (n_local, K) accumulator (`core.gee.gee_owned`)."""

    supports_row_partition = True

    def plan_host(self, graph, config, w_eff, *, mesh=None):
        if config.row_partition is None:
            return {}
        return _owned_plan_host(graph, config, w_eff)

    def plan_finalize(self, p, graph, *, mesh=None):
        if p.config.row_partition is None:
            p.data = {"u": jnp.asarray(graph.u),
                      "v": jnp.asarray(graph.v),
                      "w": jnp.asarray(p.w_eff)}
        else:
            h = p.host
            p.data = {"rows": jnp.asarray(np.asarray(h["o_rows"],
                                                     np.int32)),
                      "src": jnp.asarray(np.asarray(h["o_src"],
                                                    np.int32)),
                      "w": jnp.asarray(np.asarray(h["o_w"],
                                                  np.float32))}

    def embed(self, plan, Yj, Wv):
        from repro.core.gee import gee, gee_owned
        d = plan.data
        if plan.config.row_partition is not None:
            Z = gee_owned(d["rows"], d["src"], d["w"], Yj, Wv,
                          K=plan.config.K, n_local=plan.n_local)
            return Z, {}
        Z = gee(d["u"], d["v"], d["w"], Yj, K=plan.config.K, n=plan.n,
                Wv=Wv)
        return Z, {}


@register_backend("pallas")
class PallasBackend(Backend):
    """Destination-tiled one-hot matmul kernel.

    The plan packs (tile-local row, source node, weight) — all
    label-free — so refits resolve classes/values on device from the
    current (Y, Wv) and skip the O(s log s) host sort entirely.  Padded
    slots carry w = 0 and are no-ops for any labeling.  The packed
    buffers are the host half: a persistent-cache hit skips the sort in
    a fresh process too.

    Under a row partition the contributions bucketed by owned
    destination (`plan.owned_contributions`, destinations remapped to
    [0, hi - lo)) feed the SAME destination packing over the local row
    range, so sharded rebuilds get both the edge-parallel kernel and
    the O(n/p) (hi - lo, K) accumulator; the packed blocks are the
    persisted tier-2 artifact, keyed on the partition via the config
    token like every other backend.

    The kernel's compile/interpret mode resolves per platform at plan
    finalize (`kernels.resolve_interpret`: compiled on TPU/GPU,
    interpreter elsewhere unless the config forces a bool); the
    resolved mode lands in plan.data, the embed info dict, and the
    ``repro_kernels_pallas_interpret_mode`` gauge — it is per-process
    runtime state, never persisted.
    """

    supports_row_partition = True
    #: v2: partitioned plans pack over local rows [0, hi - lo)
    plan_version = 2

    def plan_host(self, graph, config, w_eff, *, mesh=None):
        from repro.kernels.ops import _round_up, pack_edges
        if config.row_partition is not None:
            lo, hi = config.row_partition
            dst, src, w2 = owned_contributions(graph, w_eff, lo, hi)
            n_rows = hi - lo
        else:
            u, v = np.asarray(graph.u), np.asarray(graph.v)
            dst = np.concatenate([u, v])
            src = np.concatenate([v, u])          # label donor
            w2 = np.concatenate([w_eff, w_eff])
            n_rows = graph.n
        rows, srcb, wb, T = pack_edges(dst, src, w2, n_rows,
                                       config.tile_n, config.edge_block)
        return {"rows": rows, "src": srcb, "w_packed": wb, "T": T,
                "kdim": _round_up(config.K, 8)}

    def plan_finalize(self, p, graph, *, mesh=None):
        from repro.kernels.gee_scatter import (interpret_mode_name,
                                               resolve_interpret)
        h = p.host
        interp = resolve_interpret(p.config.interpret)
        p.data = {"rows": jnp.asarray(h["rows"]),
                  "src": jnp.asarray(h["src"]),
                  "w": jnp.asarray(np.asarray(h["w_packed"], np.float32)),
                  "T": int(h["T"]), "kdim": int(h["kdim"]),
                  "interpret": interp}
        if obs.enabled():
            obs.gauge("repro_kernels_pallas_interpret_mode",
                      1.0 if interp else 0.0,
                      mode=interpret_mode_name(interp))

    def embed(self, plan, Yj, Wv):
        from repro.kernels.gee_scatter import gee_scatter_pallas
        d, cfg = plan.data, plan.config
        t0 = obs.tick()
        Ys = Yj[d["src"]]
        cls = jnp.maximum(Ys, 0)
        val = jnp.where(Ys >= 0, Wv[d["src"]] * d["w"], 0.0)
        Z = gee_scatter_pallas(d["rows"], cls, val, num_tiles=d["T"],
                               tile_n=cfg.tile_n, kdim=d["kdim"],
                               interpret=d["interpret"])
        Z = Z[:plan.n_local, :cfg.K]
        if obs.enabled():
            self._record_kernel(plan, Z, t0)
        return Z, {"interpret": d["interpret"]}


@register_backend("streaming")
class StreamingBackend(Backend):
    """`gee_streaming`'s accumulate loop over bucket-padded chunks, with
    the Embedder-owned Wv: bounded DEVICE working set — each chunk is
    uploaded, folded into Z, and released, so only O(chunk) edge data
    plus Z ever lives on device (the serving-rebuild and out-of-core
    ingestion path).  Chunks stay host-side in the plan (non-tail
    chunks are views of the caller's arrays, not copies; chunking is
    cheap, so only w_eff rides the persistent cache).

    Under a row partition the chunks are owned-destination
    contribution triples and the accumulator is (n_local, K) — device
    memory is O(chunk + n/p), the sharded serving rebuild path."""

    supports_row_partition = True

    def plan_host(self, graph, config, w_eff, *, mesh=None):
        if config.row_partition is None:
            return {}
        # the O(s) destination bucketing is the expensive half here —
        # persist it; chunking the bucketed arrays stays per-process
        return _owned_plan_host(graph, config, w_eff)

    def plan_finalize(self, p, graph, *, mesh=None):
        from repro.graph.edges import chunk_edges
        if p.config.row_partition is None:
            p.data = {"chunks": list(chunk_edges(
                np.asarray(graph.u, np.int32),
                np.asarray(graph.v, np.int32),
                p.w_eff, p.config.chunk_size))}
        else:
            h = p.host
            # chunk_edges pads tails with (0, 0, 0.0) triples — local
            # row 0 with w = 0 is a no-op contribution for any labeling
            p.data = {"chunks": list(chunk_edges(
                np.asarray(h["o_rows"], np.int32),
                np.asarray(h["o_src"], np.int32),
                np.asarray(h["o_w"], np.float32),
                p.config.chunk_size))}

    def embed(self, plan, Yj, Wv):
        from repro.core.gee import gee_streaming, gee_streaming_owned
        cfg = plan.config
        t0 = obs.tick()
        if cfg.row_partition is not None:
            Z = gee_streaming_owned(
                ((jnp.asarray(r), jnp.asarray(s), jnp.asarray(w))
                 for (r, s, w) in plan.data["chunks"]),
                Yj, K=cfg.K, n_local=plan.n_local, Wv=Wv)
        else:
            Z = gee_streaming(
                ((jnp.asarray(u), jnp.asarray(v), jnp.asarray(w))
                 for (u, v, w) in plan.data["chunks"]),
                Yj, K=cfg.K, n=plan.n, Wv=Wv)
        if obs.enabled():
            self._record_kernel(plan, Z, t0)
        return Z, {"chunks": len(plan.data["chunks"])}


class DistributedBackend(Backend):
    """SPMD collectives over the edge mesh (`core.distributed`).

    The plan pads edges and rows to the mesh, places the padded arrays,
    and — for bucketed modes — measures the exact zero-drop capacity
    factor from the owner histogram (an O(s) host pass now done once
    instead of per fit).  The capacity factor depends on the device
    count, so it is the persisted host artifact and the device count is
    baked into the cache key (`cache_context`); padding and placement
    are per-process finalize work.
    """

    mode = "ring"
    exact = False          # bucketed modes depend on capacity padding

    @staticmethod
    def _mesh(mesh):
        from repro.core.distributed import edge_mesh
        return mesh if mesh is not None else edge_mesh()

    def cache_context(self, *, mesh=None) -> str:
        return f"nd={self._mesh(mesh).devices.size}"

    def plan_host(self, graph, config, w_eff, *, mesh=None):
        from repro.core.distributed import exact_capacity_factor
        nd = self._mesh(mesh).devices.size
        cf = config.capacity_factor
        if cf is None and self.mode in ("a2a", "ring"):
            cf = exact_capacity_factor(graph, nd)
        return {"capacity_factor": cf if cf is not None else 2.0}

    def plan_finalize(self, p, graph, *, mesh=None):
        from repro.core.distributed import pad_rows
        mesh = self._mesh(mesh)
        nd = mesh.devices.size
        n_pad = pad_rows(graph.n, nd)
        s_pad = pad_rows(graph.s, nd)
        g = Graph(np.asarray(graph.u), np.asarray(graph.v), p.w_eff,
                  graph.n).pad_to(s_pad)
        p.data = {"mesh": mesh, "n_pad": n_pad,
                  "capacity_factor": float(p.host["capacity_factor"]),
                  "u": jnp.asarray(g.u), "v": jnp.asarray(g.v),
                  "w": jnp.asarray(g.w)}

    def embed(self, plan, Yj, Wv):
        from repro.core.distributed import gee_sharded
        d, cfg = plan.data, plan.config
        Y_pad = jnp.concatenate([
            Yj, jnp.full(d["n_pad"] - plan.n, -1, jnp.int32)])
        Z, dropped = gee_sharded(
            d["u"], d["v"], d["w"], Y_pad, K=cfg.K, n=d["n_pad"],
            mesh=d["mesh"], mode=self.mode,
            capacity_factor=d["capacity_factor"])
        return Z[:plan.n], {"dropped": int(dropped)}


for _mode in ("replicated", "reduce_scatter", "a2a", "ring"):
    # replicated / reduce_scatter are pure scatter+collective paths
    # (float-exact); a2a / ring bucket with capacity padding.
    register_backend(f"distributed:{_mode}")(
        type(f"Distributed{_mode.title().replace('_', '')}Backend",
             (DistributedBackend,),
             {"mode": _mode,
              "exact": _mode in ("replicated", "reduce_scatter")}))


# -- backend="auto": the plan-time selection policy -------------------------

#: edge count past which a single device should stop holding the whole
#: edge list and stream chunks instead (tunable; ~3 int/float arrays of
#: this length is the resident cost the threshold bounds)
AUTO_STREAMING_EDGES = 32_000_000


def _rule_multi_device(n, s, device_kind, device_count):
    return "distributed:reduce_scatter" if device_count > 1 else None


def _rule_out_of_core(n, s, device_kind, device_count):
    return "streaming" if s >= AUTO_STREAMING_EDGES else None


def _rule_tpu_kernel(n, s, device_kind, device_count):
    return "pallas" if device_kind == "tpu" else None


#: ordered (name, rule(n, s, device_kind, device_count) -> backend name
#: or None) pairs; the first rule returning a name wins, fallback is
#: "xla".  Overridable: mutate this list (insert/replace rules) to
#: change policy globally — it is data, not code.
AUTO_POLICY: List[Tuple[str, Callable]] = [
    ("multi_device", _rule_multi_device),
    ("out_of_core", _rule_out_of_core),
    ("tpu_kernel", _rule_tpu_kernel),
]


def resolve_auto(n: int, s: int, *, device_kind: Optional[str] = None,
                 device_count: Optional[int] = None, mesh=None) -> str:
    """Resolve `backend="auto"` for a graph of (n, s) on this runtime.

    Device kind/count default to the provided mesh, else
    `jax.devices()`.  Walks `AUTO_POLICY` in order; first hit wins,
    fallback "xla".  Pure given explicit kind/count (unit-testable
    without hardware)."""
    if device_kind is None or device_count is None:
        devs = (list(mesh.devices.flat) if mesh is not None
                else jax.devices())
        if device_kind is None:
            device_kind = devs[0].platform
        if device_count is None:
            device_count = len(devs)
    for _, rule in AUTO_POLICY:
        name = rule(n, s, device_kind, device_count)
        if name is not None:
            return name
    return "xla"
