"""Backend registry: every execution strategy behind one interface.

A backend turns a `Plan` plus the *current* labels into Z.  All of them
compute the same mathematical object (conformance-tested); they differ
in where the scatter runs and how contributions move:

  numpy           `ref_python.gee_numpy` — the compiled-serial oracle.
  xla             `core.gee` — jitted XLA scatter-add (CPU/GPU/TPU).
  pallas          `kernels.gee_scatter` — destination-tiled one-hot
                  matmul; edges packed ONCE at plan time by destination
                  tile with their *source node* (not class), so label
                  changes re-resolve on device and never re-pack.
  streaming       chunked accumulate (O(chunk) device memory) —
                  the out-of-core / serving-rebuild path.
  distributed:M   `core.distributed.gee_sharded` for M in
                  {replicated, reduce_scatter, a2a, ring} — SPMD
                  collectives; the plan pads edges/rows to the mesh and
                  measures the exact zero-drop capacity factor once.

Register new strategies with ``@register_backend("name")``; callers
select them by name through ``Embedder(..., backend="name")`` without
touching any call site.
"""
from __future__ import annotations

from typing import Dict, Tuple, Type

import jax.numpy as jnp
import numpy as np

from repro.encoder.config import EncoderConfig
from repro.encoder.plan import Plan, effective_weights
from repro.graph.edges import Graph

_REGISTRY: Dict[str, Type["Backend"]] = {}


def register_backend(name: str):
    """Class decorator: make a Backend constructible by name."""
    def deco(cls: Type["Backend"]) -> Type["Backend"]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_backend(name: str) -> "Backend":
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; registered: "
                       f"{', '.join(sorted(_REGISTRY))}") from None


def list_backends() -> list[str]:
    return sorted(_REGISTRY)


class Backend:
    """One execution strategy: label-free `plan`, label-dependent `embed`."""

    name: str = "?"
    #: scatter-path backends reproduce the oracle to float tolerance;
    #: bucketed collective modes additionally depend on capacity padding.
    exact: bool = True

    def _base(self, graph: Graph, config: EncoderConfig) -> Plan:
        return Plan(backend=self.name, config=config, n=graph.n, s=graph.s,
                    w_eff=effective_weights(graph, config),
                    **Plan.anchors(graph))

    def plan(self, graph: Graph, config: EncoderConfig, *,
             mesh=None) -> Plan:
        raise NotImplementedError

    def embed(self, plan: Plan, Yj: jnp.ndarray, Wv: jnp.ndarray
              ) -> Tuple[jnp.ndarray, dict]:
        """Return (Z (n, K) float32, info dict)."""
        raise NotImplementedError


@register_backend("numpy")
class NumpyBackend(Backend):
    """`ref_python.gee_numpy`: the host-side oracle every other backend
    is conformance-checked against."""

    def plan(self, graph, config, *, mesh=None):
        p = self._base(graph, config)
        p.data = {"u": np.asarray(graph.u), "v": np.asarray(graph.v)}
        return p

    def embed(self, plan, Yj, Wv):
        from repro.core.ref_python import gee_numpy
        Y = np.asarray(Yj)
        Z = gee_numpy(plan.data["u"], plan.data["v"], plan.w_eff, Y,
                      plan.config.K, plan.n)
        return jnp.asarray(Z), {}


@register_backend("xla")
class XlaBackend(Backend):
    """`core.gee` (jitted XLA scatter-add) — the single-device hot
    path.  Passes the Embedder-owned Wv through `gee`'s precompute
    parameter instead of re-deriving it from Y."""

    def plan(self, graph, config, *, mesh=None):
        p = self._base(graph, config)
        p.data = {"u": jnp.asarray(graph.u), "v": jnp.asarray(graph.v),
                  "w": jnp.asarray(p.w_eff)}
        return p

    def embed(self, plan, Yj, Wv):
        from repro.core.gee import gee
        d = plan.data
        Z = gee(d["u"], d["v"], d["w"], Yj, K=plan.config.K, n=plan.n,
                Wv=Wv)
        return Z, {}


@register_backend("pallas")
class PallasBackend(Backend):
    """Destination-tiled one-hot matmul kernel.

    The plan packs (tile-local row, source node, weight) — all
    label-free — so refits resolve classes/values on device from the
    current (Y, Wv) and skip the O(s log s) host sort entirely.  Padded
    slots carry w = 0 and are no-ops for any labeling.
    """

    def plan(self, graph, config, *, mesh=None):
        from repro.kernels.ops import _round_up, pack_edges
        p = self._base(graph, config)
        u, v = np.asarray(graph.u), np.asarray(graph.v)
        dst = np.concatenate([u, v])
        src = np.concatenate([v, u])          # label donor
        w2 = np.concatenate([p.w_eff, p.w_eff])
        rows, srcb, wb, T = pack_edges(dst, src, w2, graph.n,
                                       config.tile_n, config.edge_block)
        p.data = {"rows": jnp.asarray(rows), "src": jnp.asarray(srcb),
                  "w": jnp.asarray(wb), "T": T,
                  "kdim": _round_up(config.K, 8)}
        return p

    def embed(self, plan, Yj, Wv):
        from repro.kernels.gee_scatter import gee_scatter_pallas
        d, cfg = plan.data, plan.config
        Ys = Yj[d["src"]]
        cls = jnp.maximum(Ys, 0)
        val = jnp.where(Ys >= 0, Wv[d["src"]] * d["w"], 0.0)
        Z = gee_scatter_pallas(d["rows"], cls, val, num_tiles=d["T"],
                               tile_n=cfg.tile_n, kdim=d["kdim"],
                               interpret=cfg.interpret)
        return Z[:plan.n, :cfg.K], {}


@register_backend("streaming")
class StreamingBackend(Backend):
    """`gee_streaming`'s accumulate loop over bucket-padded chunks, with
    the Embedder-owned Wv: bounded DEVICE working set — each chunk is
    uploaded, folded into Z, and released, so only O(chunk) edge data
    plus Z ever lives on device (the serving-rebuild and out-of-core
    ingestion path).  Chunks stay host-side in the plan (non-tail
    chunks are views of the caller's arrays, not copies)."""

    def plan(self, graph, config, *, mesh=None):
        from repro.graph.edges import chunk_edges
        p = self._base(graph, config)
        p.data = {"chunks": list(chunk_edges(
            np.asarray(graph.u, np.int32), np.asarray(graph.v, np.int32),
            p.w_eff, config.chunk_size))}
        return p

    def embed(self, plan, Yj, Wv):
        from repro.core.gee import gee_streaming
        cfg = plan.config
        Z = gee_streaming(
            ((jnp.asarray(u), jnp.asarray(v), jnp.asarray(w))
             for (u, v, w) in plan.data["chunks"]),
            Yj, K=cfg.K, n=plan.n, Wv=Wv)
        return Z, {"chunks": len(plan.data["chunks"])}


class DistributedBackend(Backend):
    """SPMD collectives over the edge mesh (`core.distributed`).

    The plan pads edges and rows to the mesh, places the padded arrays,
    and — for bucketed modes — measures the exact zero-drop capacity
    factor from the owner histogram (an O(s) host pass now done once
    instead of per fit).
    """

    mode = "ring"
    exact = False          # bucketed modes depend on capacity padding

    def plan(self, graph, config, *, mesh=None):
        from repro.core.distributed import (edge_mesh,
                                            exact_capacity_factor,
                                            pad_rows)
        p = self._base(graph, config)
        mesh = mesh if mesh is not None else edge_mesh()
        nd = mesh.devices.size
        cf = config.capacity_factor
        if cf is None and self.mode in ("a2a", "ring"):
            cf = exact_capacity_factor(graph, nd)
        n_pad = pad_rows(graph.n, nd)
        s_pad = pad_rows(graph.s, nd)
        g = Graph(np.asarray(graph.u), np.asarray(graph.v), p.w_eff,
                  graph.n).pad_to(s_pad)
        p.data = {"mesh": mesh, "n_pad": n_pad,
                  "capacity_factor": cf if cf is not None else 2.0,
                  "u": jnp.asarray(g.u), "v": jnp.asarray(g.v),
                  "w": jnp.asarray(g.w)}
        return p

    def embed(self, plan, Yj, Wv):
        from repro.core.distributed import gee_sharded
        d, cfg = plan.data, plan.config
        Y_pad = jnp.concatenate([
            Yj, jnp.full(d["n_pad"] - plan.n, -1, jnp.int32)])
        Z, dropped = gee_sharded(
            d["u"], d["v"], d["w"], Y_pad, K=cfg.K, n=d["n_pad"],
            mesh=d["mesh"], mode=self.mode,
            capacity_factor=d["capacity_factor"])
        return Z[:plan.n], {"dropped": int(dropped)}


for _mode in ("replicated", "reduce_scatter", "a2a", "ring"):
    # replicated / reduce_scatter are pure scatter+collective paths
    # (float-exact); a2a / ring bucket with capacity padding.
    register_backend(f"distributed:{_mode}")(
        type(f"Distributed{_mode.title().replace('_', '')}Backend",
             (DistributedBackend,),
             {"mode": _mode,
              "exact": _mode in ("replicated", "reduce_scatter")}))
