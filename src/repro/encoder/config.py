"""EncoderConfig: everything about *what* to compute, none of *how*.

The paper's algorithm has one mathematical definition (Z = scatter-add
of per-edge label contributions) and many execution strategies.  The
config captures the math-level choices — number of classes, Laplacian
scaling, refinement schedule, output dtype — plus the per-backend
tuning knobs (tile sizes, chunk sizes, capacity factors) that change
performance but never the answer.  Frozen and hashable so plans can be
keyed on it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple, Union


@dataclass(frozen=True)
class EncoderConfig:
    """Configuration for :class:`repro.encoder.Embedder`.

    Math-level options (change Z):
      K           number of classes / embedding dimension.
      laplacian   GEE paper's Laplacian scaling w' = w/sqrt(deg_u*deg_v).
                  Applied once at plan time (degrees are label-free), so
                  every backend sees pre-scaled weights.
      dtype       output dtype of ``transform`` ("float32"/"bfloat16"/...).
                  Z is always accumulated in float32.

    Refinement (unsupervised GEE clustering, ``Embedder.refine``):
      refine_iters   embed -> k-means -> reassign rounds.
      kmeans_iters   k-means steps per round.

    Row partitioning (owned-rows accumulate — shrinks Z, not its rows):
      row_partition  (lo, hi) global row range this Embedder OWNS (a
                  `graph.partition.RowPartition` slice), or None for
                  the full embedding.  When set, the plan buckets edge
                  contributions by owned destination (remapped to local
                  rows [0, hi - lo)), the backend allocates only an
                  (hi - lo, K) accumulator, and the fitted `Z_` holds
                  exactly the owned rows — labels stay GLOBAL (an owned
                  row's value depends on its neighbors' labels), and
                  node-id arguments to `transform`/`predict` stay
                  global too.  The partition joins the plan-cache key
                  (tier 1 and tier 2), so a resharded deployment can
                  never hit a stale plan.  Supported by the numpy /
                  xla / streaming / pallas backends (the distributed
                  collective modes shard internally instead).

    Backend tuning (never change Z, only speed/memory):
      backend     execution strategy by registry name, or "auto"
                  (default) — resolved at plan time from (n, s, device
                  kind, device count) via the overridable policy table
                  in `repro.encoder.backends.AUTO_POLICY`.  An explicit
                  `Embedder(..., backend=...)` argument overrides this.
      tile_n, edge_block, interpret   Pallas kernel geometry.
      chunk_size                      streaming chunk length.
      capacity_factor                 distributed bucket padding; None
                                      measures the exact zero-drop factor
                                      from the owner histogram (cached in
                                      the plan).
    """

    K: int
    laplacian: bool = False
    dtype: str = "float32"
    backend: str = "auto"
    row_partition: Optional[Tuple[int, int]] = None
    # refinement
    refine_iters: int = 10
    kmeans_iters: int = 3
    # pallas: interpret is "auto" (compiled on TPU/GPU, interpreter
    # elsewhere — resolved at plan time by kernels.resolve_interpret),
    # or an explicit bool to force a mode
    tile_n: int = 256
    edge_block: int = 512
    interpret: Union[bool, str] = "auto"
    # streaming
    chunk_size: int = 1 << 20
    # distributed
    capacity_factor: Optional[float] = None

    def __post_init__(self) -> None:
        if self.K < 1:
            raise ValueError(f"K must be >= 1, got {self.K}")
        if self.chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        if not isinstance(self.interpret, bool) and \
                self.interpret != "auto":
            raise ValueError(
                f"interpret must be True, False, or 'auto', got "
                f"{self.interpret!r}")
        if self.row_partition is not None:
            try:
                lo, hi = self.row_partition
            except (TypeError, ValueError):
                raise ValueError(
                    f"row_partition must be a (lo, hi) pair, got "
                    f"{self.row_partition!r}") from None
            if not (0 <= int(lo) < int(hi)):
                raise ValueError(
                    f"row_partition needs 0 <= lo < hi, got ({lo}, {hi})")
            # normalize (lists, np ints) so the config stays hashable
            # and its cache token is canonical
            object.__setattr__(self, "row_partition",
                               (int(lo), int(hi)))
