"""GEE <-> LM bridge: embedding-table initialization from a token
co-occurrence graph (the canonical home; `repro.core.embed_init` is a
lazy deprecation shim over this module).

GEE's role in the original papers is a near-free spectral-like
embedding.  Here we apply it to the one place an LM has a graph: the
vocabulary.  Build a co-occurrence graph over token ids from the
training stream (edge (a, b, count) when b follows a within a window),
cluster it with unsupervised GEE refinement through the unified
`Embedder` front door, then project K -> d_model with
`Embedder.to_features` (fixed random rotation + scaled-noise blend).
This gives the embedding table a topic-structured starting point at
O(s) cost, through the same plan-cached, backend-pluggable path as
every other embedding in the system.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.encoder.config import EncoderConfig
from repro.encoder.embedder import Embedder
from repro.graph.edges import Graph


def token_cooccurrence(tokens: np.ndarray, vocab: int, window: int = 2,
                       max_edges: int = 2_000_000) -> Graph:
    """tokens: (N,) int stream -> co-occurrence edge list (deduplicated
    with counts as weights)."""
    pairs = []
    for d in range(1, window + 1):
        a, b = tokens[:-d], tokens[d:]
        pairs.append(np.stack([a, b], 1))
    e = np.concatenate(pairs, 0)
    key = e[:, 0].astype(np.int64) * vocab + e[:, 1]
    uniq, counts = np.unique(key, return_counts=True)
    if uniq.shape[0] > max_edges:
        top = np.argsort(-counts)[:max_edges]
        uniq, counts = uniq[top], counts[top]
    u = (uniq // vocab).astype(np.int32)
    v = (uniq % vocab).astype(np.int32)
    return Graph(u, v, counts.astype(np.float32), vocab)


def gee_embedding_init(tokens: np.ndarray, vocab: int, d_model: int,
                       K: int = 64, key=None, window: int = 2,
                       refine_iters: int = 6,
                       blend: float = 0.5) -> np.ndarray:
    """(vocab, d_model) initializer built from GEE over co-occurrences:
    unsupervised `Embedder.refine` clustering, then
    `Embedder.to_features`."""
    key = key if key is not None else jax.random.PRNGKey(0)
    g = token_cooccurrence(tokens, vocab, window)
    K = min(K, max(2, vocab // 4))
    k_refine, k_project = jax.random.split(key)
    emb = Embedder(EncoderConfig(K=K, refine_iters=refine_iters),
                   backend="xla")
    emb.fit(g, np.full(vocab, -1, np.int32))
    emb.refine(k_refine)
    return emb.to_features(d_model, key=k_project, blend=blend)
