"""Plan: the cached, label-independent half of an embedding.

Every GEE backend splits into two phases:

  1. **plan** — host-side preprocessing that depends only on the edge
     multiset and the config: Laplacian degree precompute + weight
     scaling, padding, destination-tile packing (Pallas), owner-bucket
     capacity measurement and edge padding (distributed), chunking
     (streaming), device placement.  O(s) to O(s log s).
  2. **embed** — the label-dependent pass: resolve per-edge classes and
     projection weights from the *current* Y and scatter.  O(s) device
     work, no host packing.

The split is what makes refits cheap: labels change every refinement
round and every serving epoch, the edge multiset does not.  A `Plan`
therefore carries only label-free artifacts and is reused across
`fit`/`refit` calls on the same graph (matched by array identity —
O(1), no content hashing; a new edge multiset means new arrays means a
new plan).

The plan itself splits once more, into a **host** half and a **device**
half (`Backend.plan_host` / `Backend.plan_finalize`): the host half is
the expensive, device-free preprocessing (w_eff, Pallas destination
packing, distributed capacity factors) and is what the persistent
cross-process plan cache (`repro.encoder.plan_cache`) persists, keyed
on the graph's content fingerprint; the device half (uploads, mesh
placement, chunk views) is rebuilt cheaply in every process.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import numpy as np

from repro.encoder.config import EncoderConfig
from repro.graph.edges import Graph


def owned_contributions(graph: Graph, w_eff: np.ndarray, lo: int,
                        hi: int) -> tuple:
    """Bucket the edge multiset by OWNED destination row.

    Each edge (u, v, w) contributes to rows u (from source v) and v
    (from source u); a row partition owning [lo, hi) only ever
    accumulates the contributions whose destination falls in that
    range.  Returns (rows, src, w): LOCAL destination rows in
    [0, hi - lo), GLOBAL label-donor nodes, effective weights — the
    label-free host artifact of a partitioned plan (persisted by the
    tier-2 cache; O(s) to build, ~2s/p entries to store).

    Laplacian scaling happens upstream in `effective_weights`, against
    the degrees of the graph as passed — pass the FULL unpadded graph
    (not a routed sub-multiset) when `laplacian=True`, so the
    normalizer sees every edge of every endpoint."""
    u = np.asarray(graph.u)
    v = np.asarray(graph.v)
    w = np.asarray(w_eff, np.float32)
    dst = np.concatenate([u, v])
    src = np.concatenate([v, u])          # label donor
    wc = np.concatenate([w, w])
    m = (dst >= lo) & (dst < hi)
    return ((dst[m] - lo).astype(np.int32),
            src[m].astype(np.int32),
            wc[m].astype(np.float32))


def effective_weights(graph: Graph, config: EncoderConfig) -> np.ndarray:
    """Laplacian-scaled weights, computed ONCE per plan.

    Degrees come from the **unpadded** graph in float64 (`Graph.degrees`)
    so backend-specific padding can never perturb the normalizer; all
    backends then run the plain (laplacian=False) kernel on w_eff and
    agree on Z by construction.
    """
    w = np.asarray(graph.w, np.float32)
    if not config.laplacian:
        return w
    deg = graph.degrees()
    scale = 1.0 / np.sqrt(np.maximum(deg, 1.0), dtype=np.float64)
    w_eff = (w.astype(np.float64) * scale[graph.u] * scale[graph.v])
    return w_eff.astype(np.float32)


@dataclass
class Plan:
    """Cached per-backend preprocessing for one (graph, config) pair."""

    backend: str
    config: EncoderConfig
    n: int
    s: int
    w_eff: np.ndarray                   # laplacian-scaled edge weights
    data: Dict[str, Any] = field(default_factory=dict)
    #: the persistable host half (np arrays / scalars only) — what the
    #: cross-process plan cache stores; carries "w_eff" only when
    #: Laplacian scaling makes it a real artifact (else it is graph.w)
    host: Dict[str, Any] = field(default_factory=dict)
    # identity anchors for O(1) cache matching
    _u: Optional[np.ndarray] = None
    _v: Optional[np.ndarray] = None
    _w: Optional[np.ndarray] = None

    @property
    def n_local(self) -> int:
        """Accumulator height: hi - lo under a row partition, else n.
        (`n` stays the GLOBAL node count — labels are always (n,).)"""
        rp = self.config.row_partition
        return self.n if rp is None else rp[1] - rp[0]

    @classmethod
    def anchors(cls, graph: Graph) -> dict:
        return {"_u": graph.u, "_v": graph.v, "_w": graph.w}

    def matches(self, graph: Graph, backend: str,
                config: EncoderConfig) -> bool:
        """True iff this plan was built for exactly these arrays."""
        return (self.backend == backend and self.config == config
                and self.n == graph.n
                and self._u is graph.u and self._v is graph.v
                and self._w is graph.w)
