"""Persistent cross-process plan cache: tier 2 of `Embedder.plan`.

Tier 1 (in `Embedder`) matches plans by array identity — O(1), but it
dies with the process.  GEE's practical workload re-embeds the *same
graph* many times as labels churn, across restarts, CI reruns, and
serving replicas; for those, graph identity is content, not arrays.
This module stores each plan's **host half** (w_eff, Pallas destination
packing, distributed capacity factors — everything expensive and
device-free) on disk, keyed on:

    (graph fingerprint, backend name, backend plan_version,
     config fields, backend cache context e.g. device count)

so a fresh process skips host packing entirely and goes straight to
`Backend.plan_finalize` (cheap device placement).

Location: ``$REPRO_PLAN_CACHE`` if set (the values ``0 / off / none /
disable(d)`` or empty disable the tier), else
``$XDG_CACHE_HOME/repro-gee/plans`` (``~/.cache/repro-gee/plans``).

Robustness contract (tested):
  * writes are atomic (tmp file + os.replace) — a crashed writer can
    never leave a partial entry visible;
  * entries are versioned (format + per-backend plan_version) and
    self-describing — a stale entry is treated as a miss and rebuilt;
  * a corrupt entry (truncated, garbage) is deleted and rebuilt — the
    cache can only ever cost a rebuild, never a wrong answer;
  * a hit is verified against the request's full metadata, so a key
    collision degrades to a miss.

Plan directories would otherwise grow without bound (every distinct
(graph, backend, config) writes an entry, and serving fleets churn
graphs): construct with ``max_entries=`` / ``max_bytes=`` (or set
``REPRO_PLAN_CACHE_MAX_ENTRIES`` / ``REPRO_PLAN_CACHE_MAX_BYTES`` for
the default cache) and the cache evicts **least-recently-used**
entries after each store — a hit touches the entry's mtime, so
`last_used` recency is tracked by the filesystem with no side index to
corrupt.  Eviction is best-effort like everything else here: it can
only ever cost a rebuild.

``PlanDiskCache.clear()`` wipes the directory (also: just delete it),
and ``python -m repro.encoder.plan_cache --stats|--clear`` does both
from the shell.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import time
from dataclasses import asdict
from pathlib import Path
from typing import Any, Dict, Optional

import numpy as np

from repro import obs

FORMAT_VERSION = 1
_META_KEY = "__meta__"
_OFF_VALUES = ("", "0", "off", "none", "disable", "disabled")


def config_token(config) -> str:
    """Canonical string of the config fields a plan depends on.  The
    `backend` field is excluded: the resolved backend NAME is its own
    key component (so `backend="auto"` and an explicit name that auto
    resolves to share entries).  `row_partition` is deliberately IN
    the token — a partitioned plan's host artifacts are bucketed and
    remapped for one specific (lo, hi) slice, so a resharded
    deployment addresses different entries and can never hit a stale
    plan (tested in test_encoder.py::TestOwnedRows)."""
    d = {k: v for k, v in asdict(config).items() if k != "backend"}
    return json.dumps(d, sort_keys=True)


class PlanDiskCache:
    """Content-addressed npz store for plan host halves.

    `max_entries` / `max_bytes` (None = unbounded) cap the directory;
    when a store pushes it over, least-recently-used entries are
    evicted (`last_used` = file mtime, refreshed on every hit)."""

    def __init__(self, root, *, max_entries: Optional[int] = None,
                 max_bytes: Optional[int] = None):
        self.root = Path(root)
        self.max_entries = max_entries
        self.max_bytes = max_bytes

    # -- keying -----------------------------------------------------------

    def describe(self, fingerprint: str, backend, config, *,
                 mesh=None) -> Dict[str, Any]:
        """The full metadata a cached entry must match to be served."""
        return {"format": FORMAT_VERSION,
                "fingerprint": fingerprint,
                "backend": backend.name,
                "plan_version": backend.plan_version,
                "config": config_token(config),
                "context": backend.cache_context(mesh=mesh)}

    @staticmethod
    def key(meta: Dict[str, Any]) -> str:
        blob = json.dumps(meta, sort_keys=True).encode()
        return hashlib.blake2b(blob, digest_size=16).hexdigest()

    def path(self, meta: Dict[str, Any]) -> Path:
        return self.root / (self.key(meta) + ".npz")

    # -- load / store -----------------------------------------------------

    def load(self, meta: Dict[str, Any]) -> Optional[Dict[str, Any]]:
        """The stored host dict, or None (miss / stale / corrupt).

        Corrupt entries are deleted so the subsequent rebuild's store
        replaces them; stale ones (old format, different config hash
        behind a colliding key) are simply ignored."""
        path = self.path(meta)
        if not path.exists():
            return None
        try:
            with np.load(path, allow_pickle=False) as d:
                stored = json.loads(str(d[_META_KEY][()]))
                if stored != meta:
                    obs.counter("repro_encoder_plan_cache_total",
                                event="stale")
                    return None                       # stale / collision
                host = {k: d[k] for k in d.files if k != _META_KEY}
            try:
                os.utime(path)          # refresh last_used for the LRU
            except OSError:
                pass
            return host
        except Exception:
            obs.counter("repro_encoder_plan_cache_total",
                        event="corrupt")
            try:
                path.unlink()
            except OSError:
                pass
            return None

    def store(self, meta: Dict[str, Any], host: Dict[str, Any]) -> bool:
        """Atomically persist `host` under `meta`'s key.  Best-effort:
        an unwritable cache dir must never break embedding."""
        path = self.path(meta)
        tmp = path.with_name(f"{path.stem}.tmp{os.getpid()}.npz")
        try:
            self.root.mkdir(parents=True, exist_ok=True)
            with open(tmp, "wb") as f:
                np.savez(f, **{_META_KEY: np.asarray(json.dumps(meta))},
                         **host)
            os.replace(tmp, path)
            self.evict()
            return True
        except Exception:
            try:
                tmp.unlink()
            except OSError:
                pass
            return False

    # -- maintenance ------------------------------------------------------

    def entries(self) -> list[Path]:
        if not self.root.is_dir():
            return []
        return sorted(p for p in self.root.glob("*.npz"))

    def evict(self) -> int:
        """Drop least-recently-used entries until the directory fits
        `max_entries` / `max_bytes`.  Returns how many were removed.
        Best-effort: races with other processes (an entry vanishing
        under us) and unwritable dirs are ignored."""
        if self.max_entries is None and self.max_bytes is None:
            return 0
        stats = []
        for p in self.entries():
            try:
                st = p.stat()
                stats.append((st.st_mtime, p.name, st.st_size, p))
            except OSError:
                continue
        stats.sort()                    # oldest last_used first
        total = sum(s[2] for s in stats)
        removed = 0
        while stats and (
                (self.max_entries is not None
                 and len(stats) > self.max_entries)
                or (self.max_bytes is not None
                    and total > self.max_bytes)):
            _, _, size, path = stats.pop(0)
            try:
                path.unlink()
                removed += 1
            except OSError:
                pass
            total -= size
        if removed:
            obs.counter("repro_encoder_plan_cache_total", removed,
                        event="evict")
        return removed

    def stats(self) -> Dict[str, Any]:
        """Directory summary for the CLI / observability."""
        entries = []
        for p in self.entries():
            try:
                st = p.stat()
                entries.append((st.st_mtime, st.st_size))
            except OSError:
                continue
        now = time.time()
        return {"root": str(self.root),
                "entries": len(entries),
                "bytes": sum(s for _, s in entries),
                "max_entries": self.max_entries,
                "max_bytes": self.max_bytes,
                "oldest_idle_s": (now - min(m for m, _ in entries)
                                  if entries else 0.0),
                "newest_idle_s": (now - max(m for m, _ in entries)
                                  if entries else 0.0)}

    def clear(self) -> int:
        """Delete every entry; returns how many were removed."""
        removed = 0
        for p in self.entries():
            try:
                p.unlink()
                removed += 1
            except OSError:
                pass
        return removed


def _env_limit(name: str) -> Optional[int]:
    raw = os.environ.get(name)
    if raw is None or not raw.strip():
        return None
    try:
        val = int(raw)
    except ValueError:
        return None
    return val if val > 0 else None


def default_cache() -> Optional[PlanDiskCache]:
    """Resolve the process-wide default cache from the environment
    (None = persistent tier disabled).  REPRO_PLAN_CACHE_MAX_ENTRIES /
    REPRO_PLAN_CACHE_MAX_BYTES bound it with LRU eviction."""
    limits = {"max_entries": _env_limit("REPRO_PLAN_CACHE_MAX_ENTRIES"),
              "max_bytes": _env_limit("REPRO_PLAN_CACHE_MAX_BYTES")}
    env = os.environ.get("REPRO_PLAN_CACHE")
    if env is not None:
        if env.strip().lower() in _OFF_VALUES:
            return None
        return PlanDiskCache(env, **limits)
    base = os.environ.get("XDG_CACHE_HOME") or (Path.home() / ".cache")
    return PlanDiskCache(Path(base) / "repro-gee" / "plans", **limits)


def main(argv=None) -> int:
    """CLI: inspect or clear the persistent plan cache.

        python -m repro.encoder.plan_cache --stats
        python -m repro.encoder.plan_cache --clear
        python -m repro.encoder.plan_cache --dir /path --stats
    """
    ap = argparse.ArgumentParser(
        prog="repro.encoder.plan_cache",
        description="Inspect or clear the persistent GEE plan cache.")
    ap.add_argument("--dir", default=None,
                    help="cache directory (default: the resolved "
                         "REPRO_PLAN_CACHE / XDG location)")
    ap.add_argument("--stats", action="store_true",
                    help="print entry count / bytes / idle ages "
                         "(the default action)")
    ap.add_argument("--clear", action="store_true",
                    help="delete every cached entry")
    args = ap.parse_args(argv)
    cache = (PlanDiskCache(args.dir) if args.dir is not None
             else default_cache())
    if cache is None:
        print("plan cache disabled (REPRO_PLAN_CACHE="
              f"{os.environ.get('REPRO_PLAN_CACHE')!r})")
        return 1
    if args.clear:
        print(f"cleared {cache.clear()} entr(y|ies) from {cache.root}")
    if args.stats or not args.clear:
        st = cache.stats()
        print(f"root:        {st['root']}")
        print(f"entries:     {st['entries']}")
        print(f"bytes:       {st['bytes']:,}")
        print(f"limits:      max_entries={st['max_entries']} "
              f"max_bytes={st['max_bytes']}")
        if st["entries"]:
            print(f"oldest idle: {st['oldest_idle_s']:.0f}s   "
                  f"newest idle: {st['newest_idle_s']:.0f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
