"""Persistent cross-process plan cache: tier 2 of `Embedder.plan`.

Tier 1 (in `Embedder`) matches plans by array identity — O(1), but it
dies with the process.  GEE's practical workload re-embeds the *same
graph* many times as labels churn, across restarts, CI reruns, and
serving replicas; for those, graph identity is content, not arrays.
This module stores each plan's **host half** (w_eff, Pallas destination
packing, distributed capacity factors — everything expensive and
device-free) on disk, keyed on:

    (graph fingerprint, backend name, backend plan_version,
     config fields, backend cache context e.g. device count)

so a fresh process skips host packing entirely and goes straight to
`Backend.plan_finalize` (cheap device placement).

Location: ``$REPRO_PLAN_CACHE`` if set (the values ``0 / off / none /
disable(d)`` or empty disable the tier), else
``$XDG_CACHE_HOME/repro-gee/plans`` (``~/.cache/repro-gee/plans``).

Robustness contract (tested):
  * writes are atomic (tmp file + os.replace) — a crashed writer can
    never leave a partial entry visible;
  * entries are versioned (format + per-backend plan_version) and
    self-describing — a stale entry is treated as a miss and rebuilt;
  * a corrupt entry (truncated, garbage) is deleted and rebuilt — the
    cache can only ever cost a rebuild, never a wrong answer;
  * a hit is verified against the request's full metadata, so a key
    collision degrades to a miss.

``PlanDiskCache.clear()`` wipes the directory (also: just delete it).
"""
from __future__ import annotations

import hashlib
import json
import os
from dataclasses import asdict
from pathlib import Path
from typing import Any, Dict, Optional

import numpy as np

FORMAT_VERSION = 1
_META_KEY = "__meta__"
_OFF_VALUES = ("", "0", "off", "none", "disable", "disabled")


def config_token(config) -> str:
    """Canonical string of the config fields a plan depends on.  The
    `backend` field is excluded: the resolved backend NAME is its own
    key component (so `backend="auto"` and an explicit name that auto
    resolves to share entries)."""
    d = {k: v for k, v in asdict(config).items() if k != "backend"}
    return json.dumps(d, sort_keys=True)


class PlanDiskCache:
    """Content-addressed npz store for plan host halves."""

    def __init__(self, root):
        self.root = Path(root)

    # -- keying -----------------------------------------------------------

    def describe(self, fingerprint: str, backend, config, *,
                 mesh=None) -> Dict[str, Any]:
        """The full metadata a cached entry must match to be served."""
        return {"format": FORMAT_VERSION,
                "fingerprint": fingerprint,
                "backend": backend.name,
                "plan_version": backend.plan_version,
                "config": config_token(config),
                "context": backend.cache_context(mesh=mesh)}

    @staticmethod
    def key(meta: Dict[str, Any]) -> str:
        blob = json.dumps(meta, sort_keys=True).encode()
        return hashlib.blake2b(blob, digest_size=16).hexdigest()

    def path(self, meta: Dict[str, Any]) -> Path:
        return self.root / (self.key(meta) + ".npz")

    # -- load / store -----------------------------------------------------

    def load(self, meta: Dict[str, Any]) -> Optional[Dict[str, Any]]:
        """The stored host dict, or None (miss / stale / corrupt).

        Corrupt entries are deleted so the subsequent rebuild's store
        replaces them; stale ones (old format, different config hash
        behind a colliding key) are simply ignored."""
        path = self.path(meta)
        if not path.exists():
            return None
        try:
            with np.load(path, allow_pickle=False) as d:
                stored = json.loads(str(d[_META_KEY][()]))
                if stored != meta:
                    return None                       # stale / collision
                return {k: d[k] for k in d.files if k != _META_KEY}
        except Exception:
            try:
                path.unlink()
            except OSError:
                pass
            return None

    def store(self, meta: Dict[str, Any], host: Dict[str, Any]) -> bool:
        """Atomically persist `host` under `meta`'s key.  Best-effort:
        an unwritable cache dir must never break embedding."""
        path = self.path(meta)
        tmp = path.with_name(f"{path.stem}.tmp{os.getpid()}.npz")
        try:
            self.root.mkdir(parents=True, exist_ok=True)
            with open(tmp, "wb") as f:
                np.savez(f, **{_META_KEY: np.asarray(json.dumps(meta))},
                         **host)
            os.replace(tmp, path)
            return True
        except Exception:
            try:
                tmp.unlink()
            except OSError:
                pass
            return False

    # -- maintenance ------------------------------------------------------

    def entries(self) -> list[Path]:
        if not self.root.is_dir():
            return []
        return sorted(p for p in self.root.glob("*.npz"))

    def clear(self) -> int:
        """Delete every entry; returns how many were removed."""
        removed = 0
        for p in self.entries():
            try:
                p.unlink()
                removed += 1
            except OSError:
                pass
        return removed


def default_cache() -> Optional[PlanDiskCache]:
    """Resolve the process-wide default cache from the environment
    (None = persistent tier disabled)."""
    env = os.environ.get("REPRO_PLAN_CACHE")
    if env is not None:
        if env.strip().lower() in _OFF_VALUES:
            return None
        return PlanDiskCache(env)
    base = os.environ.get("XDG_CACHE_HOME") or (Path.home() / ".cache")
    return PlanDiskCache(Path(base) / "repro-gee" / "plans")
