"""repro.encoder — the unified GEE embedding API (the one front door).

    from repro.encoder import Embedder, EncoderConfig

    emb = Embedder(EncoderConfig(K=5), backend="xla").fit(graph, Y)
    Z   = emb.transform()
    emb.partial_fit(delta)        # exact O(batch) update
    emb.refit(new_Y)              # cached plan, no host re-packing

Backends (select by name, register new ones with `register_backend`):
numpy, xla, pallas, streaming, distributed:{replicated, reduce_scatter,
a2a, ring}.  All produce the same Z (see tests/test_encoder.py's
cross-backend conformance suite); they differ only in where the work
runs.  The legacy per-strategy functions remain as internals under
`repro.core` / `repro.kernels`.
"""
from repro.encoder.backends import (Backend, get_backend, list_backends,
                                    register_backend)
from repro.encoder.config import EncoderConfig
from repro.encoder.embedder import Embedder, NotFittedError
from repro.encoder.plan import Plan

__all__ = ["Backend", "Embedder", "EncoderConfig", "NotFittedError",
           "Plan", "get_backend", "list_backends", "register_backend"]
