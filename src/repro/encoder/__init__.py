"""repro.encoder — the unified GEE embedding API (the one front door).

    from repro.encoder import Embedder, EncoderConfig

    emb = Embedder(EncoderConfig(K=5)).fit(graph, Y)   # backend="auto"
    Z   = emb.transform()
    emb.partial_fit(delta)        # exact O(batch) update
    emb.refit(new_Y)              # cached plan, no host re-packing

Backends (select by name, register new ones with `register_backend`):
numpy, xla, pallas, streaming, distributed:{replicated, reduce_scatter,
a2a, ring} — or "auto" (the default), resolved at plan time from
(n, s, device kind, device count) via the overridable
`backends.AUTO_POLICY` table.  All produce the same Z (see
tests/test_encoder.py's cross-backend conformance suite); they differ
only in where the work runs.  The legacy per-strategy functions remain
as internals under `repro.core` / `repro.kernels`.

`fit`/`plan` accept a `repro.graph.sources.GraphSource` anywhere a
Graph is accepted; the source's content fingerprint keys the
persistent cross-process plan cache (`plan_cache.PlanDiskCache`,
REPRO_PLAN_CACHE to relocate or disable), so a fresh process embedding
a known graph skips host packing entirely.
"""
from repro.encoder.backends import (AUTO_POLICY, Backend, get_backend,
                                    list_backends, register_backend,
                                    resolve_auto)
from repro.encoder.config import EncoderConfig
from repro.encoder.embedder import Embedder, NotFittedError
from repro.encoder.plan import Plan
from repro.encoder.plan_cache import PlanDiskCache, default_cache

__all__ = ["AUTO_POLICY", "Backend", "Embedder", "EncoderConfig",
           "NotFittedError", "Plan", "PlanDiskCache", "default_cache",
           "get_backend", "list_backends", "register_backend",
           "resolve_auto"]
