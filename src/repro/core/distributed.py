"""Distributed GEE: the paper's shared-memory edge-parallelism mapped to
SPMD collectives.

The paper's Ligra implementation parallelizes the edge loop across cores
that share one coherent DRAM array Z, racing on Z[u, k] and resolving
races with lock-free atomic adds.  On a TPU pod there is no shared
mutable HBM, so "who owns Z" becomes an explicit design axis.  Four
reduction modes, all computing bit-identical Z:

  replicated      every chip: local scatter-add into a full (n, K) Z,
                  then all-reduce (psum).  Direct analog of the paper's
                  shared array.  Memory O(n*K) per chip.
  reduce_scatter  same local pass, but psum_scatter leaves each chip
                  with its own row shard.  Memory O(n*K) transient,
                  O(n*K/P) resident; collective cost = 1 reduce-scatter.
  a2a             contributions bucketed by destination row-shard
                  (sort + capacity-padded pack, exactly like an MoE
                  dispatch), exchanged with one all_to_all, then local
                  scatter into the (n/P, K) shard.  Memory O(s/P).
  ring            the same buckets forwarded around the ring with
                  collective_permute (ICI-neighbor traffic only), each
                  chip folding in its bucket as the accumulator passes.
                  P-1 steps; peak memory O(n*K/P + s/P); this is the
                  TPU-native replacement for atomics: deterministic
                  neighbor exchanges instead of racing writes.

Bucketed modes use capacity padding (cap = mean * capacity_factor).
With randomly-shuffled edges, bucket sizes concentrate tightly around
the mean; overflow is *counted and returned* so callers can assert
drops == 0 (tests do) or re-run with a higher factor.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.gee import edge_contributions, make_w
from repro.models.attention import shard_map

AXIS = "edges"


def edge_mesh(devices=None) -> Mesh:
    """Flat 1-D mesh over all devices (GEE has no model dimension)."""
    devices = np.asarray(devices if devices is not None else jax.devices())
    return Mesh(devices.reshape(-1), (AXIS,))


def pad_rows(n: int, p: int) -> int:
    return ((n + p - 1) // p) * p


# ---------------------------------------------------------------------------
# in-shard helpers
# ---------------------------------------------------------------------------


def _bucket_by_owner(dst, cls, val, rows: int, p: int, cap: int):
    """Pack contributions into (p, cap) per-owner buckets (sort + pad).

    Returns (b_row, b_cls, b_val, dropped).  b_row holds owner-local row
    indices; padded slots have val 0."""
    owner = dst // rows
    order = jnp.argsort(owner)
    owner_s = owner[order]
    row_s = (dst - owner * rows)[order]
    cls_s = cls[order]
    val_s = val[order]

    starts = jnp.searchsorted(owner_s, jnp.arange(p))
    pos = jnp.arange(owner.shape[0]) - starts[owner_s]
    keep = pos < cap
    slot = jnp.where(keep, owner_s * cap + pos, p * cap)

    def pack(x, fill):
        buf = jnp.full((p * cap + 1,), fill, x.dtype).at[slot].set(x)
        return buf[:-1].reshape(p, cap)

    b_row = pack(row_s, jnp.int32(0))
    b_cls = pack(cls_s, jnp.int32(0))
    b_val = pack(jnp.where(keep, val_s, 0.0), jnp.float32(0))
    dropped = jnp.sum(~keep)
    return b_row, b_cls, b_val, dropped


def _scatter_rows(rows: int, K: int, r, c, v):
    return jnp.zeros((rows, K), jnp.float32).at[r, c].add(v)


# ---------------------------------------------------------------------------
# shard_map bodies
# ---------------------------------------------------------------------------


def _body_replicated(u, v, w, Y, Wv, *, K, n):
    dst, cls, val = edge_contributions(u, v, w, Y, Wv)
    Z = _scatter_rows(n, K, dst, cls, val)
    return jax.lax.psum(Z, AXIS), jnp.zeros((), jnp.int32)


def _body_reduce_scatter(u, v, w, Y, Wv, *, K, n, p):
    dst, cls, val = edge_contributions(u, v, w, Y, Wv)
    Z = _scatter_rows(n, K, dst, cls, val)
    Zs = jax.lax.psum_scatter(Z, AXIS, scatter_dimension=0, tiled=True)
    return Zs, jnp.zeros((), jnp.int32)


def _body_a2a(u, v, w, Y, Wv, *, K, n, p, cap):
    rows = n // p
    dst, cls, val = edge_contributions(u, v, w, Y, Wv)
    b_row, b_cls, b_val, dropped = _bucket_by_owner(dst, cls, val, rows, p,
                                                    cap)
    r = jax.lax.all_to_all(b_row, AXIS, split_axis=0, concat_axis=0,
                           tiled=False)
    c = jax.lax.all_to_all(b_cls, AXIS, split_axis=0, concat_axis=0,
                           tiled=False)
    x = jax.lax.all_to_all(b_val, AXIS, split_axis=0, concat_axis=0,
                           tiled=False)
    Z = _scatter_rows(rows, K, r.reshape(-1), c.reshape(-1), x.reshape(-1))
    return Z, jax.lax.psum(dropped, AXIS)


def _body_a2a_prebucketed(b_dst, b_cls, b_wv, Y, Wv, *, K, n, p):
    """Steady-state a2a: buckets were built once at ingestion (the owner
    of a contribution depends only on the destination node, not on the
    labels), so refinement iterations skip the sort entirely.  b_* are
    (p, cap) per-owner buckets of (local_row, class-source node, weight).
    Class/value are resolved per iteration from the CURRENT labels."""
    cls = jnp.maximum(Y[b_cls], 0)
    val = jnp.where(Y[b_cls] >= 0, Wv[b_cls] * b_wv, 0.0)
    r = jax.lax.all_to_all(b_dst, AXIS, split_axis=0, concat_axis=0)
    c = jax.lax.all_to_all(cls, AXIS, split_axis=0, concat_axis=0)
    x = jax.lax.all_to_all(val, AXIS, split_axis=0, concat_axis=0)
    rows = n // p
    Z = _scatter_rows(rows, K, r.reshape(-1), c.reshape(-1), x.reshape(-1))
    return Z, jnp.zeros((), jnp.int32)


def prebucket_host(graph, p: int, capacity_factor=None):
    """One-time ingestion pass: route every directed contribution to its
    destination's row-owner bucket.  Returns (b_dst_local, b_srcnode,
    b_weight) arrays of shape (p_shards, p_owners, cap) — give shard i
    its [i] slice.  The class/value resolution stays per-iteration."""
    if capacity_factor is None:
        capacity_factor = exact_capacity_factor(graph, p)
    n_pad = pad_rows(graph.n, p)
    s_pad = pad_rows(graph.s, p)
    g = graph.pad_to(s_pad)
    rows = n_pad // p
    per = s_pad // p
    cap = int(np.ceil(2 * per / p * capacity_factor)) + 8
    b_dst = np.zeros((p, p, cap), np.int32)
    b_src = np.zeros((p, p, cap), np.int32)
    b_w = np.zeros((p, p, cap), np.float32)
    for shard in range(p):
        sl = slice(shard * per, (shard + 1) * per)
        dst = np.concatenate([g.u[sl], g.v[sl]])
        src = np.concatenate([g.v[sl], g.u[sl]])   # label donor
        w = np.concatenate([g.w[sl], g.w[sl]])
        owner = dst // rows
        order = np.argsort(owner, kind="stable")
        dst, src, w, owner = dst[order], src[order], w[order], owner[order]
        starts = np.searchsorted(owner, np.arange(p))
        pos = np.arange(dst.shape[0]) - starts[owner]
        keep = pos < cap
        b_dst[shard, owner[keep], pos[keep]] = dst[keep] - owner[keep] * rows
        b_src[shard, owner[keep], pos[keep]] = src[keep]
        b_w[shard, owner[keep], pos[keep]] = w[keep]
        assert keep.all(), "prebucket overflow; raise capacity_factor"
    return b_dst, b_src, b_w, n_pad


def gee_a2a_steady(b_dst, b_src, b_w, Y, *, K: int, n_pad: int, mesh: Mesh):
    """Per-iteration embed with pre-bucketed contributions (no sort).

    b_* are the (p, p, cap) host buckets flattened to (p*p, cap) so the
    leading dim shards p-ways (each shard gets its (p, cap) slab)."""
    p = mesh.shape[AXIS]
    Wv = make_w(Y, K)
    body = functools.partial(_body_a2a_prebucketed, K=K, n=n_pad, p=p)
    fn = shard_map(body, mesh,
                   in_specs=(P(AXIS), P(AXIS), P(AXIS), P(), P()),
                   out_specs=(P(AXIS, None), P()))
    return fn(b_dst, b_src, b_w, Y, Wv)


def _body_ring(u, v, w, Y, Wv, *, K, n, p, cap):
    rows = n // p
    me = jax.lax.axis_index(AXIS)
    dst, cls, val = edge_contributions(u, v, w, Y, Wv)
    b_row, b_cls, b_val, dropped = _bucket_by_owner(dst, cls, val, rows, p,
                                                    cap)

    def bucket_dense(c):
        r = jax.lax.dynamic_index_in_dim(b_row, c, 0, keepdims=False)
        k = jax.lax.dynamic_index_in_dim(b_cls, c, 0, keepdims=False)
        x = jax.lax.dynamic_index_in_dim(b_val, c, 0, keepdims=False)
        return _scatter_rows(rows, K, r, k, x)

    perm = [(i, (i - 1) % p) for i in range(p)]
    acc = bucket_dense((me + 1) % p)

    def step(t, acc):
        acc = jax.lax.ppermute(acc, AXIS, perm)
        return acc + bucket_dense((me + t + 1) % p)

    acc = jax.lax.fori_loop(1, p, step, acc)
    return acc, jax.lax.psum(dropped, AXIS)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def gee_sharded(u, v, w, Y, *, K: int, n: int, mesh: Mesh,
                mode: str = "ring", capacity_factor: float = 2.0,
                laplacian: bool = False):
    """Distributed GEE under shard_map.

    u, v, w: (s,) edge arrays, s divisible by mesh size (pad first —
    `Graph.pad_to`).  Y: (n_pad,) labels, n divisible by mesh size for
    row-sharded modes.  Returns (Z, dropped):
      replicated          -> Z (n, K) replicated
      others              -> Z (n, K) row-sharded over the mesh
    """
    p = mesh.shape[AXIS]
    assert u.shape[0] % p == 0, (u.shape, p)
    w = w.astype(jnp.float32)
    if laplacian:
        deg = jnp.zeros(n, jnp.float32).at[u].add(w).at[v].add(w)
        scale = jax.lax.rsqrt(jnp.maximum(deg, 1.0))
        w = w * scale[u] * scale[v]
    Wv = make_w(Y, K)

    s_local = u.shape[0] // p
    cap = int(np.ceil(2 * s_local / p * capacity_factor)) + 8

    espec = P(AXIS)
    rspec = P()
    if mode == "replicated":
        body = functools.partial(_body_replicated, K=K, n=n)
        out_z = P()
    elif mode == "reduce_scatter":
        assert n % p == 0, (n, p)
        body = functools.partial(_body_reduce_scatter, K=K, n=n, p=p)
        out_z = P(AXIS, None)
    elif mode == "a2a":
        assert n % p == 0, (n, p)
        body = functools.partial(_body_a2a, K=K, n=n, p=p, cap=cap)
        out_z = P(AXIS, None)
    elif mode == "ring":
        assert n % p == 0, (n, p)
        body = functools.partial(_body_ring, K=K, n=n, p=p, cap=cap)
        out_z = P(AXIS, None)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    fn = shard_map(body, mesh,
                   in_specs=(espec, espec, espec, rspec, rspec),
                   out_specs=(out_z, P()))
    return fn(u, v, w, Y, Wv)


def exact_capacity_factor(graph, p: int) -> float:
    """Capacity factor guaranteeing zero drops: measured from the actual
    per-(shard, owner) bucket histogram.  O(s) host pass.  This is the
    skew-robust answer to what Ligra got from work stealing: supernodes
    (power-law hubs) concentrate contributions on one row-owner, which a
    mean-sized bucket cannot hold."""
    from repro.graph.partition import owner_histogram
    hist = owner_histogram(graph, p)
    s_pad = pad_rows(graph.s, p)
    mean_bucket = max(2 * (s_pad // p) / p, 1.0)
    return float(hist.max()) / mean_bucket + 0.05


def gee_distributed(graph, Y, *, K: int, mode: str = "ring",
                    mesh: Optional[Mesh] = None,
                    capacity_factor=None,
                    laplacian: bool = False):
    """Host-friendly wrapper: pads edges/rows, runs, unpads.

    capacity_factor None -> exact (zero-drop) factor measured from the
    graph's owner histogram.  Returns (Z (n, K), dropped count)."""
    mesh = mesh or edge_mesh()
    p = mesh.shape[AXIS]
    if capacity_factor is None:
        capacity_factor = exact_capacity_factor(graph, p)
    n_pad = pad_rows(graph.n, p)
    s_pad = pad_rows(graph.s, p)
    g = graph.pad_to(s_pad)
    Y_pad = np.full(n_pad, -1, np.int32)
    Y_pad[:graph.n] = Y
    Z, dropped = gee_sharded(
        jnp.asarray(g.u), jnp.asarray(g.v), jnp.asarray(g.w),
        jnp.asarray(Y_pad), K=K, n=n_pad, mesh=mesh, mode=mode,
        capacity_factor=capacity_factor, laplacian=laplacian)
    return np.asarray(Z)[:graph.n], int(dropped)
