"""One-Hot Graph Encoder Embedding (GEE) — the paper's algorithm in JAX.

Label convention: Y in {-1 = unknown, 0..K-1}.

The serial edge loop with atomic ``writeAdd`` becomes a vectorized
scatter-add (XLA ``scatter`` with add-combiner): race-free by
construction and bitwise deterministic, computing exactly the same Z.

Variants:
  * ``gee``            — jit-able single-device embedding (weighted,
                          directed; symmetric contribution per the paper)
  * ``laplacian=True`` — the GEE paper's Laplacian scaling
                          (w' = w / sqrt(deg_u * deg_v))
  * ``gee_refine``     — unsupervised GEE clustering: embed -> k-means
                          reassign -> re-embed (Shen et al.'s iterative
                          refinement; replaces the Leiden bootstrap)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def make_w(Y: jnp.ndarray, K: int) -> jnp.ndarray:
    """Per-node projection weight: 1/|class(Y_i)| (0 for unlabeled)."""
    labeled = Y >= 0
    counts = jnp.zeros(K, jnp.float32).at[jnp.where(labeled, Y, 0)].add(
        labeled.astype(jnp.float32))
    inv = jnp.where(counts > 0, 1.0 / jnp.maximum(counts, 1.0), 0.0)
    return jnp.where(labeled, inv[jnp.maximum(Y, 0)], 0.0)


def edge_contributions(u, v, w, Y, Wv):
    """Per-directed-edge (dst, class, value) pairs — both directions.

    Returns (dst (2s,), cls (2s,), val (2s,)).  Edges whose source label
    is unknown contribute value 0 (class index clamped to 0)."""
    yv, yu = Y[v], Y[u]
    dst = jnp.concatenate([u, v])
    cls = jnp.concatenate([jnp.maximum(yv, 0), jnp.maximum(yu, 0)])
    val = jnp.concatenate([
        jnp.where(yv >= 0, Wv[v] * w, 0.0),
        jnp.where(yu >= 0, Wv[u] * w, 0.0)])
    return dst, cls, val


@functools.partial(jax.jit, static_argnames=("K", "n", "laplacian"))
def gee(u, v, w, Y, *, K: int, n: int, laplacian: bool = False,
        deg: Optional[jnp.ndarray] = None,
        Wv: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """One-pass GEE embedding. Returns Z (n, K) float32.

    Wv: optional precomputed projection weights (callers that own the
    weights — `repro.encoder.Embedder` — pass them; default derives
    them from Y, like the optional `deg` precompute)."""
    w = w.astype(jnp.float32)
    if laplacian:
        if deg is None:
            deg = (jnp.zeros(n, jnp.float32).at[u].add(w).at[v].add(w))
        scale = jax.lax.rsqrt(jnp.maximum(deg, 1.0))
        w = w * scale[u] * scale[v]
    if Wv is None:
        Wv = make_w(Y, K)
    dst, cls, val = edge_contributions(u, v, w, Y, Wv)
    return jnp.zeros((n, K), jnp.float32).at[dst, cls].add(val)


def gee_dense_oracle(u, v, w, Y, K: int, n: int) -> jnp.ndarray:
    """O(n^2) dense formulation Z = A @ Wmat — tiny-graph test oracle.

    Wmat is the paper's actual (n, K) one-hot projection matrix; the
    adjacency is symmetrized the way Algorithm 1's two updates imply."""
    A = jnp.zeros((n, n), jnp.float32).at[u, v].add(w).at[v, u].add(w)
    Wv = make_w(Y, K)
    onehot = jax.nn.one_hot(jnp.maximum(Y, 0), K) * (Y >= 0)[:, None]
    Wmat = onehot * Wv[:, None]
    return A @ Wmat


# ---------------------------------------------------------------------------
# Streaming / incremental updates (beyond-paper: dynamic graphs)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("K",))
def gee_apply_delta(Z, u, v, w, Y, Wv, *, K: int, sign: float = 1.0):
    """Incremental GEE: fold an edge batch into an existing Z.

    Exact by additivity (Z is linear in the edge multiset — property-
    tested), so edge insertions (sign=+1) and deletions (sign=-1) cost
    O(batch) instead of a full O(s) re-embed.  Label changes still
    require re-embedding the affected class columns (W changes).
    Wv must be the same projection weights Z was built with."""
    dst, cls, val = edge_contributions(u, v, w.astype(jnp.float32), Y, Wv)
    return Z.at[dst, cls].add(sign * val)


def gee_streaming(chunks, Y, *, K: int, n: int,
                  Wv: Optional[jnp.ndarray] = None):
    """Single-pass streaming embed over an iterator of (u, v, w) chunks —
    the out-of-core ingestion path (pairs with graph.io.ShardedEdgeReader).
    Wv: optional owned projection weights, as in `gee`."""
    if Wv is None:
        Wv = make_w(Y, K)
    Z = jnp.zeros((n, K), jnp.float32)
    for (u, v, w) in chunks:
        Z = gee_apply_delta(Z, u, v, w, Y, Wv, K=K)
    return Z


# ---------------------------------------------------------------------------
# Owned-rows (partitioned) accumulate: O(n/p) accumulators per shard
# ---------------------------------------------------------------------------
#
# A row partition assigns each worker the contiguous Z rows [lo, hi).
# Because GEE maps over edges and an edge (u, v, w) touches only rows u
# and v, the contributions landing in a worker's rows are a filterable
# subset of the edge multiset: (dst, src, w) triples with dst in
# [lo, hi), remapped to local row dst - lo.  These kernels scatter that
# pre-bucketed form into an (n_local, K) accumulator — the labels Y and
# projection weights Wv stay GLOBAL (an owned row's value depends on
# its neighbors' labels, which may live on other workers), only the
# accumulator shrinks.


def owned_edge_contributions(src, w, Y, Wv):
    """Per-contribution (class, value) for owned-destination triples.

    `src` is the GLOBAL label-donor node of each contribution; unknown
    source labels contribute value 0 (class clamped to 0), exactly as
    in `edge_contributions` — this is one direction of that function,
    already filtered/remapped by the host plan."""
    ys = Y[src]
    cls = jnp.maximum(ys, 0)
    val = jnp.where(ys >= 0, Wv[src] * w, 0.0)
    return cls, val


@functools.partial(jax.jit, static_argnames=("K", "n_local"))
def gee_owned(rows, src, w, Y, Wv, *, K: int, n_local: int):
    """One-pass GEE over owned-destination contributions.

    rows: LOCAL destination rows in [0, n_local); src: GLOBAL label
    donors; Y/Wv: global labels and projection weights.  Returns the
    (n_local, K) owned slice of Z — bit-identical in content to the
    corresponding rows of the full accumulate."""
    cls, val = owned_edge_contributions(src, w.astype(jnp.float32), Y, Wv)
    return jnp.zeros((n_local, K), jnp.float32).at[rows, cls].add(val)


@functools.partial(jax.jit, static_argnames=("K",))
def gee_apply_delta_owned(Z, rows, src, w, Y, Wv, *, K: int,
                          sign: float = 1.0):
    """Fold owned-destination contributions into an (n_local, K) slice
    (the partitioned twin of `gee_apply_delta`; exact by linearity).
    Padded slots carry w = 0 and are no-ops for any labeling."""
    cls, val = owned_edge_contributions(src, w.astype(jnp.float32), Y, Wv)
    return Z.at[rows, cls].add(sign * val)


def gee_streaming_owned(chunks, Y, *, K: int, n_local: int,
                        Wv: Optional[jnp.ndarray] = None):
    """Chunked owned-rows accumulate: device working set is O(chunk)
    contribution data plus the (n_local, K) slice — the shard-rebuild
    path.  `chunks` yields (rows, src, w) triples."""
    if Wv is None:
        Wv = make_w(Y, K)
    Z = jnp.zeros((n_local, K), jnp.float32)
    for (rows, src, w) in chunks:
        Z = gee_apply_delta_owned(Z, rows, src, w, Y, Wv, K=K)
    return Z


# ---------------------------------------------------------------------------
# Unsupervised refinement (GEE clustering)
# ---------------------------------------------------------------------------


def _kmeans_assign(Z, centers):
    d2 = (jnp.sum(Z * Z, 1, keepdims=True)
          - 2 * Z @ centers.T + jnp.sum(centers * centers, 1))
    return jnp.argmin(d2, axis=1).astype(jnp.int32)


def _kmeans_update(Z, labels, K):
    onehot = jax.nn.one_hot(labels, K, dtype=Z.dtype)
    sums = onehot.T @ Z
    counts = onehot.sum(0)[:, None]
    return sums / jnp.maximum(counts, 1.0)


def kmeans_refine_round(Z, labels, Y0, K: int, kmeans_iters: int):
    """One refinement round's label update: row-normalize Z, k-means,
    reassign with the supervised labels in Y0 pinned.  THE one copy of
    the refinement math — shared by `gee_refine` and
    `repro.encoder.Embedder.refine`."""
    Zn = Z / jnp.maximum(jnp.linalg.norm(Z, axis=1, keepdims=True), 1e-9)
    centers = _kmeans_update(Zn, labels, K)
    for _ in range(kmeans_iters):
        assign = _kmeans_assign(Zn, centers)
        centers = _kmeans_update(Zn, assign, K)
    return jnp.where(Y0 >= 0, Y0, assign)


@functools.partial(jax.jit, static_argnames=("K", "n", "iters", "kmeans_iters"))
def gee_refine(u, v, w, Y0, key, *, K: int, n: int, iters: int = 10,
               kmeans_iters: int = 3):
    """Iterative GEE clustering: embed with current labels, k-means in the
    K-dim embedding, reassign, repeat.  Y0 may be all-unknown (-1), in
    which case labels bootstrap from a random assignment."""
    rand = jax.random.randint(key, (n,), 0, K, jnp.int32)
    labels = jnp.where(Y0 >= 0, Y0, rand)

    def body(labels, _):
        Z = gee(u, v, w, labels, K=K, n=n)
        labels = kmeans_refine_round(Z, labels, Y0, K, kmeans_iters)
        return labels, None

    labels, _ = jax.lax.scan(body, labels, None, length=iters)
    Z = gee(u, v, w, labels, K=K, n=n)
    return Z, labels
