"""Baseline GEE implementations that mirror the paper's comparison points.

* ``gee_python``  — interpreted pure-Python loop over edges: the paper's
  "GEE-Python" reference implementation (Algorithm 1, taken literally).
* ``gee_numpy``   — vectorized ``np.add.at`` scatter: plays the role of
  the paper's Numba-JIT version (compiled, serial, single pass).

Both use the shared label convention Y in {-1 unknown, 0..K-1}.
"""
from __future__ import annotations

import numpy as np


def make_w(Y: np.ndarray, K: int) -> np.ndarray:
    """Per-node projection value: 1/count(class(Y)) for labeled, else 0.

    This is the diagonal content of the paper's W matrix (n x K one-hot
    rows); storing the scalar per node is equivalent and O(n)."""
    counts = np.bincount(Y[Y >= 0], minlength=K).astype(np.float64)
    inv = np.where(counts > 0, 1.0 / np.maximum(counts, 1), 0.0)
    w = np.where(Y >= 0, inv[np.maximum(Y, 0)], 0.0)
    return w.astype(np.float32)


def gee_python(u, v, w, Y, K: int, n: int) -> np.ndarray:
    """Algorithm 1, literal serial loop (slow on purpose)."""
    Wv = make_w(np.asarray(Y), K)
    Z = np.zeros((n, K), np.float64)
    for i in range(len(u)):
        ui, vi, wi = int(u[i]), int(v[i]), float(w[i])
        yv, yu = int(Y[vi]), int(Y[ui])
        if yv >= 0:
            Z[ui, yv] += Wv[vi] * wi
        if yu >= 0:
            Z[vi, yu] += Wv[ui] * wi
    return Z.astype(np.float32)


def gee_numpy(u, v, w, Y, K: int, n: int) -> np.ndarray:
    """Vectorized single-pass scatter (the compiled-serial analog)."""
    Y = np.asarray(Y)
    Wv = make_w(Y, K)
    Z = np.zeros((n, K), np.float32)
    yv, yu = Y[v], Y[u]
    mv, mu = yv >= 0, yu >= 0
    np.add.at(Z, (u[mv], yv[mv]), Wv[v[mv]] * w[mv])
    np.add.at(Z, (v[mu], yu[mu]), Wv[u[mu]] * w[mu])
    return Z


def gee_numpy_owned(rows, src, w, Y, Wv, K: int, n_local: int
                    ) -> np.ndarray:
    """Owned-rows scatter over pre-bucketed (local row, global source,
    weight) contributions — the host oracle for the partitioned
    accumulate path (`core.gee.gee_owned`).  Wv is passed in (the
    Embedder owns the projection weights Z is built with)."""
    Y = np.asarray(Y)
    Wv = np.asarray(Wv, np.float32)
    Z = np.zeros((n_local, K), np.float32)
    ys = Y[src]
    m = ys >= 0
    np.add.at(Z, (rows[m], ys[m]), Wv[src[m]] * w[m])
    return Z
