"""GEE <-> LM bridge: embedding-table initialization from a token
co-occurrence graph.

GEE's role in the original papers is a near-free spectral-like embedding.
Here we apply it to the one place an LM has a graph: the vocabulary.
Build a co-occurrence graph over token ids from the training stream
(edge (a, b, count) when b follows a within a window), cluster it with
unsupervised GEE refinement, embed to (V, K), then project K -> d_model
with a fixed random rotation and blend with scaled noise.  This gives
the embedding table a topic-structured starting point at O(s) cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gee import gee_refine
from repro.graph.edges import Graph


def token_cooccurrence(tokens: np.ndarray, vocab: int, window: int = 2,
                       max_edges: int = 2_000_000) -> Graph:
    """tokens: (N,) int stream -> co-occurrence edge list (deduplicated
    with counts as weights)."""
    pairs = []
    for d in range(1, window + 1):
        a, b = tokens[:-d], tokens[d:]
        pairs.append(np.stack([a, b], 1))
    e = np.concatenate(pairs, 0)
    key = e[:, 0].astype(np.int64) * vocab + e[:, 1]
    uniq, counts = np.unique(key, return_counts=True)
    if uniq.shape[0] > max_edges:
        top = np.argsort(-counts)[:max_edges]
        uniq, counts = uniq[top], counts[top]
    u = (uniq // vocab).astype(np.int32)
    v = (uniq % vocab).astype(np.int32)
    return Graph(u, v, counts.astype(np.float32), vocab)


def gee_embedding_init(tokens: np.ndarray, vocab: int, d_model: int,
                       K: int = 64, key=None, window: int = 2,
                       refine_iters: int = 6,
                       blend: float = 0.5) -> np.ndarray:
    """(vocab, d_model) initializer built from GEE over co-occurrences."""
    key = key if key is not None else jax.random.PRNGKey(0)
    g = token_cooccurrence(tokens, vocab, window)
    K = min(K, max(2, vocab // 4))
    Y0 = jnp.full((vocab,), -1, jnp.int32)
    k1, k2, k3 = jax.random.split(key, 3)
    Z, _ = gee_refine(jnp.asarray(g.u), jnp.asarray(g.v), jnp.asarray(g.w),
                      Y0, k1, K=K, n=vocab, iters=refine_iters)
    Z = Z / jnp.maximum(jnp.linalg.norm(Z, axis=1, keepdims=True), 1e-9)
    # fixed random rotation K -> d_model (isometry-ish)
    R = jax.random.normal(k2, (K, d_model), jnp.float32) / np.sqrt(K)
    base = Z @ R
    noise = jax.random.normal(k3, (vocab, d_model), jnp.float32)
    scale = 1.0 / np.sqrt(d_model)
    table = scale * (blend * base * np.sqrt(d_model) + (1 - blend) * noise)
    return np.asarray(table, np.float32)
