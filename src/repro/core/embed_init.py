"""Deprecated: the GEE <-> LM bridge moved to `repro.encoder.bridge`.

The bridge is now part of the unified Embedder API —
``Embedder.to_features(d_model)`` projects any fitted embedding to a
feature table, and `repro.encoder.bridge.gee_embedding_init` composes
it with `token_cooccurrence` + unsupervised `Embedder.refine`.  This
module lazily re-exports the old names with a deprecation warning so
existing imports keep working.
"""
from __future__ import annotations

import warnings

_MOVED = ("token_cooccurrence", "gee_embedding_init")


def __getattr__(name):
    if name in _MOVED:
        warnings.warn(
            f"repro.core.embed_init.{name} moved to "
            f"repro.encoder.bridge.{name} (the Embedder front door: "
            "Embedder.to_features); this shim will be removed",
            DeprecationWarning, stacklevel=2)
        from repro.encoder import bridge
        return getattr(bridge, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(_MOVED)
