"""repro.core — GEE algorithm internals.

DEPRECATED as a call-site API: new code should go through the unified
front door, ``repro.encoder.Embedder`` (backend selection, plan
caching, owned projection weights).  The per-strategy functions below
remain the backend *internals* and are re-exported here (lazily, PEP
562) for backward compatibility:

    gee_refine, gee_streaming, gee_apply_delta, gee_dense_oracle,
    make_w                      <- repro.core.gee
    gee_distributed, gee_sharded, edge_mesh, exact_capacity_factor
                                <- repro.core.distributed
    gee_numpy, gee_python       <- repro.core.ref_python

(`repro.core.gee` stays the submodule — the function is
`repro.core.gee.gee` — so `from repro.core import gee as G` keeps its
historical module meaning.)
"""
from __future__ import annotations

import importlib

_FORWARDS = {
    "gee_refine": "repro.core.gee",
    "gee_streaming": "repro.core.gee",
    "gee_apply_delta": "repro.core.gee",
    "gee_dense_oracle": "repro.core.gee",
    "make_w": "repro.core.gee",
    "gee_distributed": "repro.core.distributed",
    "gee_sharded": "repro.core.distributed",
    "edge_mesh": "repro.core.distributed",
    "exact_capacity_factor": "repro.core.distributed",
    "gee_numpy": "repro.core.ref_python",
    "gee_python": "repro.core.ref_python",
}

__all__ = sorted(_FORWARDS)


def __getattr__(name: str):
    if name in _FORWARDS:
        return getattr(importlib.import_module(_FORWARDS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
