"""Gradient compression for the cross-pod (DCN-analog) axis.

int8 block quantization with error feedback: gradients are quantized
per-block before the (slow) cross-pod all-reduce and dequantized after;
the quantization residual is fed back into the next step's gradient so
the scheme is unbiased in the long run (EF-SGD).  On the dry-run the
compression shows up as a 4x reduction of the collective-bytes term on
the pod axis.

`int8_roundtrip` is the inline (single-allreduce-graph) form used by
make_train_step: XLA's SPMD partitioner reduces the int8-scaled tensors
over the pod axis where the sharding dictates.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

BLOCK = 256


class EFState:
    """Error-feedback residual tree (host-managed)."""

    def __init__(self, params):
        self.residual = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_block(g32):
    orig_shape = g32.shape
    flat = g32.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, orig_shape, pad


def _dequant_block(q, scale, orig_shape, pad):
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        deq = deq[:-pad]
    return deq.reshape(orig_shape)


def quantize_tree(grads):
    return jax.tree_util.tree_map(lambda g: _quant_block(
        g.astype(jnp.float32)), grads,
        is_leaf=lambda x: hasattr(x, "shape"))


def int8_roundtrip(grads):
    """Quantize -> dequantize each gradient leaf (simulates the wire
    format; under SPMD the reduce happens on the int8+scale pair)."""
    def rt(g):
        q, s, shape, pad = _quant_block(g.astype(jnp.float32))
        return _dequant_block(q, s, shape, pad).astype(g.dtype)
    return jax.tree_util.tree_map(rt, grads)


def compress_with_feedback(grads, ef: "EFState"):
    """EF-SGD: g' = Q(g + residual); residual = (g + residual) - g'."""
    def one(g, r):
        tot = g.astype(jnp.float32) + r
        q, s, shape, pad = _quant_block(tot)
        deq = _dequant_block(q, s, shape, pad)
        return deq.astype(g.dtype), tot - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([o[0] for o in out])
    ef.residual = treedef.unflatten([o[1] for o in out])
    return new_g
