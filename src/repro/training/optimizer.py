"""Optimizers (self-contained — no optax dependency).

AdamW with dtype-configurable moments: 314B-class configs use bf16
moments (state_dtype in the arch config) to fit 16 GB/chip; master
params stay in the param dtype.  The optimizer state tree mirrors the
param tree, so param shardings apply verbatim (m, v inherit the ZeRO-3
layout for free).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"
    clip_norm: float = 1.0
    schedule: Optional[Callable] = None     # step -> lr multiplier

    def init(self, params) -> AdamWState:
        dt = jnp.dtype(self.state_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree_util.tree_map(zeros, params),
                          jax.tree_util.tree_map(zeros, params))

    def init_abstract(self, abstract_params) -> AdamWState:
        dt = jnp.dtype(self.state_dtype)
        zeros = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
        return AdamWState(jax.ShapeDtypeStruct((), jnp.int32),
                          jax.tree_util.tree_map(zeros, abstract_params),
                          jax.tree_util.tree_map(zeros, abstract_params))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        g32 = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm:
            gn = global_norm(g32)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-9))
            g32 = jax.tree_util.tree_map(lambda g: g * scale, g32)

        dt = jnp.dtype(self.state_dtype)
        b1, b2 = self.b1, self.b2

        def upd(g, m, v, p):
            m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
            v32 = v.astype(jnp.float32) * b2 + jnp.square(g) * (1 - b2)
            mhat = m32 / (1 - b1 ** step.astype(jnp.float32))
            vhat = v32 / (1 - b2 ** step.astype(jnp.float32))
            lr = self.lr * (self.schedule(step) if self.schedule else 1.0)
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:    # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m32.astype(dt), v32.astype(dt)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(g32)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(g, m, v, p) for g, m, v, p in
               zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step, new_m, new_v)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def cosine_schedule(warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos
    return fn
