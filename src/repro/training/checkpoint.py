"""Fault-tolerant checkpointing: atomic, async, resumable.

Layout:  <dir>/step_<N>/arrays.npz + meta.json, plus <dir>/LATEST
pointing at the newest COMPLETE checkpoint.  Writes go to a tmp dir
that is os.replace()'d into place — a host dying mid-write can never
corrupt the restore path (restore reads LATEST, which is updated last).

`AsyncCheckpointer` moves serialization off the training thread: save()
snapshots device arrays to host (blocking only for the device->host
copy) and a worker thread does the npz write.  wait() drains before
exit / before the next save of the same step.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra_meta: Optional[dict] = None) -> str:
    """Blocking atomic save. Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step, "num_leaves": len(leaves),
            "treedef": str(treedef), "time": time.time()}
    meta.update(extra_meta or {})
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    # LATEST updated last => always points at a complete checkpoint
    latest_tmp = os.path.join(directory, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[1])


def restore_checkpoint(directory: str, tree_like: Any,
                       step: Optional[int] = None):
    """Restore into the structure of `tree_like`. Returns (tree, step).
    tree_like may contain ShapeDtypeStructs (no allocation needed)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(tree_like)
    assert len(leaves) == len(data.files), \
        f"checkpoint has {len(data.files)} leaves, model wants {len(leaves)}"
    restored = [data[f"a{i}"] for i in range(len(leaves))]
    for want, got in zip(leaves, restored):
        assert tuple(want.shape) == tuple(got.shape), (want.shape, got.shape)
    return jax.tree_util.tree_unflatten(treedef, restored), step


def prune_old(directory: str, keep: int = 3) -> None:
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Background-thread checkpoint writer (one in flight at a time)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, meta = item
            try:
                save_checkpoint(self.directory, step, tree, meta)
                prune_old(self.directory, self.keep)
            except BaseException as e:   # surfaced on next save()/wait()
                self._err = e
            finally:
                self._q.task_done()

    def save(self, step: int, tree: Any, meta: Optional[dict] = None):
        if self._err:
            raise self._err
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # D2H now
        self._q.put((step, host_tree, meta))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._t.join(timeout=10)
