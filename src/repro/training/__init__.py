"""Training substrate: optimizer, loop, checkpoints, fault tolerance."""
