"""Training step factory: grad-accum microbatching, remat, compression.

`make_train_step` returns the function the dry-run lowers and the real
trainer executes — identical code path, which is the point: the compiled
artifact analyzed in §Roofline IS the production step.

Microbatching (`accum_steps > 1`) reshapes the global batch to
(accum, B/accum, S) and lax.scan's the fwd+bwd, psum-ing gradients into
an accumulator.  XLA overlaps the per-microbatch gradient reductions
with the next microbatch's compute (the standard DP overlap trick).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.training.optimizer import AdamW, global_norm
from repro.training import compression


def make_loss_fn(cfg, impl: str = "flash"):
    def loss_fn(params, batch):
        return M.forward_train(cfg, params, batch, impl=impl)
    return loss_fn


def make_train_step(cfg, opt: AdamW, *, impl: str = "flash",
                    accum_steps: int = 1,
                    compress_grads: bool = False,
                    donate: bool = True) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(cfg, impl)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                acc = carry
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return acc, (l, m["aux_loss"])

            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]), batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, auxes) = jax.lax.scan(micro, zero, mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / accum_steps, grads)
            loss = losses.mean()
            metrics = {"loss": loss, "aux_loss": auxes.mean(),
                       "tokens": jnp.array(
                           batch["tokens"].shape[0]
                           * (batch["tokens"].shape[1] - 1))}

        if compress_grads:
            grads = compression.int8_roundtrip(grads)

        new_params, new_opt = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = global_norm(grads)
        return new_params, new_opt, metrics

    return step


def jit_train_step(cfg, opt: AdamW, mesh, rules, **kw):
    """jit with explicit in/out shardings (the dry-run entry point)."""
    from repro.sharding import spec_tree_shardings
    step = make_train_step(cfg, opt, **kw)
    pshard = spec_tree_shardings(rules, M.param_specs(cfg))
    ostate = AdamWState_shardings(opt, pshard, rules)
    dshard = rules.named(rules.act_spec((1, 1), ("batch", "seq")))
    in_sh = (pshard, ostate, {"tokens": dshard})
    return jax.jit(step, in_shardings=in_sh,
                   out_shardings=(pshard, ostate, None),
                   donate_argnums=(0, 1))


def AdamWState_shardings(opt, param_shardings, rules):
    from repro.training.optimizer import AdamWState
    none_sh = rules.named(jax.sharding.PartitionSpec())
    return AdamWState(none_sh, param_shardings, param_shardings)
