"""Fault tolerance & elasticity for 1000+-node runs.

What a real multi-pod deployment needs and how this repo provides it:

1. Crash recovery — atomic checkpoints + `restore_checkpoint`
   (checkpoint.py); the train loop periodically saves params+opt+data
   state and resumes from LATEST on restart.  Tested by killing a
   training subprocess mid-run (tests/test_checkpoint.py).

2. Node failure / elastic re-mesh — `ElasticMeshManager` rebuilds the
   mesh from the surviving device list at the next checkpoint boundary
   and re-jits the step.  Because checkpoints are stored UNSHARDED
   (host npz) and shardings are derived from (mesh, logical rules),
   restoring onto a different device count is just `make_rules(new_mesh)`
   — no resharding pass needed.  The `pod` axis being pure-DP means a
   lost pod only changes the gradient denominator.

3. Straggler mitigation — `StragglerMonitor` tracks per-step wall
   times; a step exceeding `deadline_factor` x the trailing median is
   logged and counted.  On TPU pods the SPMD step is collectively
   synchronous, so mitigation = re-mesh without the slow host (policy
   hook `on_straggler`), plus data-time skipping for input stalls.

4. Heartbeats — `Heartbeat` files under the run dir let an external
   supervisor (or another pod) detect a dead host by mtime; this is the
   standard file-based liveness contract for batch schedulers.
"""
from __future__ import annotations

import collections
import json
import os
import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax


class Heartbeat:
    def __init__(self, run_dir: str, host_id: int = 0,
                 interval_s: float = 10.0):
        self.path = os.path.join(run_dir, f"heartbeat_{host_id}")
        self.interval = interval_s
        self._last = 0.0
        os.makedirs(run_dir, exist_ok=True)

    def beat(self, step: int) -> None:
        now = time.time()
        if now - self._last >= self.interval:
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"step": step, "time": now}, f)
            os.replace(tmp, self.path)
            self._last = now

    @staticmethod
    def dead_hosts(run_dir: str, timeout_s: float = 60.0) -> list:
        now = time.time()
        dead = []
        for f in os.listdir(run_dir):
            if f.startswith("heartbeat_") and not f.endswith(".tmp"):
                if now - os.path.getmtime(os.path.join(run_dir, f)) > \
                        timeout_s:
                    dead.append(int(f.split("_")[1]))
        return dead


@dataclass
class StragglerMonitor:
    deadline_factor: float = 3.0
    window: int = 50
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    times: collections.deque = field(
        default_factory=lambda: collections.deque(maxlen=50))
    straggler_steps: list = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step counts as a straggler."""
        is_straggler = False
        if len(self.times) >= 10:
            med = statistics.median(self.times)
            if dt > self.deadline_factor * med:
                is_straggler = True
                self.straggler_steps.append((step, dt, med))
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        self.times.append(dt)
        return is_straggler


class ElasticMeshManager:
    """Rebuild mesh/rules/step when the healthy device set changes.

    Works with any (pod, data, model)-style factorization: the model
    axis is preserved (weights must still fit), the data axes shrink to
    the largest multiple that the surviving devices support."""

    def __init__(self, build_step: Callable, model_axis_size: int):
        self.build_step = build_step
        self.model_axis = model_axis_size
        self.generation = 0

    def remesh(self, healthy_devices) -> tuple:
        n = len(healthy_devices)
        model = self.model_axis
        assert n >= model, "not enough devices for the model axis"
        data = n // model
        usable = data * model
        mesh = jax.make_mesh((data, model), ("data", "model"),
                             devices=healthy_devices[:usable])
        self.generation += 1
        step = self.build_step(mesh)
        return mesh, step, self.generation


def simulate_failure(devices, kill: int):
    """Test hook: drop `kill` devices from the tail (a dead host)."""
    return devices[:len(devices) - kill]
