"""repro.index — sub-linear approximate-nearest-neighbor serving over Z.

The serving engine's exact top-k scans every owned row per query; at
millions of nodes that full blocked cosine scan is the QPS ceiling.
GEE hands us a coarse quantizer for free: by construction rows
concentrate around their class centroids (One-Hot Graph Encoder
Embedding), so an IVF-style index — assign every row to its nearest
class centroid, keep per-cell inverted lists, score a query only
against the ``nprobe`` most promising cells with the same exact
blocked top-k kernel — answers in sub-linear time while staying
*exact-testable*: probing all ``K`` cells partitions the rows, so the
answer is bit-identical to the full scan (the query kernels order
candidates lexicographically by ``(-score, ascending global id)``).

`IVFIndex` (`ivf.py`) is the per-shard half: inverted lists over one
shard's owned rows, **delta-maintained** — an edge delta touches only
incident rows, so membership updates are O(batch rows); the engine
owns the shared quantizer centroids and the churn-gated
re-quantization policy (`ServingEngine.query_topk(mode="ivf")`).
"""
from repro.index.ivf import DEFAULT_NPROBE, IVFIndex

__all__ = ["DEFAULT_NPROBE", "IVFIndex"]
