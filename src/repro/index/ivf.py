"""IVFIndex: per-shard inverted-file index over owned embedding rows.

One `IVFIndex` covers one shard's owned slice ``Zn`` (row-normalized,
``(owned, K)``) living at global rows ``[row_offset, row_offset +
owned)``.  The coarse quantizer is the matrix of class centroids the
engine already computes (`queries.class_sums`): every owned row is
assigned to its nearest centroid in cosine space (ties to the lowest
cell id — `argmax` is deterministic), and each cell keeps its member
rows as a **sorted** array of local row ids.

Why sorted matters: the query kernels (`queries.topk_cosine_ids`)
break score ties by ascending global id, which makes the per-cell
top-k lists — and therefore the lexicographic `queries.merge_topk` of
any set of cells — bit-identical to the full scan whenever the probed
cells cover all rows.  ``nprobe=K`` *is* the exact scan, just routed
through the index.

Delta maintenance: the index never stores Z values, only memberships,
so an edge delta that changes a batch of incident rows is absorbed by
re-assigning exactly those rows against the *fixed* build-time
centroids (`update_rows`, O(batch) assignments + per-affected-cell
membership splices).  Untouched rows keep their assignment, which is
still what a fresh `build` under the same centroids would compute —
the delta-maintained index and a rebuilt one answer identically
(property-tested).  Centroid drift is the engine's business: it
tracks cumulative moved rows and re-quantizes (fresh centroids, full
re-assign) past a churn threshold, the same policy shape as its
rebuild-vs-delta gate.

Per-cell candidate matrices are cached on device keyed by the identity
of the ``Zn`` array (the shard's normalized-slice cache): any write
replaces that array, which drops this cache wholesale — repeated
queries between writes skip the gather entirely.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.graph.edges import bucket_size
from repro.serving import queries as Q

#: default number of probed cells for ``mode="ivf"`` queries — 2 keeps
#: recall@10 >= 0.9 on community-structured graphs while scanning
#: ~2/K of the rows (`benchmarks/index_bench.py` charts the trade-off).
DEFAULT_NPROBE = 2


class IVFIndex:
    """Inverted label-cell lists over one shard's owned rows."""

    def __init__(self, *, K: int, row_offset: int = 0):
        self.K = int(K)
        self.row_offset = int(row_offset)
        #: quantizer centroids (K, K) float32 — fixed between builds
        self.centroids: Optional[np.ndarray] = None
        self._cn = None                    # row-normalized centroids
        self.assign: Optional[np.ndarray] = None   # (owned,) cell ids
        self._members: list = [np.zeros(0, np.int64)
                               for _ in range(self.K)]
        self.owned = 0
        #: rows re-assigned to a different cell since the last build —
        #: the engine's re-quantization churn signal
        self.moved_rows = 0
        self.builds = 0
        self.updates = 0
        self._zn_ref = None                # identity key of the cache
        self._cells_cache: dict = {}

    # -- quantization ------------------------------------------------------

    def _assign_cells(self, Zn, rows: Optional[np.ndarray] = None
                      ) -> np.ndarray:
        """Nearest-centroid cell per row (cosine; ties -> lowest cell)."""
        sub = Zn if rows is None else Zn[jnp.asarray(rows)]
        # .copy(): jax buffers come back read-only; `assign` is mutated
        # in place by update_rows
        return np.asarray(jnp.argmax(sub @ self._cn.T, axis=1),
                          np.int32).copy()

    def build(self, Zn, centroids) -> None:
        """Full (re)quantization of every owned row under `centroids`.

        A class with no labeled nodes yields an all-zero centroid;
        `normalize_rows` maps it to the zero vector (never NaN), so it
        simply attracts no rows and its cell stays empty."""
        t0 = obs.tick()
        self.centroids = np.asarray(centroids, np.float32)
        assert self.centroids.shape == (self.K, self.K)
        self._cn = Q.normalize_rows(jnp.asarray(self.centroids))
        self.owned = int(Zn.shape[0]) if Zn is not None else 0
        if self.owned:
            self.assign = self._assign_cells(Zn)
        else:
            self.assign = np.zeros(0, np.int32)
        self._members = [
            np.nonzero(self.assign == c)[0].astype(np.int64)
            for c in range(self.K)]        # np.nonzero -> sorted ids
        self.moved_rows = 0
        self.builds += 1
        self._drop_cache()
        if obs.enabled():
            obs.observe("repro_index_build_seconds", obs.tock(t0))
            obs.counter("repro_index_builds_total")

    def update_rows(self, Zn, local_rows) -> int:
        """Delta maintenance: re-assign exactly `local_rows` (the rows
        an edge batch touched) against the FIXED build-time centroids;
        returns how many changed cell.  O(batch) assignments plus a
        sorted splice per affected cell — never a full re-quantization
        (that is the engine's churn-gated `build`)."""
        if self.assign is None:
            raise RuntimeError("IVFIndex.update_rows before build()")
        t0 = obs.tick()
        rows = np.unique(np.asarray(local_rows, np.int64))
        if rows.size and (rows[0] < 0 or rows[-1] >= self.owned):
            raise IndexError(
                f"local rows outside [0, {self.owned})")
        moved = 0
        if rows.size:
            new = self._assign_cells(Zn, rows)
            old = self.assign[rows]
            changed = new != old
            moved = int(changed.sum())
            if moved:
                mrows, mold, mnew = rows[changed], old[changed], \
                    new[changed]
                for c in np.unique(mold):
                    self._members[c] = np.setdiff1d(
                        self._members[c], mrows[mold == c],
                        assume_unique=True)
                for c in np.unique(mnew):
                    self._members[c] = np.union1d(
                        self._members[c], mrows[mnew == c])
                self.assign[rows] = new
                self.moved_rows += moved
        self.updates += 1
        self._drop_cache()                 # Zn changed under the delta
        if obs.enabled():
            obs.observe("repro_index_update_seconds", obs.tock(t0))
            obs.counter("repro_index_updates_total")
            if moved:
                obs.counter("repro_index_moved_rows_total", moved)
        return moved

    @property
    def churn(self) -> float:
        """Fraction of owned rows that changed cell since the last
        build — the engine re-quantizes past its threshold."""
        return self.moved_rows / max(self.owned, 1)

    def cell_sizes(self) -> np.ndarray:
        """Rows per cell (K,) — the occupancy the server's --obs-dump
        reports; sums to `owned`."""
        return np.array([m.shape[0] for m in self._members], np.int64)

    # -- query -------------------------------------------------------------

    def _drop_cache(self) -> None:
        self._zn_ref = None
        self._cells_cache.clear()

    def _cell_matrix(self, Zn, c: int):
        """(rows, global ids) for cell `c`, gathered once per Zn
        version (any write replaces the shard's normalized slice, which
        invalidates this cache by identity)."""
        if self._zn_ref is not Zn:
            self._zn_ref = Zn
            self._cells_cache.clear()
        hit = self._cells_cache.get(c)
        if hit is None:
            rows = self._members[c]
            hit = (Zn[jnp.asarray(rows)],
                   (rows + self.row_offset).astype(np.int32))
            self._cells_cache[c] = hit
        return hit

    def topk(self, Zn, q, qnodes, probe, *, k: int,
             block_rows: int = 1 << 14):
        """Exact blocked top-k of unit-norm queries `q` against the
        union of this shard's rows in the probed cells.

        `probe` is the engine's (nq, nprobe) cell choice (shared across
        shards so every shard scores the same cells).  Returns
        ``(idx (nq, k) int32, val (nq, k) float32, rows_scanned)`` with
        global-id-stamped candidates in ``(-score, id)`` order, -1/-inf
        padded when fewer than k rows were probed — ready for
        `queries.merge_topk` across shards."""
        qnodes = np.asarray(qnodes, np.int32)
        probe = np.asarray(probe)
        nq = int(q.shape[0])
        vals = np.full((nq, k), -np.inf, np.float32)
        idxs = np.full((nq, k), -1, np.int32)
        scanned = 0
        for c in np.unique(probe):
            if c < 0 or not self._members[c].size:
                continue                   # empty cell: nothing to score
            qsel = np.nonzero((probe == c).any(axis=1))[0]
            if not qsel.size:
                continue
            Zc, ids = self._cell_matrix(Zn, int(c))
            # pad the query batch to a power-of-two bucket so the
            # jitted block kernel compiles per bucket, not per subset
            qb = bucket_size(qsel.size, floor=32)
            qpad = np.zeros(qb, np.int64)
            qpad[:qsel.size] = qsel
            qn = np.full(qb, -1, np.int32)
            qn[:qsel.size] = qnodes[qsel]
            pi, pv = Q.topk_cosine_ids(
                Zc, ids, q[jnp.asarray(qpad)], qn, k=k,
                block_rows=block_rows)
            pi, pv = pi[:qsel.size], pv[:qsel.size]
            scanned += int(self._members[c].size) * int(qsel.size)
            mi, mv = Q.merge_topk([idxs[qsel], pi], [vals[qsel], pv],
                                  k=k)
            idxs[qsel], vals[qsel] = mi, mv
        return idxs, vals, scanned
