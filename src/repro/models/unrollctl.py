"""Unroll control for cost-probe lowering.

XLA cost_analysis counts while-loop bodies once; probe lowerings enable
unroll mode so every layer / attention block / mLSTM chunk appears
literally in the HLO and is counted.  Never enabled in production paths
(the scanned lowering is what ships); only launch/dryrun probe cells set
it.
"""
from __future__ import annotations

import contextlib

_UNROLL = False


def enabled() -> bool:
    return _UNROLL


@contextlib.contextmanager
def unrolled():
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev
