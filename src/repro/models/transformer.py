"""Block assembly for every assigned architecture family.

All stacks scan over layers (``lax.scan`` over stacked params) with
optional remat — this keeps the HLO O(1) in depth, which is what makes an
80-layer 110B config lower+compile in seconds on the dry-run host.

Block contract (uniform across attn / moe / mamba / mlstm / slstm):

    body(x, p, c, mode) -> (x_out, new_cache, aux)

where `c`/`new_cache` are per-layer cache entries (None in train mode)
and aux is a scalar (MoE load-balance loss, 0 elsewhere).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (ParamSpec, apply_mlp, apply_norm, ashard,
                                 mlp_specs, norm_specs, stack_specs)

# ---------------------------------------------------------------------------
# current mesh hook (set by repro.sharding.use_sharding)
# ---------------------------------------------------------------------------

_CURRENT_MESH = None


def set_current_mesh(mesh) -> None:
    global _CURRENT_MESH
    _CURRENT_MESH = mesh


def current_mesh():
    return _CURRENT_MESH


# ---------------------------------------------------------------------------
# Attention (+MLP / +MoE) block
# ---------------------------------------------------------------------------


def attn_block_specs(cfg, use_moe: bool = False, cross: bool = False):
    sp = {"ln1": norm_specs(cfg, cfg.d_model),
          "attn": attn.attn_specs(cfg),
          "ln2": norm_specs(cfg, cfg.d_model)}
    if cross:
        sp["lnx"] = norm_specs(cfg, cfg.d_model)
        sp["xattn"] = attn.attn_specs(cfg, cross=True)
    if use_moe:
        sp["moe"] = moe_mod.moe_specs(cfg)
    elif cfg.d_ff:
        sp["mlp"] = mlp_specs(cfg, cfg.d_model, cfg.d_ff)
    return sp


def _ffn(cfg, p, x):
    """Second half-block: norm + (moe|mlp) + residual. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        h = apply_norm(cfg, p["ln2"], x)
        aux = moe_mod.aux_load_balance_loss(cfg, p["moe"], h)
        x = x + moe_mod.apply_moe(cfg, p["moe"], h)
    elif "mlp" in p:
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
    return x, aux


def attn_block_train(cfg, p, x, positions, *, impl="flash", causal=True,
                     enc_out=None):
    """Train/prefill-shaped attention block. Returns (x, kv, aux)."""
    h = apply_norm(cfg, p["ln1"], x)
    q, k, v = attn.project_qkv(cfg, p["attn"], h, positions)
    if causal:
        o = attn.self_attention(cfg, q, k, v, positions, positions, impl=impl)
    else:
        o = attn.attn_full(q, k, v, positions, positions, causal=False)
    x = x + attn.out_proj(cfg, p["attn"], o)
    if enc_out is not None:                      # decoder cross-attention
        h = apply_norm(cfg, p["lnx"], x)
        qx, _, _ = attn.project_qkv(cfg, p["xattn"], h, positions, rope=False)
        ek = jnp.einsum("bfd,dhk->bfhk", enc_out,
                        p["xattn"]["wk"].astype(enc_out.dtype))
        ev = jnp.einsum("bfd,dhk->bfhk", enc_out,
                        p["xattn"]["wv"].astype(enc_out.dtype))
        ox = attn.cross_attention(cfg, qx, ek, ev)
        x = x + attn.out_proj(cfg, p["xattn"], ox)
    x, aux = _ffn(cfg, p, x)
    x = ashard(x, "batch", "seq", "embed")
    return x, (k, v), aux


def attn_block_decode(cfg, p, x, pos, cache, *, cross_kv=None):
    """One-token attention block. x: (B, D). cache: {"k","v"}[, cross]."""
    h = apply_norm(cfg, p["ln1"], x)[:, None]            # (B,1,D)
    pos_arr = jnp.full((1,), pos)
    q, k, v = attn.project_qkv(cfg, p["attn"], h, pos_arr)
    o, new_cache = attn.decode_attention(
        cfg, cache, q[:, 0], k[:, 0], v[:, 0], pos, mesh=current_mesh())
    x = x + attn.out_proj(cfg, p["attn"], o[:, None])[:, 0]
    if cross_kv is not None:
        hx = apply_norm(cfg, p["lnx"], x)[:, None]
        qx, _, _ = attn.project_qkv(cfg, p["xattn"], hx, pos_arr, rope=False)
        ox = attn.cross_attention(cfg, qx, cross_kv["k"], cross_kv["v"])
        x = x + attn.out_proj(cfg, p["xattn"], ox)[:, 0]
    x2, aux = _ffn(cfg, p, x[:, None])
    return x2[:, 0], new_cache, aux


# ---------------------------------------------------------------------------
# Mamba2 / mLSTM / sLSTM blocks (pre-norm + residual)
# ---------------------------------------------------------------------------


def mamba_block_specs(cfg):
    return {"ln": norm_specs(cfg, cfg.d_model), "ssm": ssm_mod.ssm_specs(cfg)}


def mamba_block(cfg, p, x, state=None):
    h = apply_norm(cfg, p["ln"], x)
    out, new_state = ssm_mod.apply_ssm(cfg, p["ssm"], h, state)
    return x + out, new_state


def mlstm_block_specs(cfg):
    return {"ln": norm_specs(cfg, cfg.d_model),
            "mlstm": xlstm_mod.mlstm_specs(cfg)}


def mlstm_block(cfg, p, x, state=None):
    h = apply_norm(cfg, p["ln"], x)
    out, new_state = xlstm_mod.apply_mlstm(cfg, p["mlstm"], h, state)
    return x + out, new_state


def slstm_block_specs(cfg):
    return {"ln": norm_specs(cfg, cfg.d_model),
            "slstm": xlstm_mod.slstm_specs(cfg)}


def slstm_block(cfg, p, x, state=None):
    h = apply_norm(cfg, p["ln"], x)
    out, new_state = xlstm_mod.apply_slstm(cfg, p["slstm"], h, state)
    return x + out, new_state


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------


def _maybe_remat(cfg, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def maybe_scan(f, init, xs):
    """lax.scan that honors unroll mode (see unrollctl)."""
    from repro.models import unrollctl
    if not unrollctl.enabled():
        return jax.lax.scan(f, init, xs)
    L = jax.tree_util.tree_leaves(xs)[0].shape[0]
    take = lambda t, i: jax.tree_util.tree_map(lambda a: a[i], t)
    carry, ys = init, []
    for i in range(L):
        carry, y = f(carry, take(xs, i))
        ys.append(y)
    ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    return carry, ys


def scan_stack(cfg, body, x, stacked_params, stacked_cache=None):
    """Scan body(x, p, c) -> (x, new_c, aux) over the layer dim.

    Unroll mode (cost probes / cfg.scan_layers=False) runs a python loop
    over the same stacked params so every layer's ops appear in HLO."""
    def f(carry, inp):
        p, c = inp
        x_new, c_new, aux = body(carry, p, c)
        return x_new, (c_new, aux)

    f = _maybe_remat(cfg, f)

    from repro.models import unrollctl
    if unrollctl.enabled() or not cfg.scan_layers:
        L = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]
        take = lambda t, i: jax.tree_util.tree_map(lambda a: a[i], t)
        caches, auxs = [], []
        for i in range(L):
            c = None if stacked_cache is None else take(stacked_cache, i)
            x, (c_new, aux) = f(x, (take(stacked_params, i), c))
            caches.append(c_new)
            auxs.append(aux)
        new_cache = None if caches[0] is None else \
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches)
        return x, new_cache, sum(auxs)

    x, (new_cache, auxs) = jax.lax.scan(f, x, (stacked_params, stacked_cache))
    return x, new_cache, jnp.sum(auxs)


# ----- homogeneous decoder (dense / moe / vlm) ------------------------------


def uniform_stack_specs(cfg):
    block = attn_block_specs(cfg, use_moe=cfg.moe is not None)
    return stack_specs(block, cfg.n_layers)


def uniform_stack_train(cfg, params, x, positions, *, impl="flash",
                        collect_kv=False, max_len=None):
    ml = max_len or positions.shape[0]

    def body(x, p, _):
        x, kv, aux = attn_block_train(cfg, p, x, positions, impl=impl)
        if collect_kv:
            cache = attn.fill_kv_cache(
                cfg, attn.init_kv_cache(cfg, x.shape[0], ml, x.dtype),
                kv[0], kv[1])
        else:
            cache = None
        return x, cache, aux

    return scan_stack(cfg, body, x, params, None)


def uniform_stack_decode(cfg, params, x, pos, cache):
    def body(x, p, c):
        return attn_block_decode(cfg, p, x, pos, c)

    return scan_stack(cfg, body, x, params, cache)


# ----- xLSTM stack ----------------------------------------------------------


def xlstm_group_layout(cfg):
    """(n_groups, mlstm_per_group) — one sLSTM closes each group."""
    every = cfg.xlstm.slstm_every
    assert cfg.n_layers % every == 0, (cfg.n_layers, every)
    return cfg.n_layers // every, every - 1


def xlstm_stack_specs(cfg):
    g, m = xlstm_group_layout(cfg)
    group = {"mlstm": stack_specs(mlstm_block_specs(cfg), m, "inner"),
             "slstm": slstm_block_specs(cfg)}
    return stack_specs(group, g, "layers")


def xlstm_stack_apply(cfg, params, x, state=None):
    """Works for train (state=None -> zero states consumed, states
    returned) and decode/prefill-continuation (state given)."""
    B = x.shape[0]
    zero = state is None

    def group_body(x, p, c):
        if zero:
            c = {"mlstm": jax.tree_util.tree_map(
                     lambda s: jnp.broadcast_to(
                         s, (p_inner_len,) + s.shape),
                     xlstm_mod.init_mlstm_state(cfg, B)),
                 "slstm": xlstm_mod.init_slstm_state(cfg, B)}

        def inner(x, ip, ic):
            x, st = mlstm_block(cfg, ip, x, ic)
            return x, st, jnp.zeros((), jnp.float32)

        x, m_states, _ = scan_stack(cfg, inner, x, p["mlstm"], c["mlstm"])
        x, s_state = slstm_block(cfg, p["slstm"], x, c["slstm"])
        return x, {"mlstm": m_states, "slstm": s_state}, \
            jnp.zeros((), jnp.float32)

    g, p_inner_len = xlstm_group_layout(cfg)
    x, new_state, _ = scan_stack(cfg, group_body, x, params,
                                 None if zero else state)
    return x, new_state, jnp.zeros((), jnp.float32)


def xlstm_state_specs(cfg, batch):
    g, m = xlstm_group_layout(cfg)
    group = {"mlstm": stack_specs(xlstm_mod.mlstm_state_specs(cfg, batch),
                                  m, "inner"),
             "slstm": xlstm_mod.slstm_state_specs(cfg, batch)}
    return stack_specs(group, g, "layers")


def xlstm_init_state(cfg, batch):
    g, m = xlstm_group_layout(cfg)

    def rep(t, n):
        return jax.tree_util.tree_map(
            lambda s: jnp.broadcast_to(s, (n,) + s.shape).copy(), t)

    group = {"mlstm": rep(xlstm_mod.init_mlstm_state(cfg, batch), m),
             "slstm": xlstm_mod.init_slstm_state(cfg, batch)}
    return rep(group, g)


# ----- zamba2 hybrid stack --------------------------------------------------


def zamba_layout(cfg):
    g = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers - g * cfg.attn_every
    return g, cfg.attn_every, tail


def zamba_stack_specs(cfg):
    g, per, tail = zamba_layout(cfg)
    sp = {
        "groups": stack_specs(
            {"mamba": stack_specs(mamba_block_specs(cfg), per, "inner")},
            g, "layers"),
        "shared_attn": attn_block_specs(cfg),
        "shared_proj": ParamSpec((2 * cfg.d_model, cfg.d_model),
                                 ("embed", None), fan_in=2 * cfg.d_model),
    }
    if tail:
        sp["tail"] = stack_specs(mamba_block_specs(cfg), tail, "layers")
    return sp


def _zamba_shared_in(cfg, p, x, x0):
    h = jnp.concatenate([x, x0], axis=-1)
    return jnp.einsum("...e,ed->...d", h, p["shared_proj"].astype(x.dtype))


def zamba_stack_train(cfg, params, x, positions, *, impl="flash",
                      collect=False, max_len=None):
    """Returns (x, cache, aux). cache collects ssm states (+kv if collect)."""
    x0 = x
    B, S = x.shape[0], x.shape[1]
    ml = max_len or S

    def group_body(x, p, _):
        def inner(x, ip, _c):
            x, st = mamba_block(cfg, ip, x, None)
            return x, st if collect else None, jnp.zeros((), jnp.float32)

        x, m_states, _ = scan_stack(cfg, inner, x, p["mamba"], None)
        h = _zamba_shared_in(cfg, params, x, x0)
        h, kv, aux = attn_block_train(cfg, params["shared_attn"], h,
                                      positions, impl=impl)
        x = x + h
        cache = None
        if collect:
            kvc = attn.fill_kv_cache(
                attn_cfg_for_shared(cfg),
                attn.init_kv_cache(attn_cfg_for_shared(cfg), B, ml, x.dtype),
                kv[0], kv[1])
            cache = {"mamba": m_states, "attn": kvc}
        return x, cache, aux

    g, per, tail = zamba_layout(cfg)
    x, gcache, aux = scan_stack(cfg, group_body, x, params["groups"], None)
    tcache = None
    if tail:
        def tail_body(x, p, _):
            x, st = mamba_block(cfg, p, x, None)
            return x, st if collect else None, jnp.zeros((), jnp.float32)
        x, tcache, _ = scan_stack(cfg, tail_body, x, params["tail"], None)
    cache = {"groups": gcache, "tail": tcache} if collect else None
    return x, cache, aux


def attn_cfg_for_shared(cfg):
    return cfg          # shared attn uses the same dims; no SWA


def zamba_stack_decode(cfg, params, x, pos, cache):
    x0 = x

    def group_body(x, p_c):
        p, c = p_c

        def inner(x, inp):
            ip, ic = inp
            y, st = mamba_block(cfg, ip, x[:, None], ic)
            return y[:, 0], st

        x, m_states = maybe_scan(inner, x, (p["mamba"], c["mamba"]))
        h = _zamba_shared_in(cfg, params, x, x0)
        h, kvc, aux = attn_block_decode(cfg, params["shared_attn"], h, pos,
                                        c["attn"])
        x = x + h
        return x, ({"mamba": m_states, "attn": kvc}, aux)

    f = _maybe_remat(cfg, group_body)
    x, (gcache, auxs) = maybe_scan(
        lambda carry, inp: f(carry, inp), x,
        (params["groups"], cache["groups"]))
    tcache = None
    if cache.get("tail") is not None:
        def tail_body(x, inp):
            p, c = inp
            y, st = mamba_block(cfg, p, x[:, None], c)
            return y[:, 0], st
        x, tcache = maybe_scan(tail_body, x,
                               (params["tail"], cache["tail"]))
    return x, {"groups": gcache, "tail": tcache}, jnp.sum(auxs)


# ----- whisper enc-dec stack ------------------------------------------------


def whisper_specs(cfg):
    enc_block = attn_block_specs(cfg)
    dec_block = attn_block_specs(cfg, cross=True)
    return {
        "enc": stack_specs(enc_block, cfg.enc_layers),
        "dec": stack_specs(dec_block, cfg.dec_layers),
        "enc_pos": ParamSpec((cfg.n_frames, cfg.d_model), (None, "embed"),
                             "pos"),
        "enc_norm": norm_specs(cfg, cfg.d_model),
    }


def whisper_encode(cfg, params, frames):
    """frames: (B, F, D) precomputed embeddings (conv frontend stub)."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + params["enc_pos"].astype(x.dtype)
    positions = jnp.arange(frames.shape[1])

    def body(x, p, _):
        x, _, aux = attn_block_train(cfg, p, x, positions, causal=False)
        return x, None, aux

    x, _, _ = scan_stack(cfg, body, x, params["enc"], None)
    return apply_norm(cfg, params["enc_norm"], x)


def whisper_decode_train(cfg, params, enc_out, x, positions, *,
                         impl="flash", collect_kv=False, max_len=None):
    B, S = x.shape[0], x.shape[1]
    ml = max_len or S

    def body(x, p, _):
        x, kv, aux = attn_block_train(cfg, p, x, positions, impl=impl,
                                      enc_out=enc_out)
        cache = None
        if collect_kv:
            kvc = attn.fill_kv_cache(
                cfg, attn.init_kv_cache(cfg, B, ml, x.dtype), kv[0], kv[1])
            ek = jnp.einsum("bfd,dhk->bfhk", enc_out,
                            p["xattn"]["wk"].astype(enc_out.dtype))
            ev = jnp.einsum("bfd,dhk->bfhk", enc_out,
                            p["xattn"]["wv"].astype(enc_out.dtype))
            cache = {"self": kvc, "cross": {"k": ek, "v": ev}}
        return x, cache, aux

    return scan_stack(cfg, body, x, params["dec"], None)


def whisper_stack_decode(cfg, params, x, pos, cache):
    def body(x, p, c):
        x, new_self, aux = attn_block_decode(cfg, p, x, pos, c["self"],
                                             cross_kv=c["cross"])
        return x, {"self": new_self, "cross": c["cross"]}, aux

    return scan_stack(cfg, body, x, params["dec"], cache)
