"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-
parallel) and sLSTM (scalar memory, strictly recurrent).

mLSTM cell (stabilized, per head):
    i_t = exp(~i_t),  f_t = sigmoid-or-exp(~f_t)   (log-space here)
    C_t = f_t C_{t-1} + i_t v_t k_t^T      (matrix memory, Dh x Dh)
    n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)
We run the chunkwise-parallel form (same trick as SSD/GLA): within a
chunk, weights w_tj = exp(cumF_t - cumF_j + logi_j) form a lower-
triangular attention-like matrix; across chunks the (C, n) state is
carried by a short lax.scan.  Max-stabilization keeps exp() bounded.

sLSTM is sequential by construction (recurrent h_{t-1} feeds the gates),
so prefill scans over time — this is faithful to the paper (sLSTM blocks
trade parallelism for state-tracking ability; xlstm-1.3b has 1 sLSTM per
8 blocks so the cost is bounded).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, ashard

_NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_dims(cfg):
    """(d_inner, H, Dv, Dqk): block-diagonal per-head projections with
    half-dim q/k (official xLSTM-1.3b structure)."""
    x = cfg.xlstm
    d_inner = x.mlstm_expand * cfg.d_model
    H = cfg.n_heads
    Dv = d_inner // H
    return d_inner, H, Dv, max(Dv // 2, 1)


def mlstm_specs(cfg):
    d = cfg.d_model
    d_inner, H, Dv, Dqk = mlstm_dims(cfg)
    return {
        "w_up": ParamSpec((d, 2 * d_inner), ("embed", "mlp")),   # [x_in, z]
        "wq": ParamSpec((H, Dv, Dqk), ("heads", None, None), fan_in=Dv),
        "wk": ParamSpec((H, Dv, Dqk), ("heads", None, None), fan_in=Dv),
        "wv": ParamSpec((H, Dv, Dv), ("heads", None, None), fan_in=Dv),
        "w_if": ParamSpec((d_inner, 2 * H), ("mlp", None)),      # gates
        "b_if": ParamSpec((2 * H,), (None,), "zeros"),
        "norm_scale": ParamSpec((d_inner,), ("mlp",), "ones"),
        "w_down": ParamSpec((d_inner, d), ("mlp", "embed")),
    }


def _mlstm_chunked(q, k, v, logi, logf, chunk, state=None):
    """q,k,v: (B,T,H,Dh) f32; logi/logf: (B,T,H) f32 (log gates).

    Returns h (B,T,H,Dh), new_state (C (B,H,Dh,Dh), n (B,H,Dh), m (B,H)).
    """
    B, T, H, Dqk = q.shape
    Dv = v.shape[-1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:  # logi=-inf (no contribution), logf=0 (no decay) on padding
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)),
                       constant_values=_NEG)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
    T_pad = T + pad
    nc = T_pad // chunk
    scale = Dqk ** -0.5
    q = q * scale

    qc = q.reshape(B, nc, chunk, H, Dqk)
    kc = k.reshape(B, nc, chunk, H, Dqk)
    vc = v.reshape(B, nc, chunk, H, Dv)
    lic = logi.reshape(B, nc, chunk, H).transpose(0, 1, 3, 2)   # (B,nc,H,L)
    lfc = logf.reshape(B, nc, chunk, H).transpose(0, 1, 3, 2)

    cumf = jnp.cumsum(lfc, axis=-1)                              # (B,nc,H,L)
    # log weight of source j at target t (within chunk, j <= t):
    #   cumf_t - cumf_j + logi_j
    lw = (cumf[..., :, None] - cumf[..., None, :] + lic[..., None, :])
    L = chunk
    mask = jnp.tril(jnp.ones((L, L), bool))
    lw = jnp.where(mask, lw, _NEG)

    # chunk-state log weights: contribution of j to end-of-chunk state
    lw_state = cumf[..., -1:] - cumf + lic                       # (B,nc,H,L)
    # inter-chunk: state entering chunk c decays by cumf_t within chunk
    lw_in = cumf                                                  # (B,nc,H,L)

    if state is None:
        C0 = jnp.zeros((B, H, Dqk, Dv), jnp.float32)
        n0 = jnp.zeros((B, H, Dqk), jnp.float32)
        m0 = jnp.full((B, H), _NEG, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    # ---- sequential pass over chunks (carries C, n, m) --------------------
    def step(carry, inp):
        C_prev, n_prev, m_prev = carry
        qb, kb, vb, lwb, lwsb, lwib, cumfb = inp
        # stabilizer: max over intra weights and inherited state magnitude
        m_intra = lwb.max(-1)                                    # (B,H,L)
        m_t = jnp.maximum(m_prev[..., None] + cumfb, m_intra)    # (B,H,L)
        # intra-chunk
        w = jnp.exp(lwb - m_t[..., None])                        # (B,H,L,L)
        scores = jnp.einsum("bthd,bshd->bhts", qb, kb)           # (B,H,L,L)
        num_intra = jnp.einsum("bhts,bhts,bshd->bthd",
                               scores, w, vb)
        den_intra = jnp.einsum("bhts,bhts->bth", scores, w)
        # inter-chunk (state from previous chunks)
        decay_in = jnp.exp(lwib + m_prev[..., None] - m_t)       # (B,H,L)
        num_inter = jnp.einsum("bthd,bhde,bht->bthe",
                               qb, C_prev, decay_in)
        den_inter = jnp.einsum("bthd,bhd,bht->bth",
                               qb, n_prev, decay_in)
        num = num_intra + num_inter                              # (B,L,H,Dh)
        den = den_intra + den_inter                              # (B,L,H)
        floor = jnp.exp(-m_t).transpose(0, 2, 1)                 # (B,L,H)
        h = num / jnp.maximum(jnp.abs(den), floor)[..., None]
        # ---- update state to end of chunk
        m_end = jnp.maximum(m_prev + cumfb[..., -1], lwsb.max(-1))
        ws = jnp.exp(lwsb - m_end[..., None])                    # (B,H,L)
        C_new = (C_prev * jnp.exp(m_prev + cumfb[..., -1]
                                  - m_end)[..., None, None]
                 + jnp.einsum("bht,bthd,bthe->bhde", ws, kb, vb))
        n_new = (n_prev * jnp.exp(m_prev + cumfb[..., -1] - m_end)[..., None]
                 + jnp.einsum("bht,bthd->bhd", ws, kb))
        return (C_new, n_new, m_end), h

    xs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), lw.transpose(1, 0, 2, 3, 4),
          lw_state.transpose(1, 0, 2, 3), lw_in.transpose(1, 0, 2, 3),
          cumf.transpose(1, 0, 2, 3))
    from repro.models import unrollctl
    if unrollctl.enabled():
        carry, hs_list = (C0, n0, m0), []
        for i in range(nc):
            carry, hh = step(carry, jax.tree_util.tree_map(
                lambda a, i=i: a[i], xs))
            hs_list.append(hh)
        Cf, nf, mf = carry
        hs = jnp.stack(hs_list)
    else:
        (Cf, nf, mf), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, T_pad, H, Dv)
    return h[:, :T], {"C": Cf, "n": nf, "m": mf}


def apply_mlstm(cfg, p, x, state=None):
    """mLSTM block. x: (B,T,D) -> (out, new_state)."""
    d_inner, H, Dv, Dqk = mlstm_dims(cfg)
    cdt = x.dtype
    up = jnp.einsum("btd,de->bte", x, p["w_up"].astype(cdt))
    xin, z = jnp.split(up, 2, axis=-1)
    xin = ashard(xin, "batch", "seq", "mlp")
    xh = xin.reshape(*xin.shape[:2], H, Dv)      # per-head stream
    q = jnp.einsum("bthe,hed->bthd", xh, p["wq"].astype(cdt))
    k = jnp.einsum("bthe,hed->bthd", xh, p["wk"].astype(cdt))
    v = jnp.einsum("bthe,hed->bthd", xh, p["wv"].astype(cdt))
    gates = (jnp.einsum("bte,eg->btg", xin, p["w_if"].astype(cdt))
             + p["b_if"].astype(cdt)).astype(jnp.float32)
    logi, logf_raw = jnp.split(gates, 2, axis=-1)                # (B,T,H)
    logf = jax.nn.log_sigmoid(logf_raw)

    h, new_state = _mlstm_chunked(
        q.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), logi, logf, cfg.xlstm.mlstm_chunk, state)
    h = h.reshape(*h.shape[:2], d_inner).astype(cdt)
    h = rms_gate(h, z, p["norm_scale"])
    out = jnp.einsum("bte,ed->btd", h, p["w_down"].astype(cdt))
    return out, new_state


def rms_gate(h, z, scale):
    from repro.models.layers import rms_norm
    return rms_norm(h, scale) * jax.nn.silu(z)


def init_mlstm_state(cfg, batch, dtype=jnp.float32):
    d_inner, H, Dv, Dqk = mlstm_dims(cfg)
    return {"C": jnp.zeros((batch, H, Dqk, Dv), jnp.float32),
            "n": jnp.zeros((batch, H, Dqk), jnp.float32),
            "m": jnp.full((batch, H), _NEG, jnp.float32)}


def mlstm_state_specs(cfg, batch):
    d_inner, H, Dv, Dqk = mlstm_dims(cfg)
    return {"C": ParamSpec((batch, H, Dqk, Dv),
                           ("batch", "heads", None, None), "zeros",
                           jnp.float32),
            "n": ParamSpec((batch, H, Dqk), ("batch", "heads", None),
                           "zeros", jnp.float32),
            "m": ParamSpec((batch, H), ("batch", "heads"), "zeros",
                           jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_dims(cfg):
    H = cfg.n_heads
    return H, cfg.d_model // H


def slstm_specs(cfg):
    d = cfg.d_model
    H, Dh = slstm_dims(cfg)
    return {
        # 4 gates (i, f, z, o) from input and recurrent h (block-diag/head)
        "w_x": ParamSpec((d, H, 4 * Dh), ("embed", "heads", None), fan_in=d),
        "r_h": ParamSpec((H, Dh, 4 * Dh), ("heads", None, None), fan_in=Dh),
        "bias": ParamSpec((H, 4 * Dh), ("heads", None), "zeros"),
        "norm_scale": ParamSpec((d,), ("embed",), "ones"),
        "w_down": ParamSpec((d, d), ("embed", "embed_out")),
    }


def _slstm_cell(p, xg, state):
    """xg: (B, H, 4Dh) f32 gate pre-activations. States all f32 (the
    scan carry must be dtype-stable)."""
    c, n, m, h = state
    rg = jnp.einsum("bhd,hdg->bhg", h, p["r_h"].astype(jnp.float32))
    g = xg + rg + p["bias"].astype(jnp.float32)
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    logf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(logf + m, gi)
    i = jnp.exp(gi - m_new)
    f = jnp.exp(logf + m - m_new)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new)


def apply_slstm(cfg, p, x, state=None):
    """sLSTM block: sequential scan over time. x: (B,T,D)."""
    B, T, D = x.shape
    H, Dh = slstm_dims(cfg)
    cdt = x.dtype
    xg_all = jnp.einsum("btd,dhg->bthg", x,
                        p["w_x"].astype(cdt)).astype(jnp.float32)
    if state is None:
        state = init_slstm_state(cfg, B)
    state = tuple(state[k].astype(jnp.float32)
                  for k in ("c", "n", "m", "h"))

    def step(carry, xg):
        new = _slstm_cell(p, xg, carry)
        return new, new[3]

    (c, n, m, h), hs = jax.lax.scan(step, state,
                                    xg_all.transpose(1, 0, 2, 3))
    out = hs.transpose(1, 0, 2, 3).reshape(B, T, D).astype(cdt)
    from repro.models.layers import rms_norm
    out = rms_norm(out, p["norm_scale"])
    out = jnp.einsum("btd,de->bte", out, p["w_down"].astype(cdt))
    new_state = {"c": c, "n": n, "m": m, "h": h}
    return out, new_state


def init_slstm_state(cfg, batch, dtype=jnp.float32):
    H, Dh = slstm_dims(cfg)
    z = lambda: jnp.zeros((batch, H, Dh), jnp.float32)
    return {"c": z(), "n": z(), "m": jnp.full((batch, H, Dh), 0.0,
                                              jnp.float32), "h": z()}


def slstm_state_specs(cfg, batch):
    H, Dh = slstm_dims(cfg)
    sp = ParamSpec((batch, H, Dh), ("batch", "heads", None), "zeros",
                   jnp.float32)
    return {"c": sp, "n": sp, "m": sp, "h": sp}
