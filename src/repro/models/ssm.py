"""Mamba2 (SSD) block: chunked-parallel scan for train/prefill, O(1)-state
recurrence for decode.

Faithful to the SSD formulation (Dao & Gu 2024): scalar-per-head A,
single B/C group, depthwise conv frontend, gated RMSNorm before out-proj.
The chunked algorithm computes, per chunk of length L:
  intra-chunk:  Y_ij = C_i . B_j * exp(cumA_i - cumA_j) * dt_j  (j <= i)
  chunk state:  S_c  = sum_j exp(cumA_last - cumA_j) * dt_j * (B_j x X_j)
  inter-chunk:  lax.scan over chunk states (the only sequential part)
so the sequential depth is T/chunk instead of T.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, ashard, rms_norm


def ssm_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.head_dim, s.state


def ssm_specs(cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, Pdim, N = ssm_dims(cfg)
    conv_dim = d_inner + 2 * N          # x, B, C all pass the conv
    return {
        # in_proj -> [z, xBC, dt]
        "w_in": ParamSpec((d, 2 * d_inner + 2 * N + H), ("embed", "mlp")),
        "conv_w": ParamSpec((s.conv, conv_dim), (None, "mlp"), fan_in=s.conv),
        "conv_b": ParamSpec((conv_dim,), ("mlp",), "zeros"),
        "A_log": ParamSpec((H,), (None,), "mamba_a"),
        "D": ParamSpec((H,), (None,), "ones"),
        "dt_bias": ParamSpec((H,), (None,), "dt_bias"),
        "norm_scale": ParamSpec((d_inner,), ("mlp",), "ones"),
        "w_out": ParamSpec((d_inner, d), ("mlp", "embed")),
    }


def _split_in(cfg, proj):
    d_inner, H, Pdim, N = ssm_dims(cfg)
    z, xBC, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xBC, dt


def _conv1d(cfg, p, xBC, conv_state=None):
    """Causal depthwise conv. xBC: (B, T, conv_dim).

    Returns (out (B,T,conv_dim), new_conv_state (B, conv-1, conv_dim)).
    """
    W = p["conv_w"]                      # (K, conv_dim)
    K = W.shape[0]
    B = xBC.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((B, K - 1, xBC.shape[-1]), xBC.dtype)
    xpad = jnp.concatenate([conv_state, xBC], axis=1)
    # depthwise causal conv as sum of shifted scaled copies (K is tiny)
    out = sum(xpad[:, i:i + xBC.shape[1]] * W[i].astype(xBC.dtype)
              for i in range(K))
    out = out + p["conv_b"].astype(xBC.dtype)
    out = jax.nn.silu(out)
    new_state = xpad[:, xpad.shape[1] - (K - 1):]
    return out, new_state


def _segsum(a):
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} a[..., k] for
    i >= j, -inf elsewhere.  a: (..., L)."""
    L = a.shape[-1]
    c = jnp.cumsum(a, axis=-1)
    diff = c[..., :, None] - c[..., None, :]      # cum_i - cum_j
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, initial_state=None):
    """SSD chunked scan.

    x:  (B, T, H, P)   inputs per head
    dt: (B, T, H)      positive step sizes
    A:  (H,)           negative decay rates
    Bm: (B, T, N)      input mixers (single group)
    Cm: (B, T, N)      output mixers
    initial_state: (B, H, N, P) carried state (decode / continuation)
    Returns y: (B, T, H, P), final_state: (B, H, N, P).
    """
    Bsz, T, H, Pdim = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:  # dt=0 on padding => decay 1, contribution 0 (exact)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    T_pad = T + pad
    nc = T_pad // chunk

    xc = x.reshape(Bsz, nc, chunk, H, Pdim)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    a = dtc * A                                   # (B,nc,L,H), negative
    a = a.transpose(0, 1, 3, 2)                   # (B,nc,H,L)
    cum_a = jnp.cumsum(a, axis=-1)                # (B,nc,H,L)

    # ---- intra-chunk (quadratic within chunk) -----------------------------
    Lmat = jnp.exp(_segsum(a))                    # (B,nc,H,L,L)
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)    # (B,nc,L,L)
    W = CB[:, :, None] * Lmat * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", W, xc)

    # ---- per-chunk state contribution -------------------------------------
    decay_to_end = jnp.exp(cum_a[..., -1:] - cum_a)          # (B,nc,H,L)
    Sc = jnp.einsum("bchl,bclh,bcln,bclhp->bchnp",
                    decay_to_end, dtc, Bc, xc)               # (B,nc,H,N,P)

    # ---- inter-chunk recurrence (sequential over chunks) -------------------
    chunk_decay = jnp.exp(cum_a[..., -1])                    # (B,nc,H)

    def step(S_prev, inp):
        Sc_c, dec_c = inp                                    # (B,H,N,P),(B,H)
        S_new = S_prev * dec_c[..., None, None] + Sc_c
        return S_new, S_prev

    S0 = (jnp.zeros((Bsz, H, N, Pdim), x.dtype) if initial_state is None
          else initial_state.astype(x.dtype))
    S_final, S_prevs = jax.lax.scan(
        step, S0, (Sc.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)               # (B,nc,H,N,P)

    # ---- inter-chunk output ------------------------------------------------
    decay_from_start = jnp.exp(cum_a)                        # (B,nc,H,L)
    y_inter = jnp.einsum("bcln,bchl,bchnp->bclhp",
                         Cc, decay_from_start, S_prevs)
    y = (y_intra + y_inter).reshape(Bsz, T_pad, H, Pdim)
    return y[:, :T], S_final


def apply_ssm(cfg, p, x, state=None):
    """Full mamba2 block. x: (B, T, D).

    state: None (train) or dict(conv, ssm) for chunk-continuation.
    Returns (out (B,T,D), new_state).
    """
    s = cfg.ssm
    d_inner, H, Pdim, N = ssm_dims(cfg)
    cdt = x.dtype
    proj = jnp.einsum("btd,de->bte", x, p["w_in"].astype(cdt))
    z, xBC, dt = _split_in(cfg, proj)
    conv_state = None if state is None else state["conv"]
    xBC, new_conv = _conv1d(cfg, p, xBC, conv_state)
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    xs = xs.reshape(*xs.shape[:2], H, Pdim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    init_S = None if state is None else state["ssm"]
    y, S_final = ssd_chunked(xs.astype(jnp.float32), dt, A,
                             Bm.astype(jnp.float32),
                             Cm.astype(jnp.float32), s.chunk,
                             initial_state=init_S)
    y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(*y.shape[:2], d_inner).astype(cdt)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    y = ashard(y, "batch", "seq", "mlp")
    out = jnp.einsum("bte,ed->btd", y, p["w_out"].astype(cdt))
    new_state = {"conv": new_conv, "ssm": S_final}
    return out, new_state


def init_ssm_state(cfg, batch: int, dtype):
    s = cfg.ssm
    d_inner, H, Pdim, N = ssm_dims(cfg)
    conv_dim = d_inner + 2 * N
    return {"conv": jnp.zeros((batch, s.conv - 1, conv_dim), dtype),
            "ssm": jnp.zeros((batch, H, N, Pdim), jnp.float32)}


def ssm_state_specs(cfg, batch: int, dtype):
    s = cfg.ssm
    d_inner, H, Pdim, N = ssm_dims(cfg)
    conv_dim = d_inner + 2 * N
    return {
        "conv": ParamSpec((batch, s.conv - 1, conv_dim),
                          ("batch", None, "mlp"), "zeros", dtype),
        "ssm": ParamSpec((batch, H, N, Pdim),
                         ("batch", "heads", None, None), "zeros",
                         jnp.float32),
    }


def decode_ssm(cfg, p, x, state):
    """One-token decode. x: (B, D). Returns (out (B,D), new_state)."""
    out, new_state = apply_ssm(cfg, p, x[:, None], state)
    return out[:, 0], new_state
