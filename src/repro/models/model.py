"""Top-level model API: one entry point per lifecycle stage.

    param_specs(cfg)            -> ParamSpec tree (shapes + logical axes)
    init_params(cfg, key)       -> materialized pytree (smoke/training)
    abstract_params(cfg)        -> ShapeDtypeStruct tree (dry-run, no alloc)
    forward_train(cfg, p, batch)-> (loss, metrics)
    prefill(cfg, p, batch)      -> (last_logits, cache)
    decode_step(cfg, p, tok, pos, cache) -> (logits, cache)
    cache_specs / init_cache    -> decode cache (abstract / real)
    input_specs(cfg, shape)     -> ShapeDtypeStructs for the dry-run
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.layers import (abstract_from_specs, apply_norm, ashard,
                                 count_specs, embed_specs, embed_tokens,
                                 init_from_specs, logical_axes_tree,
                                 norm_specs, stack_specs, unembed,
                                 unembed_specs)

# ---------------------------------------------------------------------------
# Parameter trees
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig):
    sp: Dict[str, Any] = {
        "embed": embed_specs(cfg),
        "final_norm": norm_specs(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        sp["unembed"] = unembed_specs(cfg)
    if cfg.is_encdec:
        sp.update(tfm.whisper_specs(cfg))
    elif cfg.xlstm is not None:
        sp["stack"] = tfm.xlstm_stack_specs(cfg)
    elif cfg.ssm is not None and cfg.attn_every:
        sp["stack"] = tfm.zamba_stack_specs(cfg)
    else:
        sp["stack"] = tfm.uniform_stack_specs(cfg)
    return sp


def init_params(cfg: ModelConfig, key):
    return init_from_specs(param_specs(cfg), key,
                           jnp.dtype(cfg.param_dtype))


def abstract_params(cfg: ModelConfig):
    return abstract_from_specs(param_specs(cfg), jnp.dtype(cfg.param_dtype))


def logical_axes(cfg: ModelConfig):
    return logical_axes_tree(param_specs(cfg))


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    total = count_specs(param_specs(cfg))
    if active_only and cfg.moe is not None:
        m = cfg.moe
        inactive = (cfg.n_layers * 3 * (m.num_experts - m.top_k)
                    * cfg.d_model * m.expert_d_ff)
        total -= inactive
    return total


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _trunk_train(cfg, params, x, positions, impl):
    """Shared trunk: stacked blocks, train shape.  Returns (x, aux)."""
    if cfg.xlstm is not None:
        x, _, aux = tfm.xlstm_stack_apply(cfg, params["stack"], x, None)
    elif cfg.ssm is not None and cfg.attn_every:
        x, _, aux = tfm.zamba_stack_train(cfg, params["stack"], x, positions,
                                          impl=impl, collect=False)
    else:
        x, _, aux = tfm.uniform_stack_train(cfg, params["stack"], x,
                                            positions, impl=impl)
    return x, aux


def _logits(cfg, params, x):
    x = apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x,
                            params["embed"]["tokens"].astype(x.dtype))
    else:
        logits = unembed(cfg, params["unembed"], x)
    return logits


def _mask_padded_vocab(cfg, logits):
    if cfg.padded_vocab == cfg.vocab:
        return logits
    ids = jnp.arange(cfg.padded_vocab)
    return jnp.where(ids < cfg.vocab, logits, -1e30)


def cross_entropy(cfg, logits, targets):
    """logits: (B, S, Vp) any float dtype; targets: (B, S) int."""
    logits = _mask_padded_vocab(cfg, logits.astype(jnp.float32))
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (logz - gold).mean(), logz


def forward_logits(cfg, params, tokens, frames=None, impl="flash"):
    """Full-sequence logits (train shape)."""
    S = tokens.shape[1]
    positions = jnp.arange(S)
    x = embed_tokens(cfg, params["embed"], tokens,
                     positions if cfg.learned_pos else None)
    x = ashard(x, "batch", "seq", "embed")
    if cfg.is_encdec:
        enc_out = tfm.whisper_encode(cfg, params, frames)
        x, _, aux = tfm.whisper_decode_train(cfg, params, enc_out, x,
                                             positions, impl=impl)
    else:
        x, aux = _trunk_train(cfg, params, x, positions, impl)
    return _logits(cfg, params, x), aux


def forward_train(cfg, params, batch, impl="flash", aux_weight=0.01,
                  z_weight=0.0):
    """Next-token LM loss. batch: {"tokens": (B,S)[, "frames": (B,F,D)]}."""
    tokens = batch["tokens"]
    logits, aux = forward_logits(cfg, params, tokens,
                                 frames=batch.get("frames"), impl=impl)
    loss, logz = cross_entropy(cfg, logits[:, :-1], tokens[:, 1:])
    total = loss + aux_weight * aux
    if z_weight:
        total = total + z_weight * jnp.square(logz[:, :-1]).mean()
    metrics = {"loss": loss, "aux_loss": aux,
               "tokens": jnp.array(tokens.shape[0] * (tokens.shape[1] - 1))}
    return total, metrics


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def prefill(cfg, params, batch, impl="flash", max_len=None):
    """Process the prompt; return (last-token logits, decode cache).

    max_len sizes the KV caches (>= prompt length) so decode can continue
    past the prompt."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S)
    x = embed_tokens(cfg, params["embed"], tokens,
                     positions if cfg.learned_pos else None)
    x = ashard(x, "batch", "seq", "embed")

    if cfg.is_encdec:
        enc_out = tfm.whisper_encode(cfg, params, batch["frames"])
        x, cache, _ = tfm.whisper_decode_train(cfg, params, enc_out, x,
                                               positions, impl=impl,
                                               collect_kv=True,
                                               max_len=max_len)
    elif cfg.xlstm is not None:
        x, cache, _ = tfm.xlstm_stack_apply(cfg, params["stack"], x, None)
    elif cfg.ssm is not None and cfg.attn_every:
        x, cache, _ = tfm.zamba_stack_train(cfg, params["stack"], x,
                                            positions, impl=impl,
                                            collect=True, max_len=max_len)
    else:
        x, cache, _ = tfm.uniform_stack_train(cfg, params["stack"], x,
                                              positions, impl=impl,
                                              collect_kv=True,
                                              max_len=max_len)
    logits = _logits(cfg, params, x[:, -1:])[:, 0]
    return _mask_padded_vocab(cfg, logits), cache


def decode_step(cfg, params, token, pos, cache):
    """One decode step. token: (B,) int32; pos: scalar int32 (position of
    the token being fed).  Returns (logits (B, Vp), new_cache)."""
    B = token.shape[0]
    pos_b = jnp.full((B,), pos)
    x = embed_tokens(cfg, params["embed"], token[:, None],
                     pos_b[:, None] if cfg.learned_pos else None)[:, 0]

    if cfg.is_encdec:
        x, new_cache, _ = tfm.whisper_stack_decode(cfg, params, x, pos, cache)
    elif cfg.xlstm is not None:
        x2, new_cache, _ = tfm.xlstm_stack_apply(cfg, params["stack"],
                                                 x[:, None], cache)
        x = x2[:, 0]
    elif cfg.ssm is not None and cfg.attn_every:
        x, new_cache, _ = tfm.zamba_stack_decode(cfg, params["stack"], x,
                                                 pos, cache)
    else:
        x, new_cache, _ = tfm.uniform_stack_decode(cfg, params["stack"], x,
                                                   pos, cache)
    logits = _logits(cfg, params, x[:, None])[:, 0]
    return _mask_padded_vocab(cfg, logits), new_cache


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------


def cache_specs(cfg, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.compute_dtype)
    if cfg.is_encdec:
        self_sp = stack_specs(attn_mod.kv_cache_specs(cfg, batch, max_len,
                                                      dtype), cfg.dec_layers)
        from repro.models.layers import ParamSpec
        cross_shape = (batch, cfg.n_frames, cfg.n_kv_heads, cfg.head_dim)
        cross_sp = stack_specs(
            {"k": ParamSpec(cross_shape, ("batch", None, "kv_heads", None),
                            "zeros", dtype),
             "v": ParamSpec(cross_shape, ("batch", None, "kv_heads", None),
                            "zeros", dtype)}, cfg.dec_layers)
        return {"self": self_sp, "cross": cross_sp}
    if cfg.xlstm is not None:
        return tfm.xlstm_state_specs(cfg, batch)
    if cfg.ssm is not None and cfg.attn_every:
        g, per, tail = tfm.zamba_layout(cfg)
        group = {"mamba": stack_specs(
                     ssm_mod.ssm_state_specs(cfg, batch, dtype), per,
                     "inner"),
                 "attn": attn_mod.kv_cache_specs(cfg, batch, max_len, dtype)}
        sp = {"groups": stack_specs(group, g, "layers"), "tail": None}
        if tail:
            sp["tail"] = stack_specs(
                ssm_mod.ssm_state_specs(cfg, batch, dtype), tail, "layers")
        return sp
    return stack_specs(attn_mod.kv_cache_specs(cfg, batch, max_len, dtype),
                       cfg.n_layers)


def abstract_cache(cfg, batch: int, max_len: int):
    return abstract_from_specs(cache_specs(cfg, batch, max_len))


def init_cache(cfg, batch: int, max_len: int):
    """Zero-initialized decode cache (for decode-from-scratch tests)."""
    specs = cache_specs(cfg, batch, max_len)
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype or jnp.float32),
        specs, is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "init"))


# ---------------------------------------------------------------------------
# Dry-run input specs
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.is_encdec:
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frames, cfg.d_model), jnp.dtype(cfg.compute_dtype))
        return out
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.is_encdec:
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frames, cfg.d_model), jnp.dtype(cfg.compute_dtype))
        return out
    # decode: one new token against a cache of size S
    return {
        "token": jax.ShapeDtypeStruct((B,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
        "cache": abstract_cache(cfg, B, S),
    }
