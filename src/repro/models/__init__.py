"""Model zoo: 10 assigned architectures as composable JAX modules."""
