"""Attention layers: GQA, sliding-window, chunked-flash, decode paths.

Three exact-softmax implementations with one math:
  * full        — dense mask, O(S^2) memory. Small seq / encoder / cross.
  * flash       — lax.map over Q chunks x lax.scan over KV chunks with
                  online softmax.  O(S * chunk) memory, compiles on any
                  backend (CPU dry-run path; Pallas kernel is the TPU twin).
  * triangular  — statically unrolled lower-triangular block loop: Q chunk
                  i attends KV[: (i+1)*C].  Halves attention FLOPs vs.
                  `flash` (which masks but still computes upper blocks).
                  This is a beyond-paper §Perf lever.

Decode:
  * plain cache attention (one-token query vs. (B, S, KV, Dh) cache)
  * ring-buffer sliding-window cache (SWA archs; O(window) memory)
  * sequence-sharded flash-decoding under shard_map with LSE merge —
    used when kv_heads < model-axis size so the cache can shard over
    sequence instead of heads (qwen1.5-110b, yi, chameleon, grok).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import (ParamSpec, apply_rope, ashard,
                                 head_norm_specs, rms_norm)

try:  # jax >= 0.4.35
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)

_NEG = -1e30


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def attn_specs(cfg, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    sp = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", None)),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", None)),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", None)),
        "wo": ParamSpec((h, hd, d), ("heads", None, "embed"), fan_in=h * hd),
    }
    if cfg.qkv_bias:
        sp["bq"] = ParamSpec((h, hd), ("heads", None), "zeros")
        sp["bk"] = ParamSpec((kv, hd), ("kv_heads", None), "zeros")
        sp["bv"] = ParamSpec((kv, hd), ("kv_heads", None), "zeros")
    if cfg.qk_norm:
        sp["q_norm"] = head_norm_specs(cfg, h, hd)
        sp["k_norm"] = head_norm_specs(cfg, kv, hd)
    return sp


def project_qkv(cfg, p, x, positions, rope: bool = True):
    """x: (B, S, D) -> q (B,S,H,Dh), k,v (B,S,KV,Dh)."""
    cdt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cdt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"]["scale"])
        k = rms_norm(k, p["k_norm"]["scale"])
    if rope and cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = ashard(q, "batch", "seq", "heads", None)
    k = ashard(k, "batch", "seq", "kv_heads", None)
    v = ashard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def out_proj(cfg, p, attn_out):
    """attn_out: (B, S, H, Dh) -> (B, S, D)."""
    return jnp.einsum("bshk,hkd->bsd", attn_out,
                      p["wo"].astype(attn_out.dtype))


# ---------------------------------------------------------------------------
# Exact softmax attention variants (training / prefill)
# ---------------------------------------------------------------------------


def _gqa_scores(q, k, scale):
    """q: (B,Sq,H,Dh) k: (B,Skv,KV,Dh) -> scores (B,KV,G,Sq,Skv) f32."""
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, Dh).astype(jnp.float32)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg,
                      k.astype(jnp.float32)) * scale


def _gqa_weighted(pweights, v):
    """pweights: (B,KV,G,Sq,Skv) f32, v: (B,Skv,KV,Dh) -> (B,Sq,H,Dh) f32."""
    B, KV, G, Sq, Skv = pweights.shape
    out = jnp.einsum("bkgqs,bskd->bqkgd", pweights, v.astype(jnp.float32))
    return out.reshape(B, Sq, KV * G, v.shape[-1])


def _mask(q_pos, kv_pos, causal: bool, window: int, kv_len=None):
    """(Sq, Skv) boolean mask (True = attend)."""
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window:
        m &= kv_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        m &= kv_pos[None, :] < kv_len
    return m


def attn_full(q, k, v, q_pos, kv_pos, *, causal, window=0, scale=None):
    """Dense-mask exact attention. Memory O(Sq*Skv)."""
    scale = scale or q.shape[-1] ** -0.5
    s = _gqa_scores(q, k, scale)
    m = _mask(q_pos, kv_pos, causal, window)
    s = jnp.where(m[None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = _gqa_weighted(p, v)
    return out.astype(q.dtype)


def _online_block(q, kb, vb, q_pos, kv_pos_b, carry, *, causal, window, scale):
    """One KV block of online-softmax. carry = (m, l, acc)."""
    m, l, acc = carry
    s = _gqa_scores(q, kb, scale)                       # (B,KV,G,Sq,C)
    msk = _mask(q_pos, kv_pos_b, causal, window)
    s = jnp.where(msk[None, None, None], s, _NEG)
    m_new = jnp.maximum(m, s.max(-1))
    alpha = jnp.exp(m - m_new)
    pexp = jnp.exp(s - m_new[..., None])
    l = l * alpha + pexp.sum(-1)
    acc = acc * alpha[..., None] + jnp.einsum(
        "bkgqs,bskd->bkgqd", pexp, vb.astype(jnp.float32))
    return m_new, l, acc


def _finish(q, l, acc):
    B, KV, G, Sq, Dh = acc.shape
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, KV * G, Dh)
    return out.astype(q.dtype)


def attn_flash(q, k, v, q_pos, kv_pos, *, causal, window=0, scale=None,
               q_chunk=1024, kv_chunk=1024):
    """Chunked online-softmax attention: lax.map over Q, lax.scan over KV.

    Baseline flash path: computes (and masks) every QxKV block, so causal
    attention does 2x the minimal FLOPs — `attn_triangular` removes that.
    """
    scale = scale or q.shape[-1] ** -0.5
    B, Sq, H, Dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq, nkv = -(-Sq // q_chunk), -(-Skv // kv_chunk)
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0, (Sq, q_chunk, Skv, kv_chunk)
    G = H // KV

    kc = k.reshape(B, nkv, kv_chunk, KV, Dh)
    vc = v.reshape(B, nkv, kv_chunk, KV, Dh)

    kvp_all = kv_pos.reshape(nkv, kv_chunk)

    def one_q_chunk(qi, unroll=False):
        qb = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, qi * q_chunk, q_chunk)

        def kv_step(carry, inputs):
            kb, vb, kvp = inputs
            return _online_block(qb, kb, vb, qp, kvp, carry,
                                 causal=causal, window=window,
                                 scale=scale), None

        init = (jnp.full((B, KV, G, q_chunk), _NEG, jnp.float32),
                jnp.zeros((B, KV, G, q_chunk), jnp.float32),
                jnp.zeros((B, KV, G, q_chunk, Dh), jnp.float32))
        xs = (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
              kvp_all)
        if unroll:
            carry = init
            for j in range(nkv):
                carry, _ = kv_step(carry, jax.tree_util.tree_map(
                    lambda a, j=j: a[j], xs))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, init, xs)
        return _finish(qb, l, acc)

    from repro.models import unrollctl
    if unrollctl.enabled():
        outs = [one_q_chunk(qi, unroll=True) for qi in range(nq)]
        return jnp.concatenate(outs, axis=1)
    if nq == 1:
        return one_q_chunk(0)
    outs = jax.lax.map(one_q_chunk, jnp.arange(nq))   # (nq, B, C, H, Dh)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, Dh)


def attn_triangular(q, k, v, q_pos, kv_pos, *, window=0, scale=None,
                    chunk=2048):
    """FLOP-optimal causal attention: statically-unrolled lower-triangular
    block loop.  Q chunk i runs online-softmax over KV chunks 0..i only —
    upper-triangular blocks are never materialized, halving attention
    FLOPs vs. `attn_flash`.  Requires Sq == Skv (self-attention)."""
    scale = scale or q.shape[-1] ** -0.5
    B, S, H, Dh = q.shape
    KV = k.shape[2]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n = S // chunk
    G = H // KV
    outs = []
    for i in range(n):
        qb = q[:, i * chunk:(i + 1) * chunk]
        qp = q_pos[i * chunk:(i + 1) * chunk]
        carry = (jnp.full((B, KV, G, chunk), _NEG, jnp.float32),
                 jnp.zeros((B, KV, G, chunk), jnp.float32),
                 jnp.zeros((B, KV, G, chunk, Dh), jnp.float32))
        lo = 0
        if window:  # blocks entirely left of the window are all-masked
            lo = max(0, (i * chunk - window) // chunk)
        for j in range(lo, i + 1):
            kb = k[:, j * chunk:(j + 1) * chunk]
            vb = v[:, j * chunk:(j + 1) * chunk]
            kvp = kv_pos[j * chunk:(j + 1) * chunk]
            # off-diagonal in-window blocks need no mask at all
            need_mask = (j == i) or (window and (i * chunk - window
                                                 < (j + 1) * chunk))
            carry = _online_block(qb, kb, vb, qp, kvp, carry,
                                  causal=(j == i), window=window if need_mask
                                  else 0, scale=scale)
        outs.append(_finish(qb, carry[1], carry[2]))
    return jnp.concatenate(outs, axis=1)


def self_attention(cfg, q, k, v, q_pos, kv_pos, *, impl="flash"):
    window = cfg.swa_window
    if (impl == "full" or q.shape[1] <= cfg.attn_chunk
            or q.shape[1] % cfg.attn_chunk != 0):
        # small or chunk-indivisible sequences: dense-mask path
        return attn_full(q, k, v, q_pos, kv_pos, causal=True, window=window)
    if impl == "triangular":
        return attn_triangular(q, k, v, q_pos, kv_pos, window=window,
                               chunk=cfg.attn_chunk)
    return attn_flash(q, k, v, q_pos, kv_pos, causal=True, window=window,
                      q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)


# ---------------------------------------------------------------------------
# Decode paths
# ---------------------------------------------------------------------------


def init_kv_cache(cfg, batch: int, max_len: int, dtype):
    """(k, v) cache; SWA archs allocate only the window ring-buffer."""
    S = min(max_len, cfg.swa_window) if cfg.swa_window else max_len
    shape = (batch, S, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_specs(cfg, batch: int, max_len: int, dtype):
    S = min(max_len, cfg.swa_window) if cfg.swa_window else max_len
    shape = (batch, S, cfg.n_kv_heads, cfg.head_dim)
    seq_ax = "kv_seq" if _seq_sharded(cfg) else None
    sp = ParamSpec(shape, ("batch", seq_ax, "kv_heads", None), "zeros", dtype)
    return {"k": sp, "v": sp}


def _seq_sharded(cfg) -> bool:
    return bool(cfg.decode_seq_shard) and not cfg.swa_window


def fill_kv_cache(cfg, cache, k, v, start: int = 0):
    """Write prefill k/v (B, S, KV, Dh) into the cache."""
    if cfg.swa_window:
        W = cache["k"].shape[1]
        S = k.shape[1]
        if S >= W:
            # last W positions; slot p % W. (S - W) % W == 0 when W | S.
            assert (S - W) % W == 0 or S == W
            return {"k": k[:, -W:], "v": v[:, -W:]}
        k_new = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, start, 1)
        v_new = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, start, 1)
        return {"k": k_new, "v": v_new}
    k_new = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, start, 1)
    v_new = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, start, 1)
    return {"k": k_new, "v": v_new}


def decode_attention(cfg, cache, q, new_k, new_v, pos, mesh=None):
    """One-token decode. q: (B,H,Dh), new_k/new_v: (B,KV,Dh), pos: scalar.

    Returns (attn_out (B,H,Dh), new_cache).  Dispatches to the
    sequence-sharded flash-decoding path when configured and a mesh with a
    model axis is active.
    """
    if (_seq_sharded(cfg) and mesh is not None
            and "model" in getattr(mesh, "axis_names", ())
            and cache["k"].shape[1] % mesh.shape["model"] == 0):
        return _decode_attn_seq_sharded(cfg, mesh, cache, q, new_k, new_v, pos)
    return _decode_attn_local(cfg, cache, q, new_k, new_v, pos)


def _write_slot(cfg, pos, S):
    if cfg.swa_window:
        return pos % cache_window(cfg, S)
    return pos


def cache_window(cfg, S):
    return min(S, cfg.swa_window) if cfg.swa_window else S


def _decode_attn_local(cfg, cache, q, new_k, new_v, pos):
    B, S, KV, Dh = cache["k"].shape
    slot = _write_slot(cfg, pos, S)
    kc = jax.lax.dynamic_update_slice(cache["k"], new_k[:, None],
                                      (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], new_v[:, None],
                                      (0, slot, 0, 0))
    slots = jnp.arange(S)
    if cfg.swa_window:
        # ring buffer: slot s holds global position pos - ((pos - s) mod S)
        kv_pos = pos - jnp.mod(pos - slots, S)
        valid = kv_pos >= 0
    else:
        kv_pos = slots
        valid = slots <= pos
    out = _decode_scores(cfg, q, kc, vc, valid)
    return out, {"k": kc, "v": vc}


def _decode_scores(cfg, q, kc, vc, valid):
    """q (B,H,Dh), kc/vc (B,S,KV,Dh), valid (S,) -> (B,H,Dh)."""
    B, S, KV, Dh = kc.shape
    H = q.shape[1]
    G = H // KV
    scale = Dh ** -0.5
    qg = q.reshape(B, KV, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kc.astype(jnp.float32)) * scale
    s = jnp.where(valid[None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, vc.astype(jnp.float32))
    return o.reshape(B, H, Dh).astype(q.dtype)


def _decode_attn_seq_sharded(cfg, mesh, cache, q, new_k, new_v, pos):
    """Flash-decoding: cache sharded over sequence on the model axis;
    every shard computes a partial softmax over its chunk; LSE-merged
    with psum.  Replaces head-sharding when kv_heads < model-axis size."""
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0]
                                                    if batch_axes else None)
    B, S, KV, Dh = cache["k"].shape
    H = q.shape[1]
    G = H // KV
    scale = Dh ** -0.5

    def body(q, kc, vc, nk, nv, pos):
        midx = jax.lax.axis_index("model")
        S_loc = kc.shape[1]
        start = midx * S_loc
        owned = jnp.logical_and(pos >= start, pos < start + S_loc)
        li = jnp.clip(pos - start, 0, S_loc - 1)
        kc_u = jax.lax.dynamic_update_slice(kc, nk[:, None], (0, li, 0, 0))
        vc_u = jax.lax.dynamic_update_slice(vc, nv[:, None], (0, li, 0, 0))
        kc = jnp.where(owned, kc_u, kc)
        vc = jnp.where(owned, vc_u, vc)
        kv_pos = start + jnp.arange(S_loc)
        valid = kv_pos <= pos
        qg = q.reshape(-1, KV, G, Dh).astype(jnp.float32)
        s = jnp.einsum("bkgd,bskd->bkgs", qg,
                       kc.astype(jnp.float32)) * scale
        s = jnp.where(valid[None, None, None], s, _NEG)
        m_l = s.max(-1)
        pexp = jnp.exp(s - m_l[..., None])
        l_l = pexp.sum(-1)
        o_l = jnp.einsum("bkgs,bskd->bkgd", pexp, vc.astype(jnp.float32))
        m_g = jax.lax.pmax(m_l, "model")
        corr = jnp.exp(m_l - m_g)
        l_g = jax.lax.psum(l_l * corr, "model")
        o_g = jax.lax.psum(o_l * corr[..., None], "model")
        o = o_g / jnp.maximum(l_g, 1e-30)[..., None]
        return o.reshape(-1, H, Dh).astype(q.dtype), kc, vc

    out, kc, vc = shard_map(
        body, mesh,
        in_specs=(P(bspec, None, None),
                  P(bspec, "model", None, None), P(bspec, "model", None, None),
                  P(bspec, None, None), P(bspec, None, None), P()),
        out_specs=(P(bspec, None, None),
                   P(bspec, "model", None, None),
                   P(bspec, "model", None, None)),
    )(q, cache["k"], cache["v"], new_k, new_v, pos)
    return out, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# Cross attention (enc-dec)
# ---------------------------------------------------------------------------


def cross_attention(cfg, q, enc_k, enc_v):
    """q: (B,Sq,H,Dh) vs. precomputed encoder k/v (B,F,KV,Dh). Non-causal."""
    Sq = q.shape[1]
    F = enc_k.shape[1]
    q_pos = jnp.arange(Sq)
    kv_pos = jnp.arange(F)
    return attn_full(q, enc_k, enc_v, q_pos, kv_pos, causal=False)
