"""Shared model building blocks + the ParamSpec system.

Parameters are plain pytrees of jnp arrays.  Every parameter is declared
as a ParamSpec (shape, logical axis names, init rule) so that:
  * init_from_specs() materializes real params for training/smoke tests,
  * abstract_from_specs() yields ShapeDtypeStructs for the dry-run
    (no allocation — full 314B configs lower from specs alone),
  * sharding rules map logical axis names -> PartitionSpec uniformly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# ParamSpec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    logical: tuple               # logical axis name per dim (or None)
    init: str = "normal"         # normal | zeros | ones | mamba_a | dt_bias | pos
    dtype: Any = None            # None -> config param_dtype
    fan_in: int = 0              # 0 -> last-but-one dim (normal init scale)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def stack_spec(spec: ParamSpec, n: int, axis_name: str = "layers") -> ParamSpec:
    return ParamSpec((n,) + spec.shape, (axis_name,) + spec.logical,
                     spec.init, spec.dtype, spec.fan_in)


def stack_specs(tree, n: int, axis_name: str = "layers"):
    return tree_map_specs(lambda s: stack_spec(s, n, axis_name), tree)


def _init_leaf(spec: ParamSpec, key, default_dtype) -> jnp.ndarray:
    dtype = spec.dtype or default_dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "mamba_a":
        # A_log init: log of uniform [1, 16] (mamba2 convention)
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "dt_bias":
        # softplus^-1 of dt ~ uniform[1e-3, 1e-1]
        dt = jax.random.uniform(key, spec.shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(dt)).astype(dtype)
    if spec.init == "pos":
        # sinusoidal-ish small init for learned positions
        return (0.02 * jax.random.normal(key, spec.shape, jnp.float32)
                ).astype(dtype)
    # default: truncated-normal, 1/sqrt(fan_in)
    fan_in = spec.fan_in
    if fan_in == 0:
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    w = jax.random.truncated_normal(key, -2.0, 2.0, spec.shape, jnp.float32)
    return (w * scale).astype(dtype)


def init_from_specs(tree, key, default_dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(s, k, default_dtype) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_from_specs(tree, default_dtype=jnp.float32):
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or default_dtype),
        tree)


def logical_axes_tree(tree):
    return tree_map_specs(lambda s: s.logical, tree)


def count_specs(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


# ---------------------------------------------------------------------------
# Activation sharding hook (set by repro.sharding at jit-build time)
# ---------------------------------------------------------------------------

_ACT_SHARDER: Optional[Callable] = None


def set_activation_sharder(fn: Optional[Callable]) -> None:
    """fn(x, logical_axes) -> x with sharding constraint (or None to clear)."""
    global _ACT_SHARDER
    _ACT_SHARDER = fn


def ashard(x, *logical_axes):
    """Annotate activation x with logical axes (no-op outside pjit builds)."""
    if _ACT_SHARDER is None:
        return x
    return _ACT_SHARDER(x, logical_axes)


# ---------------------------------------------------------------------------
# Core ops
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale.astype(dt) + bias.astype(dt)


def norm_specs(cfg, dim: int):
    if cfg.norm == "layernorm":
        return {"scale": ParamSpec((dim,), ("embed",), "ones"),
                "bias": ParamSpec((dim,), ("embed",), "zeros")}
    return {"scale": ParamSpec((dim,), ("embed",), "ones")}


def apply_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def head_norm_specs(cfg, n_heads: int, dim: int):
    """Per-head RMS norm (qk-norm)."""
    return {"scale": ParamSpec((n_heads, dim), ("heads", None), "ones")}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                     # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs     # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin,
                           x1f * sin + x2f * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------


def mlp_specs(cfg, d_model: int, d_ff: int):
    if cfg.act == "swiglu":
        return {
            "w_gate": ParamSpec((d_model, d_ff), ("embed", "mlp")),
            "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp")),
            "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed")),
        }
    return {
        "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "b_up": ParamSpec((d_ff,), ("mlp",), "zeros"),
        "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed")),
        "b_down": ParamSpec((d_model,), ("embed",), "zeros"),
    }


def apply_mlp(cfg, p, x):
    cdt = x.dtype
    if cfg.act == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(cdt))
        u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(cdt))
        h = jax.nn.silu(g) * u
        h = ashard(h, "batch", "seq", "mlp")
        return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(cdt))
    h = jnp.einsum("...d,df->...f", x, p["w_up"].astype(cdt)) + p["b_up"].astype(cdt)
    h = jax.nn.gelu(h)
    h = ashard(h, "batch", "seq", "mlp")
    return (jnp.einsum("...f,fd->...d", h, p["w_down"].astype(cdt))
            + p["b_down"].astype(cdt))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_specs(cfg):
    v = cfg.padded_vocab
    sp = {"tokens": ParamSpec((v, cfg.d_model), ("vocab", "embed"),
                              fan_in=cfg.d_model)}
    if cfg.learned_pos:
        sp["positions"] = ParamSpec((8192, cfg.d_model), (None, "embed"), "pos")
    return sp


def embed_tokens(cfg, p, tokens, positions=None):
    x = jnp.take(p["tokens"], tokens, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    if "positions" in p and positions is not None:
        pos_emb = jnp.take(p["positions"], jnp.minimum(
            positions, p["positions"].shape[0] - 1), axis=0)
        x = x + pos_emb.astype(x.dtype)
    return x


def unembed_specs(cfg):
    return {"w": ParamSpec((cfg.d_model, cfg.padded_vocab),
                           ("embed", "vocab"))}


def unembed(cfg, p, x):
    logits = jnp.einsum("...d,dv->...v", x, p["w"].astype(x.dtype))
    return ashard(logits, "batch", "seq", "vocab")
