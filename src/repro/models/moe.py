"""Mixture-of-Experts FFN: top-k routing with sort-based dispatch.

Design notes (roofline-relevant):
  * Dense-einsum-over-all-experts routing would inflate HLO FLOPs by
    E/top_k (15x for qwen2-moe) and wreck the MODEL_FLOPS/HLO_FLOPS
    ratio; instead we use sort-based capacity dispatch (MegaBlocks /
    MaxText style): tokens are argsorted by expert id *per batch row*,
    packed into (E, capacity) buckets, run through a batched expert
    einsum, and scattered back with their gate weights.  HLO FLOPs are
    then ~ top_k * capacity_factor * dense-equivalent — faithful to the
    active-parameter cost model 6*N_active*D.
  * Routing is vmapped over the batch row so every sort/gather stays
    device-local under batch sharding (no routing collectives on the
    data axis; expert weights are TP-sharded on d_ff over "model").
  * Dropped tokens (capacity overflow) contribute zero — standard
    capacity-factor semantics; cf=1.25 default.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, ashard

def moe_specs(cfg):
    d = cfg.d_model
    m = cfg.moe
    sp = {
        "router": ParamSpec((d, m.num_experts), ("embed", "experts")),
        "w_gate": ParamSpec((m.num_experts, d, m.expert_d_ff),
                            ("experts", "embed", "mlp"), fan_in=d),
        "w_up": ParamSpec((m.num_experts, d, m.expert_d_ff),
                          ("experts", "embed", "mlp"), fan_in=d),
        "w_down": ParamSpec((m.num_experts, m.expert_d_ff, d),
                            ("experts", "mlp", "embed"), fan_in=m.expert_d_ff),
    }
    if m.num_shared:
        sp["shared"] = {
            "w_gate": ParamSpec((d, m.shared_d_ff), ("embed", "mlp")),
            "w_up": ParamSpec((d, m.shared_d_ff), ("embed", "mlp")),
            "w_down": ParamSpec((m.shared_d_ff, d), ("mlp", "embed")),
        }
        # qwen2-moe gates the shared expert with a sigmoid scalar
        sp["shared_gate"] = ParamSpec((d, 1), ("embed", None))
    return sp


def _capacity(tokens: int, num_experts: int, top_k: int, cf: float) -> int:
    c = int(tokens * top_k * cf / num_experts) + 1
    return min(max(c, top_k), tokens)


def _route_row(x, router_logits, w_gate, w_up, w_down, top_k: int,
               cf: float):
    """One batch row. x: (S, D); router_logits: (S, E). Returns (S, D)."""
    S, D = x.shape
    E = router_logits.shape[-1]
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)       # (S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)               # renormalize

    flat_expert = expert_idx.reshape(-1)                       # (S*k,)
    flat_token = jnp.repeat(jnp.arange(S), top_k)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert)                           # stable
    e_sorted = flat_expert[order]
    t_sorted = flat_token[order]
    g_sorted = flat_gate[order]

    # position within each expert's bucket
    starts = jnp.searchsorted(e_sorted, e_sorted, side="left")
    pos = jnp.arange(S * top_k) - starts
    C = _capacity(S, E, top_k, cf)
    keep = pos < C
    dest = jnp.where(keep, e_sorted * C + pos, E * C)          # overflow slot

    # pack tokens into (E*C+1, D); the +1 row swallows dropped tokens
    buf = jnp.zeros((E * C + 1, D), x.dtype)
    buf = buf.at[dest].set(x[t_sorted])
    buf = buf[:-1].reshape(E, C, D)

    # batched expert FFN (swiglu)
    cdt = x.dtype
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(cdt))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(cdt))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down.astype(cdt))
    out_buf = out_buf.reshape(E * C, D)

    # scatter back with gates
    contrib = jnp.where(keep[:, None],
                        out_buf[jnp.minimum(dest, E * C - 1)]
                        * g_sorted[:, None].astype(cdt),
                        0.0)
    out = jnp.zeros((S, D), cdt).at[t_sorted].add(contrib)
    return out


def apply_moe(cfg, p, x):
    """x: (B, S, D) -> (B, S, D).  Routed experts + optional shared block."""
    m = cfg.moe
    cdt = x.dtype
    router_logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(cdt))

    routed = jax.vmap(
        lambda xr, lr: _route_row(xr, lr, p["w_gate"], p["w_up"],
                                  p["w_down"], m.top_k,
                                  m.capacity_factor))(x, router_logits)
    routed = ashard(routed, "batch", "seq", "embed")

    if m.num_shared:
        sh = p["shared"]
        g = jnp.einsum("bsd,df->bsf", x, sh["w_gate"].astype(cdt))
        u = jnp.einsum("bsd,df->bsf", x, sh["w_up"].astype(cdt))
        h = jax.nn.silu(g) * u
        shared_out = jnp.einsum("bsf,fd->bsd", h, sh["w_down"].astype(cdt))
        sg = jax.nn.sigmoid(
            jnp.einsum("bsd,do->bso", x, p["shared_gate"].astype(cdt)))
        routed = routed + sg * shared_out

    return routed


def aux_load_balance_loss(cfg, p, x):
    """Switch-style load-balance auxiliary loss (used by train loop)."""
    m = cfg.moe
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    _, idx = jax.lax.top_k(probs, m.top_k)
    hard = jax.nn.one_hot(idx, m.num_experts).sum(-2)        # (B,S,E)
    frac_tokens = hard.mean((0, 1)) / m.top_k
    frac_probs = probs.mean((0, 1))
    return m.num_experts * jnp.sum(frac_tokens * frac_probs)
