"""Partitioning policies for distributed and sharded GEE.

Two axes, matching the two halves of the system:

* **Edge partitioning** (training/offline): the paper gets load
  balance from Ligra's dynamic scheduling; with static SPMD shards we
  get it from randomization: a shuffled edge list makes every shard's
  per-owner bucket sizes concentrate around the mean (Chernoff), which
  is what the capacity-padded a2a/ring modes rely on.  `plan_capacity`
  quantifies the tail so callers can pick a factor with a target
  overflow probability instead of guessing.

* **Row partitioning** (serving): `RowPartition` splits the n embedding
  rows into contiguous slices, one per `serving.EmbeddingShard`.  GEE's
  map-over-edges form makes this the natural serving split: an edge
  (u, v, w) contributes only to rows u and v, so a delta batch fans out
  only to the shards owning its endpoints (`route_edges`), and each
  shard's routed sub-multiset contains every edge incident to its rows
  — its owned slice of Z is exact in isolation.  A slice is also a
  first-class encoder concept: passing it as
  `EncoderConfig.row_partition=(lo, hi)` makes the backend accumulate
  ONLY the owned (hi - lo, K) rows, which is what gives sharded
  serving its O(n/p) per-shard memory.
"""
from __future__ import annotations

import numpy as np

from repro.graph.edges import Graph


def shuffle_edges(g: Graph, seed: int = 0) -> Graph:
    return g.permuted(np.random.default_rng(seed))


def owner_histogram(g: Graph, p: int) -> np.ndarray:
    """(p, p) matrix: [shard, owner] contribution counts (diagnostics)."""
    s_pad = ((g.s + p - 1) // p) * p
    gp = g.pad_to(s_pad)
    rows = ((g.n + p - 1) // p)
    hist = np.zeros((p, p), np.int64)
    per = s_pad // p
    for shard in range(p):
        sl = slice(shard * per, (shard + 1) * per)
        dst = np.concatenate([gp.u[sl], gp.v[sl]])
        np.add.at(hist[shard], np.minimum(dst // rows, p - 1), 1)
    return hist


def plan_capacity(s: int, n: int, p: int, overflow_target: float = 1e-6
                  ) -> float:
    """Capacity factor such that P(bucket > cap) < target under a
    balanced multinomial (Chernoff bound: cap = mu + 3*sigma-ish)."""
    mu = 2 * (s / p) / p
    sigma = np.sqrt(max(mu, 1.0))
    z = np.sqrt(2 * np.log(p * p / max(overflow_target, 1e-12)))
    return float((mu + z * sigma) / max(mu, 1.0))


class RowPartition:
    """Contiguous row partition of n nodes across p shards.

    Shard i owns rows [bounds[i], bounds[i+1]) with a fixed stride of
    ceil(n/p) rows per shard (same layout as `ShardedEdgeReader`'s
    contiguous edge split) — the uniform stride is what makes
    `shard_of` an O(1) division, at the cost of the LAST shard holding
    the remainder (up to p-1 rows fewer than the others).  Layouts
    whose remainder would leave a shard with zero rows are rejected.
    The partition is a pure function of (n, p), so every replica — and
    a recovered engine — agrees on ownership without coordination.
    """

    def __init__(self, n: int, p: int):
        if p < 1:
            raise ValueError(f"need p >= 1 shards, got {p}")
        if n < p:
            raise ValueError(f"cannot split {n} rows across {p} shards")
        self.n = int(n)
        self.p = int(p)
        per = (self.n + p - 1) // p
        self.bounds = np.minimum(np.arange(p + 1, dtype=np.int64) * per,
                                 self.n)
        self._per = per
        if self.bounds[-2] >= self.n:
            raise ValueError(
                f"splitting {n} rows across {p} shards (stride {per}) "
                "leaves the last shard empty; use fewer shards")

    def slice(self, shard: int) -> tuple[int, int]:
        """(lo, hi) row range owned by `shard`."""
        return int(self.bounds[shard]), int(self.bounds[shard + 1])

    def slices(self):
        """All (lo, hi) ranges in shard order — each is directly usable
        as an `EncoderConfig.row_partition`."""
        return [self.slice(i) for i in range(self.p)]

    def shard_of(self, nodes) -> np.ndarray:
        """Owning shard id per node (vectorized)."""
        return np.minimum(np.asarray(nodes, np.int64) // self._per,
                          self.p - 1).astype(np.int32)

    def route_nodes(self, nodes: np.ndarray):
        """Split a global node batch by owner.

        Yields (shard, index_into_batch) pairs for shards with work, so
        a scatter/gather caller can reassemble results in request
        order.  Order within a shard's sub-batch follows batch order.
        """
        owner = self.shard_of(nodes)
        for shard in range(self.p):
            idx = np.nonzero(owner == shard)[0]
            if idx.size:
                yield shard, idx

    def route_edges(self, u: np.ndarray, v: np.ndarray, w: np.ndarray):
        """Fan an edge batch out to owning shards.

        Yields (shard, (u, v, w)) sub-batches: shard i receives every
        edge with an endpoint in its rows, ONCE (an intra-shard edge is
        not duplicated).  Edge order is preserved within each
        sub-batch, so routing base ++ deltas equals routing each batch
        and concatenating — the invariant behind the engine's chained
        per-shard fingerprints.  Shards with no incident edges yield an
        empty sub-batch only if `u` itself is empty and p == 1.
        """
        u = np.asarray(u, np.int32)
        v = np.asarray(v, np.int32)
        w = np.asarray(w, np.float32)
        if self.p == 1:
            yield 0, (u, v, w)
            return
        su, sv = self.shard_of(u), self.shard_of(v)
        for shard in range(self.p):
            mask = (su == shard) | (sv == shard)
            if mask.any():
                yield shard, (u[mask], v[mask], w[mask])

    def route_graph(self, g: Graph):
        """`route_edges` over a Graph; yields (shard, sub_graph) with
        `n` preserved (shards embed in global coordinates)."""
        for shard, (u, v, w) in self.route_edges(g.u, g.v, g.w):
            yield shard, Graph(u, v, w, g.n)
