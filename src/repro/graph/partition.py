"""Edge partitioning policies for distributed GEE.

The paper gets load balance from Ligra's dynamic scheduling; with static
SPMD shards we get it from randomization: a shuffled edge list makes
every shard's per-owner bucket sizes concentrate around the mean
(Chernoff), which is what the capacity-padded a2a/ring modes rely on.
`plan_capacity` quantifies the tail so callers can pick a factor with a
target overflow probability instead of guessing.
"""
from __future__ import annotations

import numpy as np

from repro.graph.edges import Graph


def shuffle_edges(g: Graph, seed: int = 0) -> Graph:
    return g.permuted(np.random.default_rng(seed))


def owner_histogram(g: Graph, p: int) -> np.ndarray:
    """(p, p) matrix: [shard, owner] contribution counts (diagnostics)."""
    s_pad = ((g.s + p - 1) // p) * p
    gp = g.pad_to(s_pad)
    rows = ((g.n + p - 1) // p)
    hist = np.zeros((p, p), np.int64)
    per = s_pad // p
    for shard in range(p):
        sl = slice(shard * per, (shard + 1) * per)
        dst = np.concatenate([gp.u[sl], gp.v[sl]])
        np.add.at(hist[shard], np.minimum(dst // rows, p - 1), 1)
    return hist


def plan_capacity(s: int, n: int, p: int, overflow_target: float = 1e-6
                  ) -> float:
    """Capacity factor such that P(bucket > cap) < target under a
    balanced multinomial (Chernoff bound: cap = mu + 3*sigma-ish)."""
    mu = 2 * (s / p) / p
    sigma = np.sqrt(max(mu, 1.0))
    z = np.sqrt(2 * np.log(p * p / max(overflow_target, 1e-12)))
    return float((mu + z * sigma) / max(mu, 1.0))
