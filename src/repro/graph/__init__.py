"""Graph substrate: edge lists, generators, partitioning, IO, sources.

`repro.graph.sources` is the unified entry surface: every ingestion
path (synthetic, snapshot, sharded stream, serving store) is a
`GraphSource` yielding a `Graph` plus a content fingerprint — the
identity the encoder's persistent plan cache is keyed on.
"""
