"""Graph substrate: edge lists, generators, partitioning, IO."""
