"""Graph data structures: weighted directed edge lists.

The paper's convention: a graph G(n, s) is an edge list E in R^{s x 3}
(source, destination, weight); undirected graphs are two symmetric
directed edges; unweighted graphs have unit weights.  Labels
Y in {0..K}^n with 0 = unknown (paper) are remapped here to
{-1 = unknown, 0..K-1} for 0-based indexing.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Optional

import numpy as np

try:                                   # fast path where available
    import xxhash

    def _new_hash():
        return xxhash.xxh3_128()
except ImportError:                    # stdlib fallback, same interface
    def _new_hash():
        return hashlib.blake2b(digest_size=16)


def _hash_edges(h, u, v, w) -> None:
    """Feed (u, v, w) into hasher `h` in canonical dtypes, so two graphs
    with equal content but different array dtypes/layout agree."""
    for arr, dt in ((u, np.int32), (v, np.int32), (w, np.float32)):
        h.update(np.ascontiguousarray(arr, dt).data)


class FingerprintAccumulator:
    """Streaming edge-fingerprint builder: feed (u, v, w) batches in
    order, read `digest()` at the end.

    One hasher per column, combined at digest time — so the value
    depends only on the CONTENT streamed, never on how it was chunked
    (a reader with chunk_size=128 and one with 512 agree, and both
    agree with a whole-array `edge_fingerprint`)."""

    def __init__(self, n: int):
        self._n = int(n)
        self._cols = (_new_hash(), _new_hash(), _new_hash())

    def update(self, u, v, w) -> "FingerprintAccumulator":
        for h, arr, dt in zip(self._cols,
                              (u, v, w),
                              (np.int32, np.int32, np.float32)):
            h.update(np.ascontiguousarray(arr, dt).data)
        return self

    def digest(self) -> str:
        h = _new_hash()
        h.update(np.int64(self._n).tobytes())
        for col in self._cols:
            h.update(col.digest())
        return h.hexdigest()


def edge_fingerprint(n: int, u, v, w) -> str:
    """Content fingerprint of an edge multiset: hash over (n, u, v, w).

    O(s) over raw bytes — cheap relative to any plan build (sorting,
    capacity histograms), and the cross-process cache key for the
    encoder's persistent plan tier.  ORDER-SENSITIVE by design: plan
    artifacts (packing layouts, chunk boundaries) depend on edge order,
    so a permuted multiset correctly reads as different content."""
    return FingerprintAccumulator(n).update(u, v, w).digest()


def extend_fingerprint(fp: str, u, v, w) -> str:
    """Chain an appended edge batch onto an existing fingerprint.

    fp' = H(fp || u || v || w): lets an append-only log (the serving
    store) maintain its multiset fingerprint in O(batch) per delta
    instead of rehashing the full edge list.  The chained value differs
    from `edge_fingerprint` of the concatenated arrays — that is fine:
    any process replaying the same base + delta sequence reaches the
    same value, which is all a cache key needs."""
    h = _new_hash()
    h.update(bytes.fromhex(fp))
    _hash_edges(h, u, v, w)
    return h.hexdigest()


@dataclass
class Graph:
    """Edge-list graph. u, v: int32 (s,); w: float32 (s,); n nodes."""
    u: np.ndarray
    v: np.ndarray
    w: np.ndarray
    n: int

    @property
    def s(self) -> int:
        return int(self.u.shape[0])

    def fingerprint(self) -> str:
        """Content fingerprint (see `edge_fingerprint`), computed once
        and cached on the instance.  Sources that already know the
        fingerprint (the serving store's incrementally-maintained one,
        a generator's parameter hash) pre-stamp `_fp` so materializing
        a graph never forces a rehash.  Assumes the arrays are not
        mutated in place afterwards (nothing in this codebase does)."""
        fp = getattr(self, "_fp", None)
        if fp is None:
            fp = edge_fingerprint(self.n, self.u, self.v, self.w)
            self._fp = fp
        return fp

    def validate(self) -> None:
        assert self.u.shape == self.v.shape == self.w.shape
        if self.s == 0:        # empty edge list (e.g. an empty delta batch)
            return
        assert self.u.min() >= 0 and self.u.max() < self.n
        assert self.v.min() >= 0 and self.v.max() < self.n

    def symmetrize(self) -> "Graph":
        """Undirected -> two symmetric directed edges."""
        return Graph(np.concatenate([self.u, self.v]),
                     np.concatenate([self.v, self.u]),
                     np.concatenate([self.w, self.w]), self.n)

    def degrees(self) -> np.ndarray:
        """Weighted out+in degree (the Laplacian normalizer)."""
        d = np.zeros(self.n, np.float64)
        np.add.at(d, self.u, self.w)
        np.add.at(d, self.v, self.w)
        return d.astype(np.float32)

    def permuted(self, rng: np.random.Generator) -> "Graph":
        """Random edge order (load-balance for static sharding)."""
        p = rng.permutation(self.s)
        return Graph(self.u[p], self.v[p], self.w[p], self.n)

    def pad_to(self, s_pad: int) -> "Graph":
        """Pad with zero-weight self-loops of node 0 (no-op edges).

        Contract (regression-tested): padding preserves `n` and is
        invisible to every downstream consumer — `degrees()` and the
        Laplacian `deg` precompute are unchanged (the pad edges carry
        w = 0 exactly, so they add nothing to either endpoint), and Z
        is unchanged for any labeling (a zero-weight contribution is a
        no-op regardless of node 0's label)."""
        extra = s_pad - self.s
        assert extra >= 0
        if extra == 0:
            return self
        assert self.n >= 1, "cannot pad a graph with no nodes"
        z = np.zeros(extra, np.int32)
        return Graph(np.concatenate([np.asarray(self.u, np.int32), z]),
                     np.concatenate([np.asarray(self.v, np.int32), z]),
                     np.concatenate([np.asarray(self.w, np.float32),
                                     np.zeros(extra, np.float32)]),
                     self.n)


def bucket_size(size: int, floor: int = 256) -> int:
    """Next power-of-two >= size (>= floor) — the shared batch-padding
    policy that keeps jitted kernels at one compile per bucket, not per
    batch size (used by the encoder's delta path and the serving store)."""
    b = floor
    while b < size:
        b <<= 1
    return b


def chunk_edges(u: np.ndarray, v: np.ndarray, w: np.ndarray,
                chunk_size: int, floor: int = 256):
    """Yield (u, v, w) host chunks of at most `chunk_size` edges; the
    tail chunk is padded to a power-of-two bucket with zero-weight
    node-0 self-loops (no-op edges) so chunked consumers reuse jit
    compilations across changing edge counts.  Non-tail chunks are
    views (no copy).  THE one chunk-and-pad policy — used by the
    encoder's streaming backend and the serving store alike."""
    s = int(u.shape[0])
    for off in range(0, s, chunk_size):
        end = min(off + chunk_size, s)
        m = end - off
        if m < chunk_size:
            pad = bucket_size(m, floor) - m
            yield (np.concatenate([u[off:end], np.zeros(pad, np.int32)]),
                   np.concatenate([v[off:end], np.zeros(pad, np.int32)]),
                   np.concatenate([w[off:end], np.zeros(pad, np.float32)]))
        else:
            yield u[off:end], v[off:end], w[off:end]


def make_labels(n: int, K: int, labeled_frac: float,
                rng: np.random.Generator,
                true_labels: Optional[np.ndarray] = None) -> np.ndarray:
    """Paper setup: labels uniform over [0, K) for `labeled_frac` of nodes
    chosen uniformly at random; -1 elsewhere.  If true_labels given,
    reveal those instead of random ones (SBM quality experiments)."""
    Y = np.full(n, -1, np.int32)
    m = max(1, int(n * labeled_frac))
    idx = rng.choice(n, size=m, replace=False)
    if true_labels is not None:
        Y[idx] = true_labels[idx]
    else:
        Y[idx] = rng.integers(0, K, size=m)
    return Y
