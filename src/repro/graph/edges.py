"""Graph data structures: weighted directed edge lists.

The paper's convention: a graph G(n, s) is an edge list E in R^{s x 3}
(source, destination, weight); undirected graphs are two symmetric
directed edges; unweighted graphs have unit weights.  Labels
Y in {0..K}^n with 0 = unknown (paper) are remapped here to
{-1 = unknown, 0..K-1} for 0-based indexing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class Graph:
    """Edge-list graph. u, v: int32 (s,); w: float32 (s,); n nodes."""
    u: np.ndarray
    v: np.ndarray
    w: np.ndarray
    n: int

    @property
    def s(self) -> int:
        return int(self.u.shape[0])

    def validate(self) -> None:
        assert self.u.shape == self.v.shape == self.w.shape
        if self.s == 0:        # empty edge list (e.g. an empty delta batch)
            return
        assert self.u.min() >= 0 and self.u.max() < self.n
        assert self.v.min() >= 0 and self.v.max() < self.n

    def symmetrize(self) -> "Graph":
        """Undirected -> two symmetric directed edges."""
        return Graph(np.concatenate([self.u, self.v]),
                     np.concatenate([self.v, self.u]),
                     np.concatenate([self.w, self.w]), self.n)

    def degrees(self) -> np.ndarray:
        """Weighted out+in degree (the Laplacian normalizer)."""
        d = np.zeros(self.n, np.float64)
        np.add.at(d, self.u, self.w)
        np.add.at(d, self.v, self.w)
        return d.astype(np.float32)

    def permuted(self, rng: np.random.Generator) -> "Graph":
        """Random edge order (load-balance for static sharding)."""
        p = rng.permutation(self.s)
        return Graph(self.u[p], self.v[p], self.w[p], self.n)

    def pad_to(self, s_pad: int) -> "Graph":
        """Pad with zero-weight self-loops of node 0 (no-op edges)."""
        extra = s_pad - self.s
        assert extra >= 0
        z = np.zeros(extra, self.u.dtype)
        return Graph(np.concatenate([self.u, z]),
                     np.concatenate([self.v, z]),
                     np.concatenate([self.w, np.zeros(extra, np.float32)]),
                     self.n)


def make_labels(n: int, K: int, labeled_frac: float,
                rng: np.random.Generator,
                true_labels: Optional[np.ndarray] = None) -> np.ndarray:
    """Paper setup: labels uniform over [0, K) for `labeled_frac` of nodes
    chosen uniformly at random; -1 elsewhere.  If true_labels given,
    reveal those instead of random ones (SBM quality experiments)."""
    Y = np.full(n, -1, np.int32)
    m = max(1, int(n * labeled_frac))
    idx = rng.choice(n, size=m, replace=False)
    if true_labels is not None:
        Y[idx] = true_labels[idx]
    else:
        Y[idx] = rng.integers(0, K, size=m)
    return Y
