"""Synthetic graph generators (paper: Erdős–Rényi scaling study; we add
SBM for embedding-quality validation and power-law for skew stress)."""
from __future__ import annotations

import numpy as np

from repro.graph.edges import Graph

#: Bump whenever any generator's SAMPLING changes (not just its
#: signature).  `SyntheticSource` fingerprints a generator CALL instead
#: of the produced arrays, which is only sound while equal (kind,
#: params) implies equal output — this version, plus the numpy release
#: (Generator bit streams are not guaranteed stable across numpy
#: versions), is folded into that fingerprint so a sampling change can
#: never resurrect a stale plan from the persistent cache.
GENERATORS_VERSION = 1


def erdos_renyi(n: int, s: int, seed: int = 0, weighted: bool = False
                ) -> Graph:
    """G(n, s): s directed edges with uniform random endpoints (the G(n, M)
    variant used for runtime scaling; self-loops possible, harmless)."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, size=s, dtype=np.int32)
    v = rng.integers(0, n, size=s, dtype=np.int32)
    w = (rng.random(s, dtype=np.float32) + 0.5 if weighted
         else np.ones(s, np.float32))
    return Graph(u, v, w, n)


def sbm(n: int, K: int, s: int, p_in: float = 0.9, seed: int = 0
        ) -> tuple[Graph, np.ndarray]:
    """Stochastic block model with s expected edges; returns (graph,
    true_labels).  p_in = probability an edge is intra-community."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, K, size=n, dtype=np.int32)
    intra = rng.random(s) < p_in
    u = rng.integers(0, n, size=s, dtype=np.int32)
    # intra edges: v sampled from u's community; inter: uniform
    v = rng.integers(0, n, size=s, dtype=np.int32)
    # resample intra destinations within the same block, by rejection-free
    # trick: pick a random member of the block via sorted-by-label index
    order = np.argsort(labels, kind="stable")
    block_start = np.searchsorted(labels[order], np.arange(K))
    block_count = np.bincount(labels, minlength=K)
    lab_u = labels[u]
    offs = (rng.random(s) * block_count[lab_u]).astype(np.int64)
    v_intra = order[block_start[lab_u] + offs]
    v = np.where(intra, v_intra, v).astype(np.int32)
    return Graph(u, v, np.ones(s, np.float32), n), labels


def powerlaw(n: int, s: int, alpha: float = 1.5, seed: int = 0) -> Graph:
    """Preferential-attachment-ish skewed degree graph (Zipf endpoints)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n + 1, dtype=np.float64) ** (-alpha)
    p = ranks / ranks.sum()
    u = rng.choice(n, size=s, p=p).astype(np.int32)
    v = rng.integers(0, n, size=s, dtype=np.int32)
    return Graph(u, v, np.ones(s, np.float32), n)
