"""Graph IO: npz snapshots + host-sharded streaming loader.

At Friendster scale (1.8 B edges = ~22 GB as int32 triples) a single
host cannot hold the edge list; `ShardedEdgeReader` streams fixed-size
chunks so each host of a pod loads only its slice (the production
ingestion path; tests exercise it with small files).

The reader does NOT materialize whole npz members: each array inside
the zip is opened as a stream, its npy header parsed, bytes up to the
host's slice skipped, and chunks decoded with `np.frombuffer` — peak
host memory is O(chunk_size), independent of the file's edge count.
"""
from __future__ import annotations

import mmap as _mmap_mod
import os
import zipfile
from typing import IO, Iterator

import numpy as np

from repro.graph.edges import Graph

_SKIP_BUF = 1 << 24        # discard stride while seeking into a slice


def _advise_sequential(arr: np.ndarray) -> None:
    """Hint the kernel that a memory-mapped array will be scanned
    front-to-back (`madvise(MADV_SEQUENTIAL)`): readahead doubles and
    pages behind the scan are dropped early, which is exactly the
    access pattern of a sharded edge scan.  Purely advisory — guarded
    for platforms (or numpy internals) without madvise, where it is a
    silent no-op."""
    mm = getattr(arr, "_mmap", None)
    advise = getattr(mm, "madvise", None)                 # py>=3.8, unix
    flag = getattr(_mmap_mod, "MADV_SEQUENTIAL", None)    # not on win
    if advise is None or flag is None:
        return
    try:
        advise(flag)
    except (OSError, ValueError):      # e.g. offset-page quirks: hint
        pass                           # only, never fail the read


def save_graph(path: str, g: Graph, *, compressed: bool = True) -> None:
    """Atomic npz snapshot.  compressed=False writes STORED zip members,
    which `ShardedEdgeReader` can memory-map instead of stream-decode."""
    tmp = path + ".tmp.npz"     # keep the suffix so savez doesn't append
    savez = np.savez_compressed if compressed else np.savez
    savez(tmp, u=g.u, v=g.v, w=g.w, n=np.int64(g.n))
    os.replace(tmp, path)


def load_graph(path: str) -> Graph:
    with np.load(path) as d:    # context-managed: no leaked zip handle
        return Graph(d["u"], d["v"], d["w"], int(d["n"]))


def _open_member(zf: zipfile.ZipFile, name: str) -> tuple[IO[bytes],
                                                          np.dtype, int]:
    """Open `name.npy` inside the zip positioned at the data section.

    Returns (stream, dtype, count) without reading the array body."""
    f = zf.open(name + ".npy")
    version = np.lib.format.read_magic(f)
    if version == (1, 0):
        shape, fortran, dtype = np.lib.format.read_array_header_1_0(f)
    else:
        shape, fortran, dtype = np.lib.format.read_array_header_2_0(f)
    assert not fortran and len(shape) <= 1
    return f, dtype, int(shape[0]) if shape else 1


def _skip(f: IO[bytes], nbytes: int) -> None:
    """Advance a (possibly compressed, forward-only) stream by nbytes."""
    while nbytes > 0:
        got = f.read(min(nbytes, _SKIP_BUF))
        if not got:
            raise EOFError("npz member shorter than header promised")
        nbytes -= len(got)


def _read_exact(f: IO[bytes], nbytes: int) -> bytes:
    parts = []
    while nbytes > 0:
        got = f.read(nbytes)
        if not got:
            raise EOFError("npz member shorter than header promised")
        parts.append(got)
        nbytes -= len(got)
    return b"".join(parts)


def _mmap_member(path: str, name: str) -> np.ndarray:
    """Memory-map `name.npy` inside an UNCOMPRESSED (ZIP_STORED) npz.

    A stored zip member is a verbatim .npy file at a fixed offset, so
    the array body can be mapped directly — zero decode, zero copy, the
    OS pages in only the slices actually read."""
    with zipfile.ZipFile(path) as zf:
        zi = zf.getinfo(name + ".npy")
        if zi.compress_type != zipfile.ZIP_STORED:
            raise ValueError(f"member {name!r} is compressed; mmap needs "
                             "an uncompressed snapshot "
                             "(save_graph(..., compressed=False))")
        with zf.open(zi) as f:
            version = np.lib.format.read_magic(f)
            if version == (1, 0):
                shape, fortran, dtype = \
                    np.lib.format.read_array_header_1_0(f)
            else:
                shape, fortran, dtype = \
                    np.lib.format.read_array_header_2_0(f)
            assert not fortran and len(shape) <= 1
            header_len = f.tell()      # npy magic+header inside the member
        # data offset in the outer file: local header + npy header.
        # Parse the LOCAL header's name/extra lengths (the central
        # directory's extra field may differ from the local one).
        with open(path, "rb") as raw:
            raw.seek(zi.header_offset + 26)
            name_len, extra_len = np.frombuffer(raw.read(4), "<u2")
        data_off = (zi.header_offset + 30 + int(name_len) + int(extra_len)
                    + header_len)
    count = int(shape[0]) if shape else 1
    return np.memmap(path, dtype=dtype, mode="r", offset=data_off,
                     shape=(count,))


def is_mmapable(path: str) -> bool:
    """True iff every edge member of the npz is ZIP_STORED."""
    with zipfile.ZipFile(path) as zf:
        return all(zf.getinfo(k + ".npy").compress_type
                   == zipfile.ZIP_STORED for k in ("u", "v", "w"))


class ShardedEdgeReader:
    """Streams the edge slice belonging to (host_id, num_hosts).

    Edges are split contiguously; random edge order must be pre-shuffled
    on disk (generators do).  chunk_size bounds host memory: members are
    decoded chunk-by-chunk from the zip streams, never loaded whole.

    For UNCOMPRESSED snapshots (`save_graph(..., compressed=False)`) the
    reader takes an mmap fast-path: members are memory-mapped in place
    and chunks are zero-copy views — no inflate, no byte shuffling, and
    the page cache is shared across readers on the same host.  `mmap`
    is auto-detected (None); pass False to force the streaming path or
    True to require mapping (raises on a compressed file)."""

    def __init__(self, path: str, host_id: int, num_hosts: int,
                 chunk_size: int = 1 << 22, mmap: bool | None = None):
        self.path = path
        self.mmap = is_mmapable(path) if mmap is None else mmap
        with zipfile.ZipFile(path) as zf:
            f, _, s = _open_member(zf, "u")
            f.close()
            fn, ndt, _ = _open_member(zf, "n")
            self.n = int(np.frombuffer(_read_exact(fn, ndt.itemsize),
                                       dtype=ndt)[0])
            fn.close()
        per = (s + num_hosts - 1) // num_hosts
        self.lo = host_id * per
        self.hi = min(s, self.lo + per)
        self.chunk = chunk_size

    def _iter_mmap(self) -> Iterator[Graph]:
        u, v, w = (_mmap_member(self.path, k) for k in ("u", "v", "w"))
        for arr in (u, v, w):          # sequential-scan readahead hint
            _advise_sequential(arr)
        for off in range(self.lo, self.hi, self.chunk):
            end = min(off + self.chunk, self.hi)
            yield Graph(u[off:end], v[off:end], w[off:end], self.n)

    def __iter__(self) -> Iterator[Graph]:
        if self.lo >= self.hi:
            return
        if self.mmap:
            yield from self._iter_mmap()
            return
        with zipfile.ZipFile(self.path) as zf:
            streams = {}
            for key in ("u", "v", "w"):
                f, dtype, _ = _open_member(zf, key)
                _skip(f, self.lo * dtype.itemsize)
                streams[key] = (f, dtype)
            for off in range(self.lo, self.hi, self.chunk):
                m = min(self.chunk, self.hi - off)
                u, v, w = (
                    np.frombuffer(_read_exact(f, m * dt.itemsize), dtype=dt)
                    for (f, dt) in (streams[k] for k in ("u", "v", "w")))
                yield Graph(u, v, w, self.n)
            for f, _ in streams.values():
                f.close()
