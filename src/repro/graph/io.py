"""Graph IO: npz snapshots + host-sharded streaming loader.

At Friendster scale (1.8 B edges = ~22 GB as int32 triples) a single
host cannot hold the edge list; `ShardedEdgeReader` streams fixed-size
chunks so each host of a pod loads only its slice (the production
ingestion path; tests exercise it with small files).
"""
from __future__ import annotations

import os
from typing import Iterator

import numpy as np

from repro.graph.edges import Graph


def save_graph(path: str, g: Graph) -> None:
    tmp = path + ".tmp"
    np.savez_compressed(tmp, u=g.u, v=g.v, w=g.w, n=np.int64(g.n))
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def load_graph(path: str) -> Graph:
    d = np.load(path)
    return Graph(d["u"], d["v"], d["w"], int(d["n"]))


class ShardedEdgeReader:
    """Streams the edge slice belonging to (host_id, num_hosts).

    Edges are split contiguously; random edge order must be pre-shuffled
    on disk (generators do).  chunk_size bounds host memory."""

    def __init__(self, path: str, host_id: int, num_hosts: int,
                 chunk_size: int = 1 << 22):
        self.d = np.load(path, mmap_mode=None)
        s = self.d["u"].shape[0]
        per = (s + num_hosts - 1) // num_hosts
        self.lo = host_id * per
        self.hi = min(s, self.lo + per)
        self.chunk = chunk_size
        self.n = int(self.d["n"])

    def __iter__(self) -> Iterator[Graph]:
        for off in range(self.lo, self.hi, self.chunk):
            end = min(off + self.chunk, self.hi)
            yield Graph(self.d["u"][off:end], self.d["v"][off:end],
                        self.d["w"][off:end], self.n)
