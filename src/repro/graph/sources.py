"""GraphSource: one protocol for every way a graph enters the system.

Graphs reach the encoder through four historically ad-hoc paths —
synthetic generators, `load_graph` npz snapshots, `ShardedEdgeReader`
streams, and the serving `GraphStore`'s live multiset.  A `GraphSource`
unifies them behind two methods:

    graph()        -> Graph   materialized edge list, fingerprint stamped
    fingerprint()  -> str     cheap content identity (NOT array identity)

The fingerprint is what makes the encoder's persistent plan cache work:
`Embedder.plan` keys host preprocessing on *content*, so a fresh process
(restart, CI rerun, new serving replica) embedding the same graph skips
packing entirely.  Each source computes its fingerprint the cheapest
way it can:

  synthetic   hash of (generator, params) — no array hashing at all;
              generators are deterministic per seed.
  snapshot    content hash of the loaded arrays, computed once.
  sharded     content hash folded incrementally while chunks stream.
  store       the GraphStore's incrementally-maintained chain (O(batch)
              per delta — serving never rehashes the full edge list).

Register new ingestion paths with ``@register_source("name")``; callers
construct them via ``get_source("name", **kwargs)`` or directly.
`Embedder.fit`/`plan` accept a GraphSource anywhere a Graph is accepted
(duck-typed on ``.graph()`` — no import cycle with the encoder).
"""
from __future__ import annotations

import hashlib
import json
from typing import Callable, Dict, Iterator, Optional

import numpy as np

from repro.graph import generators as G
from repro.graph.edges import FingerprintAccumulator, Graph
from repro.graph.io import ShardedEdgeReader, load_graph

_SOURCES: Dict[str, type] = {}


def register_source(name: str):
    """Class decorator: make a GraphSource constructible by name."""
    def deco(cls):
        cls.name = name
        _SOURCES[name] = cls
        return cls
    return deco


def get_source(name: str, **kwargs) -> "GraphSource":
    try:
        cls = _SOURCES[name]
    except KeyError:
        raise KeyError(f"unknown graph source {name!r}; registered: "
                       f"{', '.join(sorted(_SOURCES))}") from None
    return cls(**kwargs)


def list_sources() -> list[str]:
    return sorted(_SOURCES)


def as_graph(obj) -> Graph:
    """Materialize a Graph from either a Graph or a GraphSource."""
    if isinstance(obj, Graph):
        return obj
    g = getattr(obj, "graph", None)
    if callable(g):
        return g()
    raise TypeError(f"expected a Graph or GraphSource, got {type(obj)!r}")


class GraphSource:
    """Base class / protocol for graph inputs (see module docstring)."""

    name: str = "?"

    def graph(self) -> Graph:
        """The materialized edge list, fingerprint pre-stamped."""
        raise NotImplementedError

    def fingerprint(self) -> str:
        """Content identity, computed as cheaply as the source allows."""
        return self.graph().fingerprint()


@register_source("synthetic")
class SyntheticSource(GraphSource):
    """A deterministic generator call: fingerprint = hash of the
    (generator, params) tuple, so identity costs nothing — the arrays
    are never hashed.  `kind` names a function in `graph.generators`
    (erdos_renyi, sbm, powerlaw); sbm's true labels are exposed as
    `.labels` after materialization."""

    def __init__(self, kind: str, **params):
        self.kind = kind
        self.params = params
        self.labels: Optional[np.ndarray] = None
        self._graph: Optional[Graph] = None
        fn: Optional[Callable] = getattr(G, kind, None)
        if fn is None or not callable(fn):
            raise KeyError(f"unknown generator {kind!r}")
        self._fn = fn
        # the call is the identity ONLY while its output is: salt with
        # the generator code version and the numpy release (Generator
        # bit streams may change between numpy versions), so drift in
        # either reads as new content, never as a stale plan-cache hit
        token = json.dumps({"kind": kind, "params": params,
                            "generators_version": G.GENERATORS_VERSION,
                            "numpy": np.__version__}, sort_keys=True)
        self._fp = "syn-" + hashlib.blake2b(
            token.encode(), digest_size=16).hexdigest()

    def graph(self) -> Graph:
        if self._graph is None:
            out = self._fn(**self.params)
            if isinstance(out, tuple):           # sbm: (graph, labels)
                self._graph, self.labels = out
            else:
                self._graph = out
            self._graph._fp = self._fp
        return self._graph

    def fingerprint(self) -> str:
        return self._fp


@register_source("snapshot")
class SnapshotSource(GraphSource):
    """An npz snapshot written by `save_graph` (or a GraphStore
    snapshot's `.edges.npz`).  Fingerprint = content hash of the loaded
    arrays — stable across re-saves and across processes, unlike a hash
    of the file bytes (zip metadata varies)."""

    def __init__(self, path: str):
        self.path = path
        self._graph: Optional[Graph] = None

    def graph(self) -> Graph:
        if self._graph is None:
            self._graph = load_graph(self.path)
        return self._graph


@register_source("sharded")
class ShardedSource(GraphSource):
    """This host's contiguous slice of an npz snapshot, materialized
    from `ShardedEdgeReader` chunks with the fingerprint folded
    incrementally while streaming — one pass over the bytes, O(chunk)
    extra memory beyond the assembled slice.  The fingerprint depends
    only on the slice's CONTENT (chunk_size is a tuning knob, not
    identity), so the full slice of a snapshot agrees with
    `SnapshotSource` of the same file."""

    def __init__(self, path: str, host_id: int = 0, num_hosts: int = 1,
                 chunk_size: int = 1 << 22, mmap: Optional[bool] = None):
        self.reader = ShardedEdgeReader(path, host_id, num_hosts,
                                        chunk_size=chunk_size, mmap=mmap)
        self._graph: Optional[Graph] = None

    def chunks(self) -> Iterator[Graph]:
        """The raw chunk stream (for out-of-core consumers that never
        want the whole slice resident)."""
        return iter(self.reader)

    def graph(self) -> Graph:
        if self._graph is None:
            n = self.reader.n
            acc = FingerprintAccumulator(n)
            us, vs, ws = [], [], []
            for c in self.reader:
                acc.update(c.u, c.v, c.w)
                us.append(np.asarray(c.u, np.int32))
                vs.append(np.asarray(c.v, np.int32))
                ws.append(np.asarray(c.w, np.float32))
            cat = (np.concatenate(a) if a else z
                   for a, z in ((us, np.zeros(0, np.int32)),
                                (vs, np.zeros(0, np.int32)),
                                (ws, np.zeros(0, np.float32))))
            self._graph = Graph(*cat, n)
            self._graph._fp = acc.digest()
        return self._graph


@register_source("store")
class StoreSource(GraphSource):
    """A live `serving.GraphStore` version.  The store maintains its
    fingerprint incrementally (chained per delta batch), so serving
    cold-starts and rebuilds get content identity for free — no rehash
    of the base multiset, ever.  Duck-typed: anything with `.edges()`
    and `.fingerprint()` works (avoids a graph -> serving import
    cycle)."""

    def __init__(self, store):
        self.store = store

    def graph(self) -> Graph:
        return self.store.edges()

    def fingerprint(self) -> str:
        return self.store.fingerprint()
