"""Process-wide metrics registry: counters, gauges, latency histograms.

One registry per process (`repro.obs` owns the default), three
instrument kinds, all labeled:

  counter    monotonically-increasing float (events, bytes, edges);
  gauge      last-write-wins float (edges/s, accumulator bytes,
             health state);
  histogram  log-bucketed value distribution with O(1) observe and
             cheap p50/p95/p99 summaries — latencies land here.

Every series is addressed by ``(name, sorted(labels))``.  Names are
validated against the repo-wide scheme ``repro_<subsystem>_<metric>``
(lowercase ``[a-z0-9_]``, at least three underscore-separated segments
with ``repro`` first) so a renamed series is a loud failure at the
emission site, not a silently-empty dashboard (`benchmarks.run`
additionally cross-checks bench rows against this scheme).

Thread safety: one lock per registry around the series maps; observe /
add / set are dict-lookup + float-add under that lock — cheap enough
for every hot path this repo has (WAL appends, batcher tickets).  The
truly-free disabled path lives in `repro.obs` (the facade returns
before any registry call when ``REPRO_OBS=off``); the registry itself
always does real work.

Histogram buckets are geometric, base 2, anchored at 1 microsecond:
bucket ``i`` holds values in ``(1e-6 * 2**(i-1), 1e-6 * 2**i]`` — 64
buckets span sub-microsecond to ~half a million years, so one layout
serves latencies, byte counts, and batch sizes alike.  Quantiles are
read from the cumulative bucket walk and reported as the matching
bucket's upper bound: an over-estimate bounded by the 2x bucket width,
the standard log-histogram trade.
"""
from __future__ import annotations

import math
import re
import threading
from typing import Any, Dict, Iterable, Optional, Tuple

_NAME_RE = re.compile(r"^repro_[a-z0-9]+(_[a-z0-9]+)+$")
_LABEL_RE = re.compile(r"^[a-z][a-z0-9_]*$")

#: bucket 0 upper bound (seconds for latencies; unitless otherwise)
_B0 = 1e-6
_NBUCKETS = 64


def valid_metric_name(name: str) -> bool:
    """True iff `name` follows ``repro_<subsystem>_<metric>``."""
    return _NAME_RE.match(name) is not None


def _check_name(name: str) -> None:
    if not valid_metric_name(name):
        raise ValueError(
            f"metric name {name!r} violates the repo naming scheme "
            "repro_<subsystem>_<metric> (lowercase [a-z0-9_], >= 3 "
            "underscore-separated segments starting with 'repro')")


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _format_series(name: str, key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return f"{name}{{{inner}}}"


def bucket_index(value: float) -> int:
    """Index of the log2 bucket holding `value` (clamped)."""
    if value <= _B0:
        return 0
    return min(_NBUCKETS - 1, int(math.ceil(math.log2(value / _B0))))


def bucket_upper(i: int) -> float:
    return _B0 * (2.0 ** i)


class _Hist:
    __slots__ = ("counts", "count", "sum", "min", "max")

    def __init__(self):
        self.counts = [0] * _NBUCKETS
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        self.counts[bucket_index(v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        """Upper bound of the bucket containing the q-quantile rank."""
        if not self.count:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                # clamp the log-bucket over-estimate to the observed
                # extremes so tiny samples read sanely
                return float(min(max(bucket_upper(i), self.min),
                                 self.max))
        return float(self.max)

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.sum,
                "min": (0.0 if self.count == 0 else self.min),
                "max": (0.0 if self.count == 0 else self.max),
                "p50": self.quantile(0.50),
                "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Labeled counters / gauges / histograms behind one lock."""

    def __init__(self):
        self._mu = threading.Lock()
        self._counters: Dict[str, Dict[Tuple, float]] = {}  # guarded by: _mu
        self._gauges: Dict[str, Dict[Tuple, float]] = {}    # guarded by: _mu
        self._hists: Dict[str, Dict[Tuple, _Hist]] = {}     # guarded by: _mu

    # -- write side --------------------------------------------------------

    def counter(self, name: str, value: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        with self._mu:
            fam = self._counters.get(name)
            if fam is None:
                _check_name(name)
                fam = self._counters[name] = {}
            fam[key] = fam.get(key, 0.0) + value

    def gauge(self, name: str, value: float, **labels) -> None:
        key = _label_key(labels)
        with self._mu:
            fam = self._gauges.get(name)
            if fam is None:
                _check_name(name)
                fam = self._gauges[name] = {}
            fam[key] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        key = _label_key(labels)
        with self._mu:
            fam = self._hists.get(name)
            if fam is None:
                _check_name(name)
                fam = self._hists[name] = {}
            h = fam.get(key)
            if h is None:
                h = fam[key] = _Hist()
            h.observe(float(value))

    def reset(self) -> None:
        with self._mu:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()

    # -- read side ---------------------------------------------------------

    def snapshot(self, prefix: str = "") -> Dict[str, Any]:
        """Point-in-time copy: flat ``series-string -> value`` maps
        (histograms -> summary dicts).  `prefix` filters by metric
        name."""
        with self._mu:
            out: Dict[str, Any] = {"counters": {}, "gauges": {},
                                   "histograms": {}}
            for name, fam in self._counters.items():
                if not name.startswith(prefix):
                    continue
                for key, v in fam.items():
                    out["counters"][_format_series(name, key)] = v
            for name, fam in self._gauges.items():
                if not name.startswith(prefix):
                    continue
                for key, v in fam.items():
                    out["gauges"][_format_series(name, key)] = v
            for name, fam in self._hists.items():
                if not name.startswith(prefix):
                    continue
                for key, h in fam.items():
                    out["histograms"][_format_series(name, key)] = \
                        h.summary()
            return out

    def series_names(self) -> set:
        """Every distinct metric NAME (label sets collapsed)."""
        with self._mu:
            return (set(self._counters) | set(self._gauges)
                    | set(self._hists))

    def counter_value(self, name: str, **labels) -> float:
        with self._mu:
            return self._counters.get(name, {}).get(
                _label_key(labels), 0.0)

    def gauge_value(self, name: str, **labels) -> Optional[float]:
        with self._mu:
            return self._gauges.get(name, {}).get(_label_key(labels))

    def hist_summary(self, name: str, **labels) -> Dict[str, float]:
        with self._mu:
            h = self._hists.get(name, {}).get(_label_key(labels))
            return h.summary() if h is not None else _Hist().summary()

    # -- export ------------------------------------------------------------

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4).

        Counters/gauges render one sample per series; histograms render
        cumulative ``_bucket{le=...}`` samples (only non-empty buckets
        plus ``+Inf``) with ``_sum`` / ``_count``."""
        def fmt(v: float) -> str:
            return f"{v:.10g}"

        lines = []
        with self._mu:
            for name in sorted(self._counters):
                lines.append(f"# TYPE {name} counter")
                for key, v in sorted(self._counters[name].items()):
                    lines.append(
                        f"{_format_series(name, key)} {fmt(v)}")
            for name in sorted(self._gauges):
                lines.append(f"# TYPE {name} gauge")
                for key, v in sorted(self._gauges[name].items()):
                    lines.append(
                        f"{_format_series(name, key)} {fmt(v)}")
            for name in sorted(self._hists):
                lines.append(f"# TYPE {name} histogram")
                for key, h in sorted(self._hists[name].items()):
                    cum = 0
                    for i, c in enumerate(h.counts):
                        if not c:
                            continue
                        cum += c
                        le = fmt(bucket_upper(i))
                        lines.append(_format_series(
                            name + "_bucket",
                            key + (("le", le),)) + f" {cum}")
                    lines.append(_format_series(
                        name + "_bucket",
                        key + (("le", "+Inf"),)) + f" {h.count}")
                    lines.append(
                        f"{_format_series(name + '_sum', key)} "
                        f"{fmt(h.sum)}")
                    lines.append(
                        f"{_format_series(name + '_count', key)} "
                        f"{h.count}")
        return "\n".join(lines) + ("\n" if lines else "")


def summarize(snapshot: Dict[str, Any],
              kinds: Iterable[str] = ("counters", "gauges",
                                      "histograms")) -> str:
    """Human-readable rendering of a `snapshot()` dict (the CLI's
    pretty printer)."""
    out = []
    for kind in kinds:
        rows = snapshot.get(kind) or {}
        if not rows:
            continue
        out.append(f"== {kind} ==")
        width = max(len(k) for k in rows)
        for series in sorted(rows):
            v = rows[series]
            if kind == "histograms":
                out.append(
                    f"{series:<{width}}  n={v['count']:<8g} "
                    f"p50={v['p50']:.3g} p95={v['p95']:.3g} "
                    f"p99={v['p99']:.3g} max={v['max']:.3g} "
                    f"sum={v['sum']:.3g}")
            else:
                out.append(f"{series:<{width}}  {v:g}")
    return "\n".join(out)
