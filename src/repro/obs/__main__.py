"""CLI: live observability snapshot / JSONL trace replay.

    python -m repro.obs --snapshot            # demo run -> pretty registry
    python -m repro.obs --snapshot --prometheus
    python -m repro.obs --snapshot --json
    python -m repro.obs --snapshot --trace-out /tmp/spans.jsonl
    python -m repro.obs --trace /tmp/spans.jsonl   # replay: span tree

``--snapshot`` stands up a tiny but complete serving deployment —
SBM graph -> `GraphStore` -> durable `ServingEngine` (WAL + snapshot in
a temp dir) -> `MicroBatcher` reads/writes -> checkpoint -> recovery —
with observability forced on, then prints the resulting registry
snapshot (pretty table by default; ``--prometheus`` for text
exposition format, ``--json`` for the raw dict).  The run exercises
every instrumented subsystem, so the output is a live catalog of the
metric names the layer emits: WAL, plan-cache, shard, batcher, engine,
and kernel series.

``--trace FILE`` reads a span JSONL file (written via
``REPRO_OBS_TRACE=FILE`` or ``--trace-out``) and pretty-prints the
parent-linked span tree with durations.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
import tempfile

from repro import obs


def _demo(n: int, edges: int, shards: int, steps: int) -> None:
    """A miniature end-to-end serving run (every hot path touched)."""
    import numpy as np

    from repro.graph.edges import make_labels
    from repro.graph.generators import sbm
    from repro.serving.batcher import MicroBatcher
    from repro.serving.engine import ServingEngine
    from repro.serving.store import GraphStore

    rng = np.random.default_rng(0)
    K = 4
    g, truth = sbm(n, K, edges, p_in=0.85, seed=0)
    Y = make_labels(n, K, 0.2, rng, true_labels=truth)
    d = tempfile.mkdtemp(prefix="repro-obs-demo-")
    try:
        with obs.span("obs.demo", n=n, edges=edges, shards=shards):
            eng = ServingEngine(GraphStore(g, Y, K), num_shards=shards,
                                data_dir=d, plan_cache=None)
            batcher = MicroBatcher(eng, topk=5)
            for _ in range(steps):
                for kind in ("embed", "predict", "topk"):
                    batcher.submit(
                        kind, rng.integers(0, n, 16).astype(np.int32))
                b = 64
                batcher.submit("insert",
                               (rng.integers(0, n, b).astype(np.int32),
                                rng.integers(0, n, b).astype(np.int32),
                                rng.random(b).astype(np.float32) + 0.5))
                batcher.flush()
            batcher.submit(
                "labels",
                (np.arange(n, dtype=np.int64), truth.astype(np.int32)))
            batcher.flush()
            eng.checkpoint()
            eng.close()
            rec = ServingEngine.open(d, plan_cache=None)
            rec.query_topk(np.arange(8, dtype=np.int32), k=5)
            rec.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs",
        description="Observability snapshot / trace replay.")
    ap.add_argument("--snapshot", action="store_true",
                    help="run the instrumented demo deployment and "
                         "print the registry snapshot (default when "
                         "no --trace is given)")
    ap.add_argument("--prometheus", action="store_true",
                    help="print Prometheus text format instead of the "
                         "pretty table")
    ap.add_argument("--json", action="store_true",
                    help="print the raw snapshot dict as JSON")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="replay a span JSONL file as a parent-linked "
                         "tree (skips the demo)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write the demo run's spans to FILE as JSONL")
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--edges", type=int, default=4000)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--steps", type=int, default=3)
    args = ap.parse_args(argv)

    if args.trace is not None:
        events = obs.load_jsonl(args.trace)
        if not events:
            print(f"no parseable span events in {args.trace}",
                  file=sys.stderr)
            return 1
        print(obs.render_tree(events))
        return 0

    if not obs.enabled():
        print("# REPRO_OBS=off in the environment; enabling for this "
              "demo run", file=sys.stderr)
    obs.configure(enabled=True)
    obs.reset()
    if args.trace_out:
        obs.configure(trace_path=args.trace_out)
    _demo(args.n, args.edges, args.shards, args.steps)
    if args.trace_out:
        obs.configure(trace_path="")     # flush + close the sink
        print(f"# spans written to {args.trace_out}", file=sys.stderr)

    snap = obs.snapshot()
    if args.prometheus:
        sys.stdout.write(obs.render_prometheus())
    elif args.json:
        print(json.dumps(snap, indent=2, sort_keys=True))
    else:
        print(obs.summarize(snap))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
