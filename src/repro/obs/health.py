"""Health state machine for long-running components.

Three states, strictly ordered by severity::

    starting  ->  serving  <->  degraded

* ``starting``  — construction/recovery in progress; reads may block
  or be refused.
* ``serving``   — steady state.
* ``degraded``  — still answering, but a standing fault is present
  (the serving engine enters it when the background flush loop has
  recorded a ``loop_error``, or when WAL append/fsync latency breaches
  its threshold).  Degraded is re-evaluated, not latched: when the
  condition clears the tracker returns to ``serving``.

Every transition is counted (``repro_<component>_health_transitions_
total{to=...}``) and the current state is exported as a gauge
(``repro_<component>_health_state``: 0 starting / 1 serving /
2 degraded) so a Prometheus alert can fire on ``> 1``.
"""
from __future__ import annotations

import time
from typing import Optional

STARTING = "starting"
SERVING = "serving"
DEGRADED = "degraded"

STATE_VALUES = {STARTING: 0, SERVING: 1, DEGRADED: 2}


class HealthTracker:
    """Tracks one component's health state + reason; exports gauges."""

    def __init__(self, component: str):
        self.component = str(component)
        self.state = STARTING
        self.reason: Optional[str] = None
        self.since = time.time()
        self._export()

    def _export(self) -> None:
        from repro import obs
        obs.gauge(f"repro_{self.component}_health_state",
                  STATE_VALUES[self.state])

    def to(self, state: str, reason: Optional[str] = None) -> bool:
        """Transition (idempotent).  Returns True iff the state
        actually changed; the reason refreshes either way."""
        assert state in STATE_VALUES, state
        changed = state != self.state
        self.reason = reason
        if changed:
            self.state = state
            self.since = time.time()
            from repro import obs
            obs.counter(
                f"repro_{self.component}_health_transitions_total",
                to=state)
            self._export()
        return changed

    def as_dict(self) -> dict:
        out = {"state": self.state, "since": self.since}
        if self.reason:
            out["reason"] = self.reason
        return out
