"""repro.obs — the unified observability layer.

One process-wide substrate for every subsystem's telemetry, so "where
did the time go" has a single answer across encoder, serving, and
kernels (the paper's claim is a throughput number; this is the layer
that makes our reproduction's numbers legible):

* **metrics registry** (`registry.MetricsRegistry`) — labeled
  counters, gauges, and log-bucketed latency histograms with
  p50/p95/p99 summaries; every series follows
  ``repro_<subsystem>_<metric>`` (validated — a renamed series fails
  loudly).
* **span tracing** (`trace`) — ``span("encoder.plan", n=..., s=...)``
  context managers producing parent-linked timed events into a
  bounded ring plus an optional JSONL sink, with
  ``sp.fence(device_array)`` jax block-until-ready fencing so async
  device work is billed to the span that launched it.
* **export surfaces** — ``snapshot()`` (flat dict, the engine's
  ``stats()`` substrate), ``render_prometheus()`` (text exposition
  format), and the ``python -m repro.obs`` CLI (live demo snapshot /
  JSONL trace replay).

Enable/disable: **on by default**; ``REPRO_OBS=off`` (or ``0/none/
disable(d)``) turns the whole layer into true no-ops — module-level
helpers return before touching the registry, ``span()`` hands back a
shared do-nothing singleton that never calls the clock and never
blocks on device work.  The bench gate (`benchmarks.obs_gate`) holds
the instrumented hot paths to within 3% of the disabled path.

Environment:

    REPRO_OBS        on (default) / off
    REPRO_OBS_TRACE  path: append every span as a JSON line
    REPRO_OBS_RING   in-memory span ring capacity (default 4096)

Usage::

    from repro import obs

    obs.counter("repro_serving_wal_records_total")
    obs.observe("repro_serving_wal_append_seconds", dt)
    obs.gauge("repro_kernel_edges_per_s", s / dt, backend="streaming")
    with obs.span("serving.checkpoint",
                  metric="repro_serving_checkpoint_seconds") as sp:
        ...
        sp.fence(Z)
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, Optional

from repro.obs.registry import (MetricsRegistry, summarize,
                                valid_metric_name)
from repro.obs.trace import (NOOP_SPAN, Span, Tracer, load_jsonl,
                             render_tree)

__all__ = ["MetricsRegistry", "Tracer", "configure", "counter",
           "enabled", "gauge", "load_jsonl", "observe", "registry",
           "render_prometheus", "render_tree", "reset", "snapshot",
           "span", "summarize", "tick", "tracer", "valid_metric_name"]

_OFF_VALUES = ("0", "off", "none", "disable", "disabled", "false")


def _env_enabled() -> bool:
    return os.environ.get("REPRO_OBS", "on").strip().lower() \
        not in _OFF_VALUES


def _env_ring() -> int:
    try:
        return max(1, int(os.environ.get("REPRO_OBS_RING", "4096")))
    except ValueError:
        return 4096


_ENABLED: bool = _env_enabled()
_REGISTRY = MetricsRegistry()
_TRACER = Tracer(ring=_env_ring(),
                 trace_path=os.environ.get("REPRO_OBS_TRACE") or None)


def registry() -> MetricsRegistry:
    return _REGISTRY


def tracer() -> Tracer:
    return _TRACER


def enabled() -> bool:
    """Is the observability layer live?  (`REPRO_OBS`, default on.)
    Call sites with non-trivial measurement work (clock reads, label
    dict builds) should guard on this; the helpers below already
    no-op."""
    return _ENABLED


def configure(*, enabled: Optional[bool] = None,
              trace_path: Optional[str] = None,
              ring: Optional[int] = None) -> None:
    """Runtime overrides (tests, the bench gate, the CLI).
    ``trace_path=""`` closes the JSONL sink."""
    global _ENABLED
    if enabled is not None:
        _ENABLED = bool(enabled)
    if trace_path is not None:
        _TRACER.set_sink(trace_path or None)
    if ring is not None:
        _TRACER.set_ring(ring)


def reset() -> None:
    """Clear every metric series and the span ring (tests/CLI)."""
    _REGISTRY.reset()
    _TRACER.reset()


# -- hot-path helpers (each returns immediately when disabled) ---------------

def tick() -> float:
    """perf_counter when enabled, 0.0 when not — the cheap way to
    bracket a measurement without an enabled() branch at the call
    site.  Pair with `tock`."""
    return time.perf_counter() if _ENABLED else 0.0


def tock(t0: float) -> float:
    """Seconds since `tick()`'s return, or 0.0 when disabled."""
    return time.perf_counter() - t0 if _ENABLED else 0.0


def counter(name: str, value: float = 1.0, **labels) -> None:
    if _ENABLED:
        _REGISTRY.counter(name, value, **labels)


def gauge(name: str, value: float, **labels) -> None:
    if _ENABLED:
        _REGISTRY.gauge(name, value, **labels)


def observe(name: str, value: float, **labels) -> None:
    if _ENABLED:
        _REGISTRY.observe(name, value, **labels)


def span(name: str, *, metric: Optional[str] = None,
         mlabels: Optional[Dict[str, str]] = None, **attrs):
    """Context manager tracing one operation (see `repro.obs.trace`).
    ``metric=`` mirrors the span duration into a registry histogram on
    exit.  Disabled -> a shared no-op singleton (no clock, no block)."""
    if not _ENABLED:
        return NOOP_SPAN
    sp = _TRACER.begin(name, dict(attrs))
    if metric is not None:
        sp.metric = metric
        sp.mlabels = mlabels or {}
        sp._registry = _REGISTRY
    return sp


# -- export surfaces ---------------------------------------------------------

def snapshot(prefix: str = "") -> Dict[str, Any]:
    """Flat point-in-time view of every series (optionally filtered by
    metric-name prefix), plus the enabled flag."""
    out = _REGISTRY.snapshot(prefix)
    out["enabled"] = _ENABLED
    return out


def render_prometheus() -> str:
    """The full registry in Prometheus text exposition format."""
    return _REGISTRY.render_prometheus()


def trace_events():
    """The in-memory span ring, oldest first."""
    return _TRACER.events()
