"""Span tracing: parent-linked timed events for "where did the time go".

A span is a context manager around one logical operation::

    with obs.span("encoder.plan", backend="pallas", n=n, s=s) as sp:
        plan = build(...)
        sp.fence(plan_arrays)      # jax.block_until_ready: device work
                                   # is attributed to THIS span

Spans nest per thread (a thread-local stack links each span to its
parent), so a serving rebuild shows up as one ``serving.rebuild`` span
with ``encoder.plan`` / ``encoder.fit`` children per shard.  On exit
each span becomes an **event**:

    {"name", "id", "parent", "t0" (epoch seconds), "dur_s", "thread",
     "attrs", "error"?}

Events land in a bounded in-memory ring (default 4096, newest wins;
``REPRO_OBS_RING``) and, when a JSONL sink is configured
(``REPRO_OBS_TRACE=/path`` or ``obs.configure(trace_path=...)``), are
appended one JSON object per line — ``python -m repro.obs --trace f``
rebuilds and pretty-prints the parent-linked tree from such a file.

``fence()`` matters because JAX dispatch is asynchronous: without a
block-until-ready at the span boundary, device work started inside the
span would be billed to whichever LATER span happens to synchronize.
The fence is a no-op for non-jax values and when tracing is disabled
(the no-op span singleton neither times nor blocks).
"""
from __future__ import annotations

import collections
import itertools
import json
import threading
import time
from typing import Any, Dict, List, Optional


class Tracer:
    """Ring buffer + optional JSONL sink; one per process."""

    def __init__(self, ring: int = 4096,
                 trace_path: Optional[str] = None):
        self._mu = threading.Lock()
        self._ids = itertools.count(1)   # guarded by: _mu
        self._tls = threading.local()
        # guarded by: _mu
        self.ring: collections.deque = collections.deque(maxlen=ring)
        self._trace_path: Optional[str] = None   # guarded by: _mu
        self._trace_file = None          # guarded by: _mu
        self.set_sink(trace_path)

    # -- configuration -----------------------------------------------------

    def set_ring(self, size: int) -> None:
        with self._mu:
            self.ring = collections.deque(self.ring, maxlen=int(size))

    def set_sink(self, path: Optional[str]) -> None:
        """(Re)point the JSONL sink; None/"" closes it."""
        with self._mu:
            if self._trace_file is not None:
                try:
                    self._trace_file.close()
                except OSError:
                    pass
                self._trace_file = None
            self._trace_path = path or None

    @property
    def trace_path(self) -> Optional[str]:
        with self._mu:
            return self._trace_path

    # -- span plumbing -----------------------------------------------------

    def _stack(self) -> List[int]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def begin(self, name: str, attrs: Dict[str, Any]) -> "Span":
        sp = Span(self, name, attrs)
        stack = self._stack()
        sp.parent = stack[-1] if stack else None
        with self._mu:
            sp.id = next(self._ids)
        stack.append(sp.id)
        return sp

    def end(self, sp: "Span") -> None:
        stack = self._stack()
        # tolerate exotic exits (generator spans resumed on another
        # thread): only pop if we are the top of OUR thread's stack
        if stack and stack[-1] == sp.id:
            stack.pop()
        event = {"name": sp.name, "id": sp.id, "parent": sp.parent,
                 "t0": sp.t_wall, "dur_s": sp.duration,
                 "thread": sp.thread, "attrs": sp.attrs}
        if sp.error:
            event["error"] = sp.error
        with self._mu:
            self.ring.append(event)
            if self._trace_path is not None:
                try:
                    if self._trace_file is None:
                        self._trace_file = open(self._trace_path, "a")
                    self._trace_file.write(
                        json.dumps(event, default=str) + "\n")
                    self._trace_file.flush()
                except OSError:
                    self._trace_path = None     # sink broke: stop trying

    def events(self) -> List[Dict[str, Any]]:
        with self._mu:
            return list(self.ring)

    def reset(self) -> None:
        with self._mu:
            self.ring.clear()


class Span:
    """One live span (use via ``obs.span(...)`` — not constructed
    directly).  `metric`/`mlabels` optionally mirror the duration into
    a registry histogram on exit, so call sites need one construct for
    both tracing and metrics."""

    __slots__ = ("tracer", "name", "attrs", "id", "parent", "t_wall",
                 "_t0", "duration", "thread", "error", "metric",
                 "mlabels", "_registry")

    def __init__(self, tracer: Tracer, name: str,
                 attrs: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self.id = 0
        self.parent: Optional[int] = None
        self.t_wall = 0.0
        self._t0 = 0.0
        self.duration = 0.0
        self.thread = threading.current_thread().name
        self.error: Optional[str] = None
        self.metric: Optional[str] = None
        self.mlabels: Dict[str, str] = {}
        self._registry = None

    def set(self, **attrs) -> "Span":
        """Attach/override attributes mid-span."""
        self.attrs.update(attrs)
        return self

    def fence(self, value):
        """`jax.block_until_ready(value)` so async device work is
        attributed to this span; returns `value` (non-jax values pass
        through untouched)."""
        try:
            import jax
            jax.block_until_ready(value)
        except Exception:
            pass
        return value

    def __enter__(self) -> "Span":
        self.t_wall = time.time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.duration = time.perf_counter() - self._t0
        if exc is not None:
            self.error = repr(exc)
        self.tracer.end(self)
        if self.metric is not None and self._registry is not None:
            self._registry.observe(self.metric, self.duration,
                                   **self.mlabels)


class NoopSpan:
    """The disabled path: a shared singleton that neither times,
    records, nor blocks."""

    __slots__ = ()

    #: call sites may read `sp.duration` after the block (edges/s
    #: gauges); disabled spans report 0.0 and the gauge is skipped
    duration = 0.0

    def set(self, **attrs) -> "NoopSpan":
        return self

    def fence(self, value):
        return value                      # no block: stay async

    def __enter__(self) -> "NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


NOOP_SPAN = NoopSpan()


# -- trace replay ------------------------------------------------------------

def load_jsonl(path: str) -> List[Dict[str, Any]]:
    """Parse a JSONL trace file (bad lines skipped, not fatal)."""
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return events


def render_tree(events: List[Dict[str, Any]]) -> str:
    """Pretty-print events as parent-linked trees, start-time ordered.

    Spans record on EXIT, so children precede parents in the stream;
    the tree is rebuilt from the explicit parent links.  A child whose
    parent fell off the ring/file renders as a root."""
    ids = {e.get("id") for e in events}
    children: Dict[Any, list] = {}
    roots = []
    for e in events:
        p = e.get("parent")
        if p is not None and p in ids:
            children.setdefault(p, []).append(e)
        else:
            roots.append(e)

    def start(e):
        return e.get("t0") or 0.0

    out: List[str] = []

    def walk(e, depth):
        attrs = e.get("attrs") or {}
        extras = " ".join(f"{k}={v}" for k, v in attrs.items())
        err = "  ERROR " + e["error"] if e.get("error") else ""
        out.append(f"{'  ' * depth}- {e.get('name', '?')} "
                   f"{1e3 * (e.get('dur_s') or 0.0):.3f}ms"
                   + (f"  [{extras}]" if extras else "") + err)
        for c in sorted(children.get(e.get("id"), []), key=start):
            walk(c, depth + 1)

    for r in sorted(roots, key=start):
        walk(r, 0)
    return "\n".join(out)
