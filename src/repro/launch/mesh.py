"""Production meshes.

A v5e pod is 16x16 = 256 chips; the multi-pod mesh stacks pods on a
leading pure-DP axis (cross-pod traffic is gradient all-reduce only, so
adding pods never changes the per-pod program — the elasticity story).

Defined as functions, not module constants: importing this module must
never touch jax device state (the dry-run sets XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    need = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:need])


def make_gee_mesh(*, multi_pod: bool = False):
    """GEE runs edge-parallel over every chip: flat 1-D mesh."""
    n = 512 if multi_pod else 256
    return jax.make_mesh((n,), ("edges",), devices=jax.devices()[:n])


def make_host_mesh():
    """Whatever devices exist (tests / CPU): 1-D data mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
