import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import anywhere: jax locks
# the device count on first init, and the dry-run needs 512 placeholder
# host devices to build the production meshes.  (Tests and benches never
# import this module, so they keep seeing 1 device.)
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the REAL step function (the same code the trainer/server
executes) is jitted with explicit shardings and compiled for the
16x16=256-chip single-pod mesh and the 2x16x16=512-chip multi-pod mesh.
``compiled.memory_analysis()`` proves the cell fits; ``cost_analysis()``
+ HLO collective parsing feed EXPERIMENTS.md §Roofline.

Usage:
    python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    python -m repro.launch.dryrun --all                 # single-pod sweep
    python -m repro.launch.dryrun --all --multi-pod     # 512-chip sweep
    python -m repro.launch.dryrun --gee                 # paper workload
Results land in artifacts/dryrun/<mesh>/<arch>__<shape>.json.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_shape, list_archs
from repro.launch import roofline as RL
from repro.launch.mesh import make_gee_mesh, make_production_mesh
from repro.models import model as M
from repro.sharding import make_rules, use_sharding
from repro.training.optimizer import AdamW
from repro.training.train_loop import make_train_step

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "dryrun")


def _sharded_abstract(tree_specs, rules):
    """ParamSpec tree -> ShapeDtypeStruct-with-sharding tree."""
    from repro.models.layers import tree_map_specs
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype or jnp.dtype("float32"),
            sharding=rules.named(rules.weight_spec(s.shape, s.logical))),
        tree_specs)


def _batch_abstract(cfg, shape, rules):
    B, S = shape.global_batch, shape.seq_len
    bsh = rules.named(rules.act_spec((B, S), ("batch", "seq")))
    out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=bsh)}
    if cfg.is_encdec:
        fsh = rules.named(rules.act_spec(
            (B, cfg.n_frames, cfg.d_model), ("batch", "seq", "embed")))
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frames, cfg.d_model), jnp.dtype(cfg.compute_dtype),
            sharding=fsh)
    return out


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               impl: str = "flash", fsdp: bool = True,
               seq_shard_acts: bool = False, accum_steps: int = 1,
               compress_grads: bool = False,
               cfg_override=None, shape_override=None,
               compile_it: bool = True, compiler_options=None):
    """Returns (lowered, compiled, mesh, cfg, shape)."""
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = shape_override if shape_override is not None \
        else get_shape(shape_name)
    if cfg_override is None and shape_name not in \
            [s.name for s in cfg.shapes()]:
        raise ValueError(f"{arch} skips {shape_name} "
                         f"(sub_quadratic={cfg.sub_quadratic})")
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, fsdp=fsdp, seq_shard_acts=seq_shard_acts)

    with use_sharding(mesh, rules):
        pspecs = M.param_specs(cfg)
        params_abs = _sharded_abstract(pspecs, rules)

        if shape.kind == "train":
            opt = AdamW(state_dtype=cfg.state_dtype,
                        clip_norm=float(os.environ.get("DRYRUN_CLIP",
                                                       "1.0")))
            step = make_train_step(cfg, opt, impl=impl,
                                   accum_steps=accum_steps,
                                   compress_grads=compress_grads)
            opt_abs = opt.init_abstract(params_abs)
            # opt moments share the param shardings; step is replicated
            batch_abs = _batch_abstract(cfg, shape, rules)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            def prefill_fn(params, batch):
                return M.prefill(cfg, params, batch, impl=impl)
            batch_abs = _batch_abstract(cfg, shape, rules)
            lowered = jax.jit(prefill_fn).lower(params_abs, batch_abs)
        else:  # decode
            def serve_step(params, token, pos, cache):
                return M.decode_step(cfg, params, token, pos, cache)
            B = shape.global_batch
            tok = jax.ShapeDtypeStruct(
                (B,), jnp.int32,
                sharding=rules.named(rules.act_spec((B,), ("batch",))))
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            cache_specs = M.cache_specs(cfg, B, shape.seq_len)
            cache_abs = _sharded_abstract(cache_specs, rules)
            lowered = jax.jit(serve_step, donate_argnums=(3,)).lower(
                params_abs, tok, pos, cache_abs)

        if compile_it:
            compiled = (lowered.compile(compiler_options)
                        if compiler_options else lowered.compile())
        else:
            compiled = None
    return lowered, compiled, mesh, cfg, shape


def _probe_costs(arch, shape_name, *, multi_pod, impl, fsdp,
                 seq_shard_acts, accum_steps, compress_grads=False):
    """Differential depth probes (see launch/analytic.py): lower the cell
    at unit and 2x-unit depth with all scans unrolled, returning the
    extrapolated full-depth {flops, bytes, coll_*} dict.

    Probe lowerings use remat=False + backend opt level 0 (compile-time
    economy on the 1-core host); for remat'd train cells the flops are
    corrected by 4/3 (full recompute re-runs the forward: fwd+bwd = 3
    units -> remat adds 1).  xlstm prefill probes run at seq 4096 and
    scale linearly (attention-free family: every term is T-linear)."""
    import dataclasses as _dc

    from repro.launch import analytic
    from repro.models import unrollctl

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    cfg_u, cfg_2u, n_units, tail_units = analytic.probe_unit(cfg)
    cfg_u = _dc.replace(cfg_u, remat=False)
    cfg_2u = _dc.replace(cfg_2u, remat=False)

    shape_probe, seq_scale = shape, 1.0
    if cfg.xlstm is not None and shape.kind == "prefill" \
            and shape.seq_len > 4096:
        shape_probe = _dc.replace(shape, seq_len=4096)
        seq_scale = shape.seq_len / 4096.0

    def cost_of(c):
        with unrollctl.unrolled():
            _, compiled, _, _, _ = lower_cell(
                arch, shape_name, multi_pod=multi_pod, impl=impl,
                fsdp=fsdp, seq_shard_acts=seq_shard_acts,
                accum_steps=accum_steps, compress_grads=compress_grads,
                cfg_override=c, shape_override=shape_probe,
                compiler_options={"xla_backend_optimization_level": "0"})
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        colls = RL.parse_collectives(compiled.as_text())
        out = {"flops": float(ca.get("flops", 0.0)),
               "bytes": float(ca.get("bytes accessed", 0.0))}
        for kind, v in colls.items():
            out[f"coll_{kind}"] = v["wire_bytes"]
        out["coll_total"] = sum(v["wire_bytes"] for v in colls.values())
        return out

    ext = analytic.extrapolate(cost_of(cfg_u), cost_of(cfg_2u),
                               n_units, tail_units)
    if seq_scale != 1.0:
        ext = {k: v * seq_scale for k, v in ext.items()}
    if shape.kind == "train" and cfg.remat:
        ext["flops"] *= 4.0 / 3.0       # remat recompute correction
    ext["flops"] += analytic.slstm_correction_flops(cfg, shape)
    return ext


def run_cell(arch, shape_name, *, multi_pod=False, impl="flash",
             fsdp=True, seq_shard_acts=False, accum_steps=1,
             compress_grads=False, save=True, tag="", probe=True):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    lowered, compiled, mesh, cfg, shape = lower_cell(
        arch, shape_name, multi_pod=multi_pod, impl=impl, fsdp=fsdp,
        seq_shard_acts=seq_shard_acts, accum_steps=accum_steps,
        compress_grads=compress_grads)
    dt = time.time() - t0
    chips = int(np.prod(list(mesh.shape.values())))
    rl = RL.build(arch, shape, mesh_name, chips, compiled, cfg)
    rec = rl.to_dict()
    rec["raw_scan_counted"] = {          # undercounted (scan body once)
        "flops": rl.flops_per_device, "bytes": rl.bytes_per_device,
        "collective_bytes": rl.collective_bytes}

    if probe:
        # replace the scan-undercounted terms with depth-probe totals
        t1 = time.time()
        ext = _probe_costs(arch, shape_name, multi_pod=multi_pod,
                           impl=impl, fsdp=fsdp,
                           seq_shard_acts=seq_shard_acts,
                           accum_steps=accum_steps,
                           compress_grads=compress_grads)
        rl.flops_per_device = ext["flops"]
        rl.bytes_per_device = ext["bytes"]
        rl.collective_bytes = ext["coll_total"]
        rec.update(rl.to_dict())
        rec["probe"] = ext
        rec["probe_s"] = time.time() - t1

    rec["compile_s"] = dt
    rec["impl"] = impl
    rec["fsdp"] = fsdp
    rec["tag"] = tag
    ma = compiled.memory_analysis()
    rec["memory_analysis"] = {
        k: getattr(ma, k) for k in
        ("argument_size_in_bytes", "output_size_in_bytes",
         "temp_size_in_bytes", "alias_size_in_bytes")}
    if save:
        d = os.path.join(ART, mesh_name)
        os.makedirs(d, exist_ok=True)
        fn = f"{arch}__{shape_name}{('__' + tag) if tag else ''}.json"
        with open(os.path.join(d, fn), "w") as f:
            json.dump(rec, f, indent=1)
    print(f"[dryrun] {mesh_name} {arch:18s} {shape_name:12s} "
          f"compile={dt:6.1f}s flops/dev={rl.flops_per_device:.3e} "
          f"bytes/dev={rl.bytes_per_device:.3e} "
          f"coll/dev={rl.collective_bytes:.3e} dom={rl.dominant:10s} "
          f"args+tmp={(rl.arg_bytes + rl.temp_bytes)/1e9:7.2f}GB "
          f"mfu={rl.mfu:.3f}")
    return rec


# ---------------------------------------------------------------------------
# GEE (the paper's own workload) at Friendster scale
# ---------------------------------------------------------------------------


def run_gee(*, multi_pod=False, mode="ring", n=65_000_000,
            s=1_800_000_000, K=50, save=True):
    from repro.core.distributed import AXIS, gee_a2a_steady, gee_sharded
    mesh = make_gee_mesh(multi_pod=multi_pod)
    p = mesh.shape[AXIS]
    n_pad = ((n + p - 1) // p) * p
    s_pad = ((s + p - 1) // p) * p
    espec = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(AXIS))
    rspec = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    u = jax.ShapeDtypeStruct((s_pad,), jnp.int32, sharding=espec)
    w = jax.ShapeDtypeStruct((s_pad,), jnp.float32, sharding=espec)
    Y = jax.ShapeDtypeStruct((n_pad,), jnp.int32, sharding=rspec)

    t0 = time.time()
    if mode == "a2a_steady":
        # pre-bucketed steady-state (refinement-loop) step: buckets are
        # built once at ingestion; per-iteration program is just
        # gather -> all_to_all -> scatter (no sort).
        cap = int(np.ceil(2 * (s_pad // p) / p * 2.0)) + 8
        bi = jax.ShapeDtypeStruct((p * p, cap), jnp.int32, sharding=espec)
        bf = jax.ShapeDtypeStruct((p * p, cap), jnp.float32,
                                  sharding=espec)

        def fn(b_dst, b_src, b_w, Y):
            return gee_a2a_steady(b_dst, b_src, b_w, Y, K=K, n_pad=n_pad,
                                  mesh=mesh)

        lowered = jax.jit(fn).lower(bi, bi, bf, Y)
    else:
        def fn(u, v, w, Y):
            Z, dropped = gee_sharded(u, v, w, Y, K=K, n=n_pad, mesh=mesh,
                                     mode=mode)
            return Z, dropped

        lowered = jax.jit(fn).lower(u, u, w, Y)
    compiled = lowered.compile()
    dt = time.time() - t0

    class _Shape:
        name = f"gee_{mode}"
        kind = "gee"
        tokens = s
        global_batch = 1

    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    colls = RL.parse_collectives(compiled.as_text())
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    if mode == "ring":
        # the ppermute + accumulate live inside a fori_loop that XLA's
        # cost analysis counts once; the ring runs p-1 iterations.
        colls["collective-permute"]["wire_bytes"] *= (p - 1)
        rows = n_pad // p
        cap = int(np.ceil(2 * (s_pad // p) / p * 2.0)) + 8
        bytes_dev += (p - 2) * (2 * rows * K * 4 + cap * 12)
    wire = sum(c["wire_bytes"] for c in colls.values())
    ma = compiled.memory_analysis()
    mesh_name = ("pod2x16x16" if multi_pod else "pod16x16")
    rec = {
        "arch": "gee-friendster", "shape": f"gee_{mode}", "mesh": mesh_name,
        "chips": p, "compile_s": dt,
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_per_device": bytes_dev,
        "collective_bytes": wire, "collectives": colls,
        "compute_s": float(ca.get("flops", 0.0)) / RL.PEAK_FLOPS,
        "memory_s": bytes_dev / RL.HBM_BW,
        "collective_s": wire / RL.ICI_BW,
        "arg_bytes": ma.argument_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "model_edges": s,
    }
    rec["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                          key=lambda k: rec[k]).replace("_s", "")
    if save:
        d = os.path.join(ART, mesh_name)
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"gee__{mode}.json"), "w") as f:
            json.dump(rec, f, indent=1)
    print(f"[dryrun] {mesh_name} gee-friendster mode={mode:14s} "
          f"compile={dt:6.1f}s flops/dev={rec['flops_per_device']:.3e} "
          f"bytes/dev={rec['bytes_per_device']:.3e} "
          f"coll/dev={wire:.3e} dom={rec['dominant']} "
          f"args+tmp={(rec['arg_bytes'] + rec['temp_bytes'])/1e9:7.2f}GB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--gee", action="store_true")
    ap.add_argument("--gee-mode", default=None,
                    help="ring|a2a|reduce_scatter|replicated (default all)")
    ap.add_argument("--impl", default="flash",
                    choices=["flash", "triangular", "full"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--seq-shard-acts", action="store_true")
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    failures = []
    if args.gee:
        modes = [args.gee_mode] if args.gee_mode else \
            ["ring", "a2a", "reduce_scatter", "replicated"]
        for mode in modes:
            try:
                run_gee(multi_pod=args.multi_pod, mode=mode)
            except Exception as e:
                traceback.print_exc()
                failures.append(("gee", mode, repr(e)))
    elif args.all:
        for arch in list_archs():
            cfg = get_config(arch)
            for shape in cfg.shapes():
                try:
                    # probes (roofline terms) are a single-pod deliverable;
                    # the multi-pod pass proves the pod axis shards.
                    run_cell(arch, shape.name, multi_pod=args.multi_pod,
                             impl=args.impl, fsdp=not args.no_fsdp,
                             seq_shard_acts=args.seq_shard_acts,
                             accum_steps=args.accum_steps, tag=args.tag,
                             probe=not args.multi_pod)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape.name, repr(e)))
            for skipped in cfg.skipped_shapes():
                print(f"[dryrun] SKIP {arch} {skipped} "
                      f"(full attention; see DESIGN.md §Arch-applicability)")
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                 impl=args.impl, fsdp=not args.no_fsdp,
                 seq_shard_acts=args.seq_shard_acts,
                 accum_steps=args.accum_steps,
                 compress_grads=args.compress_grads, tag=args.tag)

    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("   ", f)
        sys.exit(1)
    print("[dryrun] OK")


if __name__ == "__main__":
    main()
