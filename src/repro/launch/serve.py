"""Batched serving driver: continuous-batching-style prefill + decode.

Requests arrive with different prompt lengths; the server left-pads to
the bucket size, prefills the whole batch once, then decodes greedily
token-by-token with the shared KV cache.  On TPU the decode step is the
donated-cache jitted function the dry-run analyzed (decode_32k cells);
here it runs reduced configs on CPU.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.sharding import make_rules, use_sharding


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    rules = make_rules(mesh)
    rng = np.random.default_rng(args.seed)
    B, S, G = args.batch, args.prompt_len, args.gen
    max_len = S + G

    prompts = rng.integers(0, cfg.vocab, size=(B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(rng.normal(
            0, 1, (B, cfg.n_frames, cfg.d_model)).astype(np.float32))

    with use_sharding(mesh, rules):
        params = M.init_params(cfg, jax.random.PRNGKey(args.seed))

        prefill_fn = jax.jit(
            lambda p, b: M.prefill(cfg, p, b, max_len=max_len))
        decode_fn = jax.jit(
            lambda p, t, pos, c: M.decode_step(cfg, p, t, pos, c),
            donate_argnums=(3,))

        t0 = time.time()
        logits, cache = prefill_fn(params, batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        toks = jnp.argmax(logits, -1)
        out = [np.asarray(toks)]
        t0 = time.time()
        for i in range(G - 1):
            logits, cache = decode_fn(params, toks, jnp.int32(S + i), cache)
            toks = jnp.argmax(logits, -1)
            out.append(np.asarray(toks))
        jax.block_until_ready(toks)
        t_decode = time.time() - t0

        gen = np.stack(out, 1)
        print(f"[serve] arch={cfg.name} batch={B} prompt={S} gen={G}")
        print(f"[serve] prefill {t_prefill*1e3:9.1f} ms "
              f"({B*S/max(t_prefill,1e-9):,.0f} tok/s)")
        print(f"[serve] decode  {t_decode*1e3:9.1f} ms "
              f"({B*(G-1)/max(t_decode,1e-9):,.0f} tok/s)")
        print(f"[serve] sample continuation[0]: {gen[0][:12].tolist()}")
        return gen


if __name__ == "__main__":
    main()
