"""Kernel-geometry autotuning against the roofline bandwidth model.

The GEE scatter and fused top-k kernels are memory-bound by design
(the paper's whole point: edge-parallel scatter at memory bandwidth),
so the right figure of merit for a geometry candidate is **achieved
HBM fraction**: bytes the kernel must move (from the traffic models
below) divided by measured wall time, over `roofline.HBM_BW`.

Search: greedy coordinate descent over the per-kernel geometry space —
sweep one knob at a time holding the others at the incumbent, repeat
until a full round improves nothing.  The spaces are tiny (a few
points per knob) so this converges in two or three rounds; it exists
so a new chip/topology retunes `TILE_N`/`EDGE_BLOCK`/`block_rows` with
one command instead of a hand sweep:

    PYTHONPATH=src python -m repro.launch.hillclimb gee-scatter-tune
    PYTHONPATH=src python -m repro.launch.hillclimb gee-topk-tune

On a CPU container the kernels run in interpret mode, so absolute
times (and hence achieved-bandwidth fractions) are interpreter
throughput, NOT kernel performance — the tuner prints the resolved
mode and `benchmarks.kernels_bench` carries the same warning.  The
machinery itself is platform-independent: on TPU the same commands
tune the compiled kernels.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import jax
import numpy as np

from repro.launch.roofline import HBM_BW

#: geometry spaces swept by the coordinate descent (ascending so the
#: sweep output reads as a size scan)
SCATTER_SPACE: Dict[str, Tuple[int, ...]] = {
    "tile_n": (64, 128, 256, 512),
    "edge_block": (128, 256, 512, 1024),
}
TOPK_SPACE: Dict[str, Tuple[int, ...]] = {
    "block_rows": (256, 1024, 4096, 16384),
}


def median_time(fn: Callable[[], object], *, warmup: int = 1,
                iters: int = 3) -> float:
    """Median wall seconds per call, async-dispatch aware."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def scatter_traffic_bytes(T: int, bpt: int, eb: int, tile_n: int,
                          kdim: int) -> int:
    """HBM bytes one scatter pass must move: the three packed edge
    arrays (int32 rows/cls + f32 val) stream through once, and each Z
    tile — VMEM-resident across its inner grid dimension — is written
    once.  A lower bound (ignores the on-device class/value resolve
    reads), which is what an achieved-fraction denominator wants."""
    return 3 * T * bpt * eb * 4 + T * tile_n * kdim * 4


def topk_traffic_bytes(m: int, K: int, nq: int, k: int,
                       bucket: int) -> int:
    """HBM bytes one fused top-k scan must move: the candidate slice
    streams once, the query block re-reads per candidate block (it is
    small but revisited), and the (vals, idxs) running block writes
    once."""
    nb = max(1, -(-max(m, 1) // bucket))
    return m * K * 4 + nb * nq * K * 4 + nq * k * 8


def _coordinate_descent(space: Dict[str, Tuple[int, ...]],
                        measure: Callable[[dict], float],
                        start: dict, *, log: Callable = print) -> dict:
    """Greedy per-knob sweep to a local optimum of `measure` (seconds,
    lower is better).  Returns {'best': cfg, 'seconds': t, 'trace':
    [(cfg, t), ...]} with every point measured."""
    best = dict(start)
    trace = []
    best_t = measure(best)
    trace.append((dict(best), best_t))
    improved = True
    while improved:
        improved = False
        for knob, points in space.items():
            for p in points:
                if p == best[knob]:
                    continue
                cand = {**best, knob: p}
                t = measure(cand)
                trace.append((dict(cand), t))
                if t < best_t:
                    best, best_t = cand, t
                    improved = True
            log(f"  {knob}: best so far {best} -> {best_t * 1e3:.2f} ms")
    return {"best": best, "seconds": best_t, "trace": trace}


def tune_scatter(n: int = 20_000, s: int = 200_000, K: int = 16, *,
                 space: Dict[str, Tuple[int, ...]] = None,
                 iters: int = 2, log: Callable = print) -> dict:
    """Tune (tile_n, edge_block) for the GEE scatter kernel on an
    Erdos-Renyi workload of (n, s); refits time the kernel alone (the
    plan's destination packing is cached per geometry)."""
    from repro.encoder import Embedder, EncoderConfig
    from repro.graph.edges import make_labels
    from repro.graph.generators import erdos_renyi
    from repro.kernels.gee_scatter import (interpret_mode_name,
                                           resolve_interpret)
    space = dict(SCATTER_SPACE if space is None else space)
    g = erdos_renyi(n, s, seed=0)
    Y = make_labels(g.n, K, 0.2, np.random.default_rng(0))
    mode = interpret_mode_name(resolve_interpret("auto"))
    log(f"scatter tune: n={n} s={s} K={K} mode={mode}")

    embs: dict = {}

    def measure(cfg: dict) -> float:
        key = (cfg["tile_n"], cfg["edge_block"])
        if key not in embs:
            embs[key] = Embedder(
                EncoderConfig(K=K, tile_n=cfg["tile_n"],
                              edge_block=cfg["edge_block"]),
                backend="pallas", plan_cache=None).fit(g, Y)
        e = embs[key]
        return median_time(lambda: e.refit(Y).Z_, iters=iters)

    out = _coordinate_descent(space, measure, {
        "tile_n": space["tile_n"][0], "edge_block": space["edge_block"][0],
    }, log=log)
    best = out["best"]
    e = embs[(best["tile_n"], best["edge_block"])]
    d = e._plan.data
    moved = scatter_traffic_bytes(d["T"], d["rows"].shape[1],
                                  d["rows"].shape[2], best["tile_n"],
                                  d["kdim"])
    out.update(_bandwidth(moved, out["seconds"], mode, log=log))
    return out


def tune_topk(m: int = 50_000, K: int = 16, nq: int = 64,
              k: int = 10, *,
              space: Dict[str, Tuple[int, ...]] = None,
              iters: int = 2, log: Callable = print) -> dict:
    """Tune block_rows for the fused normalize+cosine+top-k kernel over
    an (m, K) candidate slice."""
    import jax.numpy as jnp
    from repro.kernels.gee_scatter import (interpret_mode_name,
                                           resolve_interpret)
    from repro.serving import queries as Q
    space = dict(TOPK_SPACE if space is None else space)
    rng = np.random.default_rng(0)
    Z = jnp.asarray(rng.normal(size=(m, K)).astype(np.float32))
    Zn = Q.normalize_rows(Z)
    qnodes = rng.integers(0, m, nq).astype(np.int32)
    q = Zn[jnp.asarray(qnodes)]
    mode = interpret_mode_name(resolve_interpret("auto"))
    log(f"topk tune: m={m} K={K} nq={nq} k={k} mode={mode}")

    def measure(cfg: dict) -> float:
        return median_time(
            lambda: Q.topk_cosine_fused(Zn, q, qnodes, k=k,
                                        block_rows=cfg["block_rows"]),
            iters=iters)

    out = _coordinate_descent(space, measure,
                              {"block_rows": space["block_rows"][0]},
                              log=log)
    bucket = Q._bucket_rows(m, out["best"]["block_rows"])
    moved = topk_traffic_bytes(m, K, nq, k, bucket)
    out.update(_bandwidth(moved, out["seconds"], mode, log=log))
    return out


def _bandwidth(moved_bytes: int, seconds: float, mode: str, *,
               log: Callable = print) -> dict:
    gbps = moved_bytes / seconds / 1e9 if seconds > 0 else 0.0
    frac = gbps * 1e9 / HBM_BW
    log(f"  traffic {moved_bytes / 1e6:.1f} MB, achieved "
        f"{gbps:.2f} GB/s = {frac * 100:.2f}% of roofline HBM "
        f"({HBM_BW / 1e9:.0f} GB/s) [{mode} mode]")
    return {"moved_bytes": moved_bytes, "achieved_gbps": gbps,
            "roofline_frac": frac, "mode": mode}
