"""End-to-end training driver.

Runs any registry arch (or its reduced config on CPU) with the full
production loop: sharded step, grad-accum, async atomic checkpoints,
resume-from-LATEST, heartbeats, straggler monitoring, optional GEE
embedding init and int8 gradient compression.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.sharding import make_rules, use_sharding
from repro.training import checkpoint as CK
from repro.training.fault_tolerance import Heartbeat, StragglerMonitor
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train_loop import make_train_step


def build_batch_fn(cfg, args):
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed)
    src = SyntheticTokens(data_cfg)

    def get(step):
        b = {"tokens": jnp.asarray(src.batch(step))}
        if cfg.is_encdec:
            rng = np.random.default_rng(step)
            b["frames"] = jnp.asarray(rng.normal(
                0, 1, (args.batch, cfg.n_frames, cfg.d_model)
            ).astype(np.float32))
        return b
    return get


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="family-preserving small config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--gee-embed-init", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, remat=False) if args.reduced else cfg

    mesh = make_host_mesh()
    rules = make_rules(mesh)
    opt = AdamW(lr=args.lr, state_dtype=cfg.state_dtype,
                schedule=cosine_schedule(warmup=20, total=args.steps))
    get_batch = build_batch_fn(cfg, args)

    with use_sharding(mesh, rules):
        step_fn = jax.jit(make_train_step(
            cfg, opt, accum_steps=args.accum_steps,
            compress_grads=args.compress_grads), donate_argnums=(0, 1))

        params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
        if args.gee_embed_init:
            from repro.encoder.bridge import gee_embedding_init
            stream = np.concatenate(
                [np.asarray(get_batch(s)["tokens"]).reshape(-1)
                 for s in range(4)])
            table = gee_embedding_init(stream, cfg.padded_vocab,
                                       cfg.d_model)
            params["embed"]["tokens"] = jnp.asarray(
                table, params["embed"]["tokens"].dtype)
            print("[train] GEE co-occurrence embedding init applied")
        opt_state = opt.init(params)

        start = 0
        ck = None
        if args.ckpt_dir:
            ck = CK.AsyncCheckpointer(args.ckpt_dir)
            if CK.latest_step(args.ckpt_dir) is not None:
                (params, opt_state), start = CK.restore_checkpoint(
                    args.ckpt_dir, (params, opt_state))
                print(f"[train] resumed from step {start}")
            hb = Heartbeat(args.ckpt_dir)
        mon = StragglerMonitor()

        losses = []
        for step in range(start, args.steps):
            t0 = time.time()
            batch = get_batch(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            mon.record(step, dt)
            losses.append(loss)
            if args.ckpt_dir:
                hb.beat(step)
                if (step + 1) % args.ckpt_every == 0:
                    ck.save(step + 1, (params, opt_state))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):8.3f} "
                      f"dt {dt*1e3:7.1f}ms")

        if ck:
            ck.save(args.steps, (params, opt_state))
            ck.close()
        if mon.straggler_steps:
            print(f"[train] stragglers: {mon.straggler_steps}")
        print(f"[train] first loss {losses[0]:.4f} last {losses[-1]:.4f}")
        return losses


if __name__ == "__main__":
    main()
