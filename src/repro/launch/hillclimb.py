import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
"""§Perf hillclimbing driver: run tagged optimization variants of the
three chosen cells and print before/after roofline terms.

The three pairs (selection rationale in EXPERIMENTS.md §Perf):
  1. qwen1.5-110b x train_4k   — worst memory blow-up (biggest dense)
  2. grok-1-314b  x train_4k   — most collective-bound
  3. gee-friendster (ring)     — the paper's own workload

Each variant re-lowers the cell with one change and records the probe
terms under a tag; compare with
    PYTHONPATH=src python -m repro.launch.hillclimb --list
"""
import argparse

from repro.launch.dryrun import run_cell, run_gee

VARIANTS = {
    # --- qwen110 train: memory term ------------------------------------
    "qwen110-base": dict(kind="cell", arch="qwen1.5-110b",
                         shape="train_4k", kw={}),
    "qwen110-tri": dict(kind="cell", arch="qwen1.5-110b", shape="train_4k",
                        kw=dict(impl="triangular", tag="tri")),
    "qwen110-accum8": dict(kind="cell", arch="qwen1.5-110b",
                           shape="train_4k",
                           kw=dict(accum_steps=8, tag="accum8")),
    "qwen110-seqshard": dict(kind="cell", arch="qwen1.5-110b",
                             shape="train_4k",
                             kw=dict(seq_shard_acts=True, tag="seqshard")),
    "qwen110-tri-accum8": dict(kind="cell", arch="qwen1.5-110b",
                               shape="train_4k",
                               kw=dict(impl="triangular", accum_steps=8,
                                       tag="tri-accum8")),
    "qwen110-accum8-seqshard": dict(
        kind="cell", arch="qwen1.5-110b", shape="train_4k",
        kw=dict(accum_steps=8, seq_shard_acts=True,
                tag="accum8-seqshard")),
    "qwen110-accum16-seqshard": dict(
        kind="cell", arch="qwen1.5-110b", shape="train_4k",
        kw=dict(accum_steps=16, seq_shard_acts=True,
                tag="accum16-seqshard")),
    # prefill cell where attention flops dominate: triangular matters
    "qwen110-prefill-base": dict(kind="cell", arch="qwen1.5-110b",
                                 shape="prefill_32k", kw={}),
    "qwen110-prefill-tri": dict(kind="cell", arch="qwen1.5-110b",
                                shape="prefill_32k",
                                kw=dict(impl="triangular", tag="tri")),
    "grok-seqshard": dict(kind="cell", arch="grok-1-314b",
                          shape="train_4k",
                          kw=dict(seq_shard_acts=True, tag="seqshard")),
    # --- grok train: collective term ------------------------------------
    "grok-base": dict(kind="cell", arch="grok-1-314b", shape="train_4k",
                      kw={}),
    "grok-tri": dict(kind="cell", arch="grok-1-314b", shape="train_4k",
                     kw=dict(impl="triangular", tag="tri")),
    "grok-nofsdp": dict(kind="cell", arch="grok-1-314b", shape="train_4k",
                        kw=dict(fsdp=False, tag="nofsdp")),
    "grok-accum8": dict(kind="cell", arch="grok-1-314b", shape="train_4k",
                        kw=dict(accum_steps=8, tag="accum8")),
    "grok-int8": dict(kind="cell", arch="grok-1-314b", shape="train_4k",
                      kw=dict(compress_grads=True, tag="int8")),
    "grok-tri-accum8": dict(kind="cell", arch="grok-1-314b",
                            shape="train_4k",
                            kw=dict(impl="triangular", accum_steps=8,
                                    tag="tri-accum8")),
    # --- GEE friendster: the paper's workload ---------------------------
    "gee-ring": dict(kind="gee", mode="ring"),
    "gee-a2a": dict(kind="gee", mode="a2a"),
    "gee-rs": dict(kind="gee", mode="reduce_scatter"),
    "gee-repl": dict(kind="gee", mode="replicated"),
    # --- kernel-geometry autotune (repro.launch.autotune): coordinate
    # descent over TILE_N/EDGE_BLOCK (scatter) and block_rows (fused
    # top-k), reporting achieved-vs-roofline HBM bandwidth.  Run with
    # XLA_FLAGS=--xla_force_host_platform_device_count=1 on CPU (this
    # module's 512-device default exists for the SPMD dry runs and
    # only slows single-kernel timing).
    "gee-scatter-tune": dict(kind="kernel", fn="scatter"),
    "gee-topk-tune": dict(kind="kernel", fn="topk"),
}

#: --quick workload shrink for the kernel tuners (bench-smoke lane:
#: exercise the whole descent + bandwidth report in seconds)
_KERNEL_QUICK = {
    "scatter": dict(n=1_000, s=8_000, K=8,
                    space={"tile_n": (64, 128),
                           "edge_block": (128, 256)}, iters=1),
    "topk": dict(m=2_000, K=8, nq=16, k=5,
                 space={"block_rows": (256, 1024)}, iters=1),
}


def _run_kernel_tune(fn: str, quick: bool) -> None:
    from repro.launch.autotune import tune_scatter, tune_topk
    tuner = {"scatter": tune_scatter, "topk": tune_topk}[fn]
    kw = _KERNEL_QUICK[fn] if quick else {}
    out = tuner(**kw)
    print(f"best[{fn}]: {out['best']}  {out['seconds'] * 1e3:.2f} ms  "
          f"{out['achieved_gbps']:.2f} GB/s "
          f"({out['roofline_frac'] * 100:.2f}% roofline, "
          f"{out['mode']} mode)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("variant", nargs="*", help=list(VARIANTS))
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="tiny kernel-tune workloads (bench-smoke lane)")
    args = ap.parse_args()
    if args.list or not args.variant:
        for k in VARIANTS:
            print(k)
        return
    for name in args.variant:
        v = VARIANTS[name]
        if v["kind"] == "gee":
            run_gee(mode=v["mode"])
        elif v["kind"] == "kernel":
            _run_kernel_tune(v["fn"], args.quick)
        else:
            run_cell(v["arch"], v["shape"], **v["kw"])


if __name__ == "__main__":
    main()
