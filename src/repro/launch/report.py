"""Render EXPERIMENTS.md tables from artifacts/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report [--section dryrun|roofline]

Prints markdown to stdout; the EXPERIMENTS.md author splices it in.
"""
from __future__ import annotations

import argparse
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "dryrun")


def _load(mesh: str):
    d = os.path.join(ART, mesh)
    if not os.path.isdir(d):
        return {}
    out = {}
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".json"):
            out[fn[:-5]] = json.load(open(os.path.join(d, fn)))
    return out


def _fmt(x, unit=""):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    for div, suf in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "K")):
        if abs(x) >= div:
            return f"{x / div:.2f}{suf}{unit}"
    return f"{x:.3g}{unit}"


def dryrun_table(mesh: str) -> str:
    recs = _load(mesh)
    lines = [
        f"### {mesh}",
        "",
        "| arch | shape | compile s | bytes/dev (arg+tmp) | "
        "collectives (AG/AR/RS/A2A/CP counts) | fits 16GB |",
        "|---|---|---|---|---|---|",
    ]
    for r in recs.values():
        if r.get("tag"):
            continue              # hillclimb variants live in §Perf
        if "memory_analysis" not in r:
            ma = {"argument_size_in_bytes": r.get("arg_bytes", 0),
                  "temp_size_in_bytes": r.get("temp_bytes", 0)}
        else:
            ma = r["memory_analysis"]
        tot = (ma.get("argument_size_in_bytes", 0)
               + ma.get("temp_size_in_bytes", 0))
        c = r.get("collectives", {})

        def cnt(k, c=c):
            return c.get(k, {}).get("count", 0)

        cs = (f"{cnt('all-gather')}/{cnt('all-reduce')}/"
              f"{cnt('reduce-scatter')}/{cnt('all-to-all')}/"
              f"{cnt('collective-permute')}")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('compile_s', 0):.1f} "
            f"| {tot/1e9:.2f} GB | {cs} "
            f"| {'Y' if tot <= 16e9 else 'N'} |")
    return "\n".join(lines)


def roofline_table(mesh: str = "pod16x16") -> str:
    recs = _load(mesh)
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| MODEL_FLOPS | useful ratio | MFU |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs.values():
        if "compute_s" not in r or r.get("tag"):
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {_fmt(r.get('model_flops_global'))} "
            f"| {r.get('useful_flops_ratio', 0):.2f} "
            f"| {r.get('mfu', 0):.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    args = ap.parse_args()
    if args.section in ("dryrun", "all"):
        print("## §Dry-run tables\n")
        for mesh in ("pod16x16", "pod2x16x16"):
            print(dryrun_table(mesh))
            print()
    if args.section in ("roofline", "all"):
        print("## §Roofline table (single-pod)\n")
        print(roofline_table())


if __name__ == "__main__":
    main()
