"""Differential depth-probing: exact per-layer costs from compiled HLO.

Problem: XLA's ``cost_analysis()`` counts a ``while``-loop body ONCE —
under our scan-over-layers lowering, flops/bytes/collective counts are
under-reported by ~the trip count (verified in this repo: a scanned
8-step matmul reports 1/8 of the unrolled flops).

Fix: for every cell we additionally lower the SAME model at depth u and
2u, where u is the family's repeating pattern unit (1 layer for uniform
stacks, one 8-block group for xlstm, one 6-mamba+shared-attn group for
zamba2, one enc+dec layer pair for whisper).  Then

    per_unit = cost(2u) - cost(u)          # exact: scan bodies unrolled
    const    = cost(u) - per_unit          # embed/unembed/loss/opt edges
    total    = const + per_unit * n_units  (+ tail correction)

applies to flops, bytes-accessed and per-kind collective wire bytes
alike.  Memory analysis always comes from the FULL-depth compile (buffer
assignment is whole-program and correct).

Probes lower at depth u <= 2 units, so the scan trip count is 1-2 and
the body is fully visible to cost analysis: at depth u the scan is
unrolled by XLA (trip count 1) or counted once=trip count. To be safe,
probes monkey-patch the config with scan_layers=False (python-loop
lowering), making the HLO literally contain every op.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.configs.base import ModelConfig


def probe_unit(cfg: ModelConfig) -> Tuple[ModelConfig, ModelConfig, float,
                                          float]:
    """Returns (cfg_u, cfg_2u, n_units, tail_units).

    total_cost = const + per_unit * (n_units + tail_units)."""
    if cfg.is_encdec:
        # unit = 1 encoder layer + 1 decoder layer
        u = dataclasses.replace(cfg, n_layers=1, enc_layers=1, dec_layers=1)
        u2 = dataclasses.replace(cfg, n_layers=2, enc_layers=2, dec_layers=2)
        return u, u2, float(cfg.enc_layers), 0.0
    if cfg.xlstm is not None:
        every = cfg.xlstm.slstm_every
        u = dataclasses.replace(cfg, n_layers=every)
        u2 = dataclasses.replace(cfg, n_layers=2 * every)
        return u, u2, float(cfg.n_layers // every), 0.0
    if cfg.ssm is not None and cfg.attn_every:
        per = cfg.attn_every
        g = cfg.n_layers // per
        tail = cfg.n_layers - g * per
        u = dataclasses.replace(cfg, n_layers=per)
        u2 = dataclasses.replace(cfg, n_layers=2 * per)
        # tail mamba layers cost ~ (1/(per+1)) of a group each
        return u, u2, float(g), tail / (per + 1.0)
    u = dataclasses.replace(cfg, n_layers=1)
    u2 = dataclasses.replace(cfg, n_layers=2)
    return u, u2, float(cfg.n_layers), 0.0


def extrapolate(cost_u: dict, cost_2u: dict, n_units: float,
                tail_units: float) -> dict:
    """Per-key linear extrapolation of probe costs to full depth."""
    out = {}
    mult = n_units + tail_units
    for k in cost_u:
        per = cost_2u.get(k, 0.0) - cost_u.get(k, 0.0)
        per = max(per, 0.0)
        const = max(cost_u.get(k, 0.0) - per, 0.0)
        out[k] = const + per * mult
    return out


def slstm_correction_flops(cfg: ModelConfig, shape) -> float:
    """sLSTM's recurrent (h_{t-1} @ R) matmul lives inside a T-step scan
    that probes cannot unroll (T up to 524288); its flops are exactly
    known and added analytically.  Per token per sLSTM layer:
    2 * H * Dh * 4Dh, x3 for train (fwd+bwd) x n_slstm_layers."""
    if cfg.xlstm is None:
        return 0.0
    from repro.models.xlstm import slstm_dims
    H, Dh = slstm_dims(cfg)
    n_slstm = cfg.n_layers // cfg.xlstm.slstm_every
    per_tok = 2.0 * H * Dh * 4 * Dh
    if shape.kind == "train":
        tokens, mult = shape.tokens, 3.0     # fwd + 2x bwd
    elif shape.kind == "prefill":
        tokens, mult = shape.tokens, 1.0
    else:
        tokens, mult = shape.global_batch, 1.0
    return per_tok * tokens * mult * n_slstm
