"""Roofline-term derivation from compiled dry-run artifacts.

Conventions (documented once, used everywhere):
  * ``cost_analysis()`` on an SPMD executable reports PER-DEVICE flops
    and bytes (verified empirically in this repo), so
        compute_term_s = flops / PEAK_FLOPS
        memory_term_s  = bytes / HBM_BW
    need no further division by chip count.
  * collective bytes are parsed from the compiled HLO: for every
    all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute op we take the RESULT shape's bytes (the
    per-device view).  All-reduce is weighted 2x (ring send+recv);
    others 1x.  This is a structural lower bound — it ignores the
    (P-1)/P factors and latency terms, which is fine for a
    dominant-term comparison.
  * MODEL_FLOPS = 6*N*D for training (fwd+bwd), 2*N*D for fwd-only
    (prefill/decode), with D = global tokens in the step and N the
    (active) parameter count.  The ratio MODEL_FLOPS / (flops * chips)
    measures how much compiled compute is "useful".

Hardware model (TPU v5e, per the brief):
  197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
# matches e.g. "%all-reduce.5 = f32[16,128]{1,0} all-reduce("
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_WIRE_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def shape_bytes(shape_str: str) -> int:
    """'f32[16,128]{1,0}' or '(f32[2], bf16[4,4])' -> total bytes."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def parse_collectives(hlo_text: str) -> Dict[str, dict]:
    """Per collective kind: {'count', 'bytes', 'wire_bytes'} (per-device).

    reduce-scatter's RESULT is the scattered shard (input/P), so its wire
    cost is result_bytes x (group_size - 1) — the group size is parsed
    from the op's replica_groups attribute (iota form [G,N]<=[...])."""
    out = {k: {"count": 0, "bytes": 0, "wire_bytes": 0.0}
           for k in _COLL_KINDS}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = shape_bytes(shape_str)
        w = b * _WIRE_WEIGHT[kind]
        if kind == "reduce-scatter":
            g = _GROUP_RE.search(line)
            if g:
                gsize = int(g.group(2))
            else:
                gl = _GROUP_LIST_RE.search(line)
                gsize = len(gl.group(1).split(",")) if gl else 2
            w = b * max(gsize - 1, 1)
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
        out[kind]["wire_bytes"] += w
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float          # per-device wire bytes
    collectives: dict
    model_flops_global: float
    arg_bytes: int = 0
    temp_bytes: int = 0
    out_bytes: int = 0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Optimistic (perfect-overlap) step time = max of terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        tot = self.flops_per_device * self.chips
        return self.model_flops_global / tot if tot else 0.0

    @property
    def mfu(self) -> float:
        """MODEL_FLOPS / (step_s * chips * peak) — roofline fraction."""
        denom = self.step_s * self.chips * PEAK_FLOPS
        return self.model_flops_global / denom if denom else 0.0

    @property
    def hbm_fit(self) -> bool:
        return (self.arg_bytes + self.temp_bytes) <= 16e9

    def to_dict(self) -> dict:
        d = {k: getattr(self, k) for k in (
            "arch", "shape", "mesh", "chips", "flops_per_device",
            "bytes_per_device", "collective_bytes", "model_flops_global",
            "arg_bytes", "temp_bytes", "out_bytes")}
        d["collectives"] = self.collectives
        for k in ("compute_s", "memory_s", "collective_s", "dominant",
                  "step_s", "useful_flops_ratio", "mfu", "hbm_fit"):
            d[k] = getattr(self, k)
        return d


def model_flops(cfg, shape) -> float:
    """6*N*D train / 2*N*D fwd-only, N = active params."""
    from repro.models.model import count_params_analytic
    n = count_params_analytic(cfg, active_only=True)
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch        # decode: one token per seq


def build(arch: str, shape, mesh_name: str, chips: int, compiled,
          cfg=None) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    colls = parse_collectives(compiled.as_text())
    wire = sum(c["wire_bytes"] for c in colls.values())
    ma = compiled.memory_analysis()
    mf = model_flops(cfg, shape) if cfg is not None else 0.0
    return Roofline(
        arch=arch, shape=shape.name if hasattr(shape, "name") else shape,
        mesh=mesh_name, chips=chips,
        flops_per_device=float(ca.get("flops", 0.0)),
        bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        collective_bytes=wire, collectives=colls,
        model_flops_global=mf,
        arg_bytes=getattr(ma, "argument_size_in_bytes", 0),
        temp_bytes=getattr(ma, "temp_size_in_bytes", 0),
        out_bytes=getattr(ma, "output_size_in_bytes", 0))
