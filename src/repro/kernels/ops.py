"""Jitted wrappers around the Pallas kernels.

``gee_pallas`` packs edges into destination-sorted uniform blocks
(host-side, static shapes) and dispatches the gee_scatter kernel; it is
the TPU hot path behind ``repro.core.gee`` when running on real
hardware.  On this CPU container the kernels execute in interpret mode
(Python evaluation of the kernel body) — correctness-equivalent,
performance-irrelevant.
"""
from __future__ import annotations

from typing import Union

import jax.numpy as jnp
import numpy as np

from repro.core.gee import edge_contributions, make_w
from repro.kernels.gee_scatter import (EDGE_BLOCK, TILE_N,
                                       gee_scatter_pallas)
from repro.kernels import flash_attention as fa


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pack_edges(dst, cls, val, n: int, tile_n: int = TILE_N,
               edge_block: int = EDGE_BLOCK):
    """Sort contributions by destination tile and pack into uniform
    (T, BPT, EB) blocks.  Host-side numpy (static output shapes depend on
    the max bucket size).  Padded slots: val = 0."""
    dst = np.asarray(dst)
    cls = np.asarray(cls)
    val = np.asarray(val)
    T = _round_up(n, tile_n) // tile_n
    tile = dst // tile_n
    order = np.argsort(tile, kind="stable")
    tile_s, dst_s, cls_s, val_s = tile[order], dst[order], cls[order], \
        val[order]
    counts = np.bincount(tile_s, minlength=T)
    bpt = max(1, int(np.ceil(counts.max() / edge_block)))
    slots = T * bpt * edge_block
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(dst_s.shape[0]) - starts[tile_s]
    slot = tile_s * (bpt * edge_block) + pos

    rows_buf = np.zeros(slots, np.int32)
    cls_buf = np.zeros(slots, np.int32)
    val_buf = np.zeros(slots, np.float32)
    rows_buf[slot] = dst_s - tile_s * tile_n
    cls_buf[slot] = cls_s
    val_buf[slot] = val_s
    shape = (T, bpt, edge_block)
    return (rows_buf.reshape(shape), cls_buf.reshape(shape),
            val_buf.reshape(shape), T)


def gee_pallas(u, v, w, Y, *, K: int, n: int, tile_n: int = TILE_N,
               edge_block: int = EDGE_BLOCK,
               interpret: Union[bool, str] = "auto",
               pad_k: int = 8) -> jnp.ndarray:
    """GEE via the Pallas scatter kernel. Returns Z (n, K) float32."""
    Wv = make_w(jnp.asarray(Y), K)
    dst, cls, val = edge_contributions(
        jnp.asarray(u), jnp.asarray(v), jnp.asarray(w, jnp.float32),
        jnp.asarray(Y), Wv)
    kdim = _round_up(K, pad_k)
    rows, clsb, valb, T = pack_edges(dst, cls, val, n, tile_n, edge_block)
    Z = gee_scatter_pallas(jnp.asarray(rows), jnp.asarray(clsb),
                           jnp.asarray(valb), num_tiles=T, tile_n=tile_n,
                           kdim=kdim, interpret=interpret)
    return Z[:n, :K]


def flash_attention(q, k, v, *, bq: int = fa.DEFAULT_BQ,
                    bk: int = fa.DEFAULT_BK, interpret: bool = True):
    return fa.flash_attention(q, k, v, bq=bq, bk=bk, interpret=interpret)
