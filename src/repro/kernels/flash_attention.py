"""Causal flash-attention forward Pallas kernel (GQA-aware).

Online-softmax over KV blocks with the Q tile, running max/denominator
and output accumulator resident in VMEM scratch; out-of-band (fully
masked) KV blocks are skipped with pl.when, so the kernel does the
triangular FLOP count, not the rectangular one.

Layout: q (B, H, S, D), k/v (B, KV, S, D), KV | H.  Grid =
(B*H, S/BQ, S/BK) with the KV dimension innermost (revisiting scratch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

DEFAULT_BQ = 256
DEFAULT_BK = 256
_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, scale: float, n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal block skip: KV block strictly above the diagonal has no
    # unmasked entry.
    @pl.when(ki * bk <= qi * bq + (bq - 1))
    def _compute():
        q = q_ref[0].astype(jnp.float32)                       # (BQ, D)
        k = k_ref[0].astype(jnp.float32)                       # (BK, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(k_pos <= q_pos, s, _NEG)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = True):
    """Causal self-attention. q: (B,H,S,D); k,v: (B,KV,S,D). Returns
    (B,H,S,D)."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    n_q, n_k = S // bq, S // bk
    scale = D ** -0.5

    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * KV, S, D)
    vf = v.reshape(B * KV, S, D)

    def kv_index(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * KV + h // G, ki, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, scale=scale, n_k=n_k),
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            _VMEM((bq,), jnp.float32),
            _VMEM((bq,), jnp.float32),
            _VMEM((bq, D), jnp.float32),
        ] if _VMEM is not None else [],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)
