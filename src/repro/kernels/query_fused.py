"""Fused query-side Pallas kernels for the serving read/write hot path.

Two kernels, both blocked over the shard's owned slice:

``topk_fused``
    normalize + cosine score + running top-k merge in ONE pallas_call,
    replacing the separate ``normalize_rows`` pass and per-block jitted
    ``_topk_block`` calls of `repro.serving.queries`.  The grid walks
    candidate blocks in ascending-global-id order while the running
    (vals, idxs) top-k stays resident in the revisited output block, so
    the whole scan is a single dispatch with no intermediate Zn
    materialization (``normalize=True`` additionally emits the
    normalized slice so a caller can populate its Zn cache from the
    same pass).

``gee_delta_renorm``
    delta-apply + renormalize in ONE pallas_call for the
    partial_fit-then-query serving turnaround: the grid is the same
    destination-tiled (T, BPT) layout as `gee_scatter`, each Z tile is
    loaded once, accumulated over its packed contribution blocks, and
    row-normalized on the final visit — Z never makes a second
    HBM round trip between the write and the read path.

**Bit-equality contract.**  ``topk_fused`` reproduces the
`repro.serving.queries` blocked scan bit-exactly (tested with
``np.array_equal``, not allclose): per-block math is the identical
``q @ block.T`` / mask / concat-running-BEFORE-block / ``lax.top_k``
sequence, blocks are presented in ascending-global-id order, and
normalization reduces over exactly K columns (the candidate block's
second dim is K, never the lane-padded kdim), so score ties resolve to
the ascending global id exactly as the unfused path does.

``lax.top_k`` inside the kernel body relies on the interpreter (CPU)
or Mosaic's top_k support (TPU); ``interpret="auto"`` resolves per
platform like every other kernel here.
"""
from __future__ import annotations

import functools
from typing import Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gee_scatter import resolve_interpret

EPS = 1e-9      # normalize_rows' clamp — must match queries.normalize_rows


def _normalize(z, eps):
    return z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), eps)


def _topk_kernel(z_ref, q_ref, qn_ref, vals_ref, idxs_ref, *rest,
                 bucket: int, k: int, m: int, row_offset: int,
                 exclude_self: bool, normalize: bool, eps: float):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        vals_ref[...] = jnp.full(vals_ref.shape, -jnp.inf, jnp.float32)
        idxs_ref[...] = jnp.full(idxs_ref.shape, -1, jnp.int32)

    z = z_ref[...]                                        # (bucket, K)
    if normalize:
        z = _normalize(z, eps)
        rest[0][...] = z                                  # zn_ref
    q = q_ref[...]                                        # (nq, K)
    qnodes = qn_ref[:, 0]                                 # (nq,)
    local = b * bucket + jax.lax.broadcasted_iota(jnp.int32, (bucket,), 0)
    gidx = jnp.where(local < m, row_offset + local, -1)   # -1: padding
    scores = q @ z.T                                      # (nq, bucket)
    mask = gidx[None, :] < 0
    if exclude_self:
        mask = mask | (gidx[None, :] == qnodes[:, None])
    scores = jnp.where(mask, -jnp.inf, scores)
    # running candidates BEFORE the block: ties resolve to the lower
    # (earlier, ascending) global id via lax.top_k's position rule
    cat_v = jnp.concatenate([vals_ref[...], scores], 1)
    cat_i = jnp.concatenate(
        [idxs_ref[...], jnp.broadcast_to(gidx, scores.shape)], 1)
    v, sel = jax.lax.top_k(cat_v, k)
    vals_ref[...] = v
    idxs_ref[...] = jnp.take_along_axis(cat_i, sel, 1)


def topk_fused(Z_rows, q, qnodes, *, k: int, bucket: int,
               row_offset: int = 0, exclude_self: bool = True,
               normalize: bool = False, eps: float = EPS,
               interpret: Union[bool, str] = "auto"):
    """Blocked normalize+cosine+top-k in one pallas_call.

    Z_rows (m, K): candidate rows at global ids [row_offset,
    row_offset + m) — RAW when normalize=True, unit-norm otherwise.
    q (nq, K) unit-norm queries; qnodes (nq,) global ids for
    self-exclusion.  `bucket` is the static block size (the caller owns
    the blocking policy — `queries._topk_blocked`'s bucket rule).

    Returns (vals (nq, k) f32, idxs (nq, k) i32) device arrays — plus
    Zn (m, K) when normalize=True.  Unfilled slots are NOT clamped here
    (the queries-layer wrapper applies the shared isfinite -> -1 pass).
    """
    interpret = resolve_interpret(interpret)
    m, K = Z_rows.shape
    nq = q.shape[0]
    mp = max(((max(m, 1) + bucket - 1) // bucket) * bucket, bucket)
    Zp = jnp.asarray(Z_rows)
    if mp != m:
        Zp = jnp.pad(Zp, ((0, mp - m), (0, 0)))
    nb = mp // bucket
    qn = jnp.asarray(qnodes, jnp.int32).reshape(nq, 1)
    z_spec = pl.BlockSpec((bucket, K), lambda b: (b, 0))
    q_spec = pl.BlockSpec((nq, K), lambda b: (0, 0))
    qn_spec = pl.BlockSpec((nq, 1), lambda b: (0, 0))
    run_spec = pl.BlockSpec((nq, k), lambda b: (0, 0))    # revisited
    out_specs = [run_spec, run_spec]
    out_shape = [jax.ShapeDtypeStruct((nq, k), jnp.float32),
                 jax.ShapeDtypeStruct((nq, k), jnp.int32)]
    if normalize:
        out_specs.append(z_spec)
        out_shape.append(jax.ShapeDtypeStruct((mp, K), jnp.float32))
    out = pl.pallas_call(
        functools.partial(_topk_kernel, bucket=bucket, k=k, m=m,
                          row_offset=row_offset,
                          exclude_self=exclude_self,
                          normalize=normalize, eps=eps),
        grid=(nb,),
        in_specs=[z_spec, q_spec, qn_spec],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(Zp, jnp.asarray(q), qn)
    if normalize:
        vals, idxs, zn = out
        return vals, idxs, zn[:m]
    vals, idxs = out
    return vals, idxs


def _delta_kernel(rows_ref, cls_ref, val_ref, z_ref, znew_ref, zn_ref, *,
                  tile_n: int, kdim: int, bpt: int, eps: float):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        znew_ref[...] = z_ref[...]

    rows = rows_ref[0, 0, :]                              # (EB,) int32
    cls = cls_ref[0, 0, :]
    val = val_ref[0, 0, :].astype(jnp.float32)
    eb = rows.shape[0]
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (eb, tile_n), 1)
    cls_iota = jax.lax.broadcasted_iota(jnp.int32, (eb, kdim), 1)
    R = (rows[:, None] == row_iota).astype(jnp.float32)
    C = (cls[:, None] == cls_iota).astype(jnp.float32) * val[:, None]
    znew_ref[...] += jax.lax.dot_general(
        R, C, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(b == bpt - 1)
    def _renorm():
        zn_ref[...] = _normalize(znew_ref[...], eps)


def gee_delta_renorm(Z, rows, cls, val, *, tile_n: int, eps: float = EPS,
                     interpret: Union[bool, str] = "auto"):
    """Fold packed delta contributions into Z and renormalize — one
    pallas_call, one Z round trip.

    Z (n_local, K) float32; rows/cls/val (T, BPT, EB) packed blocks
    over local destination rows (see ops.pack_edges — padded slots
    carry val = 0 and are no-ops).  The second dim stays K (no lane
    padding) so the row norm reduces over exactly the K real columns,
    matching queries.normalize_rows bit-for-bit.

    Returns (Z_new (n_local, K), Zn (n_local, K)) device arrays.
    """
    interpret = resolve_interpret(interpret)
    T, BPT, EB = rows.shape
    n_local, K = Z.shape
    Zp = jnp.asarray(Z, jnp.float32)
    if T * tile_n != n_local:
        Zp = jnp.pad(Zp, ((0, T * tile_n - n_local), (0, 0)))
    eb_spec = pl.BlockSpec((1, 1, EB), lambda t, b: (t, b, 0))
    z_spec = pl.BlockSpec((tile_n, K), lambda t, b: (t, 0))
    znew, zn = pl.pallas_call(
        functools.partial(_delta_kernel, tile_n=tile_n, kdim=K,
                          bpt=BPT, eps=eps),
        grid=(T, BPT),
        in_specs=[eb_spec, eb_spec, eb_spec, z_spec],
        out_specs=[z_spec, z_spec],
        out_shape=[jax.ShapeDtypeStruct((T * tile_n, K), jnp.float32),
                   jax.ShapeDtypeStruct((T * tile_n, K), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(rows), jnp.asarray(cls), jnp.asarray(val), Zp)
    return znew[:n_local], zn[:n_local]
