"""GEE edge-scatter Pallas kernel: the paper's atomic ``writeAdd`` loop
as a TPU-native one-hot matmul accumulation.

The CPU algorithm does, per edge, a random-index read-modify-write into
Z — exactly the op TPUs don't have.  The TPU formulation:

  * edges are pre-sorted by destination tile (``dst // TILE_N``) and
    packed into uniform edge blocks (host-side, O(s log s) once);
  * grid = (num_tiles, blocks_per_tile); the Z tile (TILE_N, K) stays
    resident in VMEM across the inner grid dimension (revisiting
    BlockSpec), so all accumulation happens on-chip;
  * each edge block turns its scatter into two one-hot expansions and a
    dense (TILE_N x EB) @ (EB x K) matmul on the MXU:
        R[e, r] = [row_local(e) == r]        (EB, TILE_N)
        C[e, k] = [cls(e) == k] * val(e)     (EB, K)
        Z_tile += R^T @ C
    No RMW race is possible: one grid instance owns the tile, and the
    matmul reduction replaces the atomic adds (deterministically).

This mirrors how the paper's cache analysis maps to the TPU memory
hierarchy: their "Z(u,:) stays in processor cache during a vertex's edge
list" becomes "the Z tile stays in VMEM during its edge blocks"; their
cache-missing Z(v,:) random writes disappear entirely because sorting
made the destination local.
"""
from __future__ import annotations

import functools
from typing import Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 256          # Z rows per VMEM tile
EDGE_BLOCK = 512      # edges per inner grid step

#: platforms with a real pallas lowering — everywhere else the kernels
#: run in the interpreter (correctness path, NOT kernel performance)
COMPILED_PLATFORMS = ("tpu", "gpu")


def resolve_interpret(interpret: Union[bool, str] = "auto") -> bool:
    """Resolve an ``interpret`` knob to a concrete bool for pallas_call.

    ``"auto"`` (the `EncoderConfig` default) compiles on TPU/GPU —
    platforms where pallas has a native lowering — and falls back to
    the interpreter elsewhere (CPU).  An explicit True/False is passed
    through: True forces the interpreter (debugging), False forces
    compilation (fails loudly where no lowering exists, which is the
    point — a silent interpreter fallback is how a "fast kernel" path
    ends up measured in pure Python)."""
    if interpret == "auto" or interpret is None:
        return jax.default_backend() not in COMPILED_PLATFORMS
    return bool(interpret)


def interpret_mode_name(interpret: bool) -> str:
    """Human/metric label for a resolved interpret flag."""
    return "interpret" if interpret else "compiled"


def _kernel(rows_ref, cls_ref, val_ref, z_ref, *, tile_n: int, kdim: int):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    rows = rows_ref[0, 0, :]                                  # (EB,) int32
    cls = cls_ref[0, 0, :]
    val = val_ref[0, 0, :].astype(jnp.float32)

    eb = rows.shape[0]
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (eb, tile_n), 1)
    cls_iota = jax.lax.broadcasted_iota(jnp.int32, (eb, kdim), 1)
    R = (rows[:, None] == row_iota).astype(jnp.float32)        # (EB, TILE_N)
    C = (cls[:, None] == cls_iota).astype(jnp.float32) * val[:, None]
    z_ref[...] += jax.lax.dot_general(
        R, C, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (TILE_N, K)


def gee_scatter_pallas(rows, cls, val, *, num_tiles: int, tile_n: int,
                       kdim: int, interpret: Union[bool, str] = "auto"):
    """rows/cls/val: (T, BPT, EB) packed edge blocks (see ops.pack_edges).

    Returns Z (num_tiles * tile_n, kdim) float32."""
    interpret = resolve_interpret(interpret)
    T, BPT, EB = rows.shape
    assert T == num_tiles
    grid = (T, BPT)
    eb_spec = pl.BlockSpec((1, 1, EB), lambda t, b: (t, b, 0))
    z_spec = pl.BlockSpec((tile_n, kdim), lambda t, b: (t, 0))
    out = pl.pallas_call(
        functools.partial(_kernel, tile_n=tile_n, kdim=kdim),
        grid=grid,
        in_specs=[eb_spec, eb_spec, eb_spec],
        out_specs=z_spec,
        out_shape=jax.ShapeDtypeStruct((T * tile_n, kdim), jnp.float32),
        interpret=interpret,
    )(rows, cls, val)
    return out
