"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gee import edge_contributions, make_w


def gee_scatter_ref(dst, cls, val, n: int, K: int) -> jnp.ndarray:
    """Segment-sum oracle for the gee_scatter kernel."""
    return jnp.zeros((n, K), jnp.float32).at[dst, cls].add(
        val.astype(jnp.float32))


def gee_ref(u, v, w, Y, n: int, K: int) -> jnp.ndarray:
    Wv = make_w(Y, K)
    dst, cls, val = edge_contributions(u, v, w.astype(jnp.float32), Y, Wv)
    return gee_scatter_ref(dst, cls, val, n, K)


def flash_attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q: (B, H, S, D); k, v: (B, KV, S, D) with KV | H (GQA)."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, S, D).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg,
                   k.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, D).astype(q.dtype)
