"""h2o-danube-3-4b — llama+mistral mix, SWA [arXiv:2401.16818; unverified].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.
Sliding-window attention (mistral-style, 4096 window) -> sub-quadratic
decode (window-bounded KV cache) -> long_500k applies.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    head_dim=120,
    swa_window=4096,
    sub_quadratic=True,
)
