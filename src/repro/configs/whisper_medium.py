"""whisper-medium — enc-dec, conv frontend (stub) [arXiv:2212.04356].

24L d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=51865.
24 encoder + 24 decoder layers; learned positions, LayerNorm + GELU.
The conv1d audio frontend is a STUB: input_specs() provides precomputed
frame embeddings (batch, n_frames=1500, d_model) per the brief.
vocab 51865 is padded to 51968 (multiple of 128) for model-axis sharding.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    norm="layernorm",
    act="gelu",
    use_rope=False,
    learned_pos=True,            # learned absolute positions
    enc_layers=24,
    dec_layers=24,
    n_frames=1500,
    sub_quadratic=False,
)
