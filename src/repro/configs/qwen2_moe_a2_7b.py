"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (MHA kv=16) d_ff=1408 vocab=151936, MoE 60e top-4.
d_ff=1408 is the per-expert hidden dim; the 4 shared experts form one
always-on block of 4*1408=5632 hidden.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    qkv_bias=True,
    moe=MoEConfig(num_experts=60, top_k=4, num_shared=4,
                  expert_d_ff=1408, shared_d_ff=4 * 1408),
    sub_quadratic=False,
)
