"""zamba2-1.2b — Mamba2 + shared attn blocks [arXiv:2411.15242; hf].

38L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=32000, ssm_state=64.
38 Mamba2 layers with ONE shared (weight-tied) attention+FFN block
applied every `attn_every` mamba layers (zamba2's distinguishing trick).
SSM backbone -> sub-quadratic -> long_500k applies.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    head_dim=64,
    ssm=SSMConfig(state=64, conv=4, expand=2, head_dim=64, chunk=256),
    attn_every=6,                # shared attn block after every 6 mamba layers
    sub_quadratic=True,
)
