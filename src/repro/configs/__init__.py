"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K, PAPER_GRAPHS,
    GraphSpec, ModelConfig, MoEConfig, SSMConfig, ShapeSpec, XLSTMConfig,
)

# arch-id -> module (exact ids from the assignment)
_REGISTRY: dict[str, str] = {
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "yi-9b": "repro.configs.yi_9b",
    "yi-6b": "repro.configs.yi_6b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube_3_4b",
    "qwen1.5-110b": "repro.configs.qwen1_5_110b",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "whisper-medium": "repro.configs.whisper_medium",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "grok-1-314b": "repro.configs.grok_1_314b",
}


def list_archs() -> list[str]:
    return list(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    return importlib.import_module(_REGISTRY[arch]).CONFIG


def get_shape(name: str) -> ShapeSpec:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, including inapplicable-marked ones."""
    cells = []
    for arch in _REGISTRY:
        cfg = get_config(arch)
        for shape in cfg.shapes():
            cells.append((arch, shape.name))
    return cells
