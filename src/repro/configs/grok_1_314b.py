"""grok-1-314b — 8 experts top-2 [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
314B params: bf16 params + bf16 adam states to fit 256 x 16 GB HBM
(2+2+2+2 = 8 B/param = 2.5 TB -> 9.8 GB/chip).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    moe=MoEConfig(num_experts=8, top_k=2, num_shared=0, expert_d_ff=32768),
    sub_quadratic=False,
    decode_seq_shard=True,
    param_dtype="bfloat16",
    state_dtype="bfloat16",
)
