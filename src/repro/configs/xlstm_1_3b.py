"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304.  d_ff=0: xLSTM blocks
carry their own up-projections (mlstm_expand), there is no separate FFN.
Attention-free -> sub-quadratic -> long_500k applies.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=512,
    norm="layernorm",
    act="gelu",
    use_rope=False,
    xlstm=XLSTMConfig(slstm_every=8, mlstm_expand=2, mlstm_chunk=256),
    sub_quadratic=True,
    notes="sLSTM every 8th block, mLSTM elsewhere; recurrent-state decode",
)
