"""chameleon-34b — early-fusion VLM, VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
Early fusion means image patches are VQ-quantized into ordinary tokens in
the shared 65536 vocab — the modality frontend is a STUB (token ids are
the input; the VQ tokenizer is out of scope per the brief).  Backbone is
a dense GQA decoder with qk-norm (chameleon's stabilizer).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
    sub_quadratic=False,
    decode_seq_shard=True,
    param_dtype="bfloat16",
)
