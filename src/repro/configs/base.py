"""Configuration dataclasses for the repro framework.

Every architecture in the assigned pool is described by a ModelConfig;
every benchmark shape by a ShapeSpec.  Configs are plain frozen
dataclasses — no jax imports here, so importing a config never touches
device state (required for the dry-run's XLA_FLAGS ordering).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""
    num_experts: int
    top_k: int
    num_shared: int = 0          # always-on shared experts (qwen2-moe style)
    expert_d_ff: int = 0         # hidden dim per routed expert
    shared_d_ff: int = 0         # hidden dim of the shared expert block
    capacity_factor: float = 1.25    # tokens kept per expert bucket;
    # set >= num_experts/top_k for dropless routing (serving equivalence)
    router_jitter: float = 0.0
    # capacity factor only matters for dropping implementations; we use
    # dropless dense-gather einsum routing (see models/moe.py).


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""
    state: int = 64              # N: per-head state size
    conv: int = 4                # depthwise conv width
    expand: int = 2              # d_inner = expand * d_model
    head_dim: int = 64           # P: channels per SSM head
    chunk: int = 256             # chunked-scan block length


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block mix (arXiv:2405.04517)."""
    slstm_every: int = 8         # one sLSTM block every N blocks (rest mLSTM)
    mlstm_expand: int = 2        # up-projection factor for mLSTM
    mlstm_chunk: int = 256       # chunkwise-parallel block length


@dataclass(frozen=True)
class ShapeSpec:
    """A benchmark cell's input shape."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

SHAPES: dict[str, ShapeSpec] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


@dataclass(frozen=True)
class ModelConfig:
    """One assigned architecture (exact dims from the public source)."""
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # --- block construction -------------------------------------------------
    head_dim: int = 0            # 0 -> d_model // n_heads
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "swiglu"          # swiglu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    use_rope: bool = True
    learned_pos: bool = False    # learned absolute positions (whisper)
    tie_embeddings: bool = False
    swa_window: int = 0          # >0: sliding-window attention
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    attn_every: int = 0          # zamba2: shared attn block every N ssm layers
    # --- encoder-decoder (whisper) ------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0          # 0 -> decoder-only with n_layers
    n_frames: int = 0            # audio frontend stub: frames fed to encoder
    # --- numerics / scale ---------------------------------------------------
    param_dtype: str = "float32"
    state_dtype: str = "float32"     # optimizer m/v dtype
    compute_dtype: str = "bfloat16"
    vocab_pad: int = 128         # pad vocab to a multiple of this
    remat: bool = True
    scan_layers: bool = True
    # --- serving ------------------------------------------------------------
    sub_quadratic: bool = False  # True -> long_500k applies
    decode_seq_shard: bool = False   # seq-sharded flash-decoding path
    attn_chunk: int = 1_024      # KV-block size for chunked (flash) attention
    notes: str = ""

    # -------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab, self.vocab_pad)

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def shapes(self) -> Tuple[ShapeSpec, ...]:
        """Shapes applicable to this arch (long_500k needs sub-quadratic)."""
        out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
        if self.sub_quadratic:
            out.append(LONG_500K)
        return tuple(out)

    def skipped_shapes(self) -> Tuple[str, ...]:
        return () if self.sub_quadratic else (LONG_500K.name,)

    # -------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (exact for our implementation)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)

    # -------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Family-preserving reduced config for CPU smoke tests."""
        kw: dict = dict(
            n_layers=min(self.n_layers, 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // self.n_heads)),
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab=256,
            vocab_pad=8,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
            decode_seq_shard=False,
            attn_chunk=32,
        )
        if self.moe is not None:
            kw["moe"] = replace(
                self.moe, num_experts=4, top_k=2,
                num_shared=min(self.moe.num_shared, 1),
                expert_d_ff=32, shared_d_ff=64 if self.moe.shared_d_ff else 0)
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, state=8, head_dim=8, chunk=16)
        if self.xlstm is not None:
            kw["xlstm"] = replace(self.xlstm, slstm_every=2, mlstm_chunk=16)
        if self.attn_every:
            kw["attn_every"] = 2
        if self.is_encdec:
            kw["enc_layers"] = 2
            kw["dec_layers"] = 2
            kw["n_frames"] = 8
        if self.swa_window:
            kw["swa_window"] = 32
        return replace(self, **kw)


@dataclass(frozen=True)
class GraphSpec:
    """A GEE benchmark graph (paper Table I analogs + synthetic)."""
    name: str
    n: int                       # nodes
    s: int                       # edges
    K: int = 50                  # classes
    labeled_frac: float = 0.10   # paper: 10% of nodes labeled
    generator: str = "erdos_renyi"   # erdos_renyi | sbm | powerlaw
    seed: int = 0


# Paper Table I graphs (exact n, s) — used for the dry-run-scale roofline;
# benchmarks run scaled-down versions that fit one CPU core.
PAPER_GRAPHS: dict[str, GraphSpec] = {
    "twitch": GraphSpec("twitch", 168_000, 6_800_000),
    "soc-pokec": GraphSpec("soc-pokec", 1_600_000, 30_000_000),
    "soc-livejournal": GraphSpec("soc-livejournal", 6_400_000, 69_000_000),
    "soc-orkut": GraphSpec("soc-orkut", 3_000_000, 117_000_000),
    "orkut-groups": GraphSpec("orkut-groups", 3_000_000, 327_000_000),
    "friendster": GraphSpec("friendster", 65_000_000, 1_800_000_000),
}
