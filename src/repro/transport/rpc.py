"""Socket RPC: one request/response frame pair per call.

`RpcServer` hosts a **handler** object (a `worker.ShardHost` or
`worker.ReplicaHost`) on a localhost TCP socket or a UNIX-domain
socket.  Requests dispatch by method name to the handler's public
methods — there is no pickle and no eval; an unknown or underscored
method is an error response, never an attribute walk.

    request  = {"id": int, "method": str, "args": [...], "kwargs": {...}}
    response = {"id": int, "ok": True,  "value": ...}
             | {"id": int, "ok": False, "etype": str, "error": str}

`RpcClient` adds the robustness the router needs:

* **per-call timeouts** — a socket deadline per request/response pair;
* **bounded retry with jitter, idempotent calls only** — reads may
  execute twice (a timed-out request can still land server-side), so
  only calls declared ``idempotent=True`` are retried, always on a
  FRESH connection (the old stream may hold a stale response that
  would otherwise be mis-paired with the retry);
* **connection re-establishment** — connects lazily, drops the socket
  on any framing/IO error, and reconnects on the next call.

One connection per client, one in-flight call per connection: the
engine already serializes calls per shard under its own lock, so the
simple protocol (strict request/response alternation, ids as a sanity
check) is exactly enough.
"""
from __future__ import annotations

import os
import random
import socket
import threading
import time
from typing import Any, Optional, Tuple, Union

from repro import obs
from repro.transport.errors import (CallTimeout, FrameError, TransportError,
                                    from_wire_error, to_wire_error)
from repro.transport.framing import recv_msg, send_msg

Addr = Union[Tuple[str, int], str]       # (host, port) | unix socket path


def parse_addr(addr: str) -> Addr:
    """"host:port" -> (host, port); "unix:/path" -> "/path"."""
    if addr.startswith("unix:"):
        return addr[len("unix:"):]
    host, _, port = addr.rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"address {addr!r} is not HOST:PORT or unix:PATH")
    return host, int(port)


def format_addr(addr: Addr) -> str:
    if isinstance(addr, str):
        return f"unix:{addr}"
    return f"{addr[0]}:{addr[1]}"


def _connect(addr: Addr, timeout: Optional[float]) -> socket.socket:
    if isinstance(addr, str):
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.settimeout(timeout)
        sock.connect(addr)
    else:
        sock = socket.create_connection(addr, timeout=timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return sock


class RpcServer:
    """Hosts one handler object; threaded accept loop, one thread per
    connection, dispatch serialized by a handler lock (reconnects can
    briefly overlap connections; the handler itself stays
    single-threaded)."""

    def __init__(self, handler, *, host: str = "127.0.0.1", port: int = 0,
                 path: Optional[str] = None):
        self.handler = handler
        self._lock = threading.Lock()
        self._stop = threading.Event()
        #: bookkeeping lock for the thread list — deliberately NOT
        #: _lock, which is held across handler dispatch: accepting a
        #: new connection must not wait out a slow RPC
        self._tlock = threading.Lock()
        self._threads: list[threading.Thread] = []  # guarded by: _tlock
        if path is not None:
            if os.path.exists(path):
                os.unlink(path)
            self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            self._sock.bind(path)
            self.addr: Addr = path
        else:
            self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            self._sock.bind((host, port))
            self.addr = self._sock.getsockname()[:2]
        self._sock.listen(16)

    @property
    def address(self) -> str:
        return format_addr(self.addr)

    def start(self) -> "RpcServer":
        t = threading.Thread(target=self.serve_forever,
                             name="rpc-accept", daemon=True)
        t.start()
        with self._tlock:
            self._threads.append(t)
        return self

    def serve_forever(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:              # listener closed: shutdown
                break
            conn.settimeout(None)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name="rpc-conn", daemon=True)
            t.start()
            with self._tlock:
                self._threads.append(t)

    def _serve_conn(self, conn: socket.socket) -> None:
        """Request loop for one connection.  A torn frame, a mid-message
        disconnect, or garbage bytes end THIS connection only — the
        server keeps accepting (clean failure routing: a flaky client
        cannot take the worker down)."""
        try:
            while not self._stop.is_set():
                try:
                    req = recv_msg(conn)
                except (FrameError, OSError):
                    return               # torn/closed stream: drop conn
                resp = self._dispatch(req)
                try:
                    send_msg(conn, resp)
                except OSError:
                    return               # peer vanished mid-response
                if req.get("method") == "__shutdown__":
                    self.close()
                    return
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def _dispatch(self, req: Any) -> dict:
        rid = req.get("id", -1) if isinstance(req, dict) else -1
        try:
            if not isinstance(req, dict):
                raise TypeError("request is not a message dict")
            method = req["method"]
            if method == "__shutdown__":
                return {"id": rid, "ok": True, "value": None}
            if method.startswith("_") or not hasattr(self.handler, method):
                raise AttributeError(f"no such RPC method {method!r}")
            fn = getattr(self.handler, method)
            t0 = obs.tick()
            with self._lock:
                value = fn(*req.get("args", ()), **req.get("kwargs", {}))
            if obs.enabled():
                obs.observe("repro_transport_server_seconds",
                            obs.tock(t0), method=method)
                obs.counter("repro_transport_server_requests_total",
                            method=method)
            return {"id": rid, "ok": True, "value": value}
        except BaseException as e:       # noqa: BLE001 — errors cross the
            etype, msg = to_wire_error(e)   # wire, they don't kill the loop
            if obs.enabled():
                obs.counter("repro_transport_server_errors_total",
                            etype=etype)
            return {"id": rid, "ok": False, "etype": etype, "error": msg}

    def close(self) -> None:
        self._stop.set()
        try:
            # a plain close() does NOT wake a thread blocked in
            # accept() on Linux — shutdown() does (EINVAL there)
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        if isinstance(self.addr, str):
            try:
                os.unlink(self.addr)
            except OSError:
                pass


class RpcClient:
    """One lazy connection to an RpcServer; see the module docstring
    for the retry/reconnect policy.

    Thread-safe: a lock serializes each round trip, so concurrent
    callers (e.g. engine reads racing the health prober on one
    RemoteReplica) can never interleave frames on the shared stream.
    The backoff sleep between retry attempts happens OUTSIDE the lock,
    so a retrying caller does not stall the others."""

    def __init__(self, addr: Union[str, Addr], *, timeout_s: float = 10.0,
                 retries: int = 2, backoff_s: float = 0.05,
                 rng: Optional[random.Random] = None):
        self.addr: Addr = parse_addr(addr) if isinstance(addr, str) else addr
        self.timeout_s = float(timeout_s)
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self._rng = rng or random.Random(0xC0FFEE)
        #: serializes the (send, recv) round trip + connection state
        self._lock = threading.Lock()
        # guarded by: _lock
        self._sock: Optional[socket.socket] = None
        self._next_id = 0                # guarded by: _lock
        self.reconnects = 0              # guarded by: _lock

    @property
    def address(self) -> str:
        return format_addr(self.addr)

    # holds: _lock
    def _drop(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    # holds: _lock
    def _ensure(self, timeout: float) -> socket.socket:
        if self._sock is None:
            self._sock = _connect(self.addr, timeout)
            self.reconnects += 1
            if obs.enabled():
                obs.counter("repro_transport_reconnects_total")
        self._sock.settimeout(timeout)
        return self._sock

    def call(self, method: str, *args, idempotent: bool = False,
             timeout_s: Optional[float] = None, **kwargs) -> Any:
        """One RPC round trip.  `idempotent=True` opts into bounded
        retry (fresh connection + jittered backoff) on transport-level
        failures; remote exceptions are never retried — they are
        deterministic answers, not faults."""
        timeout = self.timeout_s if timeout_s is None else float(timeout_s)
        attempts = 1 + (self.retries if idempotent else 0)
        last: Optional[TransportError] = None
        t0 = obs.tick()
        for attempt in range(attempts):
            if attempt and obs.enabled():
                obs.counter("repro_transport_client_retries_total",
                            method=method)
            try:
                with self._lock:
                    value = self._call_once(method, args, kwargs,
                                            timeout)
                if obs.enabled():
                    obs.observe("repro_transport_client_seconds",
                                obs.tock(t0), method=method)
                    obs.counter("repro_transport_client_calls_total",
                                method=method, outcome="ok")
                return value
            except TransportError as e:
                last = e
                with self._lock:
                    self._drop()         # never reuse a torn stream
                if attempt + 1 < attempts:
                    time.sleep(self.backoff_s * (2 ** attempt)
                               * (1.0 + self._rng.random()))
        if obs.enabled():
            obs.counter("repro_transport_client_calls_total",
                        method=method, outcome="error")
        raise last if last is not None else TransportError("no attempt ran")

    # holds: _lock — call() serializes each round trip
    def _call_once(self, method: str, args, kwargs, timeout: float) -> Any:
        rid = self._next_id
        self._next_id += 1
        try:
            sock = self._ensure(timeout)
            sent = send_msg(sock, {"id": rid, "method": method,
                                   "args": list(args), "kwargs": kwargs})
            resp = recv_msg(sock)
            if obs.enabled():
                obs.counter("repro_transport_bytes_sent_total", sent)
        except socket.timeout as e:
            raise CallTimeout(
                f"{method} to {self.address} exceeded {timeout:.3f}s"
            ) from e
        except FrameError:
            raise
        except OSError as e:
            raise TransportError(
                f"{method} to {self.address} failed: {e}") from e
        if not isinstance(resp, dict) or resp.get("id") != rid:
            raise FrameError(f"response id mismatch for {method} "
                             f"(got {resp.get('id') if isinstance(resp, dict) else resp!r})")
        if resp.get("ok"):
            return resp.get("value")
        raise from_wire_error(resp.get("etype", "RemoteCallError"),
                              resp.get("error", "unknown remote error"))

    def shutdown_server(self) -> None:
        """Ask the server to exit its accept loop (best effort)."""
        try:
            self.call("__shutdown__")
        except (TransportError, OSError):
            pass

    def close(self) -> None:
        with self._lock:
            self._drop()
