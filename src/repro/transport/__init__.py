"""Multi-process transport for the serving deployment.

The serving engine's shard router already treats each `EmbeddingShard`
as an opaque worker behind a narrow call surface; this package moves
that surface across a process boundary without changing it:

* `framing`   — length-prefixed CRC frames (the WAL's discipline on a
                socket) + a no-pickle tagged codec with zero-copy
                numpy arrays;
* `rpc`       — `RpcServer`/`RpcClient`: per-call timeouts, bounded
                jittered retry for idempotent reads, connection
                re-establishment, typed errors across the wire;
* `worker`    — the subprocess entry (`python -m
                repro.transport.worker`) hosting one shard or one
                WAL-tail replica;
* `remote`    — `RemoteShard` (call-compatible with `EmbeddingShard`)
                and `RemoteReplica` proxies;
* `replica`   — `ReplicaEngine`: bootstrap from the owner's snapshot
                generation, stay fresh by tailing its WAL, serve
                version-pinned reads;
* `procs`     — spawn/handshake/teardown with the router's config
                pinned into the worker environment.

Entry point: ``ServingEngine(..., transport="socket")`` (spawn
workers) or ``transport="socket", shard_addrs=[...]`` (connect to
externally-launched ones), plus ``replicas=N`` /
``replica_addrs=[...]`` on any durable deployment.
"""
from repro.transport.errors import (CallTimeout, FrameError,
                                    RemoteCallError, ReplicaLagError,
                                    TransportError)
from repro.transport.framing import (MAX_FRAME, pack_obj, recv_frame,
                                     recv_msg, send_frame, send_msg,
                                     unpack_obj)
from repro.transport.procs import (WorkerProc, spawn_replica_worker,
                                   spawn_shard_worker, worker_env)
from repro.transport.remote import RemoteReplica, RemoteShard
from repro.transport.replica import ReplicaEngine
from repro.transport.rpc import (RpcClient, RpcServer, format_addr,
                                 parse_addr)

__all__ = [
    "CallTimeout", "FrameError", "RemoteCallError", "ReplicaLagError",
    "TransportError", "MAX_FRAME", "pack_obj", "unpack_obj",
    "send_frame", "recv_frame", "send_msg", "recv_msg", "WorkerProc",
    "spawn_shard_worker", "spawn_replica_worker", "worker_env",
    "RemoteShard", "RemoteReplica", "ReplicaEngine", "RpcClient",
    "RpcServer", "parse_addr", "format_addr",
]
