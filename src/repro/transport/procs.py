"""Worker subprocess lifecycle: spawn, handshake, pinned env, teardown.

The router spawns each shard/replica as ``python -m
repro.transport.worker`` with port 0 and learns the real address from
the worker's one-line stdout handshake (``LISTENING <addr>``).

**Environment pinning** (the config-divergence guard): a worker that
inherited a different ``REPRO_OBS`` / plan-cache / device config than
the router would silently produce different metrics, different cache
behavior, or even run on a different backend.  :func:`worker_env`
therefore stamps the router's *effective* state into the child env —
``REPRO_OBS`` from `obs.enabled()` (not the raw env: the router may
have called ``obs.configure``), ``REPRO_PLAN_CACHE`` and
``JAX_PLATFORMS`` passed through verbatim when set — and prepends the
live ``repro`` package's source root to ``PYTHONPATH`` so the child
resolves the same code regardless of how the parent was launched.

Spawn is two-phase (``wait=False`` + :meth:`WorkerProc.handshake`) so a
router bringing up N workers pays one jax-import latency, not N.
"""
from __future__ import annotations

import os
import subprocess
import sys
import threading
from typing import Optional

import repro
from repro import obs
from repro.transport.errors import TransportError

#: env vars forwarded verbatim when set in the router's process
_FORWARD = ("REPRO_PLAN_CACHE", "JAX_PLATFORMS", "XLA_FLAGS",
            "REPRO_TRANSPORT_BACKEND")


def worker_env() -> dict:
    """Child environment with the router's effective config pinned."""
    env = os.environ.copy()
    env["REPRO_OBS"] = "on" if obs.enabled() else "off"
    for key in _FORWARD:
        val = os.environ.get(key)
        if val is not None:
            env[key] = val
    # repro may be a namespace package (__file__ is None): locate the
    # source root from __path__ instead
    pkg_dir = (os.path.dirname(repro.__file__) if repro.__file__
               else list(repro.__path__)[0])
    src_root = os.path.dirname(os.path.abspath(pkg_dir))
    parts = [src_root] + [p for p in
                          env.get("PYTHONPATH", "").split(os.pathsep)
                          if p and p != src_root]
    env["PYTHONPATH"] = os.pathsep.join(parts)
    return env


class WorkerProc:
    """One spawned worker: the Popen handle plus its RPC address
    (None until :meth:`handshake` reads the LISTENING line)."""

    def __init__(self, proc: subprocess.Popen, role: str,
                 label: str):
        self.proc = proc
        self.role = role
        self.label = label
        self.addr: Optional[str] = None

    @property
    def pid(self) -> int:
        return self.proc.pid

    def alive(self) -> bool:
        return self.proc.poll() is None

    def handshake(self, timeout_s: float = 120.0) -> str:
        """Block until the worker prints ``LISTENING <addr>``; kills
        the child and raises `TransportError` on timeout or early
        exit.  Idempotent once the address is known."""
        if self.addr is not None:
            return self.addr
        timer = threading.Timer(timeout_s, self.proc.kill)
        timer.start()
        try:
            for raw in self.proc.stdout:
                line = raw.decode("utf-8", "replace").strip()
                if line.startswith("LISTENING "):
                    self.addr = line.split(" ", 1)[1]
                    return self.addr
        finally:
            timer.cancel()
        rc = self.proc.wait()
        raise TransportError(
            f"{self.label} exited (rc={rc}) before listening"
            + (" [handshake timeout]" if rc and rc < 0 else ""))

    def stop(self, timeout_s: float = 10.0) -> None:
        """Reap the child: wait briefly (the router normally sends
        ``__shutdown__`` first), then terminate, then kill."""
        if self.proc.poll() is None:
            try:
                self.proc.wait(timeout=timeout_s)
            except subprocess.TimeoutExpired:
                self.proc.terminate()
                try:
                    self.proc.wait(timeout=5.0)
                except subprocess.TimeoutExpired:
                    self.proc.kill()
                    self.proc.wait()
        if self.proc.stdout is not None:
            self.proc.stdout.close()

    def kill(self) -> None:
        """Hard-kill (the fault-injection tests' crash lever)."""
        self.proc.kill()
        self.proc.wait()
        if self.proc.stdout is not None:
            self.proc.stdout.close()


def _spawn(cmd: list, role: str, label: str, *,
           wait: bool, timeout_s: float) -> WorkerProc:
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            env=worker_env())
    wp = WorkerProc(proc, role, label)
    if obs.enabled():
        obs.counter("repro_transport_workers_spawned_total", role=role)
    if wait:
        wp.handshake(timeout_s)
    return wp


def spawn_shard_worker(shard_id: int, lo: int, hi: int, *, K: int,
                       n: int, chunk_size: int = 1 << 20,
                       backend: str = "streaming", plan_cache="auto",
                       addr: str = "127.0.0.1:0", wait: bool = True,
                       timeout_s: float = 120.0) -> WorkerProc:
    cmd = [sys.executable, "-m", "repro.transport.worker",
           "--role", "shard", "--addr", addr,
           "--shard-id", str(shard_id), "--lo", str(lo),
           "--hi", str(hi), "--classes", str(K), "--nodes", str(n),
           "--chunk-size", str(chunk_size), "--backend", backend,
           "--plan-cache", "off" if plan_cache is None
           else str(plan_cache)]
    return _spawn(cmd, "shard", f"shard worker {shard_id}",
                  wait=wait, timeout_s=timeout_s)


def spawn_replica_worker(data_dir: str, *, poll_ms: float = 20.0,
                         chunk_size: int = 1 << 20,
                         backend: str = "streaming", plan_cache="auto",
                         addr: str = "127.0.0.1:0", wait: bool = True,
                         timeout_s: float = 120.0) -> WorkerProc:
    cmd = [sys.executable, "-m", "repro.transport.worker",
           "--role", "replica", "--addr", addr,
           "--data-dir", str(data_dir), "--poll-ms", str(poll_ms),
           "--chunk-size", str(chunk_size), "--backend", backend,
           "--plan-cache", "off" if plan_cache is None
           else str(plan_cache)]
    return _spawn(cmd, "replica", f"replica worker @ {data_dir}",
                  wait=wait, timeout_s=timeout_s)
