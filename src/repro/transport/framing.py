"""Wire format: length-prefixed CRC frames + a self-describing codec.

The frame discipline is the WAL's (`repro.serving.wal`), applied to a
socket instead of a log file:

    [u32 payload_len][u32 crc32(payload)][payload]

A short read or a CRC mismatch raises `FrameError` — after a torn
frame the stream position is meaningless, so framing errors are always
connection-fatal (the RPC client drops the socket and reconnects).
`MAX_FRAME` bounds a single message so a corrupted length prefix
cannot make the reader allocate unbounded memory.

The payload codec (`pack_obj`/`unpack_obj`) is a small tagged binary
encoding for exactly the types RPC messages need — None, bool, int,
float, str, bytes, list, tuple, dict (str keys), and **numpy arrays**
(dtype + shape + raw row-major bytes, zero-copy on decode via
`np.frombuffer`).  No pickle anywhere: a worker can never be made to
execute code by a corrupted or malicious peer, and the format is
stable across Python versions.
"""
from __future__ import annotations

import socket
import struct
import zlib
from typing import Any

import numpy as np

from repro.transport.errors import FrameError

_HEADER = struct.Struct("<II")           # payload_len, crc32 (WAL's framing)
_U32 = struct.Struct("<I")
_I64 = struct.Struct("<q")
_F64 = struct.Struct("<d")

#: hard per-frame ceiling (512 MiB): a flipped length prefix must not
#: turn into an unbounded allocation
MAX_FRAME = 512 << 20

_T_NONE, _T_TRUE, _T_FALSE = b"N", b"T", b"F"
_T_INT, _T_FLOAT, _T_STR, _T_BYTES = b"i", b"f", b"s", b"b"
_T_LIST, _T_TUPLE, _T_DICT, _T_ARRAY = b"l", b"t", b"d", b"a"


# -- object codec ------------------------------------------------------------

def _pack_into(out: bytearray, obj: Any) -> None:
    if obj is None:
        out += _T_NONE
    elif obj is True:
        out += _T_TRUE
    elif obj is False:
        out += _T_FALSE
    elif isinstance(obj, (int, np.integer)):
        out += _T_INT
        out += _I64.pack(int(obj))
    elif isinstance(obj, (float, np.floating)):
        out += _T_FLOAT
        out += _F64.pack(float(obj))
    elif isinstance(obj, str):
        raw = obj.encode("utf-8")
        out += _T_STR
        out += _U32.pack(len(raw))
        out += raw
    elif isinstance(obj, bytes):
        out += _T_BYTES
        out += _U32.pack(len(obj))
        out += obj
    elif isinstance(obj, np.ndarray):
        # ascontiguousarray promotes 0-d to (1,); reshape back so the
        # decoder reproduces the exact shape
        a = np.ascontiguousarray(obj).reshape(obj.shape)
        dt = a.dtype.str.encode("ascii")     # e.g. b'<f4' (endian-stamped)
        out += _T_ARRAY
        out += _U32.pack(len(dt))
        out += dt
        out += _U32.pack(a.ndim)
        for dim in a.shape:
            out += _I64.pack(dim)
        raw = a.tobytes()
        out += _U32.pack(len(raw))
        out += raw
    elif isinstance(obj, (list, tuple)):
        out += _T_LIST if isinstance(obj, list) else _T_TUPLE
        out += _U32.pack(len(obj))
        for item in obj:
            _pack_into(out, item)
    elif isinstance(obj, dict):
        out += _T_DICT
        out += _U32.pack(len(obj))
        for key, val in obj.items():
            if not isinstance(key, str):
                raise TypeError(f"dict keys must be str, got {type(key)!r}")
            raw = key.encode("utf-8")
            out += _U32.pack(len(raw))
            out += raw
            _pack_into(out, val)
    else:
        raise TypeError(f"cannot encode {type(obj)!r} for transport")


def pack_obj(obj: Any) -> bytes:
    out = bytearray()
    _pack_into(out, obj)
    return bytes(out)


class _Reader:
    __slots__ = ("buf", "off")

    def __init__(self, buf: bytes):
        self.buf = buf
        self.off = 0

    def take(self, k: int) -> bytes:
        end = self.off + k
        if end > len(self.buf):
            raise FrameError("truncated payload inside a valid frame")
        chunk = self.buf[self.off:end]
        self.off = end
        return chunk


def _unpack_from(r: _Reader) -> Any:
    tag = r.take(1)
    if tag == _T_NONE:
        return None
    if tag == _T_TRUE:
        return True
    if tag == _T_FALSE:
        return False
    if tag == _T_INT:
        return _I64.unpack(r.take(8))[0]
    if tag == _T_FLOAT:
        return _F64.unpack(r.take(8))[0]
    if tag == _T_STR:
        return r.take(_U32.unpack(r.take(4))[0]).decode("utf-8")
    if tag == _T_BYTES:
        return r.take(_U32.unpack(r.take(4))[0])
    if tag == _T_ARRAY:
        dt = np.dtype(r.take(_U32.unpack(r.take(4))[0]).decode("ascii"))
        ndim = _U32.unpack(r.take(4))[0]
        shape = tuple(_I64.unpack(r.take(8))[0] for _ in range(ndim))
        nbytes = _U32.unpack(r.take(4))[0]
        expect = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
        if nbytes != expect:
            raise FrameError("array byte length disagrees with shape")
        return np.frombuffer(r.take(nbytes), dt).reshape(shape)
    if tag in (_T_LIST, _T_TUPLE):
        count = _U32.unpack(r.take(4))[0]
        items = [_unpack_from(r) for _ in range(count)]
        return items if tag == _T_LIST else tuple(items)
    if tag == _T_DICT:
        count = _U32.unpack(r.take(4))[0]
        out = {}
        for _ in range(count):
            key = r.take(_U32.unpack(r.take(4))[0]).decode("utf-8")
            out[key] = _unpack_from(r)
        return out
    raise FrameError(f"unknown codec tag {tag!r}")


def unpack_obj(buf: bytes) -> Any:
    r = _Reader(buf)
    try:
        obj = _unpack_from(r)
    except FrameError:
        raise
    except (ValueError, TypeError, OverflowError, struct.error) as e:
        # corrupted bytes must surface as the framing discipline's
        # error (connection-fatal), never leak a decoder internal
        raise FrameError(f"malformed payload: {e}") from e
    if r.off != len(buf):
        raise FrameError(f"{len(buf) - r.off} trailing bytes after payload")
    return obj


# -- socket framing ----------------------------------------------------------

def recv_exact(sock: socket.socket, k: int) -> bytes:
    """Read exactly k bytes or raise FrameError (EOF mid-message =
    a torn frame; the peer died or the stream is corrupt)."""
    chunks = []
    got = 0
    while got < k:
        chunk = sock.recv(min(k - got, 1 << 20))
        if not chunk:
            raise FrameError(f"connection closed mid-frame "
                             f"({got}/{k} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def send_frame(sock: socket.socket, payload: bytes) -> int:
    """Write one [len][crc][payload] frame; returns bytes on the wire."""
    if len(payload) > MAX_FRAME:
        raise FrameError(f"frame of {len(payload)} bytes exceeds "
                         f"MAX_FRAME ({MAX_FRAME})")
    header = _HEADER.pack(len(payload), zlib.crc32(payload))
    sock.sendall(header + payload)
    return len(header) + len(payload)


def recv_frame(sock: socket.socket) -> bytes:
    """Read one frame; length/CRC failures raise FrameError (the
    connection is unusable afterwards — same discipline as a torn WAL
    tail, except a socket cannot be truncated, only abandoned)."""
    header = recv_exact(sock, _HEADER.size)
    length, crc = _HEADER.unpack(header)
    if length > MAX_FRAME:
        raise FrameError(f"frame length {length} exceeds MAX_FRAME")
    payload = recv_exact(sock, length)
    if zlib.crc32(payload) != crc:
        raise FrameError("frame CRC mismatch")
    return payload


def send_msg(sock: socket.socket, obj: Any) -> int:
    return send_frame(sock, pack_obj(obj))


def recv_msg(sock: socket.socket) -> Any:
    return unpack_obj(recv_frame(sock))
