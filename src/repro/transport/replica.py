"""WAL-tail read replica: a volatile engine kept fresh by the owner's log.

One-Hot GEE is linear in the edge multiset, so a replica never needs
the owner's device state — the WAL *is* the state.  `ReplicaEngine`
bootstraps exactly like crash recovery (load the manifest's snapshot
generation, replay the WAL suffix, build Z once) and then keeps
replaying: a poll loop tails the owner's live WAL file read-only
(`serving.wal.tail_records`) and feeds each fresh record through the
same write path the owner ran, so the replica's
`(version, epoch, fingerprint)` trajectory is the owner's, record for
record.  The inner engine is a real volatile `ServingEngine` with the
owner's `num_shards` — answers are therefore **bit-identical** to the
owner's (all top-k surfaces are tie-stable and owned-rows plans are
shard-count invariant), which is what lets the router fan reads across
replicas without weakening its `np.array_equal` contract.

Freshness model — reads are **version-pinned**: every read carries the
router's current version; a replica that has not applied that version
yet raises `ReplicaLagError` instead of serving stale rows, and the
router falls back to the owner (and surfaces the lag through
`engine.health()`).  Checkpoints rotate the owner's WAL; the tail loop
watches the MANIFEST generation and re-bootstraps from the new
snapshot when it flips.  An ``mode="ivf"`` read on a replica that has
not yet seen the owner's INDEX record is also a lag (the replica must
never invent its own quantizer — divergent centroids would break
bit-equality), routed the same way.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Optional

import numpy as np

from repro import obs
from repro.serving import wal as W
from repro.serving.store import GraphStore
from repro.transport.errors import ReplicaLagError

_MANIFEST = "MANIFEST"


class ReplicaEngine:
    """Read-only replica of a durable deployment at `data_dir`."""

    def __init__(self, data_dir: str, *, poll_s: float = 0.02,
                 backend: str = "streaming", plan_cache=\
                 "auto", chunk_size: int = 1 << 20,
                 start_tail: bool = True):
        self.data_dir = str(data_dir)
        self.poll_s = float(poll_s)
        self.backend = backend
        self.plan_cache = plan_cache
        self.chunk_size = int(chunk_size)
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.records_applied = 0         # guarded by: _lock
        self.reloads = 0                 # guarded by: _lock
        #: last exception the tail loop swallowed (kept serving — a
        #: replica with a sick tail is stale, not dead; version pinning
        #: turns staleness into clean owner fallbacks)
        self.tail_error: Optional[BaseException] = None  # guarded by: _lock
        #: the wrapped volatile engine + tail cursor, all rewritten
        #: together on a generation flip (_load)
        self.engine = None               # guarded by: _lock
        self.generation = -1             # guarded by: _lock
        self._offset = 0                 # guarded by: _lock
        self._wal_path = ""              # guarded by: _lock
        self._load()
        if start_tail:
            self._thread = threading.Thread(
                target=self._tail_loop, name="replica-tail", daemon=True)
            self._thread.start()

    # -- bootstrap (the crash-recovery path, minus the WAL append handle) --

    def _load(self) -> None:
        """(Re)bootstrap from the current manifest generation: snapshot
        + full WAL replay + one Z build.  Mirrors `ServingEngine.open`
        except the WAL is read with `scan_wal` — never opened for
        append, never truncated: the file belongs to the owner."""
        from repro.serving.engine import ServingEngine
        with self._lock:
            with open(os.path.join(self.data_dir, _MANIFEST)) as f:
                gen = int(json.load(f)["generation"])
            prefix = os.path.join(self.data_dir, f"snap-{gen}")
            store = GraphStore.load(prefix)
            with open(prefix + ".engine.json") as f:
                emeta = json.load(f)
            eng = ServingEngine(
                store, num_shards=int(emeta["num_shards"]),
                rebuild_churn=float(emeta["rebuild_churn"]),
                chunk_size=self.chunk_size, backend=self.backend,
                plan_cache=self.plan_cache, _boot=False)
            eng.epoch = int(emeta["epoch"])
            eng.rebuilds = int(emeta["rebuilds"])
            eng.deltas_applied = int(emeta["deltas_applied"])
            eng.Y_epoch = store.Y.copy()
            eng._reset_shard_fps()
            imeta = emeta.get("index")
            if imeta is not None:
                eng.index_mode = imeta["mode"]
                eng.index_churn = float(imeta["churn"])
                eng.nprobe = (int(imeta["nprobe"])
                              if imeta["nprobe"] is not None else None)
                eng._index_centroids = np.asarray(
                    imeta["centroids"], np.float32).reshape(
                        store.K, store.K)
            self._wal_path = os.path.join(self.data_dir, f"wal-{gen}.log")
            records, offset = W.scan_wal(self._wal_path)
            for rec in records:
                eng._replay(rec)
            eng.version = store.version
            eng._embed_epoch()           # Z built once, post-replay
            if eng.index_mode is not None:
                eng._build_index(eng._index_centroids, record=False)
            self.engine = eng
            self.generation = gen
            self._offset = offset
            self.records_applied += len(records)
            self.reloads += 1
            if obs.enabled():
                obs.counter("repro_transport_replica_reloads_total")

    # -- WAL tail ----------------------------------------------------------

    def _tail_loop(self) -> None:
        while not self._stop.wait(self.poll_s):
            try:
                self.poll()
            except Exception as e:       # keep tailing; reads stay pinned
                with self._lock:
                    self.tail_error = e
                if obs.enabled():
                    obs.counter("repro_transport_replica_tail_errors_total")

    def poll(self) -> int:
        """One tail step: re-bootstrap if the manifest generation
        flipped (owner checkpoint rotated the WAL), otherwise apply any
        fresh records through the live write path.  Returns records
        applied; also callable directly for deterministic tests."""
        with open(os.path.join(self.data_dir, _MANIFEST)) as f:
            gen = int(json.load(f)["generation"])
        with self._lock:                 # RLock: _load re-enters fine
            if gen != self.generation:
                self._load()
                return 0
            records, offset = W.tail_records(self._wal_path,
                                             self._offset)
            for rec in records:
                self._apply_live(rec)
            self._offset = offset
            self.records_applied += len(records)
        if records and obs.enabled():
            obs.counter("repro_transport_replica_applied_total",
                        len(records))
        return len(records)

    # holds: _lock — poll() wraps the apply batch
    def _apply_live(self, rec: W.WalRecord) -> None:
        """Feed one tailed record through the SAME public write path the
        owner ran — versions, epochs, fingerprints, and churn-gated
        rebuilds advance at identical points.  (The inner engine is
        volatile: its `wal` is None, so nothing is re-logged.)"""
        eng = self.engine
        if rec.kind == W.EDGES:          # weights arrive sign-folded
            eng.apply_edge_delta(rec.a, rec.b, rec.c)
        elif rec.kind == W.LABELS:
            eng.apply_label_delta(rec.a, rec.b)
        elif rec.kind == W.COMPACT:
            eng.compact()
        elif rec.kind == W.REBUILD:
            eng.refresh()
        elif rec.kind == W.INDEX:
            cent = np.asarray(rec.a, np.float32).reshape(
                eng.store.K, eng.store.K).copy()
            with eng._mu:
                eng.index_mode = "ivf"
                eng._build_index(cent, record=False)

    # -- version-pinned reads ---------------------------------------------

    # holds: _lock — every read entry point locks before pinning
    def _pin(self, min_version: int) -> None:
        if self.engine.version < min_version:
            if obs.enabled():
                obs.counter("repro_transport_replica_lag_rejects_total")
            raise ReplicaLagError(
                f"replica at version {self.engine.version} < pinned "
                f"{min_version}", have=self.engine.version,
                want=min_version)

    def embed(self, nodes, min_version: int = 0) -> np.ndarray:
        with self._lock:
            self._pin(min_version)
            return np.asarray(self.engine.query_embed(nodes))

    def predict(self, nodes, min_version: int = 0):
        with self._lock:
            self._pin(min_version)
            pred, score = self.engine.query_predict(nodes)
            return np.asarray(pred), np.asarray(score)

    def topk(self, nodes, *, k: int = 10, block_rows: int = 1 << 14,
             mode: str = "exact", nprobe: Optional[int] = None,
             min_version: int = 0):
        with self._lock:
            self._pin(min_version)
            if mode == "ivf" and self.engine.index_mode is None:
                # the owner's INDEX record hasn't reached us: serving
                # would mean inventing a quantizer and breaking
                # bit-equality — treat as lag, owner takes the read
                raise ReplicaLagError("replica has no quantizer yet "
                                      "(INDEX record not applied)")
            idx, val = self.engine.query_topk(
                nodes, k=k, block_rows=block_rows, mode=mode,
                nprobe=nprobe)
            return np.asarray(idx), np.asarray(val)

    def status(self) -> dict:
        with self._lock:
            return {"version": self.engine.version,
                    "epoch": self.engine.epoch,
                    "fingerprint": self.engine.fingerprint(),
                    "generation": self.generation,
                    "records_applied": self.records_applied,
                    "reloads": self.reloads,
                    "tail_error": (repr(self.tail_error)
                                   if self.tail_error else None)}

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        with self._lock:                 # tail thread is gone; reads
            self.engine.close()          # racing close get a clean cut
