"""Client proxies: `RemoteShard` / `RemoteReplica`.

`RemoteShard` is **call-compatible with `EmbeddingShard`** — same
methods, same argument shapes, same return conventions (device arrays
out, global node ids in).  `ServingEngine(transport="socket")` drops it
into `engine.shards` and every existing code path — delta fan-out,
scatter/gather reads, IVF probes, stats aggregation, the p==1
`engine.embedder` compat surface — routes over RPC with zero changes
to the routing logic.  Answers stay `np.array_equal` with in-process
shards: the wire codec is lossless for the arrays involved, and the
worker runs the identical shard code.

Retry policy rides on `RpcClient`: pure reads declare
``idempotent=True`` (bounded retry + jitter on a fresh connection);
mutations never retry — a timed-out `apply_delta` MAY have landed, so
the error must surface to the engine rather than risk double-folding
an edge batch.  Builds get a stretched timeout (first build jit-
compiles on the worker).
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import numpy as np

from repro.graph.edges import Graph
from repro.transport.rpc import RpcClient

#: multiplier on the base timeout for calls that may jit-compile
#: worker-side (first build / index build)
_SLOW = 10.0


def _client(addr_or_client, timeout_s: float) -> RpcClient:
    if isinstance(addr_or_client, RpcClient):
        return addr_or_client
    return RpcClient(addr_or_client, timeout_s=timeout_s)


class _RemoteEmbedderView:
    """The p==1 compat surface (`engine.embedder`): just the fitted
    state, fetched on demand."""

    def __init__(self, shard: "RemoteShard"):
        self._shard = shard

    @property
    def Z_(self):
        import jax.numpy as jnp
        Z = self._shard._call("embedder_Z", idempotent=True)
        return None if Z is None else jnp.asarray(Z)

    @property
    def Wv_(self):
        import jax.numpy as jnp
        Wv = self._shard._call("embedder_Wv", idempotent=True)
        return None if Wv is None else jnp.asarray(Wv)


class _RemoteIndexView:
    """Mirror of the engine-facing `IVFIndex` read surface
    (`stats()` occupancy reporting)."""

    def __init__(self, shard: "RemoteShard"):
        self._shard = shard

    def cell_sizes(self) -> np.ndarray:
        return np.asarray(
            self._shard._call("index_cell_sizes", idempotent=True))


# repro: twin-of EmbeddingShard; extra: ping, close, address, client, proc, timeout_s
class RemoteShard:
    """`EmbeddingShard`, one process boundary away."""

    def __init__(self, addr_or_client, shard_id: int, lo: int, hi: int,
                 *, timeout_s: float = 60.0, proc=None):
        self.shard_id = int(shard_id)
        self.lo, self.hi = int(lo), int(hi)
        self.timeout_s = float(timeout_s)
        self.client = _client(addr_or_client, timeout_s)
        #: owning WorkerProc when the engine spawned this worker
        #: (None for --connect deployments managed externally)
        self.proc = proc

    def _call(self, method, *args, **kwargs):
        return self.client.call(method, *args, **kwargs)

    @property
    def address(self) -> str:
        return self.client.address

    def ping(self) -> dict:
        return self._call("ping", idempotent=True)

    # -- write path --------------------------------------------------------

    def build(self, graph_or_source, Y: np.ndarray) -> None:
        """Ship the routed sub-multiset (or a source's materialized
        graph) with its fingerprint, so the worker's plan cache keys on
        identical content.  Sources are resolved router-side: their
        fingerprint is the cheap one (the store's chained value), never
        a rehash."""
        if isinstance(graph_or_source, Graph):
            g, fp = graph_or_source, graph_or_source.fingerprint()
        else:                            # GraphSource duck type
            g, fp = graph_or_source.graph(), \
                graph_or_source.fingerprint()
        self._call("build", np.asarray(g.u), np.asarray(g.v),
                   np.asarray(g.w), int(g.n), fp,
                   np.asarray(Y, np.int32),
                   timeout_s=self.timeout_s * _SLOW)

    def apply_delta(self, sub: Graph) -> None:
        if sub.s:                        # NOT idempotent: never retried
            self._call("apply_delta", np.asarray(sub.u),
                       np.asarray(sub.v), np.asarray(sub.w),
                       int(sub.n))

    # -- read path (device arrays out, like the in-process shard) ----------

    @property
    def Z_owned(self):
        import jax.numpy as jnp
        return jnp.asarray(self._call("z_owned", idempotent=True))

    @property
    def accumulator_nbytes(self) -> int:
        return int(self._call("accumulator_nbytes", idempotent=True))

    def rows(self, nodes: np.ndarray):
        import jax.numpy as jnp
        return jnp.asarray(
            self._call("rows", np.asarray(nodes), idempotent=True))

    def normalized(self):
        import jax.numpy as jnp
        return jnp.asarray(self._call("normalized", idempotent=True))

    def class_stats(self, Y: np.ndarray):
        import jax.numpy as jnp
        sums, counts = self._call("class_stats",
                                  np.asarray(Y, np.int32),
                                  idempotent=True)
        return jnp.asarray(sums), jnp.asarray(counts)

    def topk_candidates(self, q, qnodes, *, k: int, block_rows: int):
        import jax.numpy as jnp
        ids, vals = self._call("topk_candidates",
                               np.asarray(q, np.float32),
                               np.asarray(qnodes, np.int32),
                               int(k), int(block_rows),
                               idempotent=True)
        return jnp.asarray(ids), jnp.asarray(vals)

    # -- IVF index ---------------------------------------------------------

    @property
    def index(self) -> Optional[_RemoteIndexView]:
        if self._call("has_index", idempotent=True):
            return _RemoteIndexView(self)
        return None

    def build_index(self, centroids) -> None:
        self._call("build_index", np.asarray(centroids, np.float32),
                   timeout_s=self.timeout_s * _SLOW)

    def update_index(self, touched_global: np.ndarray) -> int:
        return int(self._call("update_index",
                              np.asarray(touched_global, np.int64)))

    def index_topk(self, q, qnodes, probe, *, k: int, block_rows: int):
        import jax.numpy as jnp
        ids, vals, scanned = self._call(
            "index_topk", np.asarray(q, np.float32),
            np.asarray(qnodes, np.int32), np.asarray(probe, np.int32),
            int(k), int(block_rows), idempotent=True)
        return jnp.asarray(ids), jnp.asarray(vals), int(scanned)

    # -- introspection / compat --------------------------------------------

    @property
    def plan_stats(self) -> dict:
        return self._call("plan_stats", idempotent=True)

    @property
    def embedder(self) -> _RemoteEmbedderView:
        return _RemoteEmbedderView(self)

    def close(self, *, shutdown: bool = False) -> None:
        if shutdown:
            self.client.shutdown_server()
        self.client.close()
        if self.proc is not None:
            self.proc.stop()
            self.proc = None


# repro: twin-of ReplicaEngine; extra: ping, address, client, proc, timeout_s
class RemoteReplica:
    """Client for a WAL-tail replica worker.  Every method is a
    version-pinned read — all idempotent, all retried on transport
    faults; `ReplicaLagError` crosses the wire typed, so the router's
    owner-fallback logic sees the same exception it would in-process."""

    def __init__(self, addr_or_client, *, timeout_s: float = 30.0,
                 proc=None):
        self.timeout_s = float(timeout_s)
        self.client = _client(addr_or_client, timeout_s)
        self.proc = proc

    @property
    def address(self) -> str:
        return self.client.address

    def ping(self) -> dict:
        return self.client.call("ping", idempotent=True)

    def status(self, *, timeout_s: Optional[float] = None) -> dict:
        return self.client.call("status", idempotent=True,
                                timeout_s=timeout_s)

    def embed(self, nodes, *, min_version: int = 0) -> np.ndarray:
        return np.asarray(self.client.call(
            "embed", np.asarray(nodes), int(min_version),
            idempotent=True))

    def predict(self, nodes, *, min_version: int = 0
                ) -> Tuple[np.ndarray, np.ndarray]:
        pred, score = self.client.call(
            "predict", np.asarray(nodes), int(min_version),
            idempotent=True)
        return np.asarray(pred), np.asarray(score)

    def topk(self, nodes, *, k: int = 10, block_rows: int = 1 << 14,
             mode: str = "exact", nprobe: Optional[int] = None,
             min_version: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        idx, val = self.client.call(
            "topk", np.asarray(nodes), int(k), int(block_rows),
            str(mode), (int(nprobe) if nprobe is not None else None),
            int(min_version), idempotent=True)
        return np.asarray(idx), np.asarray(val)

    def close(self, *, shutdown: bool = False) -> None:
        if shutdown:
            self.client.shutdown_server()
        self.client.close()
        if self.proc is not None:
            self.proc.stop()
            self.proc = None
