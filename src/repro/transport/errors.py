"""Transport error taxonomy.

The hierarchy encodes the router's failure-routing policy, not just
"what broke":

* `TransportError` — the connection/protocol layer failed (refused,
  reset, torn frame, timeout).  For **idempotent reads** the client
  retries with jittered backoff and a fresh connection; for writes it
  surfaces immediately — a dead owner must error loudly, never
  silently re-apply a mutation.
* `FrameError` — a frame failed its length/CRC discipline (torn or
  bit-flipped bytes).  Always connection-fatal: the stream position is
  unknowable after a bad frame, so the client drops the socket and
  (for idempotent calls) re-establishes.
* `CallTimeout` — the per-call deadline expired.  A `TransportError`,
  so reads retry; the request MAY still execute on the server, which
  is exactly why only idempotent methods opt in.
* `RemoteCallError` — the wire worked; the remote handler raised
  something we don't map back to a builtin.  Never retried (the
  failure is deterministic).
* `ReplicaLagError` — a version-pinned read reached a replica that has
  not yet applied the pinned version (or lacks the index the read
  needs).  Not a fault: the router falls back to the owner and surfaces
  the lag through `engine.health()`.
"""
from __future__ import annotations


class TransportError(ConnectionError):
    """Connection/protocol-level failure (retryable for idempotent reads)."""


class FrameError(TransportError):
    """A length/CRC-framed message failed its framing discipline."""


class CallTimeout(TransportError):
    """The per-call deadline expired before a response frame arrived."""


class RemoteCallError(RuntimeError):
    """The remote handler raised; carries the remote type and message."""

    def __init__(self, etype: str, message: str):
        super().__init__(f"{etype}: {message}")
        self.etype = etype
        self.message = message


class ReplicaLagError(RuntimeError):
    """A version-pinned read outran the replica's applied WAL position."""

    def __init__(self, message: str, *, have: int = -1, want: int = -1):
        super().__init__(message)
        self.have = int(have)
        self.want = int(want)


#: wire name -> exception class for errors that must survive the RPC
#: boundary with their TYPE intact (the engine's routing logic branches
#: on them: IndexError = bad node ids, ReplicaLagError = fall back to
#: the owner, ...).  Anything else comes back as RemoteCallError.
WIRE_EXCEPTIONS = {
    "IndexError": IndexError,
    "KeyError": KeyError,
    "ValueError": ValueError,
    "TypeError": TypeError,
    "RuntimeError": RuntimeError,
    "AssertionError": AssertionError,
    "NotImplementedError": NotImplementedError,
    "ReplicaLagError": ReplicaLagError,
}


def to_wire_error(exc: BaseException) -> tuple[str, str]:
    """(etype, message) for the response frame."""
    return type(exc).__name__, str(exc)


def from_wire_error(etype: str, message: str) -> BaseException:
    cls = WIRE_EXCEPTIONS.get(etype)
    if cls is None:
        return RemoteCallError(etype, message)
    return cls(message)
