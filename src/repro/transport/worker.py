"""Worker process entry: host one shard (or one replica) over RPC.

``python -m repro.transport.worker`` is the multi-process deployment's
unit of execution.  Two roles:

* ``--role shard`` hosts one real `EmbeddingShard` (owned rows
  [lo, hi)) behind a `ShardHost` handler whose wire methods mirror the
  shard surface 1:1 — the router's `RemoteShard` proxy calls them with
  the exact arguments `ServingEngine` already produces, so the routing
  logic upstream is unchanged byte for byte.
* ``--role replica`` hosts a `ReplicaEngine` (transport.replica): a
  full read-only engine bootstrapped from the owner's snapshot and kept
  fresh by tailing its WAL, serving version-pinned reads.

Startup handshake: after binding, the worker prints one line —
``LISTENING <addr>`` — to stdout and then serves until a
``__shutdown__`` RPC (or SIGTERM).  Spawners bind port 0 and learn the
real address from that line.

Environment pinning: the spawner (`transport.procs`) stamps the
router's *effective* config into the child environment — ``REPRO_OBS``
(the router's live obs state, not just its env), ``REPRO_PLAN_CACHE``,
and ``JAX_PLATFORMS`` — and this module keeps its heavy imports inside
:func:`main`, after the environment is final, so a worker can never
diverge from the router on metrics, plan caching, or device selection.
Backend selection also honors ``REPRO_TRANSPORT_BACKEND`` as the flag
default for externally-launched workers (``serving.server
--serve-shard``).
"""
from __future__ import annotations

import argparse
import os
from typing import Optional

# deliberately light imports only (see module docstring): numpy + the
# transport layer; jax enters via the lazy imports in the host ctors
import numpy as np

from repro import obs
from repro.transport.rpc import RpcServer, parse_addr


def _np(x, dtype=None, *, copy: bool = False):
    """Wire array -> numpy.  The codec's zero-copy decode yields
    read-only views; write-path inputs (anything the embedder folds)
    are copied so downstream in-place ops can never trip on them."""
    a = np.asarray(x) if dtype is None else np.asarray(x, dtype)
    return np.array(a) if copy else a


class ShardHost:
    """Wire-facing wrapper around one `EmbeddingShard`.

    Every method takes/returns codec-friendly values (numpy arrays,
    ints, dicts); device residency is the worker's private business —
    results cross the wire as host arrays and the router re-wraps them.
    """

    role = "shard"

    def __init__(self, shard_id: int, lo: int, hi: int, *, K: int,
                 n: int, chunk_size: int = 1 << 20,
                 backend: str = "streaming", plan_cache="auto"):
        from repro.serving.shard import EmbeddingShard
        self.backend = backend
        self.shard = EmbeddingShard(shard_id, lo, hi, K=K, n=n,
                                    chunk_size=chunk_size,
                                    backend=backend,
                                    plan_cache=plan_cache)

    def ping(self) -> dict:
        return {"role": self.role, "pid": os.getpid(),
                "shard_id": self.shard.shard_id,
                "lo": self.shard.lo, "hi": self.shard.hi,
                "backend": self.backend, "obs": obs.enabled()}

    # -- write path --------------------------------------------------------

    def build(self, u, v, w, n, fp: Optional[str], Y) -> int:
        """Fit on a routed sub-multiset.  `fp` is the router's chained
        sub-multiset fingerprint, stamped onto the materialized Graph so
        the worker's plan cache keys on the same content identity the
        in-process shard would — rebuilds stay (tier-2) cache hits."""
        from repro.graph.edges import Graph
        g = Graph(_np(u, np.int32, copy=True), _np(v, np.int32, copy=True),
                  _np(w, np.float32, copy=True), int(n))
        if fp is not None:
            g._fp = fp
        self.shard.build(g, _np(Y, np.int32, copy=True))
        return self.shard.accumulator_nbytes

    def apply_delta(self, u, v, w, n) -> None:
        from repro.graph.edges import Graph
        self.shard.apply_delta(
            Graph(_np(u, np.int32, copy=True), _np(v, np.int32, copy=True),
                  _np(w, np.float32, copy=True), int(n)))

    # -- read path ---------------------------------------------------------

    def z_owned(self):
        return np.asarray(self.shard.Z_owned)

    def accumulator_nbytes(self) -> int:
        return int(self.shard.accumulator_nbytes)

    def rows(self, nodes):
        return np.asarray(self.shard.rows(_np(nodes, np.int64)))

    def normalized(self):
        return np.asarray(self.shard.normalized())

    def class_stats(self, Y):
        sums, counts = self.shard.class_stats(_np(Y, np.int32))
        return [np.asarray(sums), np.asarray(counts)]

    def topk_candidates(self, q, qnodes, k, block_rows):
        import jax.numpy as jnp
        ids, vals = self.shard.topk_candidates(
            jnp.asarray(_np(q, np.float32)), _np(qnodes, np.int32),
            k=int(k), block_rows=int(block_rows))
        return [np.asarray(ids), np.asarray(vals)]

    # -- IVF index ---------------------------------------------------------

    def has_index(self) -> bool:
        return self.shard.index is not None

    def index_cell_sizes(self):
        return np.asarray(self.shard.index.cell_sizes())

    def build_index(self, centroids) -> None:
        self.shard.build_index(_np(centroids, np.float32, copy=True))

    def update_index(self, touched_global) -> int:
        return int(self.shard.update_index(
            _np(touched_global, np.int64, copy=True)))

    def index_topk(self, q, qnodes, probe, k, block_rows):
        import jax.numpy as jnp
        ids, vals, scanned = self.shard.index_topk(
            jnp.asarray(_np(q, np.float32)), _np(qnodes, np.int32),
            _np(probe, np.int32), k=int(k), block_rows=int(block_rows))
        return [np.asarray(ids), np.asarray(vals), int(scanned)]

    # -- introspection / p==1 compat ---------------------------------------

    def plan_stats(self) -> dict:
        return dict(self.shard.plan_stats)

    def embedder_Z(self):
        Z = self.shard.embedder.Z_
        return None if Z is None else np.asarray(Z)

    def embedder_Wv(self):
        Wv = self.shard.embedder.Wv_
        return None if Wv is None else np.asarray(Wv)


class ReplicaHost:
    """Wire-facing wrapper around one `ReplicaEngine`."""

    role = "replica"

    def __init__(self, data_dir: str, *, poll_s: float = 0.02,
                 chunk_size: int = 1 << 20, backend: str = "streaming",
                 plan_cache="auto"):
        from repro.transport.replica import ReplicaEngine
        self.backend = backend
        self.rep = ReplicaEngine(data_dir, poll_s=poll_s,
                                 chunk_size=chunk_size, backend=backend,
                                 plan_cache=plan_cache)

    def ping(self) -> dict:
        out = {"role": self.role, "pid": os.getpid(),
               "backend": self.backend, "obs": obs.enabled()}
        out.update(self.rep.status())
        return out

    def status(self) -> dict:
        return self.rep.status()

    def embed(self, nodes, min_version):
        return self.rep.embed(_np(nodes, np.int64),
                              min_version=int(min_version))

    def predict(self, nodes, min_version):
        pred, score = self.rep.predict(_np(nodes, np.int64),
                                       min_version=int(min_version))
        return [pred, score]

    def topk(self, nodes, k, block_rows, mode, nprobe, min_version):
        idx, val = self.rep.topk(
            _np(nodes, np.int64), k=int(k), block_rows=int(block_rows),
            mode=str(mode),
            nprobe=(int(nprobe) if nprobe is not None else None),
            min_version=int(min_version))
        return [idx, val]


def _parse(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        prog="repro.transport.worker",
        description="host one EmbeddingShard or WAL-tail replica "
                    "over RPC")
    ap.add_argument("--role", choices=["shard", "replica"],
                    required=True)
    ap.add_argument("--addr", default="127.0.0.1:0",
                    help="HOST:PORT (port 0 = ephemeral; the real "
                         "address is printed as 'LISTENING <addr>') "
                         "or unix:PATH")
    ap.add_argument("--backend",
                    default=os.environ.get("REPRO_TRANSPORT_BACKEND",
                                           "streaming"))
    ap.add_argument("--plan-cache", default="auto",
                    help="'auto', 'off', or a cache dir")
    ap.add_argument("--chunk-size", type=int, default=1 << 20)
    ap.add_argument("--obs", choices=["on", "off"], default=None,
                    help="override the inherited REPRO_OBS state")
    # shard role
    ap.add_argument("--shard-id", type=int, default=0)
    ap.add_argument("--lo", type=int, default=None)
    ap.add_argument("--hi", type=int, default=None)
    ap.add_argument("--classes", type=int, default=None,
                    help="K, the embedding width")
    ap.add_argument("--nodes", type=int, default=None,
                    help="n, the global row count")
    # replica role
    ap.add_argument("--data-dir", default=None)
    ap.add_argument("--poll-ms", type=float, default=20.0)
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse(argv)
    if args.obs is not None:             # explicit flag wins over env
        obs.configure(enabled=(args.obs == "on"))
    plan_cache = (None if args.plan_cache in ("off", "none")
                  else args.plan_cache)
    if args.role == "shard":
        for name in ("lo", "hi", "classes", "nodes"):
            if getattr(args, name) is None:
                raise SystemExit(f"--role shard requires --{name}")
        handler = ShardHost(args.shard_id, args.lo, args.hi,
                            K=args.classes, n=args.nodes,
                            chunk_size=args.chunk_size,
                            backend=args.backend, plan_cache=plan_cache)
    else:
        if args.data_dir is None:
            raise SystemExit("--role replica requires --data-dir")
        handler = ReplicaHost(args.data_dir,
                              poll_s=args.poll_ms / 1e3,
                              chunk_size=args.chunk_size,
                              backend=args.backend,
                              plan_cache=plan_cache)
    addr = parse_addr(args.addr)
    if isinstance(addr, str):
        server = RpcServer(handler, path=addr)
    else:
        server = RpcServer(handler, host=addr[0], port=addr[1])
    # the spawner's handshake: exactly one line, then silence
    print(f"LISTENING {server.address}", flush=True)
    server.serve_forever()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
