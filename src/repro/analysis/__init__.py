"""Repo-specific static analysis (stdlib-only; never imports jax).

Run as ``python -m repro.analysis [--json] [paths...]`` or via
``make lint-static``.  See `repro.analysis.core` for the framework and
waiver syntax, `repro.analysis.checkers` for the active suite.
"""
from repro.analysis.core import (Checker, Finding, Module, Report,
                                 run_checks)

__all__ = ["Checker", "Finding", "Module", "Report", "run_checks"]
