"""Shared framework for the repo-specific static-analysis suite.

Every checker (`repro.analysis.checkers`) operates on pre-parsed
`Module` objects — source text, AST, and the per-line comment map the
annotation conventions live in — and yields `Finding`s.  `run_checks`
loads the modules once, fans them through every registered checker,
and applies the inline waiver discipline:

    # repro: allow(<rule>) — <one-line reason>

on the finding's line (or the line directly above it) suppresses that
rule there.  The reason is mandatory: a waiver without one is itself a
finding (rule ``waiver``), so every suppression in the tree carries a
written justification a reviewer can audit.

The suite is stdlib-only (``ast`` + ``tokenize``) and never imports
the code under analysis, so it runs anywhere — including CI lanes
without jax installed — in well under a second for this tree.
"""
from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, List, Optional, Sequence

#: ``# repro: allow(rule) — reason``; the dash may be -, -- or —
_WAIVER_RE = re.compile(
    r"#\s*repro:\s*allow\(\s*([a-z0-9_-]+)\s*\)\s*(?:[-—–]+\s*(.*\S))?")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""
    rule: str
    path: str            # repo-relative where possible
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def as_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path,
                "line": self.line, "message": self.message}


@dataclass
class Waiver:
    rule: str
    line: int
    reason: Optional[str]
    used: bool = False


class Module:
    """One parsed source file: AST + the comment map annotations use."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.name = os.path.basename(path)
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        #: line number -> full comment text (from tokenize, so a '#'
        #: inside a string literal can never masquerade as a comment)
        self.comments: Dict[int, str] = {}
        try:
            for tok in tokenize.generate_tokens(
                    io.StringIO(source).readline):
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = tok.string
        except (tokenize.TokenError, IndentationError):
            pass
        self.waivers: List[Waiver] = [
            Waiver(m.group(1), line, m.group(2))
            for line, text in self.comments.items()
            if (m := _WAIVER_RE.search(text)) is not None]

    def comment_block_at(self, line: int) -> str:
        """The comment on `line` plus any contiguous comment-only lines
        directly above it — the span an annotation may live in."""
        parts = []
        if line in self.comments:
            parts.append(self.comments[line])
        above = line - 1
        while above in self.comments and \
                self.lines[above - 1].lstrip().startswith("#"):
            parts.append(self.comments[above])
            above -= 1
        return "\n".join(parts)

    def comments_in(self, lo: int, hi: int) -> str:
        """All comment text on lines [lo, hi] joined."""
        return "\n".join(self.comments[i] for i in range(lo, hi + 1)
                         if i in self.comments)


@dataclass
class Report:
    findings: List[Finding] = field(default_factory=list)
    waived: int = 0
    files: int = 0
    checkers: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings


class Checker:
    """Base: subclasses set `name` and implement `check(modules)`.

    Checkers see the WHOLE module list (cross-module rules like twin
    signature compatibility and WAL replay exhaustiveness need it)."""

    name: str = "checker"

    def check(self, modules: Sequence[Module]) -> Iterator[Finding]:
        raise NotImplementedError


def iter_py_files(paths: Iterable[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if not d.startswith((".", "__pycache__")))
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def load_modules(paths: Iterable[str]) -> (List[Module], List[Finding]):
    modules, findings = [], []
    for path in iter_py_files(paths):
        rel = os.path.relpath(path)
        try:
            with open(path, encoding="utf-8") as f:
                source = f.read()
            modules.append(Module(rel, source))
        except (SyntaxError, UnicodeDecodeError, OSError) as e:
            lineno = getattr(e, "lineno", None) or 0
            findings.append(Finding("parse", rel, lineno,
                                    f"could not analyze: {e}"))
    return modules, findings


def apply_waivers(modules: Sequence[Module],
                  findings: List[Finding]) -> (List[Finding], int):
    """Drop findings covered by a same-or-previous-line waiver for the
    same rule; emit `waiver` findings for reason-less waivers."""
    by_path: Dict[str, List[Waiver]] = {m.path: m.waivers
                                        for m in modules}
    kept: List[Finding] = []
    waived = 0
    for f in findings:
        hit = None
        for w in by_path.get(f.path, ()):
            if w.rule == f.rule and w.line in (f.line, f.line - 1):
                hit = w
                break
        if hit is not None and hit.reason:
            hit.used = True
            waived += 1
        else:
            kept.append(f)
    for m in modules:
        for w in m.waivers:
            if not w.reason:
                kept.append(Finding(
                    "waiver", m.path, w.line,
                    f"waiver for '{w.rule}' has no reason — write "
                    "'# repro: allow(" + w.rule + ") — <why>'"))
    return kept, waived


def run_checks(paths: Sequence[str],
               checkers: Optional[Sequence[Checker]] = None) -> Report:
    """Load every .py under `paths`, run the checker suite, apply
    waivers.  Returns a `Report`; `report.ok` is the CI gate."""
    if checkers is None:
        from repro.analysis.checkers import default_checkers
        checkers = default_checkers()
    modules, findings = load_modules(paths)
    for checker in checkers:
        findings.extend(checker.check(modules))
    findings, waived = apply_waivers(modules, findings)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return Report(findings=findings, waived=waived, files=len(modules),
                  checkers=[c.name for c in checkers])
