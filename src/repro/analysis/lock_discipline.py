"""Checker: ``# guarded by:`` lock annotations are machine-enforced.

The repo's threaded subsystems (serving engine, batcher, RPC, replica,
obs registry, tracer) guard mutable state with ``threading.Lock`` /
``RLock`` attributes.  The convention this checker enforces turns that
from reviewer vigilance into a contract:

* In ``__init__``, an attribute assignment carrying ``# guarded by:
  _mu`` (on its line or the comment block directly above) declares
  that ``self.<attr>`` may only be touched while ``self._mu`` is held.
* A method whose def-line/leading comments carry ``# holds: _mu``
  asserts every caller already holds the lock (private helpers called
  from locked public methods, or boot-path code running before the
  object is shared).  Multiple locks: ``# holds: _mu, _replica_mu``.
* ``__init__`` itself is exempt — construction happens-before any
  publication to other threads.

Held-lock scope is lexical: ``with self._mu:`` (including multi-item
``with self._mu, obs.span(...):``) covers its body.  Bodies of nested
functions/lambdas do NOT inherit the scope — they may run after the
lock is released — so guarded access inside one needs its own lock or
a waiver.  Accesses through a non-``self`` receiver (``eng.version``)
are outside this checker's reach; keep cross-object pokes rare.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Sequence, Tuple

from repro.analysis.core import Checker, Finding, Module

RULE = "lock-discipline"

_GUARDED_RE = re.compile(r"guarded by:\s*([A-Za-z_][A-Za-z0-9_]*)")
_HOLDS_RE = re.compile(r"holds:\s*([A-Za-z_][A-Za-z0-9_, ]*)")


def _holds_locks(mod: Module, fn: ast.FunctionDef) -> frozenset:
    """Locks asserted held for the whole method via ``# holds:``."""
    first = fn.body[0].lineno if fn.body else fn.lineno
    text = mod.comments_in(fn.lineno - 1, first)
    m = _HOLDS_RE.search(text)
    if not m:
        return frozenset()
    return frozenset(name.strip() for name in m.group(1).split(",")
                     if name.strip())


def _with_locks(node: ast.With) -> frozenset:
    """Lock attr names this ``with`` acquires via ``self.<lock>``."""
    locks = set()
    for item in node.items:
        e = item.context_expr
        if (isinstance(e, ast.Attribute)
                and isinstance(e.value, ast.Name)
                and e.value.id == "self"):
            locks.add(e.attr)
    return frozenset(locks)


def _guarded_attrs(mod: Module,
                   cls: ast.ClassDef) -> Dict[str, Tuple[str, int]]:
    """attr -> (lock, declaration line) from ``__init__`` comments."""
    out: Dict[str, Tuple[str, int]] = {}
    for fn in cls.body:
        if not (isinstance(fn, ast.FunctionDef)
                and fn.name == "__init__"):
            continue
        for stmt in ast.walk(fn):
            if not isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                continue
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            attrs = [t.attr for t in targets
                     if isinstance(t, ast.Attribute)
                     and isinstance(t.value, ast.Name)
                     and t.value.id == "self"]
            if not attrs:
                continue
            m = _GUARDED_RE.search(mod.comment_block_at(stmt.lineno))
            if m:
                for attr in attrs:
                    out[attr] = (m.group(1), stmt.lineno)
    return out


class LockDiscipline(Checker):
    name = RULE

    def check(self, modules: Sequence[Module]) -> Iterator[Finding]:
        for mod in modules:
            for cls in ast.walk(mod.tree):
                if isinstance(cls, ast.ClassDef):
                    yield from self._check_class(mod, cls)

    def _check_class(self, mod: Module,
                     cls: ast.ClassDef) -> Iterator[Finding]:
        guarded = _guarded_attrs(mod, cls)
        if not guarded:
            return
        for fn in cls.body:
            if not isinstance(fn, (ast.FunctionDef,
                                   ast.AsyncFunctionDef)):
                continue
            if fn.name == "__init__":
                continue
            held = _holds_locks(mod, fn)
            findings: List[Finding] = []
            for stmt in fn.body:
                self._walk(mod, guarded, stmt, held, findings)
            yield from findings

    def _walk(self, mod: Module, guarded, node: ast.AST,
              held: frozenset, findings: List[Finding]) -> None:
        if isinstance(node, ast.With):
            for item in node.items:
                self._walk(mod, guarded, item.context_expr, held,
                           findings)
            inner = held | _with_locks(node)
            for stmt in node.body:
                self._walk(mod, guarded, stmt, inner, findings)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            # a closure may outlive the lock scope: reset to unheld
            body = node.body if isinstance(node.body, list) \
                else [node.body]
            for stmt in body:
                self._walk(mod, guarded, stmt, frozenset(), findings)
            return
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
                and node.attr in guarded):
            lock, decl = guarded[node.attr]
            if lock not in held:
                findings.append(Finding(
                    RULE, mod.path, node.lineno,
                    f"self.{node.attr} touched without holding "
                    f"self.{lock} (declared '# guarded by: {lock}' at "
                    f"line {decl}); wrap in 'with self.{lock}:' or "
                    f"annotate the method '# holds: {lock}'"))
        for child in ast.iter_child_nodes(node):
            self._walk(mod, guarded, child, held, findings)
