"""The default checker suite.

One place to register a new checker: import it, append an instance in
`default_checkers`, and document the rule in the README table.  Order
is presentation-only — findings are sorted by location before
reporting.
"""
from __future__ import annotations

from typing import List

from repro.analysis.core import Checker
from repro.analysis.lock_discipline import LockDiscipline
from repro.analysis.metric_names import MetricNames
from repro.analysis.retry_safety import RetrySafety
from repro.analysis.tracer_safety import TracerSafety
from repro.analysis.wal_exhaustive import WalExhaustive


def default_checkers() -> List[Checker]:
    return [
        LockDiscipline(),
        RetrySafety(),
        MetricNames(),
        TracerSafety(),
        WalExhaustive(),
    ]
