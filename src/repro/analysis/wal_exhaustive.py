"""Checker: WAL replay and wire-codec dispatch stay exhaustive.

Adding a WAL record kind or a codec value tag is a three-site edit —
the declaration, the encoder, and every decoder/replayer — and missing
one is silent until a crash-recovery or cross-process path exercises
it.  Three sub-rules close that gap:

* **WAL replay exhaustiveness.**  The kind registry is the all-caps
  integer tuple in a ``wal.py`` module (``EDGES, LABELS, ... = 1,
  ...``).  Every *replay* function — ``_replay`` on the serving engine
  and ``_apply_live`` on the read replica (names configurable for
  tests) — must mention every kind by name; a kind with no arm would
  make recovery silently drop (or mis-handle via a fallthrough) that
  mutation class.

* **Codec tag coverage.**  The wire-format value tags are the ``_T_*``
  assignments in a ``framing.py`` module.  Every tag must appear in at
  least one ``*pack*`` function AND one ``*unpack*`` function — a tag
  packed but never unpacked (or vice versa) is a protocol mismatch the
  first payload of that type will hit at runtime.

* **No pickle.**  The codec exists so the RPC layer never deserializes
  attacker-controllable bytes with ``pickle``; any ``import pickle``
  (or ``cPickle``/``dill``) in the tree is flagged.

Registry/codec discovery is by file name (``wal.py`` / ``framing.py``)
so the checker works on fixtures as well as the real tree; when no
registry module is in the analyzed set the matching sub-rule is
skipped rather than failed (subtree runs stay meaningful).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Sequence, Set, Tuple

from repro.analysis.core import Checker, Finding, Module

RULE = "wal-exhaustive"

_PICKLE_MODULES = ("pickle", "cPickle", "dill")
#: functions that must dispatch on every WAL kind
_REPLAY_FNS = ("_replay", "_apply_live")


def _tuple_int_consts(node: ast.Assign) -> List[Tuple[str, int]]:
    """``A, B, C = 1, 2, 3`` (or single ``A = 1``) -> [(name, int)]."""
    if len(node.targets) != 1:
        return []
    tgt, val = node.targets[0], node.value
    if isinstance(tgt, ast.Name) and isinstance(val, ast.Constant) \
            and isinstance(val.value, int):
        return [(tgt.id, val.value)]
    if isinstance(tgt, ast.Tuple) and isinstance(val, ast.Tuple) \
            and len(tgt.elts) == len(val.elts):
        out = []
        for t, v in zip(tgt.elts, val.elts):
            if isinstance(t, ast.Name) and isinstance(v, ast.Constant) \
                    and isinstance(v.value, int):
                out.append((t.id, v.value))
            else:
                return []
        return out
    return []


def _wal_kinds(mod: Module) -> Dict[str, int]:
    """All-caps integer kind names declared at wal.py module level."""
    kinds: Dict[str, int] = {}
    for node in mod.tree.body:
        if isinstance(node, ast.Assign):
            for name, value in _tuple_int_consts(node):
                if name.isupper() and not name.startswith("_"):
                    kinds[name] = value
    return kinds


def _codec_tags(mod: Module) -> Dict[str, int]:
    """``_T_*`` tag names (-> declaration line) at framing.py module
    level."""
    tags: Dict[str, int] = {}
    for node in mod.tree.body:
        if isinstance(node, ast.Assign):
            targets = node.targets[0].elts \
                if (len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Tuple)) \
                else node.targets
            for t in targets:
                if isinstance(t, ast.Name) and t.id.startswith("_T_"):
                    tags[t.id] = node.lineno
    return tags


def _names_in(fn: ast.FunctionDef) -> Set[str]:
    """Bare names and attribute tails referenced in a function body —
    ``EDGES`` and ``W.EDGES`` both count as ``EDGES``."""
    names: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Name):
            names.add(node.id)
        elif isinstance(node, ast.Attribute):
            names.add(node.attr)
    return names


class WalExhaustive(Checker):
    name = RULE

    def __init__(self, replay_fns: Sequence[str] = _REPLAY_FNS):
        self.replay_fns = tuple(replay_fns)

    def check(self, modules: Sequence[Module]) -> Iterator[Finding]:
        kinds: Dict[str, int] = {}
        for mod in modules:
            if mod.name == "wal.py":
                kinds.update(_wal_kinds(mod))
        for mod in modules:
            yield from self._check_pickle(mod)
            if kinds:
                yield from self._check_replay(mod, kinds)
            if mod.name == "framing.py":
                yield from self._check_codec(mod)

    # -- pickle ----------------------------------------------------------

    def _check_pickle(self, mod: Module) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            bad = []
            if isinstance(node, ast.Import):
                bad = [a.name for a in node.names
                       if a.name.split(".")[0] in _PICKLE_MODULES]
            elif isinstance(node, ast.ImportFrom):
                if node.module is not None \
                        and node.module.split(".")[0] in _PICKLE_MODULES:
                    bad = [node.module]
            if bad:
                yield Finding(
                    RULE, mod.path, node.lineno,
                    f"imports {bad[0]} — the transport codec "
                    "(repro.transport.framing) exists so untrusted "
                    "bytes are never unpickled; use it instead")

    # -- replay arms ------------------------------------------------------

    def _check_replay(self, mod: Module,
                      kinds: Dict[str, int]) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.FunctionDef)
                    and node.name in self.replay_fns):
                continue
            seen = _names_in(node)
            missing = sorted(k for k in kinds if k not in seen)
            if missing:
                yield Finding(
                    RULE, mod.path, node.lineno,
                    f"{node.name} has no arm for WAL kind(s) "
                    f"{missing} — a replayed log containing one "
                    "would be silently mis-handled; add an explicit "
                    "branch (or raise) for every kind")

    # -- codec tag coverage -----------------------------------------------

    def _check_codec(self, mod: Module) -> Iterator[Finding]:
        tags = _codec_tags(mod)
        if not tags:
            return
        packed: Set[str] = set()
        unpacked: Set[str] = set()
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.FunctionDef):
                continue
            if "unpack" in node.name:
                unpacked |= _names_in(node) & set(tags)
            elif "pack" in node.name:
                packed |= _names_in(node) & set(tags)
        for tag in sorted(set(tags) - packed):
            yield Finding(
                RULE, mod.path, tags[tag],
                f"codec tag {tag} is never written by a pack "
                "function — values of that type cannot round-trip")
        for tag in sorted(set(tags) - unpacked):
            yield Finding(
                RULE, mod.path, tags[tag],
                f"codec tag {tag} is never handled by an unpack "
                "function — a peer sending it gets a decode error")
