"""Checker: metric and span names conform at lint time, not emit time.

`repro.obs` validates metric names at the emission site — a
misspelled series raises ``ValueError`` the first time the code path
runs.  This checker moves that to lint time: every string literal
passed as the name to a ``counter(`` / ``gauge(`` / ``observe(`` /
``histogram(`` call must match ``repro_<subsystem>_<metric>``
(lowercase ``[a-z0-9_]``, >= 3 underscore-separated segments with
``repro`` first — the same regex the registry enforces), and every
``span(`` name must follow the dotted ``<subsystem>.<operation>``
scheme.  A ``metric=`` keyword on ``span(`` is a metric name and is
checked as one.

Dynamic names are checked as far as they can be: an f-string name must
begin with a literal ``repro_<...>_`` chunk; fully computed names
(a variable or call) are skipped — keep those rare and funnel them
through helpers that build conforming names.
"""
from __future__ import annotations

import ast
import re
from typing import Iterator, Optional, Sequence

from repro.analysis.core import Checker, Finding, Module

RULE = "metric-name"

#: mirror of repro.obs.registry._NAME_RE — kept textual so the checker
#: never imports the package under analysis
METRIC_RE = re.compile(r"^repro_[a-z0-9]+(_[a-z0-9]+)+$")
#: f-string names must open with a literal ``repro_`` family prefix
METRIC_PREFIX_RE = re.compile(r"^repro_")
SPAN_RE = re.compile(r"^[a-z0-9_]+(\.[a-z0-9_]+)+$")

_METRIC_FUNCS = ("counter", "gauge", "observe", "histogram")
_SPAN_FUNCS = ("span",)


def _func_name(call: ast.Call) -> Optional[str]:
    if isinstance(call.func, ast.Name):
        return call.func.id
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    return None


class MetricNames(Checker):
    name = RULE

    def check(self, modules: Sequence[Module]) -> Iterator[Finding]:
        for mod in modules:
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Call):
                    yield from self._check_call(mod, node)

    def _check_call(self, mod: Module,
                    call: ast.Call) -> Iterator[Finding]:
        fname = _func_name(call)
        if fname in _METRIC_FUNCS:
            if call.args:
                yield from self._check_name(mod, call.args[0],
                                            kind="metric")
        elif fname in _SPAN_FUNCS:
            if call.args:
                yield from self._check_name(mod, call.args[0],
                                            kind="span")
            for kw in call.keywords:
                if kw.arg == "metric":
                    yield from self._check_name(mod, kw.value,
                                                kind="metric")

    def _check_name(self, mod: Module, node: ast.AST, *,
                    kind: str) -> Iterator[Finding]:
        regex = METRIC_RE if kind == "metric" else SPAN_RE
        scheme = ("repro_<subsystem>_<metric>" if kind == "metric"
                  else "<subsystem>.<operation> (dotted, lowercase)")
        if isinstance(node, ast.Constant):
            if isinstance(node.value, str) \
                    and regex.match(node.value) is None:
                yield Finding(
                    RULE, mod.path, node.lineno,
                    f"{kind} name {node.value!r} violates the "
                    f"{scheme} scheme")
        elif isinstance(node, ast.JoinedStr) and kind == "metric":
            first = node.values[0] if node.values else None
            prefix = (first.value
                      if isinstance(first, ast.Constant)
                      and isinstance(first.value, str) else "")
            if METRIC_PREFIX_RE.match(prefix) is None:
                yield Finding(
                    RULE, mod.path, node.lineno,
                    "f-string metric name must start with a literal "
                    "'repro_' prefix so the series family is "
                    "greppable")
