"""Checker: RPC retry-safety and remote/in-process twin compatibility.

Two contracts keep the socket transport honest:

**Retry allowlist.**  `RpcClient.call(..., idempotent=True)` opts into
bounded retry — a timed-out request may have executed server-side, so
retrying is only sound for read-only methods.  That property used to
live in a hand-maintained flag at each call site; this checker pins it
to `READ_ONLY_RPC_METHODS` below.  Any ``idempotent=True`` call whose
method is not a string literal on the allowlist is a finding: adding a
new retried method means adding it here, in a diff a reviewer sees
next to the wire method itself.  Mutations (``build``, ``apply_delta``,
``update_index``, ...) must never appear.

**Twin signatures.**  A proxy class annotated

    # repro: twin-of <ClassName>; extra: ping, close, address

must stay call-signature-compatible with its in-process twin: every
public method/property the proxy defines (minus the declared extras)
must exist on the twin with a compatible signature — same positional
order, every twin parameter accepted by name, no proxy-only required
parameters.  Optional proxy-side additions (e.g. a ``timeout_s``
keyword) are allowed; drift in names, order, or requiredness is a
finding.  The twin class is looked up by name across the analyzed
module set; if absent (running on a subtree) the check is skipped.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.analysis.core import Checker, Finding, Module

RULE = "retry-safety"

#: The maintained read-only RPC surface: methods that are safe to
#: execute twice (a timed-out request can still land server-side).
#: Every `call(..., idempotent=True)` site must name one of these.
#: Extend ONLY for methods with no server-side state effects.
READ_ONLY_RPC_METHODS = frozenset({
    # shard worker reads (transport.worker.ShardHost)
    "ping", "z_owned", "accumulator_nbytes", "rows", "normalized",
    "class_stats", "topk_candidates", "has_index", "index_cell_sizes",
    "index_topk", "plan_stats", "embedder_Z", "embedder_Wv",
    # replica worker reads (transport.worker.ReplicaHost)
    "status", "embed", "predict", "topk",
})

_TWIN_RE = re.compile(
    r"twin-of\s+([A-Za-z_][A-Za-z0-9_]*)"
    r"(?:\s*;\s*extra:\s*([A-Za-z0-9_,\s]+))?")

#: call-method attribute names that reach RpcClient.call
_CALL_NAMES = ("call", "_call")


def _literal(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


class _Sig:
    """Flattened def signature: ordered positional names, keyword-only
    names, defaults, varargs flags."""

    def __init__(self, fn: ast.FunctionDef):
        a = fn.args
        self.pos = [p.arg for p in a.posonlyargs + a.args]
        if self.pos and self.pos[0] in ("self", "cls"):
            self.pos = self.pos[1:]
        self.kwonly = [p.arg for p in a.kwonlyargs]
        n_def = len(a.defaults)
        required_pos = self.pos[:len(self.pos) - n_def] \
            if n_def else list(self.pos)
        self.required = set(required_pos) | {
            p.arg for p, d in zip(a.kwonlyargs, a.kw_defaults)
            if d is None}
        self.has_varargs = a.vararg is not None
        self.has_kwargs = a.kwarg is not None
        self.accepts = set(self.pos) | set(self.kwonly)


def _is_property(fn: ast.FunctionDef) -> bool:
    for d in fn.decorator_list:
        if isinstance(d, ast.Name) and d.id == "property":
            return True
        if isinstance(d, ast.Attribute) and d.attr in (
                "setter", "getter", "deleter"):
            return True
    return False


def _class_surface(cls: ast.ClassDef) -> Dict[str, Tuple[str, object]]:
    """name -> ("method"|"property"|"attr", def node or None) for the
    public surface (defs, properties, and self.<attr> assignments in
    __init__)."""
    out: Dict[str, Tuple[str, object]] = {}
    for node in cls.body:
        if isinstance(node, ast.FunctionDef):
            kind = "property" if _is_property(node) else "method"
            out.setdefault(node.name, (kind, node))
            if node.name == "__init__":
                for stmt in ast.walk(node):
                    if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                        targets = (stmt.targets
                                   if isinstance(stmt, ast.Assign)
                                   else [stmt.target])
                        for t in targets:
                            if (isinstance(t, ast.Attribute)
                                    and isinstance(t.value, ast.Name)
                                    and t.value.id == "self"):
                                out.setdefault(t.attr, ("attr", None))
    return out


class RetrySafety(Checker):
    name = RULE

    def __init__(self, allowlist: Optional[frozenset] = None):
        self.allowlist = (READ_ONLY_RPC_METHODS if allowlist is None
                          else allowlist)

    def check(self, modules: Sequence[Module]) -> Iterator[Finding]:
        classes: Dict[str, Tuple[Module, ast.ClassDef]] = {}
        for mod in modules:
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.ClassDef):
                    classes.setdefault(node.name, (mod, node))
        for mod in modules:
            yield from self._check_idempotent_sites(mod)
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.ClassDef):
                    yield from self._check_twin(mod, node, classes)

    # -- idempotent=True allowlist ----------------------------------------

    def _check_idempotent_sites(self,
                                mod: Module) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _CALL_NAMES):
                continue
            idem = next((kw.value for kw in node.keywords
                         if kw.arg == "idempotent"), None)
            if idem is None:
                continue
            if not (isinstance(idem, ast.Constant)
                    and idem.value is True):
                if isinstance(idem, ast.Constant):
                    continue             # idempotent=False: fine
                yield Finding(
                    RULE, mod.path, node.lineno,
                    "idempotent= must be a literal True/False — a "
                    "computed flag cannot be checked against the "
                    "read-only allowlist")
                continue
            method = _literal(node.args[0]) if node.args else None
            if method is None:
                yield Finding(
                    RULE, mod.path, node.lineno,
                    "idempotent=True call with a non-literal method "
                    "name — the retry allowlist needs a string "
                    "literal to verify")
            elif method not in self.allowlist:
                yield Finding(
                    RULE, mod.path, node.lineno,
                    f"'{method}' is retried (idempotent=True) but is "
                    "not on READ_ONLY_RPC_METHODS "
                    "(repro.analysis.retry_safety) — retrying a "
                    "mutation can double-apply it")

    # -- twin signature compatibility --------------------------------------

    def _check_twin(self, mod: Module, cls: ast.ClassDef,
                    classes) -> Iterator[Finding]:
        text = mod.comment_block_at(cls.lineno)
        m = _TWIN_RE.search(text)
        if not m:
            return
        twin_name = m.group(1)
        extras = {e.strip() for e in (m.group(2) or "").split(",")
                  if e.strip()}
        if twin_name not in classes:
            return                       # twin outside the analyzed set
        twin_mod, twin_cls = classes[twin_name]
        twin_surface = _class_surface(twin_cls)
        for name, (kind, fn) in sorted(_class_surface(cls).items()):
            if name.startswith("_") or name in extras:
                continue
            if name not in twin_surface:
                yield Finding(
                    RULE, mod.path,
                    fn.lineno if fn is not None else cls.lineno,
                    f"{cls.name}.{name} has no counterpart on twin "
                    f"{twin_name} ({twin_mod.path}) — declare it in "
                    "'extra:' or remove the drift")
                continue
            twin_kind, twin_fn = twin_surface[name]
            if kind == "method" and twin_kind == "method":
                yield from self._compare(mod, cls.name, twin_name,
                                         name, fn, twin_fn)
            elif kind == "method" or twin_kind == "method":
                yield Finding(
                    RULE, mod.path,
                    fn.lineno if fn is not None else cls.lineno,
                    f"{cls.name}.{name} is a {kind} but "
                    f"{twin_name}.{name} is a {twin_kind} — call "
                    "sites cannot be compatible with both")

    def _compare(self, mod: Module, cname: str, tname: str, name: str,
                 fn: ast.FunctionDef,
                 twin_fn: ast.FunctionDef) -> Iterator[Finding]:
        sig, tsig = _Sig(fn), _Sig(twin_fn)
        where = f"{cname}.{name}"
        prefix = min(len(sig.pos), len(tsig.pos))
        if sig.pos[:prefix] != tsig.pos[:prefix]:
            yield Finding(
                RULE, mod.path, fn.lineno,
                f"{where} positional parameters {sig.pos} diverge "
                f"from twin {tname}.{name} {tsig.pos}")
            return
        if not sig.has_kwargs:
            missing = sorted(tsig.accepts - sig.accepts)
            if missing:
                yield Finding(
                    RULE, mod.path, fn.lineno,
                    f"{where} does not accept twin parameter(s) "
                    f"{missing} of {tname}.{name}")
        extra_required = sorted(sig.required - tsig.accepts)
        if extra_required:
            yield Finding(
                RULE, mod.path, fn.lineno,
                f"{where} requires {extra_required} which twin "
                f"{tname}.{name} does not take — existing call sites "
                "would break")
