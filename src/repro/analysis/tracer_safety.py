"""Checker: functions traced by JAX must stay tracer-safe.

A function under ``jax.jit`` (or handed to ``pallas_call``) runs once
with abstract tracers; three habits that are fine in eager numpy break
silently or loudly there, and this checker flags them statically:

* **numpy on traced values** — ``np.<fn>(x)`` where ``x`` is a traced
  parameter forces a concretization error at trace time (or worse,
  silently constant-folds when it happens to work on the first trace);
* **Python control flow on traced values** — ``if``/``while`` on a
  tracer-derived condition raises ``TracerBoolConversionError``; use
  ``jnp.where`` / ``lax.cond`` / ``lax.while_loop``;
* **mutating closed-over state** — ``nonlocal``/``global`` writes, or
  stores through a closed-over object, run once at trace time and
  never again, a classic silent-staleness bug.

What counts as traced: every parameter EXCEPT those named in the
jit decorator's ``static_argnames`` (``static_argnums`` positions) or
pre-bound via ``functools.partial(kernel, name=...)`` at a
``pallas_call`` site.  Static *uses* of traced params stay legal:
``x.shape`` / ``x.ndim`` / ``x.dtype`` / ``x.size`` are trace-time
constants, and ``x is None`` tests dispatch on the argument structure,
not its value — both are exempt.

Recognized jit spellings: ``@jax.jit``, ``@jit``,
``@functools.partial(jax.jit, ...)``, ``@partial(jit, ...)``,
``name = jax.jit(fn, ...)`` where ``fn`` is a def in the same module,
and ``pallas_call(kernel_or_partial, ...)``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Checker, Finding, Module

RULE = "tracer-safety"

_STATIC_ATTRS = ("shape", "ndim", "dtype", "size")
_NUMPY_NAMES = ("np", "numpy")


def _dotted(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _is_jit_ref(node: ast.AST) -> bool:
    return _dotted(node) in ("jit", "jax.jit")


def _str_elts(node: ast.AST) -> Set[str]:
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return {e.value for e in node.elts
                if isinstance(e, ast.Constant)
                and isinstance(e.value, str)}
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    return set()


def _jit_static_names(call: ast.Call,
                      fn: ast.FunctionDef) -> Set[str]:
    """static params from jit(...) keywords (names and positions)."""
    static: Set[str] = set()
    params = [p.arg for p in fn.args.posonlyargs + fn.args.args]
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            static |= _str_elts(kw.value)
        elif kw.arg == "static_argnums":
            nums = []
            if isinstance(kw.value, (ast.Tuple, ast.List)):
                nums = [e.value for e in kw.value.elts
                        if isinstance(e, ast.Constant)]
            elif isinstance(kw.value, ast.Constant):
                nums = [kw.value.value]
            for i in nums:
                if isinstance(i, int) and 0 <= i < len(params):
                    static.add(params[i])
    return static


def _collect_jitted(mod: Module) -> List[Tuple[ast.FunctionDef,
                                               Set[str], str]]:
    """(function, static param names, how-detected) for every function
    the module jits or hands to pallas_call."""
    defs: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.FunctionDef):
            defs.setdefault(node.name, node)
    out: List[Tuple[ast.FunctionDef, Set[str], str]] = []
    seen: Set[int] = set()

    def add(fn: ast.FunctionDef, static: Set[str], how: str) -> None:
        if id(fn) not in seen:
            seen.add(id(fn))
            out.append((fn, static, how))

    for node in ast.walk(mod.tree):
        if isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                if _is_jit_ref(dec):
                    add(node, set(), "@jit")
                elif (isinstance(dec, ast.Call)
                      and _dotted(dec.func) in ("functools.partial",
                                                "partial")
                      and dec.args and _is_jit_ref(dec.args[0])):
                    add(node, _jit_static_names(dec, node),
                        "@partial(jit)")
        elif isinstance(node, ast.Call):
            callee = _dotted(node.func)
            if _is_jit_ref(node.func) and node.args:
                target = node.args[0]
                if isinstance(target, ast.Name) \
                        and target.id in defs:
                    fn = defs[target.id]
                    add(fn, _jit_static_names(node, fn), "jit(fn)")
            elif callee is not None \
                    and callee.split(".")[-1] == "pallas_call" \
                    and node.args:
                kernel = node.args[0]
                static: Set[str] = set()
                if (isinstance(kernel, ast.Call)
                        and _dotted(kernel.func) in (
                            "functools.partial", "partial")
                        and kernel.args):
                    static = {kw.arg for kw in kernel.keywords
                              if kw.arg is not None}
                    kernel = kernel.args[0]
                if isinstance(kernel, ast.Name) \
                        and kernel.id in defs:
                    add(defs[kernel.id], static, "pallas_call")
    return out


def _bound_names(target: ast.AST) -> Iterator[str]:
    """Names a target expression BINDS: bare names and tuple/list
    unpacking — NOT the base of a subscript/attribute store, which
    mutates an existing object rather than binding a local."""
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from _bound_names(elt)
    elif isinstance(target, ast.Starred):
        yield from _bound_names(target.value)


def _local_names(fn: ast.FunctionDef) -> Set[str]:
    """Names bound inside the function body (assignment targets, loop
    vars, with-as, comprehension vars, nested defs)."""
    names: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign,
                             ast.For, ast.comprehension)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                names.update(_bound_names(t))
        elif isinstance(node, ast.withitem) and \
                node.optional_vars is not None:
            names.update(_bound_names(node.optional_vars))
        elif isinstance(node, (ast.FunctionDef, ast.Lambda)):
            if isinstance(node, ast.FunctionDef):
                names.add(node.name)
            a = node.args
            names |= {p.arg for p in (a.posonlyargs + a.args
                                      + a.kwonlyargs)}
            if a.vararg is not None:
                names.add(a.vararg.arg)
            if a.kwarg is not None:
                names.add(a.kwarg.arg)
    return names


def _traced_names_in(node: ast.AST, traced: Set[str],
                     *, allow_static_attrs: bool) -> List[str]:
    """Traced parameter names used *by value* inside `node`.  A name
    only reached through a static attribute (``x.shape``...) or an
    ``is None`` test does not count."""
    hits: List[str] = []

    def walk(n: ast.AST) -> None:
        if isinstance(n, ast.Attribute) and allow_static_attrs \
                and n.attr in _STATIC_ATTRS:
            return                       # x.shape etc: static
        if isinstance(n, ast.Compare) \
                and all(isinstance(op, (ast.Is, ast.IsNot))
                        for op in n.ops):
            return                       # x is None: structural
        if isinstance(n, ast.Call):
            fname = _dotted(n.func)
            if fname in ("isinstance", "len"):
                return                   # static under jit
        if isinstance(n, ast.Name) and n.id in traced:
            hits.append(n.id)
        for child in ast.iter_child_nodes(n):
            walk(child)

    walk(node)
    return hits


class TracerSafety(Checker):
    name = RULE

    def check(self, modules: Sequence[Module]) -> Iterator[Finding]:
        for mod in modules:
            for fn, static, how in _collect_jitted(mod):
                yield from self._check_fn(mod, fn, static, how)

    def _check_fn(self, mod: Module, fn: ast.FunctionDef,
                  static: Set[str], how: str) -> Iterator[Finding]:
        params = {p.arg for p in (fn.args.posonlyargs + fn.args.args
                                  + fn.args.kwonlyargs)}
        traced = params - static - {"self"}
        locals_ = _local_names(fn)
        # values derived from traced params count too (one level of
        # assignment dataflow: x2 = f(x) makes x2 traced)
        derived = set(traced)
        changed = True
        while changed:
            changed = False
            for node in ast.walk(fn):
                if isinstance(node, (ast.Assign, ast.AugAssign)):
                    targets = (node.targets
                               if isinstance(node, ast.Assign)
                               else [node.target])
                    if _traced_names_in(node.value, derived,
                                        allow_static_attrs=True):
                        for t in targets:
                            for nm in _bound_names(t):
                                if nm not in derived:
                                    derived.add(nm)
                                    changed = True
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                callee = _dotted(node.func)
                if (callee is not None
                        and callee.split(".")[0] in _NUMPY_NAMES):
                    args = list(node.args) + [kw.value
                                              for kw in node.keywords]
                    used = [u for a in args
                            for u in _traced_names_in(
                                a, derived, allow_static_attrs=True)]
                    if used:
                        yield Finding(
                            RULE, mod.path, node.lineno,
                            f"{callee}() applied to traced value(s) "
                            f"{sorted(set(used))} inside "
                            f"{fn.name} ({how}) — use jnp/lax; numpy "
                            "concretizes tracers")
            elif isinstance(node, (ast.If, ast.While)):
                used = _traced_names_in(node.test, derived,
                                        allow_static_attrs=True)
                kind = ("if" if isinstance(node, ast.If) else "while")
                if used:
                    yield Finding(
                        RULE, mod.path, node.lineno,
                        f"Python `{kind}` on traced value(s) "
                        f"{sorted(set(used))} inside {fn.name} "
                        f"({how}) — use jnp.where / lax.cond / "
                        "lax.while_loop, or mark the argument "
                        "static_argnames")
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                yield Finding(
                    RULE, mod.path, node.lineno,
                    f"{fn.name} ({how}) mutates "
                    f"{'/'.join(node.names)} via "
                    f"{type(node).__name__.lower()} — traced "
                    "functions run once at trace time; closed-over "
                    "writes go stale")
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets
                           if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    root = t
                    while isinstance(root, (ast.Attribute,
                                            ast.Subscript)):
                        root = root.value
                    if (isinstance(root, ast.Name) and root is not t
                            and root.id not in params
                            and root.id not in locals_):
                        yield Finding(
                            RULE, mod.path, node.lineno,
                            f"{fn.name} ({how}) stores through "
                            f"closed-over '{root.id}' — mutation "
                            "inside a traced function happens once "
                            "at trace time, not per call")
