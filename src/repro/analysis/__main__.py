"""CLI: ``python -m repro.analysis [--json] [paths...]``.

Exit status is the CI contract: 0 when every checker is quiet (waived
findings do not count), 1 when anything fires.  Default path is
``src`` so the bare invocation is the repo gate.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.core import run_checks


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis (lock discipline, "
                    "RPC retry safety, metric names, JAX tracer "
                    "safety, WAL/codec exhaustiveness)")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to analyze "
                             "(default: src)")
    parser.add_argument("--json", action="store_true",
                        help="machine-readable report on stdout")
    args = parser.parse_args(argv)

    report = run_checks(args.paths)
    if args.json:
        json.dump({"ok": report.ok,
                   "files": report.files,
                   "checkers": report.checkers,
                   "waived": report.waived,
                   "findings": [f.as_dict() for f in report.findings]},
                  sys.stdout, indent=2)
        sys.stdout.write("\n")
    else:
        for f in report.findings:
            print(f.format())
        status = "clean" if report.ok else \
            f"{len(report.findings)} finding(s)"
        print(f"repro.analysis: {status} — {report.files} file(s), "
              f"{len(report.checkers)} checker(s), "
              f"{report.waived} waived")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
