"""Online GEE serving deployment.

Turns the offline edge-parallel embedding (`core/gee.py`) into a live
system built as a **deployment**, not a single object:

* `ServingEngine` (`engine.py`) — the front door: a shard router over
  N `EmbeddingShard` workers (`shard.py`, Z rows partitioned by
  `graph.partition.RowPartition`; deltas fan out only to owning
  shards, queries scatter/gather with a blocked top-k merge), a
  durable write-ahead delta log (`wal.py`, append-before-apply, crash
  recovery replays the WAL onto the last snapshot), and an async
  flush/checkpoint loop (`start()`).
* `GraphStore` (`store.py`) — the versioned in-memory edge multiset +
  delta log the engine serializes.
* `MicroBatcher` (`batcher.py`) — read coalescing and write barriers
  over any serving target (`topk_mode="ivf"` routes coalesced top-k
  batches through the index).
* the IVF-GEE index (`repro.index`, a sibling package) — optional
  sub-linear top-k: per-shard label-cell inverted lists over the class
  centroids, delta-maintained on every edge batch, churn-gated
  re-quantization, quantizer persisted via WAL `INDEX` records
  (`ServingEngine(..., index="ivf")` / `query_topk(mode="ivf")`).
* `EmbeddingService` (`service.py`) — DEPRECATED: the 1-shard volatile
  special case of `ServingEngine`, kept as a compat shim.

The CLI driver (`server.py`) exercises the stack on a synthetic SBM
workload (`--shards N` for the partitioned path, `--data-dir` for
durability + a recovery self-check).

Version / epoch model (shared vocabulary across the subsystem):

* **version** — the graph store's logical clock.  Every applied delta
  (edge insert/delete batch, label update) increments it by one.
* **epoch**   — the label/projection-weight generation the embedding Z
  was last *rebuilt* under.  Edge deltas fold into Z exactly (GEE is
  linear in the edge multiset), so Z tracks `version` without changing
  `epoch`; label churn past a threshold, a compaction, or a checkpoint
  forces a full rebuild and bumps `epoch`.
"""
from repro.serving.batcher import MicroBatcher
from repro.serving.engine import ServingEngine
from repro.serving.service import EmbeddingService
from repro.serving.shard import EmbeddingShard
from repro.serving.store import GraphStore
from repro.serving.wal import WriteAheadLog

__all__ = ["GraphStore", "ServingEngine", "EmbeddingShard",
           "EmbeddingService", "MicroBatcher", "WriteAheadLog"]
