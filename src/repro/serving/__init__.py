"""Online GEE embedding service.

Turns the offline edge-parallel embedding (`core/gee.py`) into a live
system: a versioned graph store (`store.py`), an incrementally
maintained embedding (`service.py`), jitted query kernels
(`queries.py`), and a microbatching front-end (`batcher.py`).  The CLI
driver (`server.py`) exercises the stack on a synthetic SBM workload.

Version / epoch model (shared vocabulary across the subsystem):

* **version** — the graph store's logical clock.  Every applied delta
  (edge insert/delete batch, label update) increments it by one.
* **epoch**   — the label/projection-weight generation the embedding Z
  was last *rebuilt* under.  Edge deltas fold into Z exactly (GEE is
  linear in the edge multiset), so Z tracks `version` without changing
  `epoch`; label churn past a threshold, or a compaction, forces a
  full rebuild and bumps `epoch`.
"""
from repro.serving.batcher import MicroBatcher
from repro.serving.service import EmbeddingService
from repro.serving.store import GraphStore

__all__ = ["GraphStore", "EmbeddingService", "MicroBatcher"]
