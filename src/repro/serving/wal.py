"""Durable write-ahead delta log for the serving engine.

The `GraphStore`'s in-memory delta log dies with the process; the WAL
is its durable twin.  Every mutation the engine accepts is appended
here BEFORE it is applied (append-before-apply), so a crashed engine
can be reconstructed exactly: load the last snapshot, replay the WAL
suffix, and the recovered `(version, epoch, fingerprint)` triple — and
the rebuilt Z — match the crashed process (tested).

Record kinds mirror the engine's write surface:

  EDGES    an edge batch with sign-folded weights (deletions carry
           negative w, exactly as the store logs them);
  LABELS   a label point-update (nodes, labels);
  COMPACT  a compaction marker — compaction is a deterministic pure
           function of store state, so replaying the marker reproduces
           the coalesced base (and its rehashed fingerprint);
  REBUILD  an explicit rebuild (``refresh()``), which advances the
           epoch without changing the multiset;
  INDEX    an IVF (re-)quantization: the payload is the engine's
           quantizer centroid matrix (K*K float32), so replay restores
           the exact quantizer and the recovered index — a pure
           function of (Z, centroids) — answers identically.

On-disk format (version-stamped file header, then records):

    [u32 payload_len][u32 crc32(payload)][payload]
    payload = [u8 kind][u64 version][u64 count][column bytes...]

Appends are flushed per record, so the log survives process death
(the crash-recovery contract).  ``fsync=True`` additionally fsyncs
every append for power-failure durability at a latency cost.  A torn
tail — a crash mid-append — is detected by length/CRC and truncated on
open: the WAL can lose at most the record being written, never parse
garbage into the store.

**Group commit** (``group_commit_ms`` / ``group_commit_bytes``, only
meaningful with ``fsync=True``): instead of one fsync per append,
records accumulate in an open *commit group* and a single fsync
barrier covers them all — closed when the group's bytes pass
``group_commit_bytes``, when its oldest append is older than
``group_commit_ms`` (checked on the next append and by the engine's
flush loop via :meth:`sync_if_due`), or explicitly via :meth:`sync`.
Every record is still written + flushed per append, so
append-before-apply and process-death durability are unchanged; only
the power-loss barrier is batched.  Submitters learn their version
only after the covering fsync: the engine's batcher finishes write
tickets after calling the target's ``sync_durable()``, so an
acknowledged write is always on stable storage.  ``appends_per_fsync``
and ``fsync_seconds`` quantify the batching in ``engine.stats()`` and
the ``repro_wal_group_*`` metric family.

**Read-side tailing** (read replicas): :func:`scan_wal` returns the
valid records *and* the byte offset they end at, and
:func:`tail_records` resumes parsing from such an offset — a replica
bootstraps from the owner's snapshot, replays the scan, then polls the
tail for fresh records.  A half-flushed record at the tail simply
reads as end-of-log and is retried on the next poll; the reader never
writes, truncates, or holds a lock on the owner's file.
"""
from __future__ import annotations

import os
import struct
import time
import zlib
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro import obs

_FILE_MAGIC = b"REPROWAL1\n"
_HEADER = struct.Struct("<II")          # payload_len, crc32
_PREFIX = struct.Struct("<BQQ")         # kind, version, count

EDGES, LABELS, COMPACT, REBUILD, INDEX = 1, 2, 3, 4, 5
_MARKERS = (COMPACT, REBUILD)


@dataclass(frozen=True)
class WalRecord:
    """One replayable mutation.  For EDGES, `a, b, c` are (u, v, w)
    with w sign-folded; for LABELS they are (nodes, labels, None);
    for INDEX `a` is the flat float32 quantizer centroid matrix
    (reshaped to (K, K) by the replayer — K is the engine's);
    markers carry no arrays."""
    kind: int
    version: int
    a: Optional[np.ndarray] = None
    b: Optional[np.ndarray] = None
    c: Optional[np.ndarray] = None


def _encode(rec: WalRecord) -> bytes:
    if rec.kind == EDGES:
        u = np.ascontiguousarray(rec.a, np.int32)
        v = np.ascontiguousarray(rec.b, np.int32)
        w = np.ascontiguousarray(rec.c, np.float32)
        count = u.shape[0]
        cols = u.tobytes() + v.tobytes() + w.tobytes()
    elif rec.kind == LABELS:
        nodes = np.ascontiguousarray(rec.a, np.int64)
        labels = np.ascontiguousarray(rec.b, np.int32)
        count = nodes.shape[0]
        cols = nodes.tobytes() + labels.tobytes()
    elif rec.kind == INDEX:
        cent = np.ascontiguousarray(rec.a, np.float32).ravel()
        count = cent.shape[0]
        cols = cent.tobytes()
    elif rec.kind in _MARKERS:
        count, cols = 0, b""
    else:
        raise ValueError(f"unknown WAL record kind {rec.kind}")
    return _PREFIX.pack(rec.kind, rec.version, count) + cols


def _decode(payload: bytes) -> WalRecord:
    kind, version, count = _PREFIX.unpack_from(payload)
    body = payload[_PREFIX.size:]
    if kind == EDGES:
        expect = count * (4 + 4 + 4)
        if len(body) != expect:
            raise ValueError("EDGES record length mismatch")
        u = np.frombuffer(body[:4 * count], np.int32)
        v = np.frombuffer(body[4 * count:8 * count], np.int32)
        w = np.frombuffer(body[8 * count:], np.float32)
        return WalRecord(kind, version, u, v, w)
    if kind == LABELS:
        expect = count * (8 + 4)
        if len(body) != expect:
            raise ValueError("LABELS record length mismatch")
        nodes = np.frombuffer(body[:8 * count], np.int64)
        labels = np.frombuffer(body[8 * count:], np.int32)
        return WalRecord(kind, version, nodes, labels)
    if kind == INDEX:
        if len(body) != count * 4:
            raise ValueError("INDEX record length mismatch")
        return WalRecord(kind, version, np.frombuffer(body, np.float32))
    if kind in _MARKERS and not body:
        return WalRecord(kind, version)
    raise ValueError(f"unknown WAL record kind {kind}")


def _scan_valid(path: str) -> tuple[list[WalRecord], int]:
    """Parse records up to the first torn/corrupt one.

    Returns (records, valid_byte_length).  Standard WAL semantics: a
    crash mid-append leaves a torn tail, which reads as end-of-log."""
    records: list[WalRecord] = []
    with open(path, "rb") as f:
        magic = f.read(len(_FILE_MAGIC))
        if magic != _FILE_MAGIC:
            return [], 0 if len(magic) < len(_FILE_MAGIC) else -1
        good = f.tell()
        while True:
            header = f.read(_HEADER.size)
            if len(header) < _HEADER.size:
                break
            length, crc = _HEADER.unpack(header)
            payload = f.read(length)
            if len(payload) < length or zlib.crc32(payload) != crc:
                break
            try:
                records.append(_decode(payload))
            except ValueError:
                break
            good = f.tell()
    return records, good


def scan_wal(path: str) -> tuple[list[WalRecord], int]:
    """Valid records plus the byte offset they end at — the tail
    position a read replica resumes from with `tail_records`."""
    records, good = _scan_valid(path)
    if good < 0:
        raise ValueError(f"{path} is not a WAL file")
    return records, good


def tail_records(path: str, offset: int) -> tuple[list[WalRecord], int]:
    """Parse records appended after `offset` (a position previously
    returned by `scan_wal`/`tail_records`).  A torn or half-flushed
    record reads as end-of-log — the next poll retries from the same
    offset.  Read-only: never truncates the live writer's file.  A file
    shorter than `offset` (rotation raced the reader) yields nothing."""
    records: list[WalRecord] = []
    good = offset
    try:
        f = open(path, "rb")
    except OSError:                      # rotated away mid-poll
        return records, good
    with f:
        f.seek(0, os.SEEK_END)
        if f.tell() < offset:
            return records, good
        f.seek(offset)
        while True:
            header = f.read(_HEADER.size)
            if len(header) < _HEADER.size:
                break
            length, crc = _HEADER.unpack(header)
            payload = f.read(length)
            if len(payload) < length or zlib.crc32(payload) != crc:
                break
            try:
                records.append(_decode(payload))
            except ValueError:
                break
            good = f.tell()
    return records, good


class WriteAheadLog:
    """Append-only durable delta log (single writer).

    ``open()`` scans the file, truncates any torn tail, and returns the
    valid records so the engine can replay them; subsequent ``append_*``
    calls extend the same file.  A missing file is created empty."""

    _KIND_NAMES = {EDGES: "edges", LABELS: "labels",
                   COMPACT: "compact", REBUILD: "rebuild",
                   INDEX: "index"}

    def __init__(self, path: str, *, fsync: bool = False,
                 group_commit_ms: Optional[float] = None,
                 group_commit_bytes: Optional[int] = None):
        self.path = str(path)
        self.fsync = bool(fsync)
        #: group commit is an fsync-batching policy: without fsync
        #: there is no barrier to batch, so the knobs are inert
        self.group_commit_ms = (float(group_commit_ms)
                                if group_commit_ms is not None else None)
        self.group_commit_bytes = (int(group_commit_bytes)
                                   if group_commit_bytes is not None
                                   else None)
        self.group_commit = self.fsync and (
            self.group_commit_ms is not None
            or self.group_commit_bytes is not None)
        self.records_appended = 0
        #: wall seconds of the most recent append (write+flush[+fsync])
        #: — always tracked (cheap next to the flush syscall) because
        #: the engine's health() degrades on it even with obs off
        self.last_append_seconds = 0.0
        self.last_fsync_seconds = 0.0
        #: fsync-barrier accounting (`engine.stats()`'s wal_group row)
        self.fsyncs = 0
        self.fsync_seconds_total = 0.0
        self.appends_covered = 0
        self._pending = 0                # appends since the last barrier
        self._pending_bytes = 0
        self._pending_since = 0.0        # perf_counter of oldest pending
        self._f: Optional[object] = None

    # -- lifecycle ---------------------------------------------------------

    def open(self) -> list[WalRecord]:
        """Open for append; returns the existing valid records."""
        records: list[WalRecord] = []
        if os.path.exists(self.path):
            records, good = _scan_valid(self.path)
            if good < 0:
                raise ValueError(f"{self.path} is not a WAL file")
            if good < os.path.getsize(self.path):
                with open(self.path, "r+b") as f:  # torn tail: drop it
                    f.truncate(good)
        self._f = open(self.path, "ab")
        if self._f.tell() == 0:
            self._f.write(_FILE_MAGIC)
            self._f.flush()
        return records

    def close(self) -> None:
        if self._f is not None:
            self.sync()                  # never orphan an open group
            self._f.close()
            self._f = None

    @property
    def bytes_written(self) -> int:
        """Current file length (the checkpoint-trigger signal)."""
        if self._f is not None:
            return self._f.tell()
        return os.path.getsize(self.path) if os.path.exists(self.path) \
            else 0

    # -- appends (append-before-apply: callers write here FIRST) ----------

    def _append(self, rec: WalRecord) -> None:
        if self._f is None:
            raise RuntimeError("WAL not open")
        t0 = time.perf_counter()
        payload = _encode(rec)
        self._f.write(_HEADER.pack(len(payload), zlib.crc32(payload)))
        self._f.write(payload)
        self._f.flush()                 # survives process death
        if self.fsync:                  # survives power loss
            if self.group_commit:
                # join the open commit group; the barrier comes later
                if not self._pending:
                    self._pending_since = t0
                self._pending += 1
                self._pending_bytes += _HEADER.size + len(payload)
                if ((self.group_commit_bytes is not None
                     and self._pending_bytes >= self.group_commit_bytes)
                    or (self.group_commit_ms is not None
                        and (time.perf_counter() - self._pending_since)
                        * 1e3 >= self.group_commit_ms)):
                    self.sync()
            else:
                tf = time.perf_counter()
                os.fsync(self._f.fileno())
                self.last_fsync_seconds = time.perf_counter() - tf
                self.fsyncs += 1
                self.fsync_seconds_total += self.last_fsync_seconds
                self.appends_covered += 1
        self.last_append_seconds = time.perf_counter() - t0
        self.records_appended += 1
        if obs.enabled():
            obs.observe("repro_serving_wal_append_seconds",
                        self.last_append_seconds)
            if self.fsync and not self.group_commit:
                obs.observe("repro_serving_wal_fsync_seconds",
                            self.last_fsync_seconds)
            obs.counter("repro_serving_wal_append_bytes_total",
                        _HEADER.size + len(payload))
            obs.counter("repro_serving_wal_records_total",
                        kind=self._KIND_NAMES.get(rec.kind,
                                                  str(rec.kind)))

    # -- group commit (fsync batching) ------------------------------------

    @property
    def pending_appends(self) -> int:
        """Appends flushed but not yet covered by an fsync barrier."""
        return self._pending

    @property
    def appends_per_fsync(self) -> float:
        """Mean records per fsync barrier — the group-commit win
        (1.0 under flush-per-record fsync)."""
        return self.appends_covered / self.fsyncs if self.fsyncs else 0.0

    def sync(self) -> int:
        """Close the open commit group with one fsync; returns the
        number of appends the barrier covered.  A no-op when nothing is
        pending (non-group mode fsyncs inline, fsync=False has no
        power-loss contract to uphold)."""
        if self._f is None or not self._pending:
            return 0
        tf = time.perf_counter()
        os.fsync(self._f.fileno())
        self.last_fsync_seconds = time.perf_counter() - tf
        covered, self._pending, self._pending_bytes = self._pending, 0, 0
        self.fsyncs += 1
        self.fsync_seconds_total += self.last_fsync_seconds
        self.appends_covered += covered
        if obs.enabled():
            obs.observe("repro_serving_wal_fsync_seconds",
                        self.last_fsync_seconds)
            obs.counter("repro_wal_group_fsyncs_total")
            obs.observe("repro_wal_group_appends_per_fsync", covered)
        return covered

    def sync_if_due(self) -> int:
        """Barrier the open group iff its oldest append has aged past
        ``group_commit_ms`` — the engine's flush loop calls this every
        iteration so a write trickle is never left pending for longer
        than the knob promises."""
        if (self._pending and self.group_commit_ms is not None
                and (time.perf_counter() - self._pending_since) * 1e3
                >= self.group_commit_ms):
            return self.sync()
        return 0

    def append_edges(self, version: int, u, v, w) -> None:
        """w must already be sign-folded (deletions negative)."""
        self._append(WalRecord(EDGES, version, u, v, w))

    def append_labels(self, version: int, nodes, labels) -> None:
        self._append(WalRecord(LABELS, version, nodes, labels))

    def append_marker(self, kind: int, version: int) -> None:
        assert kind in _MARKERS, kind
        self._append(WalRecord(kind, version))

    def append_index(self, version: int, centroids) -> None:
        """Log an IVF (re-)quantization's centroids so recovery can
        rebuild the same index deterministically."""
        self._append(WalRecord(INDEX, version, centroids))


def read_wal(path: str) -> Iterator[WalRecord]:
    """Read-only replay of a WAL file (torn tail treated as EOF)."""
    records, good = _scan_valid(path)
    if good < 0:
        raise ValueError(f"{path} is not a WAL file")
    return iter(records)
