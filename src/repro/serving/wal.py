"""Durable write-ahead delta log for the serving engine.

The `GraphStore`'s in-memory delta log dies with the process; the WAL
is its durable twin.  Every mutation the engine accepts is appended
here BEFORE it is applied (append-before-apply), so a crashed engine
can be reconstructed exactly: load the last snapshot, replay the WAL
suffix, and the recovered `(version, epoch, fingerprint)` triple — and
the rebuilt Z — match the crashed process (tested).

Record kinds mirror the engine's write surface:

  EDGES    an edge batch with sign-folded weights (deletions carry
           negative w, exactly as the store logs them);
  LABELS   a label point-update (nodes, labels);
  COMPACT  a compaction marker — compaction is a deterministic pure
           function of store state, so replaying the marker reproduces
           the coalesced base (and its rehashed fingerprint);
  REBUILD  an explicit rebuild (``refresh()``), which advances the
           epoch without changing the multiset;
  INDEX    an IVF (re-)quantization: the payload is the engine's
           quantizer centroid matrix (K*K float32), so replay restores
           the exact quantizer and the recovered index — a pure
           function of (Z, centroids) — answers identically.

On-disk format (version-stamped file header, then records):

    [u32 payload_len][u32 crc32(payload)][payload]
    payload = [u8 kind][u64 version][u64 count][column bytes...]

Appends are flushed per record, so the log survives process death
(the crash-recovery contract).  ``fsync=True`` additionally fsyncs
every append for power-failure durability at a latency cost.  A torn
tail — a crash mid-append — is detected by length/CRC and truncated on
open: the WAL can lose at most the record being written, never parse
garbage into the store.
"""
from __future__ import annotations

import os
import struct
import time
import zlib
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro import obs

_FILE_MAGIC = b"REPROWAL1\n"
_HEADER = struct.Struct("<II")          # payload_len, crc32
_PREFIX = struct.Struct("<BQQ")         # kind, version, count

EDGES, LABELS, COMPACT, REBUILD, INDEX = 1, 2, 3, 4, 5
_MARKERS = (COMPACT, REBUILD)


@dataclass(frozen=True)
class WalRecord:
    """One replayable mutation.  For EDGES, `a, b, c` are (u, v, w)
    with w sign-folded; for LABELS they are (nodes, labels, None);
    for INDEX `a` is the flat float32 quantizer centroid matrix
    (reshaped to (K, K) by the replayer — K is the engine's);
    markers carry no arrays."""
    kind: int
    version: int
    a: Optional[np.ndarray] = None
    b: Optional[np.ndarray] = None
    c: Optional[np.ndarray] = None


def _encode(rec: WalRecord) -> bytes:
    if rec.kind == EDGES:
        u = np.ascontiguousarray(rec.a, np.int32)
        v = np.ascontiguousarray(rec.b, np.int32)
        w = np.ascontiguousarray(rec.c, np.float32)
        count = u.shape[0]
        cols = u.tobytes() + v.tobytes() + w.tobytes()
    elif rec.kind == LABELS:
        nodes = np.ascontiguousarray(rec.a, np.int64)
        labels = np.ascontiguousarray(rec.b, np.int32)
        count = nodes.shape[0]
        cols = nodes.tobytes() + labels.tobytes()
    elif rec.kind == INDEX:
        cent = np.ascontiguousarray(rec.a, np.float32).ravel()
        count = cent.shape[0]
        cols = cent.tobytes()
    elif rec.kind in _MARKERS:
        count, cols = 0, b""
    else:
        raise ValueError(f"unknown WAL record kind {rec.kind}")
    return _PREFIX.pack(rec.kind, rec.version, count) + cols


def _decode(payload: bytes) -> WalRecord:
    kind, version, count = _PREFIX.unpack_from(payload)
    body = payload[_PREFIX.size:]
    if kind == EDGES:
        expect = count * (4 + 4 + 4)
        if len(body) != expect:
            raise ValueError("EDGES record length mismatch")
        u = np.frombuffer(body[:4 * count], np.int32)
        v = np.frombuffer(body[4 * count:8 * count], np.int32)
        w = np.frombuffer(body[8 * count:], np.float32)
        return WalRecord(kind, version, u, v, w)
    if kind == LABELS:
        expect = count * (8 + 4)
        if len(body) != expect:
            raise ValueError("LABELS record length mismatch")
        nodes = np.frombuffer(body[:8 * count], np.int64)
        labels = np.frombuffer(body[8 * count:], np.int32)
        return WalRecord(kind, version, nodes, labels)
    if kind == INDEX:
        if len(body) != count * 4:
            raise ValueError("INDEX record length mismatch")
        return WalRecord(kind, version, np.frombuffer(body, np.float32))
    if kind in _MARKERS and not body:
        return WalRecord(kind, version)
    raise ValueError(f"unknown WAL record kind {kind}")


def _scan_valid(path: str) -> tuple[list[WalRecord], int]:
    """Parse records up to the first torn/corrupt one.

    Returns (records, valid_byte_length).  Standard WAL semantics: a
    crash mid-append leaves a torn tail, which reads as end-of-log."""
    records: list[WalRecord] = []
    with open(path, "rb") as f:
        magic = f.read(len(_FILE_MAGIC))
        if magic != _FILE_MAGIC:
            return [], 0 if len(magic) < len(_FILE_MAGIC) else -1
        good = f.tell()
        while True:
            header = f.read(_HEADER.size)
            if len(header) < _HEADER.size:
                break
            length, crc = _HEADER.unpack(header)
            payload = f.read(length)
            if len(payload) < length or zlib.crc32(payload) != crc:
                break
            try:
                records.append(_decode(payload))
            except ValueError:
                break
            good = f.tell()
    return records, good


class WriteAheadLog:
    """Append-only durable delta log (single writer).

    ``open()`` scans the file, truncates any torn tail, and returns the
    valid records so the engine can replay them; subsequent ``append_*``
    calls extend the same file.  A missing file is created empty."""

    _KIND_NAMES = {EDGES: "edges", LABELS: "labels",
                   COMPACT: "compact", REBUILD: "rebuild",
                   INDEX: "index"}

    def __init__(self, path: str, *, fsync: bool = False):
        self.path = str(path)
        self.fsync = bool(fsync)
        self.records_appended = 0
        #: wall seconds of the most recent append (write+flush[+fsync])
        #: — always tracked (cheap next to the flush syscall) because
        #: the engine's health() degrades on it even with obs off
        self.last_append_seconds = 0.0
        self.last_fsync_seconds = 0.0
        self._f: Optional[object] = None

    # -- lifecycle ---------------------------------------------------------

    def open(self) -> list[WalRecord]:
        """Open for append; returns the existing valid records."""
        records: list[WalRecord] = []
        if os.path.exists(self.path):
            records, good = _scan_valid(self.path)
            if good < 0:
                raise ValueError(f"{self.path} is not a WAL file")
            if good < os.path.getsize(self.path):
                with open(self.path, "r+b") as f:  # torn tail: drop it
                    f.truncate(good)
        self._f = open(self.path, "ab")
        if self._f.tell() == 0:
            self._f.write(_FILE_MAGIC)
            self._f.flush()
        return records

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    @property
    def bytes_written(self) -> int:
        """Current file length (the checkpoint-trigger signal)."""
        if self._f is not None:
            return self._f.tell()
        return os.path.getsize(self.path) if os.path.exists(self.path) \
            else 0

    # -- appends (append-before-apply: callers write here FIRST) ----------

    def _append(self, rec: WalRecord) -> None:
        if self._f is None:
            raise RuntimeError("WAL not open")
        t0 = time.perf_counter()
        payload = _encode(rec)
        self._f.write(_HEADER.pack(len(payload), zlib.crc32(payload)))
        self._f.write(payload)
        self._f.flush()                 # survives process death
        if self.fsync:                  # survives power loss
            tf = time.perf_counter()
            os.fsync(self._f.fileno())
            self.last_fsync_seconds = time.perf_counter() - tf
        self.last_append_seconds = time.perf_counter() - t0
        self.records_appended += 1
        if obs.enabled():
            obs.observe("repro_serving_wal_append_seconds",
                        self.last_append_seconds)
            if self.fsync:
                obs.observe("repro_serving_wal_fsync_seconds",
                            self.last_fsync_seconds)
            obs.counter("repro_serving_wal_append_bytes_total",
                        _HEADER.size + len(payload))
            obs.counter("repro_serving_wal_records_total",
                        kind=self._KIND_NAMES.get(rec.kind,
                                                  str(rec.kind)))

    def append_edges(self, version: int, u, v, w) -> None:
        """w must already be sign-folded (deletions negative)."""
        self._append(WalRecord(EDGES, version, u, v, w))

    def append_labels(self, version: int, nodes, labels) -> None:
        self._append(WalRecord(LABELS, version, nodes, labels))

    def append_marker(self, kind: int, version: int) -> None:
        assert kind in _MARKERS, kind
        self._append(WalRecord(kind, version))

    def append_index(self, version: int, centroids) -> None:
        """Log an IVF (re-)quantization's centroids so recovery can
        rebuild the same index deterministically."""
        self._append(WalRecord(INDEX, version, centroids))


def read_wal(path: str) -> Iterator[WalRecord]:
    """Read-only replay of a WAL file (torn tail treated as EOF)."""
    records, good = _scan_valid(path)
    if good < 0:
        raise ValueError(f"{path} is not a WAL file")
    return iter(records)
