"""EmbeddingService — the 1-shard special case of `ServingEngine`.

.. deprecated::
    The serving subsystem's front door is now
    `repro.serving.ServingEngine`: a deployment with a shard router
    (Z rows partitioned across `EmbeddingShard` workers), a durable
    write-ahead delta log with crash recovery, and an async
    flush/checkpoint loop.  `EmbeddingService` remains as a thin
    compat shim — exactly `ServingEngine(store, num_shards=1,
    data_dir=None)` — so existing single-host, volatile callers keep
    working unchanged.  New code should construct a `ServingEngine`
    (and pass `data_dir=` to get durability for free).

Everything documented here in earlier revisions — the version/epoch
model, the delta-vs-rebuild policy, partial_fit exactness by GEE
linearity, cold starts as plan-cache hits — now lives on the engine
and applies to every shard count; see `repro.serving.engine`.
"""
from __future__ import annotations

import warnings

from repro.serving.engine import ServingEngine
from repro.serving.store import GraphStore


class EmbeddingService(ServingEngine):
    """Serves Z for a live graph; delta-maintains, rebuilds on churn.

    Deprecated compat shim: the 1-shard, volatile (no WAL, no
    snapshots) configuration of :class:`ServingEngine`.  The legacy
    surface — ``Z``, ``Wv``, ``Y_epoch``, ``embedder``, ``churn``,
    ``apply_edge_delta`` / ``apply_label_delta``, ``compact`` /
    ``refresh``, ``centroids`` / ``normalized_Z`` — is the engine's
    own; nothing is re-implemented here."""

    def __init__(self, store: GraphStore, *, rebuild_churn: float = 0.05,
                 chunk_size: int = 1 << 20, backend: str = "streaming",
                 plan_cache="auto"):
        warnings.warn(
            "EmbeddingService is deprecated: construct "
            "repro.serving.ServingEngine (this shim is exactly "
            "ServingEngine(store, num_shards=1, data_dir=None))",
            DeprecationWarning, stacklevel=2)
        super().__init__(store, data_dir=None, num_shards=1,
                         rebuild_churn=rebuild_churn,
                         chunk_size=chunk_size, backend=backend,
                         plan_cache=plan_cache)
