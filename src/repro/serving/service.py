"""Incrementally maintained GEE embedding over a `GraphStore`.

The service is now a thin epoch/churn policy layer over the unified
``repro.encoder.Embedder`` (streaming backend): the Embedder owns Z and
the projection weights Wv, the service owns *when* to rebuild.

* **Edge deltas** fold into Z with `Embedder.partial_fit` — O(batch)
  work, exact by linearity, no epoch change.  The Embedder pads batches
  to power-of-two buckets (one jit compile per bucket, not per batch
  size) and always uses the weights Z was built with, closing the old
  Wv-mismatch footgun of calling `gee_apply_delta` by hand.
* **Label deltas** change the projection weights W, which touches every
  edge incident to the affected classes — not expressible as an edge
  delta.  The service keeps serving the previous epoch's Z (exact for
  the epoch's labels) and tracks churn vs. the epoch snapshot; once
  churn exceeds `rebuild_churn` it re-embeds from scratch via
  `Embedder.fit` and starts a new epoch.
* **Compaction** rewrites the store's base multiset and always ends in
  a rebuild, so epochs also advance on compaction.
* **Cold starts are plan-cache hits.**  The service embeds through a
  `StoreSource`, and the store maintains the multiset's content
  fingerprint incrementally — so a fresh replica (or a restart) booting
  from the same snapshot + delta sequence finds the plan's host half in
  the persistent cache (`repro.encoder.plan_cache`) and skips host
  preprocessing entirely.  `plan_cache` plumbs through to the Embedder
  ("auto" = honor REPRO_PLAN_CACHE; None disables).

Invariant (tested): with no pending label churn, Z equals a
from-scratch `gee` over the store's live multiset, to float tolerance.
"""
from __future__ import annotations

import numpy as np

from repro.encoder import Embedder, EncoderConfig
from repro.graph.edges import Graph
from repro.graph.sources import StoreSource
from repro.serving import queries as Q
from repro.serving.store import GraphStore


class EmbeddingService:
    """Serves Z for a live graph; delta-maintains, rebuilds on churn."""

    def __init__(self, store: GraphStore, *, rebuild_churn: float = 0.05,
                 chunk_size: int = 1 << 20, backend: str = "streaming",
                 plan_cache="auto"):
        self.store = store
        self.source = StoreSource(store)
        self.rebuild_churn = float(rebuild_churn)
        self.embedder = Embedder(
            EncoderConfig(K=store.K, chunk_size=int(chunk_size)),
            backend=backend, plan_cache=plan_cache)
        self.epoch = 0
        self.deltas_applied = 0
        self.rebuilds = 0
        self._rebuild()

    # -- epoch state -------------------------------------------------------

    def _rebuild(self) -> None:
        """Full re-embed under the store's current labels; new epoch."""
        self.Y_epoch = self.store.Y.copy()
        self.embedder.fit(self.source, self.Y_epoch)
        self.version = self.store.version
        self.epoch += 1
        self.rebuilds += 1
        self._invalidate_query_cache()

    @property
    def Z(self):
        """The live embedding (owned by the Embedder)."""
        return self.embedder.Z_

    @property
    def Wv(self):
        """Projection weights Z was built with (owned by the Embedder)."""
        return self.embedder.Wv_

    @property
    def _Yj(self):
        return self.embedder._Yj

    def _invalidate_query_cache(self) -> None:
        """Derived query state (centroids, normalized Z) is a pure
        function of (Z, epoch labels); drop it whenever either moves."""
        self._centroids = None
        self._Zn = None

    def centroids(self):
        """Class centroids of the current Z, cached until invalidated."""
        if self._centroids is None:
            self._centroids = Q.class_centroids(self.Z, self._Yj,
                                                K=self.store.K)
        return self._centroids

    def normalized_Z(self):
        """Row-normalized Z for cosine queries, cached until invalidated."""
        if self._Zn is None:
            self._Zn = Q.normalize_rows(self.Z)
        return self._Zn

    @property
    def churn(self) -> float:
        return self.store.churn_fraction(self.Y_epoch)

    @property
    def stale_labels(self) -> int:
        return int((self.store.Y != self.Y_epoch).sum())

    def stats(self) -> dict:
        return {"version": self.version, "epoch": self.epoch,
                "deltas_applied": self.deltas_applied,
                "rebuilds": self.rebuilds, "churn": self.churn,
                "log_edges": self.store.log_edges,
                "base_edges": self.store.base.s,
                "fingerprint": self.store.fingerprint(),
                "plan_stats": dict(self.embedder.plan_stats)}

    # -- writes ------------------------------------------------------------

    def apply_edge_delta(self, u, v, w, *, delete: bool = False) -> int:
        """Fold an edge batch into store + Z.  O(batch).  Returns version."""
        version = self.store.apply_edges(u, v, w, delete=delete)
        batch = Graph(np.asarray(u, np.int32), np.asarray(v, np.int32),
                      np.asarray(w, np.float32), self.store.n)
        if batch.s:
            self.embedder.partial_fit(batch,
                                      sign=-1.0 if delete else 1.0)
            self._invalidate_query_cache()
        self.version = version
        self.deltas_applied += 1
        return version

    def apply_label_delta(self, nodes, labels) -> int:
        """Update labels; rebuild immediately if churn passes threshold,
        otherwise keep serving the current epoch's Z."""
        version = self.store.apply_labels(nodes, labels)
        self.version = version
        if self.churn > self.rebuild_churn:
            self._rebuild()
        return version

    def compact(self) -> dict:
        """Compact the store and start a fresh epoch."""
        info = self.store.compact()
        self._rebuild()
        return info

    def refresh(self) -> None:
        """Force a rebuild (e.g. to pick up sub-threshold label churn)."""
        self._rebuild()
