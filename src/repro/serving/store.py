"""Versioned in-memory graph store with an append-only delta log.

The store separates a compacted **base** edge multiset from a log of
deltas applied since the last compaction.  Because GEE is linear in
the edge multiset, a deletion is represented exactly as the same edge
with negated weight — the materialized multiset `base ++ log` always
reproduces the live graph, and `compact()` folds the log into the base
by coalescing duplicate (u, v) keys and dropping ~zero weights.

Every applied delta (edge batch or label update) bumps `version`, the
store's logical clock; readers use it to tell which graph state a
result was computed against (see the version/epoch model in
`repro.serving.__init__`).  Label updates materialize straight into Y
rather than the log — they are not replayable against Z and only feed
the service's next rebuild.  Snapshots go through `graph/io.py`
(`save_graph`/`load_graph`) plus a sibling `.meta.npz` for labels and
counters, so a snapshot can be re-served or streamed back through
`ShardedEdgeReader`.

The store also maintains the multiset's **content fingerprint** — the
key of the encoder's persistent plan cache — incrementally: the base's
fingerprint is hashed once, then each logged edge batch is CHAINED on
in O(batch) (`extend_fingerprint`), so a store serving billions of
edges never rehashes its edge list.  `edges()` stamps the fingerprint
onto the materialized graph; two replicas replaying the same snapshot
+ delta sequence therefore agree on it and share plan-cache entries.
Label updates leave it untouched (labels are not part of the edge
multiset); compaction rewrites the base arrays and rehashes them once.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.graph.edges import (Graph, bucket_size,   # noqa: F401 (re-export)
                               extend_fingerprint)
from repro.graph.io import load_graph, save_graph

_ZERO_W = 1e-12       # coalesced weights below this are dropped


@dataclass(frozen=True)
class EdgeDelta:
    """One logged edge batch.  `w` is already sign-folded: deletions are
    stored with negative weights (exact under GEE's linearity)."""
    version: int
    u: np.ndarray
    v: np.ndarray
    w: np.ndarray


class GraphStore:
    """Append-only delta log over a compacted base edge multiset."""

    def __init__(self, g: Graph, Y: np.ndarray, K: int):
        g.validate()
        self.base = Graph(np.asarray(g.u, np.int32),
                          np.asarray(g.v, np.int32),
                          np.asarray(g.w, np.float32), g.n)
        self.Y = np.asarray(Y, np.int32).copy()
        assert self.Y.shape == (g.n,)
        self.K = int(K)
        self.version = 0
        self.compactions = 0
        self.edge_log: list[EdgeDelta] = []
        self._fp = self.base.fingerprint()     # hashed once, then chained
        self._coalesced = False    # base known duplicate-free? (compact)

    # -- delta application ------------------------------------------------

    def apply_edges(self, u, v, w, *, delete: bool = False) -> int:
        """Log an edge insert (or delete) batch; returns the new version.

        Empty batches are legal (a no-op that still bumps the clock)."""
        u = np.asarray(u, np.int32)
        v = np.asarray(v, np.int32)
        w = np.asarray(w, np.float32)
        Graph(u, v, w, self.base.n).validate()
        self.version += 1
        w = -w if delete else w
        self.edge_log.append(EdgeDelta(self.version, u, v, w))
        self._fp = extend_fingerprint(self._fp, u, v, w)   # O(batch)
        return self.version

    def apply_labels(self, nodes, labels) -> int:
        """Point-update labels; returns the new version.

        Labels are materialized straight into Y (not logged): a label
        change is not replayable against Z — the service re-derives the
        projection weights from Y on its next rebuild."""
        nodes = np.asarray(nodes, np.int64)
        labels = np.asarray(labels, np.int32)
        assert nodes.shape == labels.shape
        if nodes.size:
            assert nodes.min() >= 0 and nodes.max() < self.base.n
            assert labels.min() >= -1 and labels.max() < self.K
        self.version += 1
        self.Y[nodes] = labels
        return self.version

    # -- materialization --------------------------------------------------

    @property
    def n(self) -> int:
        return self.base.n

    @property
    def log_edges(self) -> int:
        return sum(d.u.shape[0] for d in self.edge_log)

    def fingerprint(self) -> str:
        """Content fingerprint of the live multiset (chained, O(batch)
        per delta — never a full rehash while the log grows)."""
        return self._fp

    def edges(self) -> Graph:
        """Current edge multiset = base ++ log (deletes as negative w),
        fingerprint pre-stamped so downstream plan caching never
        rehashes the materialized arrays."""
        if not self.edge_log:
            return self.base
        g = Graph(
            np.concatenate([self.base.u] + [d.u for d in self.edge_log]),
            np.concatenate([self.base.v] + [d.v for d in self.edge_log]),
            np.concatenate([self.base.w] + [d.w for d in self.edge_log]),
            self.base.n)
        g._fp = self._fp
        return g

    def churn_fraction(self, Y_epoch: np.ndarray) -> float:
        """Fraction of nodes whose label differs from an epoch snapshot."""
        return float((self.Y != Y_epoch).mean()) if self.n else 0.0

    # -- compaction & snapshots -------------------------------------------

    def compact(self) -> dict:
        """Fold the log into the base: coalesce duplicate (u, v) keys,
        sum weights, drop ~zero entries.  Logical content is unchanged
        (GEE is linear, so coalescing parallel edges is exact); the
        version counter is NOT bumped.

        A no-op compaction (empty log over an already-coalesced base —
        e.g. a snapshot right after a compact, the engine's checkpoint
        path) returns early: no O(s log s) re-sort, no fp rehash, no
        base rewrite."""
        if not self.edge_log and self._coalesced:
            return {"edges_before": self.base.s,
                    "edges_after": self.base.s,
                    "compactions": self.compactions}
        g = self.edges()
        before = g.s
        key = g.u.astype(np.int64) * g.n + g.v
        uniq, inv = np.unique(key, return_inverse=True)
        w = np.zeros(uniq.shape[0], np.float64)
        np.add.at(w, inv, g.w.astype(np.float64))
        keep = np.abs(w) > _ZERO_W
        uniq, w = uniq[keep], w[keep]
        self.base = Graph((uniq // g.n).astype(np.int32),
                          (uniq % g.n).astype(np.int32),
                          w.astype(np.float32), g.n)
        self.edge_log.clear()
        # coalescing rewrote the arrays: rehash once (plan artifacts
        # depend on the physical edge list, so the identity SHOULD move)
        self._fp = self.base.fingerprint()
        self.compactions += 1
        self._coalesced = True
        return {"edges_before": before, "edges_after": self.base.s,
                "compactions": self.compactions}

    def snapshot(self, prefix: str) -> None:
        """Write `<prefix>.edges.npz` (via graph/io) + `<prefix>.meta.npz`.

        Compacts first so the snapshot is the minimal coalesced multiset
        and the delta log is empty on reload."""
        self.compact()
        save_graph(prefix + ".edges.npz", self.base)
        np.savez_compressed(prefix + ".meta.npz", Y=self.Y,
                            K=np.int64(self.K),
                            version=np.int64(self.version),
                            compactions=np.int64(self.compactions))

    @classmethod
    def load(cls, prefix: str) -> "GraphStore":
        g = load_graph(prefix + ".edges.npz")
        meta = np.load(prefix + ".meta.npz")
        store = cls(g, meta["Y"], int(meta["K"]))
        store.version = int(meta["version"])
        store.compactions = int(meta["compactions"])
        store._coalesced = True        # snapshots are written compacted
        return store
