"""EmbeddingShard: one worker owning a contiguous slice of Z rows.

The sharded serving engine partitions the embedding by ROW (GOSH-style
partitioned embedding state): shard i is the single writer and single
reader for rows [lo, hi).  GEE's map-over-edges form makes the routed
sub-multiset self-sufficient — every edge incident to an owned row is
in it — so the shard's owned slice is exact in isolation, and an edge
delta touches only the shards owning its endpoint rows.

Each shard wraps its own `repro.encoder.Embedder` (streaming backend by
default), fitted on the routed sub-multiset.  Epoch rebuilds therefore
hit the encoder's plan cache per shard: the engine chains each shard's
sub-multiset fingerprint delta-by-delta (mirroring `GraphStore`), so a
rebuild under churned labels — new routed arrays, same content — is a
tier-2 disk hit, and a second replica or a recovered engine skips host
preprocessing entirely.

Memory: a proper sub-range shard configures its Embedder with
``EncoderConfig.row_partition=(lo, hi)``, so the backend allocates only
the owned (hi - lo, K) accumulator — per-shard device memory is
O(n/p + chunk), not O(n), and adding shards genuinely shrinks each
worker's footprint (the bench reports per-shard peak accumulator
bytes).  Labels stay global (an owned row's value depends on its
neighbors' labels, which live on other shards).  The degenerate
full-range shard — (lo, hi) == (0, n), the 1-shard deployment and the
`EmbeddingService` compat path — keeps an unpartitioned Embedder so
the old single-host surface (`engine.embedder`, tier-1 plan hits off a
quiet store) is byte-for-byte unchanged.
"""
from __future__ import annotations

from typing import Optional, Union

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.encoder import Embedder, EncoderConfig
from repro.graph.edges import Graph
from repro.serving import queries as Q


class EmbeddingShard:
    """Owns Z rows [lo, hi); embeds and serves only its slice."""

    def __init__(self, shard_id: int, lo: int, hi: int, *, K: int,
                 n: Optional[int] = None, chunk_size: int = 1 << 20,
                 backend: str = "streaming",
                 plan_cache: Union[str, None] = "auto"):
        self.shard_id = int(shard_id)
        self.lo, self.hi = int(lo), int(hi)
        #: owned-rows mode: the Embedder accumulates ONLY [lo, hi).
        #: Unknown total n (legacy direct construction) or a full-range
        #: slice keeps the unpartitioned Embedder.
        self.owned_only = (n is not None
                           and (self.lo, self.hi) != (0, int(n)))
        self.embedder = Embedder(
            EncoderConfig(K=int(K), chunk_size=int(chunk_size),
                          row_partition=((self.lo, self.hi)
                                         if self.owned_only else None)),
            backend=backend, plan_cache=plan_cache)
        #: pallas shards serve queries through the fused
        #: normalize+cosine+top-k kernel and fold deltas through the
        #: fused apply+renormalize kernel (same blocking policy and
        #: tie contract — answers are bit-identical to the jitted
        #: blocked scan, conformance-tested)
        self._fused = (backend == "pallas")
        self._Zn: Optional[jnp.ndarray] = None
        #: optional IVF index over the owned slice (engine-managed:
        #: the engine owns the shared quantizer centroids and the
        #: churn-gated re-quantization policy; `build_index` creates it)
        self._index = None

    # -- write path --------------------------------------------------------

    def build(self, graph_or_source, Y: np.ndarray) -> None:
        """(Re)fit on the routed sub-multiset under epoch labels `Y`.

        Labels are GLOBAL (O(n), every shard holds them): an owned
        row's value depends on the labels of its neighbors, which live
        on other shards."""
        self.embedder.fit(graph_or_source, Y)
        self._Zn = None
        if obs.enabled():
            # the owned-rows memory contract as a live series: per-shard
            # accumulator bytes shrink ~ n/p as shards are added
            obs.gauge("repro_serving_shard_accumulator_bytes",
                      self.accumulator_nbytes, shard=str(self.shard_id))

    def apply_delta(self, sub: Graph) -> None:
        """Fold a routed edge sub-batch into Z (weights sign-folded
        upstream; O(batch), exact by linearity).  In owned-rows mode
        the Embedder buckets the batch by owned destination itself.
        Pallas shards use the fused apply+renormalize kernel, so the
        Zn cache is REFILLED by the same pass instead of invalidated —
        the partial_fit-then-query turnaround never re-reads Z."""
        if sub.s:
            if self._fused:
                self._Zn = self.embedder.partial_fit_norm(sub)
            else:
                self.embedder.partial_fit(sub)
                self._Zn = None

    # -- read path (everything leaves in global coordinates) ---------------

    @property
    def Z_owned(self) -> jnp.ndarray:
        """The owned (hi - lo, K) slice — the only rows this shard may
        serve.  In owned-rows mode this IS the whole accumulator; the
        unpartitioned fallback slices its full-width Z (whose unowned
        rows are partial sums)."""
        if self.owned_only:
            return self.embedder.Z_
        return self.embedder.Z_[self.lo:self.hi]

    @property
    def accumulator_nbytes(self) -> int:
        """Device bytes held by this shard's Z accumulator — the
        memory the owned-rows plan shrinks from O(n) to O(n/p)."""
        Z = self.embedder.Z_
        if Z is None:
            return 0
        return int(np.prod(Z.shape)) * Z.dtype.itemsize

    def rows(self, nodes: np.ndarray) -> jnp.ndarray:
        """Z rows for OWNED global node ids.

        A real IndexError, not an assert: jnp gather silently CLAMPS
        out-of-range indices, so a routing bug would otherwise return
        plausible wrong rows (and `python -O` strips asserts)."""
        nodes = np.asarray(nodes)
        if nodes.size and (nodes.min() < self.lo
                           or nodes.max() >= self.hi):
            raise IndexError(
                f"shard {self.shard_id} owns rows [{self.lo}, "
                f"{self.hi}), got range [{nodes.min()}, {nodes.max()}]")
        if self.owned_only:
            return self.embedder.Z_[jnp.asarray(nodes - self.lo)]
        return self.embedder.Z_[jnp.asarray(nodes)]

    def normalized(self) -> jnp.ndarray:
        """Row-normalized owned slice, cached until the next write."""
        if self._Zn is None:
            self._Zn = Q.normalize_rows(self.Z_owned)
        return self._Zn

    def class_stats(self, Y: np.ndarray):
        """Per-class (sums, counts) over owned rows; the engine reduces
        across shards and divides once for global centroids."""
        return Q.class_sums(self.Z_owned,
                            jnp.asarray(np.asarray(Y)[self.lo:self.hi]),
                            K=self.embedder.config.K)

    def topk_candidates(self, q, qnodes, *, k: int, block_rows: int):
        """This shard's top-k candidates for unit-norm query vectors
        `q` — global-id-stamped, ready for `queries.merge_topk`.

        Pallas shards answer through the fused kernel: cold (no Zn
        cached) the kernel normalizes in-flight and its normalized
        slice output becomes the cache; warm it scans the cached Zn.
        Either way the (idx, score) answer is bit-identical to the
        jitted blocked scan."""
        if self._fused:
            if self._Zn is None:
                idx, vals, Zn = Q.topk_cosine_fused_norm(
                    self.Z_owned, q, qnodes, k=k, block_rows=block_rows,
                    row_offset=self.lo)
                self._Zn = Zn
                return idx, vals
            return Q.topk_cosine_fused(self._Zn, q, qnodes, k=k,
                                       block_rows=block_rows,
                                       row_offset=self.lo)
        return Q.topk_cosine_q(self.normalized(), q, qnodes, k=k,
                               block_rows=block_rows, row_offset=self.lo)

    # -- IVF index over the owned slice (repro.index) ----------------------

    @property
    def index(self):
        """The shard's `IVFIndex`, or None when indexing is off."""
        return self._index

    def build_index(self, centroids) -> None:
        """(Re)quantize the owned slice under the engine's shared
        quantizer `centroids` — a fresh index if none exists yet."""
        from repro.index import IVFIndex
        if self._index is None:
            self._index = IVFIndex(K=self.embedder.config.K,
                                   row_offset=self.lo)
        self._index.build(self.normalized(), centroids)

    def update_index(self, touched_global: np.ndarray) -> int:
        """Delta-maintain the index for GLOBAL node ids this shard owns
        (the rows an edge batch just rewrote); returns rows that
        changed cell (the engine's re-quantization churn signal)."""
        if self._index is None:
            return 0
        local = np.asarray(touched_global, np.int64) - self.lo
        return self._index.update_rows(self.normalized(), local)

    def index_topk(self, q, qnodes, probe, *, k: int, block_rows: int):
        """This shard's top-k candidates restricted to the probed
        cells — same global-id-stamped contract as `topk_candidates`,
        plus the scanned-row count for the scan-fraction metric."""
        return self._index.topk(self.normalized(), q, qnodes, probe,
                                k=k, block_rows=block_rows)

    @property
    def plan_stats(self) -> dict:
        return self.embedder.plan_stats
