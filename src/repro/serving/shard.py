"""EmbeddingShard: one worker owning a contiguous slice of Z rows.

The sharded serving engine partitions the embedding by ROW (GOSH-style
partitioned embedding state): shard i is the single writer and single
reader for rows [lo, hi).  GEE's map-over-edges form makes the routed
sub-multiset self-sufficient — every edge incident to an owned row is
in it — so the shard's owned slice is exact in isolation, and an edge
delta touches only the shards owning its endpoint rows.

Each shard wraps its own `repro.encoder.Embedder` (streaming backend by
default), fitted on the routed sub-multiset.  Epoch rebuilds therefore
hit the encoder's plan cache per shard: the engine chains each shard's
sub-multiset fingerprint delta-by-delta (mirroring `GraphStore`), so a
rebuild under churned labels — new routed arrays, same content — is a
tier-2 disk hit, and a second replica or a recovered engine skips host
preprocessing entirely.

Single-host note: the Embedder accumulates a full-width (n, K) Z and
the shard reads only its owned rows.  The boundary is message-shaped —
routed edge batches in, owned rows / global-id-stamped top-k candidates
/ per-class partial sums out — which is what a true multi-host
deployment needs; restricting the accumulator itself to owned rows is
a backend-level optimization this slicing deliberately leaves behind
the same interface.
"""
from __future__ import annotations

from typing import Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.encoder import Embedder, EncoderConfig
from repro.graph.edges import Graph
from repro.serving import queries as Q


class EmbeddingShard:
    """Owns Z rows [lo, hi); embeds and serves only its slice."""

    def __init__(self, shard_id: int, lo: int, hi: int, *, K: int,
                 chunk_size: int = 1 << 20, backend: str = "streaming",
                 plan_cache: Union[str, None] = "auto"):
        self.shard_id = int(shard_id)
        self.lo, self.hi = int(lo), int(hi)
        self.embedder = Embedder(
            EncoderConfig(K=int(K), chunk_size=int(chunk_size)),
            backend=backend, plan_cache=plan_cache)
        self._Zn: Optional[jnp.ndarray] = None

    # -- write path --------------------------------------------------------

    def build(self, graph_or_source, Y: np.ndarray) -> None:
        """(Re)fit on the routed sub-multiset under epoch labels `Y`.

        Labels are GLOBAL (O(n), every shard holds them): an owned
        row's value depends on the labels of its neighbors, which live
        on other shards."""
        self.embedder.fit(graph_or_source, Y)
        self._Zn = None

    def apply_delta(self, sub: Graph) -> None:
        """Fold a routed edge sub-batch into Z (weights sign-folded
        upstream; O(batch), exact by linearity)."""
        if sub.s:
            self.embedder.partial_fit(sub)
            self._Zn = None

    # -- read path (everything leaves in global coordinates) ---------------

    @property
    def Z_owned(self) -> jnp.ndarray:
        """The owned (hi - lo, K) slice — the only rows this shard may
        serve; unowned accumulator rows are partial sums."""
        return self.embedder.Z_[self.lo:self.hi]

    def rows(self, nodes: np.ndarray) -> jnp.ndarray:
        """Z rows for OWNED global node ids."""
        nodes = np.asarray(nodes)
        if nodes.size:
            assert nodes.min() >= self.lo and nodes.max() < self.hi, \
                f"shard {self.shard_id} asked for unowned rows"
        return self.embedder.Z_[jnp.asarray(nodes)]

    def normalized(self) -> jnp.ndarray:
        """Row-normalized owned slice, cached until the next write."""
        if self._Zn is None:
            self._Zn = Q.normalize_rows(self.Z_owned)
        return self._Zn

    def class_stats(self, Y: np.ndarray):
        """Per-class (sums, counts) over owned rows; the engine reduces
        across shards and divides once for global centroids."""
        return Q.class_sums(self.Z_owned,
                            jnp.asarray(np.asarray(Y)[self.lo:self.hi]),
                            K=self.embedder.config.K)

    def topk_candidates(self, q, qnodes, *, k: int, block_rows: int):
        """This shard's top-k candidates for unit-norm query vectors
        `q` — global-id-stamped, ready for `queries.merge_topk`."""
        return Q.topk_cosine_q(self.normalized(), q, qnodes, k=k,
                               block_rows=block_rows, row_offset=self.lo)

    @property
    def plan_stats(self) -> dict:
        return self.embedder.plan_stats
