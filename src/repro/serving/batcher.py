"""Microbatching request queue for the serving engine.

Concurrent read requests of the same kind are coalesced into one
kernel launch (node arrays concatenated, one gather / predict / top-k
call, results split back per ticket).  Writes are barriers: a write
request flushes all reads queued before it, then runs alone against
the store's version counter, so every read observes a single
well-defined (version, epoch) and writes apply in submission order.

The batcher is transport only — it talks to any **target** exposing
the small serving protocol (`n`, `version`, `epoch`,
`apply_edge_delta`, `apply_label_delta`, `query_embed`,
`query_predict`, `query_topk`): the sharded `ServingEngine` and the
1-shard `EmbeddingService` shim both do.  Kernel dispatch lives on the
target, so the sharded scatter/gather path and the single-host path
are interchangeable behind the same queue.

Each ticket records the (version, epoch) it executed against plus wall
latency; `stats()` aggregates per-kind counts, batch sizes, end-to-end
latency, and execution throughput — the observability surface
`server.py` prints.

A bad request (out-of-range node ids, malformed batch) fails only its
own ticket(s): the exception is captured on the ticket and re-raised
from `ticket.result()`; the rest of the queue is still served, so a
producer can never be left hanging on a poisoned flush.

Thread-safe: `submit` may be called from many threads; `flush` drains
the queue under a lock (single consumer — `ServingEngine.start()` runs
it in a background thread so submitters never block on kernel
launches).  Tickets carry an Event so producers can block on
`ticket.result()`.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro import obs
from repro.graph.edges import bucket_size

READ_KINDS = ("embed", "predict", "topk")
WRITE_KINDS = ("insert", "delete", "labels")


@dataclass
class Ticket:
    kind: str
    payload: Any
    submitted: float
    done: threading.Event = field(default_factory=threading.Event)
    value: Any = None
    error: Optional[BaseException] = None
    version: int = -1
    epoch: int = -1
    latency: float = 0.0

    def result(self, timeout: Optional[float] = None):
        if not self.done.wait(timeout):
            raise TimeoutError(f"{self.kind} ticket not served")
        if self.error is not None:
            raise self.error
        return self.value


class _KindStats:
    __slots__ = ("requests", "batches", "items", "wall", "exec_wall",
                 "errors")

    def __init__(self):
        self.requests = 0
        self.batches = 0
        self.items = 0
        self.wall = 0.0          # sum of per-ticket end-to-end latencies
        self.exec_wall = 0.0     # kernel/apply execution time per batch
        self.errors = 0


class MicroBatcher:
    """Coalesces reads, serializes writes, keeps per-kind stats."""

    def __init__(self, target, *, topk: int = 10,
                 topk_block_rows: int = 1 << 14,
                 topk_mode: str = "exact",
                 topk_nprobe: Optional[int] = None):
        self.target = target
        self.topk = int(topk)
        self.topk_block_rows = int(topk_block_rows)
        #: "ivf" routes coalesced top-k batches through the target's
        #: IVF index (`repro.index`); targets without mode support
        #: (the 1-shard EmbeddingService shim) only accept "exact"
        self.topk_mode = str(topk_mode)
        self.topk_nprobe = topk_nprobe
        self._lock = threading.Lock()
        self._queue: list[Ticket] = []   # guarded by: _lock
        # guarded by: _lock
        self._stats = {k: _KindStats()
                       for k in READ_KINDS + WRITE_KINDS}

    @property
    def service(self):
        """Back-compat alias for the serving target."""
        return self.target

    # -- producer side -----------------------------------------------------

    def submit(self, kind: str, payload: Any) -> Ticket:
        """Enqueue a request.  Reads: payload = node array.  Writes:
        insert/delete -> (u, v, w); labels -> (nodes, labels)."""
        # repro: allow(lock-discipline) — membership test on a key set fixed at construction; only the values behind it mutate
        assert kind in self._stats, kind
        t = Ticket(kind, payload, time.perf_counter())
        with self._lock:
            self._queue.append(t)
            self._stats[kind].requests += 1
        obs.counter("repro_serving_batcher_requests_total", kind=kind)
        return t

    # -- consumer side -----------------------------------------------------

    def flush(self) -> int:
        """Drain the queue: coalesced read batches between write barriers.
        Returns the number of tickets served.

        Group-commit interplay: when the target's WAL batches fsync
        barriers (``group_commit_*``), successful writes are APPLIED in
        order as usual but their tickets are held back and released
        only after one ``target.sync_durable()`` covering the whole
        drain — a submitter learns its version strictly after the fsync
        that made the write power-loss durable.  Reads still coalesce
        between the write barriers and are never held (they see applied
        state, same as before)."""
        with self._lock:
            batch, self._queue = self._queue, []
        served = 0
        reads: list[Ticket] = []
        deferred: list[tuple[Ticket, int, int]] = []
        defer = self._defer_writes()
        for t in batch:
            if t.kind in WRITE_KINDS:
                served += self._run_reads(reads)
                reads = []
                served += self._run_write(
                    t, deferred if defer else None)
            else:
                reads.append(t)
        served += self._run_reads(reads)
        if deferred:
            try:
                self.target.sync_durable()
            except Exception as e:       # barrier failed: the writes
                for t, _, _ in deferred:     # are NOT durable — error
                    self._finish(t, None, e)     # the tickets
            else:
                for t, version, epoch in deferred:
                    self._finish(t, version, version=version,
                                 epoch=epoch)
        return served

    def _defer_writes(self) -> bool:
        """Hold write tickets for a covering fsync barrier?  Only when
        the target's WAL actually batches barriers — otherwise ticket
        latency semantics are unchanged."""
        wal = getattr(self.target, "wal", None)
        return (wal is not None and getattr(wal, "group_commit", False)
                and callable(getattr(self.target, "sync_durable",
                                     None)))

    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    # -- execution ---------------------------------------------------------

    def _finish(self, t: Ticket, value: Any,
                error: Optional[BaseException] = None,
                version: Optional[int] = None,
                epoch: Optional[int] = None) -> None:
        t.value = value
        t.error = error
        # deferred write tickets pass the (version, epoch) captured at
        # APPLY time — by release time later writes may have advanced
        # the live counters past this ticket's write
        t.version = self.target.version if version is None else version
        t.epoch = self.target.epoch if epoch is None else epoch
        t.latency = time.perf_counter() - t.submitted
        with self._lock:          # stats() reads under the same lock
            st = self._stats[t.kind]
            st.wall += t.latency
            if error is not None:
                st.errors += 1
        # EVERY ticket — reads AND write barriers — lands in the same
        # per-kind latency histogram, so the distribution's count equals
        # the submit count (tested; write barriers used to be invisible
        # in latency summaries)
        if obs.enabled():
            obs.observe("repro_serving_batcher_ticket_seconds",
                        t.latency, kind=t.kind)
            if error is not None:
                obs.counter("repro_serving_batcher_errors_total",
                            kind=t.kind)
        t.done.set()

    def _count_batch(self, kind: str, items: int, exec_s: float) -> None:
        with self._lock:
            st = self._stats[kind]
            st.batches += 1
            st.items += items
            st.exec_wall += exec_s
        if obs.enabled():
            obs.counter("repro_serving_batcher_batches_total", kind=kind)
            if items:
                obs.counter("repro_serving_batcher_items_total", items,
                            kind=kind)
                obs.observe("repro_serving_batcher_batch_items", items,
                            kind=kind)
            obs.observe("repro_serving_batcher_exec_seconds", exec_s,
                        kind=kind)

    def _run_write(self, t: Ticket,
                   deferred: Optional[list] = None) -> int:
        t0 = time.perf_counter()
        try:
            if t.kind == "labels":
                nodes, labels = t.payload
                version = self.target.apply_label_delta(nodes, labels)
                items = len(np.atleast_1d(nodes))
            else:
                u, v, w = t.payload
                version = self.target.apply_edge_delta(
                    u, v, w, delete=(t.kind == "delete"))
                items = len(np.atleast_1d(u))
        except Exception as e:        # bad batch: fail the ticket, not
            self._count_batch(t.kind, 0, 0.0)
            self._finish(t, None, e)  # the queue behind it
        else:
            self._count_batch(t.kind, items, time.perf_counter() - t0)
            if deferred is not None:  # released after the fsync barrier
                deferred.append((t, version, self.target.epoch))
            else:
                self._finish(t, version)
        return 1

    def _run_reads(self, tickets: list[Ticket]) -> int:
        """One kernel launch per kind present in this read window.
        Node batches are padded to power-of-two buckets (node 0; the
        pad tail is never split back to a ticket) so the jitted kernels
        compile once per bucket, mirroring the write path."""
        by_kind: dict[str, list[Ticket]] = {}
        for t in tickets:
            by_kind.setdefault(t.kind, []).append(t)
        n = self.target.n
        for kind, group in by_kind.items():
            served, nodes, sizes = [], [], []
            for t in group:
                try:
                    x = np.atleast_1d(np.asarray(t.payload, np.int32))
                    # JAX gathers clamp out-of-range indices — reject
                    # them here or reads return silently-wrong rows
                    if x.size and (x.min() < 0 or x.max() >= n):
                        raise IndexError(
                            f"{kind} node ids outside [0, {n})")
                except Exception as e:     # fail this ticket only
                    self._finish(t, None, e)
                    continue
                served.append(t)
                nodes.append(x)
                sizes.append(x.shape[0])
            if not served:
                continue
            t0 = time.perf_counter()
            try:
                cat = np.concatenate(nodes)
                padded = np.zeros(bucket_size(cat.shape[0]), np.int32)
                padded[:cat.shape[0]] = cat
                parts = self._run_read_kernel(kind, padded, sizes)
            except Exception as e:
                self._count_batch(kind, 0, 0.0)
                for t in served:
                    self._finish(t, None, e)
            else:
                self._count_batch(kind, cat.shape[0],
                                  time.perf_counter() - t0)
                for t, part in zip(served, parts):
                    self._finish(t, part)
        return len(tickets)

    def _run_read_kernel(self, kind: str, cat: np.ndarray,
                         sizes: list[int]) -> list:
        if kind == "embed":
            out = self.target.query_embed(cat)
            return self._split(np.asarray(out), sizes)
        if kind == "predict":
            pred, score = self.target.query_predict(cat)
            return list(zip(self._split(np.asarray(pred), sizes),
                            self._split(np.asarray(score), sizes)))
        kwargs = {}
        if self.topk_mode != "exact":     # only pass when asked: keeps
            kwargs["mode"] = self.topk_mode   # mode-less targets working
            kwargs["nprobe"] = self.topk_nprobe
        idx, val = self.target.query_topk(
            cat, k=self.topk, block_rows=self.topk_block_rows, **kwargs)
        return list(zip(self._split(idx, sizes),
                        self._split(val, sizes)))

    @staticmethod
    def _split(arr: np.ndarray, sizes: list[int]) -> list[np.ndarray]:
        out, off = [], 0
        for s in sizes:
            out.append(arr[off:off + s])
            off += s
        return out

    # -- observability -----------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            rows = {}
            for kind, st in self._stats.items():
                if not st.requests:
                    continue
                rows[kind] = {
                    "requests": st.requests, "batches": st.batches,
                    "items": st.items, "errors": st.errors,
                    "mean_batch": st.items / max(st.batches, 1),
                    # end-to-end (incl. queue wait), per request
                    "mean_latency_ms": 1e3 * st.wall / max(st.requests, 1),
                    # kernel/apply throughput: items over *execution*
                    # time, counted once per coalesced batch
                    "items_per_s": (st.items / st.exec_wall
                                    if st.exec_wall else 0.0),
                }
            return rows
