"""ServingEngine: the sharded, durable, async front door for online GEE.

The serving subsystem's public API is a **deployment**, not an object
holding all of Z in one place:

* a **shard router** — Z rows are partitioned across N
  `EmbeddingShard` workers by `graph.partition.RowPartition`; edge
  deltas fan out only to the shards owning their endpoint rows, and
  queries scatter/gather (row gathers go to owners; top-k scores every
  shard's owned slice with global-id-stamped candidates and merges the
  per-shard lists — `queries.merge_topk`).  Each sub-range shard's
  Embedder runs the encoder's owned-rows plan
  (`EncoderConfig.row_partition`), so a shard allocates only its
  (n/p, K) accumulator slice — per-shard device memory shrinks as
  shards are added (`stats()["shard_accumulator_bytes"]`);
* a **durable write-ahead delta log** (`serving.wal`) — every accepted
  mutation is appended BEFORE it is applied, so a crashed engine
  recovers by replaying the WAL suffix on top of the last snapshot and
  reconstructs the exact `(version, epoch, fingerprint)` state
  (tested, including torn-tail truncation);
* an **async flush/compaction loop** — `start()` runs a background
  consumer that drains a `MicroBatcher` (reads coalesce between write
  barriers; submitters never block on kernel launches) and rolls a
  checkpoint — snapshot + WAL rotation — whenever the log outgrows
  `checkpoint_bytes`, so log growth is bounded without a stop-the-world
  pause on the submit path.

The version/epoch model is unchanged from `repro.serving.__init__`;
the epoch policy (delta-fold edges, rebuild on label churn past
`rebuild_churn`) now drives all shards together, and every rebuild is
a plan-cache hit per shard: the engine chains each shard's routed
sub-multiset fingerprint delta-by-delta, mirroring the store's own
chained fingerprint.

`EmbeddingService` (service.py) remains as the 1-shard volatile
special case — a thin compat shim over this class.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.obs.health import DEGRADED, SERVING, STARTING, HealthTracker
from repro.graph.edges import Graph, edge_fingerprint, extend_fingerprint
from repro.graph.partition import RowPartition
from repro.graph.sources import StoreSource
from repro.serving import queries as Q
from repro.serving import wal as W
from repro.serving.shard import EmbeddingShard
from repro.serving.store import GraphStore
from repro.serving.wal import WriteAheadLog

_MANIFEST = "MANIFEST"
_FORMAT = 1


def _atomic_write_json(path: str, obj: dict) -> None:
    tmp = path + f".tmp{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)


class ServingEngine:
    """Partitioned, durable, async serving deployment for a live GEE.

    Construct fresh over a `GraphStore` (pass ``data_dir`` to make it
    durable — the engine snapshots generation 0 and opens a WAL), or
    recover an existing deployment with :meth:`open`.
    """

    def __init__(self, store: GraphStore, *, data_dir: Optional[str] = None,
                 num_shards: int = 1, rebuild_churn: float = 0.05,
                 chunk_size: int = 1 << 20, backend: str = "streaming",
                 plan_cache: Union[str, None] = "auto",
                 fsync: bool = False, degraded_append_s: float = 0.5,
                 index: Optional[str] = None, index_churn: float = 0.25,
                 nprobe: Optional[int] = None,
                 transport: str = "local",
                 shard_addrs: Optional[list] = None,
                 replicas: int = 0,
                 replica_addrs: Optional[list] = None,
                 rpc_timeout_s: float = 60.0,
                 group_commit_ms: Optional[float] = None,
                 group_commit_bytes: Optional[int] = None,
                 _boot: bool = True):
        if index not in (None, "ivf"):
            raise ValueError(f"unknown index mode {index!r} "
                             "(None or 'ivf')")
        if transport not in ("local", "socket"):
            raise ValueError(f"unknown transport {transport!r} "
                             "('local' or 'socket')")
        self.store = store
        self.source = StoreSource(store)
        self.rebuild_churn = float(rebuild_churn)
        self.fsync = bool(fsync)
        #: WAL group-commit knobs (fsync batching; see serving.wal) —
        #: carried on the engine so checkpoint WAL rotation re-creates
        #: the log with the same policy
        self.group_commit_ms = group_commit_ms
        self.group_commit_bytes = group_commit_bytes
        #: WAL append (write+flush[+fsync]) latency past this marks the
        #: deployment `degraded` in health() — the disk is the write
        #: path's throughput ceiling, so a slow append IS an incident
        self.degraded_append_s = float(degraded_append_s)
        self._health = HealthTracker("serving")
        self.partition = RowPartition(store.n, num_shards)
        self.transport = transport
        self.rpc_timeout_s = float(rpc_timeout_s)
        #: spawned worker processes the engine owns (close() reaps)
        self._shard_procs: list = []
        self._replica_procs: list = []
        #: RemoteReplica clients reads fan out across (round-robin)
        self._replicas: list = []
        self._replica_rr = 0             # guarded by: _replica_mu
        #: per-replica last fallback reason (health() surfaces these)
        self._replica_events: dict = {}  # guarded by: _replica_mu
        #: serializes replica round-robin + event state so reads can
        #: fan out WITHOUT the engine lock; ordering: _mu may be held
        #: when taking _replica_mu (health()), never the reverse
        self._replica_mu = threading.Lock()
        if transport == "local":
            if shard_addrs:
                raise ValueError("shard_addrs requires "
                                 "transport='socket'")
            # n=store.n turns every proper sub-range shard into an
            # owned-rows Embedder (row_partition): the accumulator is
            # (n/p, K) per shard, not (n, K) — the 1-shard deployment
            # keeps the unpartitioned single-host fast path
            self.shards = [
                EmbeddingShard(i, *self.partition.slice(i), K=store.K,
                               n=store.n, chunk_size=chunk_size,
                               backend=backend, plan_cache=plan_cache)
                for i in range(num_shards)]
        else:
            # same partition, same call surface, one process boundary
            # away: RemoteShard is call-compatible with EmbeddingShard,
            # so everything below this point is transport-blind
            from repro.transport.remote import RemoteShard
            if shard_addrs is not None:
                if len(shard_addrs) != num_shards:
                    raise ValueError(
                        f"{len(shard_addrs)} shard_addrs for "
                        f"{num_shards} shards")
                self.shards = [
                    RemoteShard(a, i, *self.partition.slice(i),
                                timeout_s=self.rpc_timeout_s)
                    for i, a in enumerate(shard_addrs)]
            else:
                from repro.transport.procs import spawn_shard_worker
                procs = [spawn_shard_worker(
                    i, *self.partition.slice(i), K=store.K, n=store.n,
                    chunk_size=chunk_size, backend=backend,
                    plan_cache=plan_cache, wait=False)
                    for i in range(num_shards)]
                self.shards = []
                for i, proc in enumerate(procs):  # one import latency,
                    proc.handshake()              # not num_shards
                    self._shard_procs.append(proc)
                    self.shards.append(RemoteShard(
                        proc.addr, i, *self.partition.slice(i),
                        timeout_s=self.rpc_timeout_s, proc=proc))
        if (replicas or replica_addrs) and data_dir is None and _boot:
            raise ValueError("read replicas tail the WAL: construct "
                             "with data_dir=... (durable) first")
        self._pending_replicas = (replicas, replica_addrs)
        self._chunk_size = chunk_size
        self._backend = backend
        self._plan_cache = plan_cache
        self.epoch = 0
        self.rebuilds = 0
        self.deltas_applied = 0
        self.checkpoints = 0
        self.version = store.version     # guarded by: _mu
        self.Y_epoch = store.Y.copy()
        self.data_dir: Optional[str] = None
        self.generation: Optional[int] = None
        self.wal: Optional[WriteAheadLog] = None
        self._shard_fps: list = []       # guarded by: _mu
        self._routed_for_build = None    # guarded by: _mu
        self._centroids = None           # guarded by: _mu
        #: IVF index state (repro.index): the engine owns the shared
        #: quantizer centroids (fixed between builds — that is what
        #: makes delta maintenance == rebuild) and the churn-gated
        #: re-quantization policy, mirroring `rebuild_churn`
        self.index_mode: Optional[str] = None        # guarded by: _mu
        self.index_churn = float(index_churn)
        self.nprobe = int(nprobe) if nprobe is not None else None
        # guarded by: _mu
        self._index_centroids: Optional[np.ndarray] = None
        # row-normalized quantizer — guarded by: _mu
        self._index_cn = None
        self._index_moved = 0   # rows that changed cell; guarded by: _mu
        self.requantizes = 0
        self._mu = threading.RLock()
        self._loop_thread: Optional[threading.Thread] = None
        self._loop_stop: Optional[threading.Event] = None
        #: last engine-level exception swallowed by the flush loop
        self.loop_error: Optional[BaseException] = None  # guarded by: _mu
        if not _boot:
            return                      # open() finishes construction
        if data_dir is None:
            self._reset_shard_fps()
            self._rebuild()
            if index is not None:
                self.enable_index()
        else:
            self.data_dir = str(data_dir)
            os.makedirs(self.data_dir, exist_ok=True)
            if os.path.exists(os.path.join(self.data_dir, _MANIFEST)):
                raise FileExistsError(
                    f"{self.data_dir} already holds a deployment; "
                    "recover it with ServingEngine.open()")
            # fold the log so generation 0's snapshot IS the live state
            self.store.compact()
            self._reset_shard_fps()
            self._rebuild()
            if index is not None:
                self.enable_index()      # gen 0 snapshot carries it
            self._write_generation(0)
            self._start_replicas()       # they bootstrap from gen 0
        self._health.to(SERVING)        # boot complete: starting -> serving

    # -- recovery ----------------------------------------------------------

    @classmethod
    def open(cls, data_dir: str, *, num_shards: Optional[int] = None,
             rebuild_churn: Optional[float] = None,
             chunk_size: int = 1 << 20, backend: str = "streaming",
             plan_cache: Union[str, None] = "auto",
             fsync: bool = False,
             degraded_append_s: float = 0.5,
             transport: str = "local",
             shard_addrs: Optional[list] = None,
             replicas: int = 0,
             replica_addrs: Optional[list] = None,
             rpc_timeout_s: float = 60.0,
             group_commit_ms: Optional[float] = None,
             group_commit_bytes: Optional[int] = None) -> "ServingEngine":
        """Recover a deployment: load the manifest's snapshot, replay
        the WAL suffix (append-before-apply means every applied
        mutation is there), and rebuild Z once at the end.  The
        recovered `(version, epoch, fingerprint)` triple — and the
        epoch's label snapshot — exactly match the crashed process.

        The whole open is one ``serving.recovery`` span and lands in
        the ``repro_serving_recovery_seconds`` histogram (health is
        ``starting`` until the final rebuild completes)."""
        data_dir = str(data_dir)
        t0 = time.perf_counter()
        with obs.span("serving.recovery", data_dir=data_dir) as sp:
            with open(os.path.join(data_dir, _MANIFEST)) as f:
                gen = int(json.load(f)["generation"])
            prefix = os.path.join(data_dir, f"snap-{gen}")
            store = GraphStore.load(prefix)
            with open(prefix + ".engine.json") as f:
                emeta = json.load(f)
            eng = cls(store,
                      num_shards=(num_shards if num_shards is not None
                                  else int(emeta["num_shards"])),
                      rebuild_churn=(rebuild_churn
                                     if rebuild_churn is not None
                                     else float(emeta["rebuild_churn"])),
                      chunk_size=chunk_size, backend=backend,
                      plan_cache=plan_cache, fsync=fsync,
                      degraded_append_s=degraded_append_s,
                      transport=transport, shard_addrs=shard_addrs,
                      replicas=replicas, replica_addrs=replica_addrs,
                      rpc_timeout_s=rpc_timeout_s,
                      group_commit_ms=group_commit_ms,
                      group_commit_bytes=group_commit_bytes,
                      _boot=False)
            eng.data_dir = data_dir
            eng.generation = gen
            eng.epoch = int(emeta["epoch"])
            eng.rebuilds = int(emeta["rebuilds"])
            eng.deltas_applied = int(emeta["deltas_applied"])
            eng.checkpoints = int(emeta.get("checkpoints", 0))
            eng.Y_epoch = store.Y.copy()  # a snapshot always post-rebuild
            eng._reset_shard_fps()
            imeta = emeta.get("index")
            if imeta is not None:        # snapshot carried an index
                eng.index_mode = imeta["mode"]
                eng.index_churn = float(imeta["churn"])
                eng.nprobe = (int(imeta["nprobe"])
                              if imeta["nprobe"] is not None else None)
                eng.requantizes = int(imeta.get("requantizes", 0))
                eng._index_centroids = np.asarray(
                    imeta["centroids"], np.float32).reshape(
                        store.K, store.K)
            eng.wal = WriteAheadLog(
                os.path.join(data_dir, f"wal-{gen}.log"), fsync=fsync,
                group_commit_ms=group_commit_ms,
                group_commit_bytes=group_commit_bytes)
            replayed = 0
            for rec in eng.wal.open():   # replay; Z built once, after
                eng._replay(rec)
                replayed += 1
            eng.version = store.version
            eng._embed_epoch()           # one fresh build == gee_streaming
            if eng.index_mode is not None:
                # memberships are a pure function of (Z, centroids):
                # rebuilding under the replayed quantizer answers
                # bit-identically to the crashed process (the churn
                # counter restarts at 0 — it is a drift heuristic, not
                # answer state)
                eng._build_index(eng._index_centroids, record=False)
            sp.set(generation=gen, wal_records=replayed)
            sp.fence(eng.Z)
        if obs.enabled():
            obs.observe("repro_serving_recovery_seconds",
                        time.perf_counter() - t0)
            obs.counter("repro_serving_recovery_replayed_total",
                        replayed)
        eng._health.to(SERVING)          # recovery complete
        eng._start_replicas()            # bootstrap from the recovered gen
        return eng

    # holds: _mu — recovery runs before the engine is shared
    def _replay(self, rec: W.WalRecord) -> None:
        """Re-apply one WAL record to the store and the epoch counters
        WITHOUT embedding (Z is built once after replay).  Mirrors the
        live write path exactly, so epochs advance at the same points."""
        if rec.kind == W.EDGES:          # weights arrive sign-folded
            self.store.apply_edges(rec.a, rec.b, rec.c)
            self._routed_for_build = None    # multiset moved: stash stale
            if self.partition.p > 1:
                for i, (su, sv, sw) in self.partition.route_edges(
                        rec.a, rec.b, rec.c):
                    self._shard_fps[i] = extend_fingerprint(
                        self._shard_fps[i], su, sv, sw)
            self.deltas_applied += 1
        elif rec.kind == W.LABELS:
            self.store.apply_labels(rec.a, rec.b)
            if self.churn > self.rebuild_churn:
                self._advance_epoch()
        elif rec.kind == W.COMPACT:
            self.store.compact()
            self._reset_shard_fps()
            self._advance_epoch()
        elif rec.kind == W.REBUILD:
            self._advance_epoch()
        elif rec.kind == W.INDEX:
            # a live (re-)quantization: restore the exact quantizer;
            # the index itself is rebuilt once after replay
            K = self.store.K
            self._index_centroids = np.asarray(
                rec.a, np.float32).reshape(K, K).copy()
            self.index_mode = "ivf"

    def _advance_epoch(self) -> None:
        """Epoch bookkeeping shared by live rebuilds and replay."""
        self.Y_epoch = self.store.Y.copy()
        self.epoch += 1
        self.rebuilds += 1

    # -- shard plumbing ----------------------------------------------------

    # holds: _mu — called from locked write paths and the boot path
    def _reset_shard_fps(self) -> None:
        """(Re)derive each shard's sub-multiset fingerprint from the
        live store — called whenever the base arrays are rewritten
        (boot, compaction, recovery).  Subsequent deltas chain in
        O(batch), mirroring `GraphStore.fingerprint`; replicas and
        recovered engines replaying the same sequence agree, which is
        what lets every shard's rebuild hit the persistent plan cache.

        The routed dict is stashed for the `_embed_epoch` that every
        caller runs next, so a reset+rebuild routes the multiset once,
        not twice; any multiset change in between (WAL replay) must
        drop the stash."""
        if self.partition.p == 1:
            return                       # the store's own chain is used
        g = self.store.edges()
        routed = {i: sub for i, sub in self.partition.route_graph(g)}
        self._routed_for_build = routed
        self._shard_fps = [
            (routed[i].fingerprint() if i in routed
             else edge_fingerprint(g.n, np.zeros(0, np.int32),
                                   np.zeros(0, np.int32),
                                   np.zeros(0, np.float32)))
            for i in range(self.partition.p)]

    # holds: _mu
    def _embed_epoch(self) -> None:
        """Build every shard's Z from the live multiset under the
        current epoch labels (`Y_epoch`)."""
        with obs.span("serving.rebuild",
                      metric="repro_serving_rebuild_seconds",
                      epoch=self.epoch, shards=self.partition.p) as sp:
            if self.partition.p == 1:
                # the store source keeps array identity + the store's
                # chained fingerprint — quiet-store rebuilds stay tier-1
                # plan hits, cold starts tier-2, exactly like the old
                # single-host service
                self.shards[0].build(self.source, self.Y_epoch)
            else:
                routed, self._routed_for_build = self._routed_for_build, None
                if routed is None:
                    routed = {i: sub for i, sub in
                              self.partition.route_graph(self.store.edges())}
                for i, shard in enumerate(self.shards):
                    sub = routed.get(i)
                    if sub is None:
                        sub = Graph(np.zeros(0, np.int32),
                                    np.zeros(0, np.int32),
                                    np.zeros(0, np.float32), self.n)
                    sub._fp = self._shard_fps[i]   # chained: never rehashed
                    shard.build(sub, self.Y_epoch)
            sp.fence(self.Z)
        self._invalidate_query_cache()

    # holds: _mu
    def _rebuild(self) -> None:
        """Full re-embed under the store's current labels; new epoch.
        A wholesale Z rewrite invalidates every cell assignment, so an
        enabled index re-quantizes under fresh centroids."""
        self._advance_epoch()
        self._embed_epoch()
        self.version = self.store.version
        if self.index_mode is not None:
            self._requantize()

    # holds: _mu
    def _invalidate_query_cache(self) -> None:
        self._centroids = None

    # -- IVF index (repro.index) -------------------------------------------

    def enable_index(self) -> None:
        """Turn on IVF serving: quantize every shard's owned rows under
        the current global class centroids.  Idempotent."""
        with self._mu:
            if self.index_mode is None:
                self.index_mode = "ivf"
                self._build_index()

    # holds: _mu
    def _build_index(self, centroids=None, *, record: bool = True) -> None:
        """(Re)quantize all shards under `centroids` (default: the
        current epoch's class centroids).  On a durable engine the
        quantizer is WAL-logged (record=False during recovery, where it
        came FROM the log/snapshot)."""
        if centroids is None:
            centroids = self.centroids()
        centroids = np.asarray(centroids, np.float32)
        with obs.span("index.build", shards=self.partition.p,
                      epoch=self.epoch):
            for shard in self.shards:
                shard.build_index(centroids)
        self._index_centroids = centroids
        self._index_cn = Q.normalize_rows(jnp.asarray(centroids))
        self._index_moved = 0
        if record and self.wal is not None:
            self.wal.append_index(self.store.version, centroids)

    def _requantize(self) -> None:
        """Fresh centroids + full re-assign — the churn-gated escape
        hatch from accumulated delta drift (and the forced path after
        any epoch rebuild)."""
        self._build_index()
        self.requantizes += 1
        if obs.enabled():
            obs.counter("repro_index_requantizes_total")

    # -- durability --------------------------------------------------------

    # holds: _mu — checkpoint() locks; the boot path is pre-publication
    def _write_generation(self, gen: int) -> None:
        """Write snapshot + engine meta + fresh WAL, then flip the
        manifest.  Crash anywhere before the manifest replace leaves
        the previous generation fully intact."""
        prefix = os.path.join(self.data_dir, f"snap-{gen}")
        self.store.snapshot(prefix)
        emeta = {
            "format": _FORMAT, "epoch": self.epoch,
            "rebuilds": self.rebuilds,
            "deltas_applied": self.deltas_applied,
            "checkpoints": self.checkpoints,
            "num_shards": self.partition.p,
            "rebuild_churn": self.rebuild_churn}
        if self.index_mode is not None:
            # the quantizer IS the index's durable state: memberships
            # are a pure function of (Z, centroids), both replayable
            emeta["index"] = {
                "mode": self.index_mode, "churn": self.index_churn,
                "nprobe": self.nprobe,
                "requantizes": self.requantizes,
                "centroids": self._index_centroids.ravel().tolist()}
        _atomic_write_json(prefix + ".engine.json", emeta)
        if self.wal is not None:
            self.wal.close()
        old = self.generation
        self.wal = WriteAheadLog(
            os.path.join(self.data_dir, f"wal-{gen}.log"),
            fsync=self.fsync, group_commit_ms=self.group_commit_ms,
            group_commit_bytes=self.group_commit_bytes)
        self.wal.open()
        _atomic_write_json(os.path.join(self.data_dir, _MANIFEST),
                           {"format": _FORMAT, "generation": gen})
        self.generation = gen
        if old is not None and old != gen:       # best-effort cleanup
            for name in (f"snap-{old}.edges.npz", f"snap-{old}.meta.npz",
                         f"snap-{old}.engine.json", f"wal-{old}.log"):
                try:
                    os.unlink(os.path.join(self.data_dir, name))
                except OSError:
                    pass

    def sync_durable(self) -> int:
        """Close any open WAL commit group with one fsync barrier;
        returns the appends it covered.  The batcher calls this before
        releasing write tickets, so an acknowledged write is always on
        stable storage (group commit batches the barrier, never the
        acknowledgement contract)."""
        if self.wal is not None:
            return self.wal.sync()
        return 0

    # -- read replicas (transport.replica workers tailing our WAL) ---------

    def _start_replicas(self) -> None:
        """Spawn (or connect to) the replica workers recorded at
        construction.  Called once the data_dir holds a generation the
        replicas can bootstrap from (after the gen-0 snapshot on boot,
        after replay on recovery)."""
        replicas, replica_addrs = self._pending_replicas
        self._pending_replicas = (0, None)
        if not replicas and not replica_addrs:
            return
        from repro.transport.remote import RemoteReplica
        if replica_addrs is not None:
            self._replicas = [
                RemoteReplica(a, timeout_s=self.rpc_timeout_s)
                for a in replica_addrs]
        else:
            from repro.transport.procs import spawn_replica_worker
            procs = [spawn_replica_worker(
                self.data_dir, chunk_size=self._chunk_size,
                backend=self._backend, plan_cache=self._plan_cache,
                wait=False) for _ in range(int(replicas))]
            for proc in procs:
                proc.handshake()
                self._replica_procs.append(proc)
                self._replicas.append(RemoteReplica(
                    proc.addr, timeout_s=self.rpc_timeout_s,
                    proc=proc))

    def _replica_read(self, method: str, nodes: np.ndarray, **kwargs):
        """Try one replica (round-robin) for a read, pinned to the
        router's current version.  Returns the answer, or None to fall
        back to the owner: lag (the replica has not applied the pinned
        version / lacks the quantizer) and transport faults (a dead
        replica) both degrade to owner reads instead of failing the
        request; the reason lands in `_replica_events` for health().
        Any other remote exception (e.g. IndexError for bad node ids)
        propagates — it is the answer, not a fault."""
        from repro.transport.errors import (ReplicaLagError,
                                            TransportError)
        with self._replica_mu:
            i = self._replica_rr % len(self._replicas)
            self._replica_rr += 1
            rep = self._replicas[i]
        try:
            # repro: allow(lock-discipline) — unlocked version read is a pin, not state: staleness only widens the lag window the fallback already handles
            out = getattr(rep, method)(nodes, min_version=self.version,
                                       **kwargs)
        except ReplicaLagError as e:
            with self._replica_mu:
                self._replica_events[i] = f"lag: {e}"
            outcome = "lag"
        except TransportError as e:
            with self._replica_mu:
                self._replica_events[i] = f"unreachable: {e}"
            outcome = "dead"
        else:
            with self._replica_mu:
                self._replica_events[i] = None
            outcome = "ok"
        if obs.enabled():
            obs.counter("repro_transport_replica_reads_total",
                        method=method, outcome=outcome)
        return out if outcome == "ok" else None

    def checkpoint(self) -> dict:
        """Durable compaction: fold the log into the base, rebuild
        (new epoch), snapshot the result as a new generation, and
        rotate the WAL.  Bounds both recovery time and log size."""
        if self.data_dir is None:
            raise RuntimeError("checkpoint() needs a durable engine "
                               "(construct with data_dir=...)")
        with self._mu, obs.span(
                "serving.checkpoint",
                metric="repro_serving_checkpoint_seconds") as sp:
            info = self.store.compact()
            self._reset_shard_fps()
            self._rebuild()
            self.checkpoints += 1      # before the meta write, so a
            self._write_generation(self.generation + 1)   # recovered
            info["generation"] = self.generation   # engine restores it
            sp.set(generation=self.generation)
            obs.counter("repro_serving_checkpoints_total")
            return info

    def close(self) -> None:
        """Stop the async loop (if running), close the WAL, and tear
        down any transport: spawned shard/replica workers are shut down
        over RPC and reaped; workers connected via `shard_addrs` /
        `replica_addrs` only have their connections closed (they belong
        to whoever launched them — `shutdown_workers()` stops those
        too)."""
        self.stop()
        if self.wal is not None:
            self.wal.close()
        for rep in self._replicas:
            rep.close(shutdown=rep.proc is not None)
        self._replicas = []
        for shard in self.shards:
            close = getattr(shard, "close", None)
            if callable(close):
                close(shutdown=shard.proc is not None)
        self._shard_procs = []
        self._replica_procs = []

    def shutdown_workers(self) -> None:
        """Ask every REMOTE worker — including externally-launched ones
        this engine merely connected to — to exit, then close.  The
        explicit teardown for `--connect` deployments."""
        for rep in self._replicas:
            rep.close(shutdown=True)
        self._replicas = []
        for shard in self.shards:
            close = getattr(shard, "close", None)
            if callable(close):
                close(shutdown=True)
        self._shard_procs = []
        self._replica_procs = []

    # -- writes ------------------------------------------------------------

    def apply_edge_delta(self, u, v, w, *, delete: bool = False) -> int:
        """Fold an edge batch into store + owning shards.  O(batch).
        Appended to the WAL before any state changes; a bad batch
        raises before either.  Returns the new version."""
        u = np.asarray(u, np.int32)
        v = np.asarray(v, np.int32)
        w = np.asarray(w, np.float32)
        t0 = obs.tick()
        with self._mu:
            Graph(u, v, w, self.n).validate()    # reject BEFORE the WAL
            wsigned = -w if delete else w
            if self.wal is not None:
                self.wal.append_edges(self.store.version + 1, u, v, wsigned)
            version = self.store.apply_edges(u, v, w, delete=delete)
            self._routed_for_build = None
            fanout = 0
            if u.shape[0]:
                for i, (su, sv, sw) in self.partition.route_edges(
                        u, v, wsigned):
                    if self.partition.p > 1:
                        self._shard_fps[i] = extend_fingerprint(
                            self._shard_fps[i], su, sv, sw)
                    self.shards[i].apply_delta(Graph(su, sv, sw, self.n))
                    if self.index_mode is not None:
                        # delta-maintain: re-assign exactly the owned
                        # rows this batch rewrote (O(batch))
                        lo, hi = self.partition.slice(i)
                        pts = np.concatenate([su, sv])
                        own = np.unique(pts[(pts >= lo) & (pts < hi)])
                        self._index_moved += \
                            self.shards[i].update_index(own)
                    fanout += 1
                self._invalidate_query_cache()
                if (self.index_mode is not None
                        and self._index_moved
                        > self.index_churn * self.n):
                    self._requantize()
            self.version = version
            self.deltas_applied += 1
            if obs.enabled():
                obs.observe("repro_serving_delta_apply_seconds",
                            obs.tock(t0))
                obs.observe("repro_serving_delta_fanout_shards", fanout)
                obs.counter("repro_serving_delta_edges_total",
                            int(u.shape[0]))
            return version

    def apply_label_delta(self, nodes, labels) -> int:
        """Update labels; rebuild every shard if churn passes the
        threshold, otherwise keep serving the current epoch's Z."""
        nodes = np.asarray(nodes, np.int64)
        labels = np.asarray(labels, np.int32)
        t0 = obs.tick()
        with self._mu:
            assert nodes.shape == labels.shape   # reject BEFORE the WAL
            if nodes.size:
                assert nodes.min() >= 0 and nodes.max() < self.n
                assert labels.min() >= -1 and labels.max() < self.store.K
            if self.wal is not None:
                self.wal.append_labels(self.store.version + 1, nodes,
                                       labels)
            version = self.store.apply_labels(nodes, labels)
            self.version = version
            if self.churn > self.rebuild_churn:
                self._rebuild()
            if obs.enabled():
                obs.observe("repro_serving_label_apply_seconds",
                            obs.tock(t0))
                obs.counter("repro_serving_label_updates_total",
                            int(nodes.size))
            return version

    def compact(self) -> dict:
        """Compact the store and start a fresh epoch (volatile
        compaction; `checkpoint()` is the durable version).  On a
        durable engine a marker record keeps the WAL replayable."""
        with self._mu:
            if self.wal is not None:
                self.wal.append_marker(W.COMPACT, self.store.version)
            info = self.store.compact()
            self._reset_shard_fps()
            self._rebuild()
            return info

    def refresh(self) -> None:
        """Force a rebuild (e.g. to pick up sub-threshold label churn)."""
        with self._mu:
            if self.wal is not None:
                self.wal.append_marker(W.REBUILD, self.store.version)
            self._rebuild()

    # -- reads (scatter/gather across shards) ------------------------------

    @property
    def n(self) -> int:
        return self.store.n

    @property
    def num_shards(self) -> int:
        return self.partition.p

    def fingerprint(self) -> str:
        return self.store.fingerprint()

    @property
    def churn(self) -> float:
        return self.store.churn_fraction(self.Y_epoch)

    @property
    def stale_labels(self) -> int:
        return int((self.store.Y != self.Y_epoch).sum())

    @property
    def Z(self):
        """The live embedding, assembled from owned shard slices (for
        1 shard this is the Embedder's own Z — no copy)."""
        if self.partition.p == 1:
            return self.shards[0].embedder.Z_
        return jnp.concatenate([s.Z_owned for s in self.shards], 0)

    @property
    def Wv(self):
        """Projection weights Z was built with (identical across
        shards: all fit under the same epoch labels)."""
        return self.shards[0].embedder.Wv_

    @property
    def embedder(self):
        """The single Embedder — only meaningful for 1 shard (the
        `EmbeddingService` compat surface)."""
        if self.partition.p != 1:
            raise AttributeError(
                "a sharded engine has per-shard embedders "
                "(engine.shards[i].embedder)")
        return self.shards[0].embedder

    def _check_nodes(self, nodes: np.ndarray) -> None:
        if nodes.size and (nodes.min() < 0 or nodes.max() >= self.n):
            raise IndexError(f"node ids must be in [0, {self.n}), got "
                             f"range [{nodes.min()}, {nodes.max()}]")

    def _gather_rows(self, nodes: np.ndarray) -> jnp.ndarray:
        """Device-resident Z rows in request order: the shared gather
        half of every read path.  1 shard is a direct device gather
        (no host round-trip — the old single-host fast path); sharded
        gathers scatter per owner and reassemble on device."""
        if self.partition.p == 1:
            return self.shards[0].rows(nodes)
        t0 = obs.tick()
        out = jnp.zeros((nodes.shape[0], self.store.K), jnp.float32)
        for shard, idx in self.partition.route_nodes(nodes):
            out = out.at[jnp.asarray(idx)].set(
                self.shards[shard].rows(nodes[idx]))
        if obs.enabled():
            jax.block_until_ready(out)
            obs.observe("repro_serving_query_gather_seconds",
                        obs.tock(t0), shards=self.partition.p)
        return out

    def query_embed(self, nodes) -> np.ndarray:
        """Z rows for a node batch: scatter to owning shards, gather
        back in request order."""
        nodes = np.atleast_1d(np.asarray(nodes, np.int32))
        t0 = obs.tick()
        if self._replicas:
            out = self._replica_read("embed", nodes)
            if out is not None:
                self._record_query("embed", t0, nodes.shape[0])
                return out
        with self._mu:
            self._check_nodes(nodes)
            out = np.asarray(self._gather_rows(nodes))
        self._record_query("embed", t0, nodes.shape[0])
        return out

    def centroids(self):
        """Global class centroids: per-shard partial (sums, counts)
        reduced at the router, divided once — equal to the single-host
        `class_centroids`.  Cached until the next write/rebuild."""
        with self._mu:
            if self._centroids is None:
                sums = counts = None
                for shard in self.shards:
                    s_, c_ = shard.class_stats(self.Y_epoch)
                    sums = s_ if sums is None else sums + s_
                    counts = c_ if counts is None else counts + c_
                self._centroids = sums / jnp.maximum(counts[:, None], 1.0)
            return self._centroids

    def normalized_Z(self):
        """Row-normalized Z (compat surface; shards cache their own
        normalized slices for the top-k path)."""
        with self._mu:
            if self.partition.p == 1:
                return self.shards[0].normalized()
            return jnp.concatenate(
                [s.normalized() for s in self.shards], 0)

    def query_predict(self, nodes):
        """Centroid label prediction: gather rows from owners (device-
        resident), score against the merged centroids.  Returns
        (pred, score)."""
        nodes = np.atleast_1d(np.asarray(nodes, np.int32))
        t0 = obs.tick()
        if self._replicas:
            out = self._replica_read("predict", nodes)
            if out is not None:
                self._record_query("predict", t0, nodes.shape[0])
                return out
        with self._mu:
            self._check_nodes(nodes)
            pred, score = Q.predict_rows(self._gather_rows(nodes),
                                         self.centroids())
            out = np.asarray(pred), np.asarray(score)
        self._record_query("predict", t0, nodes.shape[0])
        return out

    def query_topk(self, nodes, *, k: int = 10,
                   block_rows: int = 1 << 14, mode: str = "exact",
                   nprobe: Optional[int] = None):
        """Top-k cosine neighbors: gather + normalize the query rows,
        score them against candidate rows (global-id-stamped), merge
        per-shard lists with a blocked top-k.

        ``mode="exact"`` scans every owned row; ``mode="ivf"`` routes
        through the per-shard IVF index (`repro.index`), scoring only
        the `nprobe` cells nearest each query — sub-linear scan volume,
        and **bit-identical** to exact at ``nprobe=K`` (probing every
        cell covers every row; all top-k surfaces order candidates by
        ``(-score, ascending id)``).  An engine constructed without
        ``index="ivf"`` builds the index lazily on the first ivf query.
        Returns (indices (q, k), scores (q, k))."""
        if mode not in ("exact", "ivf"):
            raise ValueError(f"unknown topk mode {mode!r} "
                             "('exact' or 'ivf')")
        nodes = np.atleast_1d(np.asarray(nodes, np.int32))
        t0 = obs.tick()
        if self._replicas:
            out = self._replica_read("topk", nodes, k=k,
                                     block_rows=block_rows, mode=mode,
                                     nprobe=nprobe)
            if out is not None:
                self._record_query(
                    "topk" if mode == "exact" else "topk_ivf",
                    t0, nodes.shape[0])
                return out
        with self._mu:
            self._check_nodes(nodes)
            if mode == "ivf" and self.index_mode is None:
                self.enable_index()
            if self.partition.p == 1:
                # gather from the CACHED normalized slice (the old
                # single-host path: no re-normalization per query)
                q = self.shards[0].normalized()[jnp.asarray(nodes)]
            else:
                q = Q.normalize_rows(self._gather_rows(nodes))
            ts = obs.tick()
            if mode == "ivf":
                probe = self._probe_cells(q, nprobe)
                parts = [s.index_topk(q, nodes, probe, k=k,
                                      block_rows=block_rows)
                         for s in self.shards]
                scanned = sum(p[2] for p in parts)
            else:
                parts = [s.topk_candidates(q, nodes, k=k,
                                           block_rows=block_rows)
                         for s in self.shards]
            if obs.enabled():
                jax.block_until_ready([p[:2] for p in parts])
                obs.observe("repro_serving_query_scatter_seconds",
                            obs.tock(ts), shards=self.partition.p)
            if len(parts) == 1:
                out = parts[0][0], parts[0][1]
            else:
                out = Q.merge_topk([p[0] for p in parts],
                                   [p[1] for p in parts], k=k)
            if mode == "ivf" and obs.enabled():
                obs.observe("repro_index_topk_seconds", obs.tock(ts))
                obs.counter("repro_index_queries_total")
                obs.counter("repro_index_rows_scanned_total", scanned)
                obs.observe("repro_index_scan_fraction",
                            scanned / max(nodes.shape[0] * self.n, 1))
        self._record_query("topk" if mode == "exact" else "topk_ivf",
                           t0, nodes.shape[0])
        return out

    # holds: _mu — only called from the locked region of query_topk
    def _probe_cells(self, q, nprobe: Optional[int]) -> np.ndarray:
        """The `nprobe` quantizer cells nearest each query (nq, nprobe)
        — shared across shards so every shard scores the same cells.
        Cosine similarity ties break to the ascending cell id (stable
        argsort), keeping probe choice deterministic."""
        if nprobe is None:
            nprobe = self.nprobe
        if nprobe is None:
            from repro.index import DEFAULT_NPROBE
            nprobe = DEFAULT_NPROBE
        nprobe = max(1, min(int(nprobe), self.store.K))
        sims = np.asarray(q @ self._index_cn.T)
        return np.argsort(-sims, axis=1, kind="stable")[:, :nprobe] \
            .astype(np.int32)

    def _record_query(self, kind: str, t0: float, batch: int) -> None:
        """One histogram + counter pair per read, labeled by kind —
        the scatter/gather sub-steps have their own series."""
        if not obs.enabled():
            return
        obs.observe("repro_serving_query_seconds", obs.tock(t0),
                    kind=kind)
        obs.counter("repro_serving_queries_total", kind=kind)
        obs.counter("repro_serving_query_nodes_total", batch, kind=kind)

    # -- async flush / compaction loop -------------------------------------

    def start(self, batcher=None, *, interval: float = 1e-3,
              checkpoint_bytes: Optional[int] = None):
        """Run the deployment's consumer in a background thread: drain
        the batcher (coalesced reads between write barriers — writers
        get a ticket back immediately and never block on kernel
        launches), and roll a checkpoint whenever the WAL outgrows
        `checkpoint_bytes`.  Returns the batcher to submit against."""
        if self._loop_thread is not None:
            raise RuntimeError("flush loop already running")
        if batcher is None:
            from repro.serving.batcher import MicroBatcher
            batcher = MicroBatcher(self)
        self._loop_batcher = batcher
        self._loop_stop = threading.Event()
        self._checkpoint_bytes = checkpoint_bytes
        self._flush_interval = float(interval)
        self._loop_thread = threading.Thread(
            target=self._flush_loop, name="serving-flush", daemon=True)
        self._loop_thread.start()
        return batcher

    def _flush_loop(self) -> None:
        """The background consumer must never die silently: per-ticket
        failures are already captured by the batcher, so an exception
        here is engine-level (e.g. a checkpoint hitting a full disk).
        It is recorded on `loop_error`, the failing auto-checkpoint is
        disabled (rather than retried every iteration), and the loop
        keeps draining — submitters keep getting answers instead of
        hanging forever on a dead thread."""
        while not self._loop_stop.is_set():
            try:
                served = self._loop_batcher.flush()
                if self.wal is not None and self.wal.group_commit:
                    # a write trickle must not leave its commit group
                    # open past the group_commit_ms promise
                    self.wal.sync_if_due()
            except Exception as e:       # engine bug: record, keep going
                with self._mu:
                    self.loop_error = e
                served = 0
            if obs.enabled():
                obs.counter("repro_serving_flush_iterations_total")
                if served:
                    obs.counter("repro_serving_flush_served_total", served)
            if (self.wal is not None
                    and self._checkpoint_bytes is not None
                    and self.wal.bytes_written > self._checkpoint_bytes):
                try:
                    self.checkpoint()
                except Exception as e:
                    with self._mu:
                        self.loop_error = e
                    self._checkpoint_bytes = None
            if not served:
                self._loop_stop.wait(self._flush_interval)

    def stop(self) -> None:
        """Stop the flush loop and drain anything still queued."""
        if self._loop_thread is None:
            return
        self._loop_stop.set()
        self._loop_thread.join()
        self._loop_thread = None
        self._loop_batcher.flush()       # nothing left behind

    # -- observability -----------------------------------------------------

    def health(self) -> dict:
        """Deployment health, re-evaluated on every call (not latched):
        ``starting`` until the boot/recovery rebuild lands, then
        ``serving``, and ``degraded`` while the flush loop has recorded
        an engine-level error or the last WAL append (write + flush
        [+fsync]) exceeded `degraded_append_s`.  A degraded deployment
        still serves — the state is a signal, not a circuit breaker."""
        with self._mu:
            reasons = []
            if self.loop_error is not None:
                reasons.append(f"loop_error: {self.loop_error!r}")
            if (self.wal is not None
                    and self.wal.last_append_seconds
                    > self.degraded_append_s):
                reasons.append(
                    "wal append "
                    f"{self.wal.last_append_seconds * 1e3:.1f}ms > "
                    f"{self.degraded_append_s * 1e3:.1f}ms")
            with self._replica_mu:       # ordering: _mu -> _replica_mu
                events = dict(self._replica_events)
            replicas = []
            for i, rep in enumerate(self._replicas):
                row = {"replica": i, "addr": rep.address,
                       "last_event": events.get(i)}
                try:
                    st = rep.status(timeout_s=min(
                        2.0, self.rpc_timeout_s))
                    row.update(
                        version=st["version"],
                        lag=self.version - int(st["version"]),
                        generation=st["generation"],
                        records_applied=st["records_applied"],
                        tail_error=st["tail_error"])
                except Exception as e:   # a dead replica degrades; it
                    row["error"] = repr(e)   # must never fail health()
                    reasons.append(f"replica {i} unreachable")
                replicas.append(row)
            if reasons:
                self._health.to(DEGRADED, reason="; ".join(reasons))
            elif self._health.state != STARTING:
                self._health.to(SERVING)
            out = self._health.as_dict()
            if replicas:
                out["replicas"] = replicas
            return out

    def stats(self) -> dict:
        """Introspection snapshot, read atomically under the engine
        lock so the `(version, epoch, fingerprint, durability)` group
        is never torn against a concurrent writer.  The legacy scalar
        keys are kept verbatim; `health` is the health() state and
        `metrics` is the process registry's `repro_serving_*` slice."""
        with self._mu:
            plan = {"built": 0, "hits": 0, "disk_hits": 0,
                    "disk_stores": 0}
            for s in self.shards:
                for key, val in s.plan_stats.items():
                    plan[key] += val
            acc = [s.accumulator_nbytes for s in self.shards]
            out = {"version": self.version, "epoch": self.epoch,
                   "num_shards": self.partition.p,
                   "deltas_applied": self.deltas_applied,
                   "rebuilds": self.rebuilds, "churn": self.churn,
                   "log_edges": self.store.log_edges,
                   "base_edges": self.store.base.s,
                   "fingerprint": self.store.fingerprint(),
                   "plan_stats": plan,
                   # the owned-rows memory contract, observable: peak
                   # per-shard accumulator bytes scales ~ n/p
                   "shard_accumulator_bytes": acc,
                   "peak_shard_accumulator_bytes": max(acc, default=0),
                   "health": self.health()}
            if self.loop_error is not None:
                out["loop_error"] = repr(self.loop_error)
            if self.index_mode is not None:
                from repro.index import DEFAULT_NPROBE
                out["index"] = {
                    "mode": self.index_mode,
                    "nprobe": (self.nprobe if self.nprobe is not None
                               else DEFAULT_NPROBE),
                    "churn_threshold": self.index_churn,
                    "moved_rows": self._index_moved,
                    "moved_fraction": self._index_moved / max(self.n, 1),
                    "requantizes": self.requantizes,
                    # per-shard rows-per-cell occupancy (sums to n)
                    "cell_sizes": [s.index.cell_sizes().tolist()
                                   for s in self.shards
                                   if s.index is not None]}
            if self.data_dir is not None:
                out["durability"] = {
                    "generation": self.generation,
                    "checkpoints": self.checkpoints,
                    "wal_records": self.wal.records_appended,
                    "wal_bytes": self.wal.bytes_written,
                    # fsync-barrier accounting: under group commit
                    # appends_per_fsync > 1 is the whole point; 1.0
                    # under flush-per-record fsync; 0 with fsync off
                    "fsync": self.fsync,
                    "group_commit": self.wal.group_commit,
                    "fsyncs": self.wal.fsyncs,
                    "fsync_seconds": self.wal.fsync_seconds_total,
                    "appends_per_fsync": self.wal.appends_per_fsync,
                    "pending_appends": self.wal.pending_appends}
            if self.transport != "local" or self._replicas:
                out["transport"] = {
                    "mode": self.transport,
                    "shard_addrs": [getattr(s, "address", "in-process")
                                    for s in self.shards],
                    "replica_addrs": [r.address
                                      for r in self._replicas]}
            if obs.enabled():
                out["metrics"] = obs.snapshot(prefix="repro_serving")
            return out

    def __enter__(self) -> "ServingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
