"""Jitted query kernels over a served embedding Z (n, K).

Three read paths, each shaped for microbatching (`batcher.py` stacks
many user requests into one kernel call):

* ``gather_embeddings``  — Z rows for a node batch.
* ``predict_labels``     — nearest-class-centroid label prediction in
  cosine space (centroids from the epoch's labeled nodes).
* ``topk_cosine``        — blocked top-k cosine nearest neighbors over
  all n rows; the candidate matrix is processed ``block_rows`` rows at
  a time so peak memory is O(q · block_rows), not O(q · n), and the
  running top-k is merged with ``lax.top_k`` per block.

Every kernel also exists in a **row-sliced** form for the sharded
engine, where no process holds all of Z (with owned-rows encoder plans
a shard's `Z_owned` IS its whole accumulator — these kernels only ever
see the (n/p, K) local slice plus its global `row_offset`):

* ``topk_cosine_q``      — top-k of externally supplied query vectors
  against a candidate row block living at ``row_offset`` in the global
  index space (a shard's owned slice); per-shard results merge exactly
  because scores are global-id-stamped.
* ``topk_cosine_ids``    — same, but for **gathered** candidate rows
  with explicit (ascending) global ids — the IVF index's per-cell
  scorer (`repro.index`), where a cell's rows are not contiguous.
* ``class_sums``         — per-class (sums, counts) over a row slice;
  the engine reduces slices and divides once, so merged centroids
  equal the single-host ``class_centroids``.
* ``predict_rows``       — centroid prediction from gathered rows
  (the engine gathers rows from owning shards first).

**Tie-breaking contract (bit-stable results).**  Every top-k surface
here orders candidates lexicographically by ``(-score, ascending
global id)``.  Inside the blocked scans this falls out of two
invariants rather than an explicit composite sort: ``lax.top_k``
breaks value ties in favor of the lower input position, and candidates
are always presented in ascending-global-id order (blocks scan rows in
id order; the running top-k — itself tie-ordered by id, inductively —
is concatenated *before* the new block, whose ids are all larger).
``merge_topk`` gets parts whose id ranges interleave (shards, IVF
cells), so it sorts explicitly and is order-invariant in its inputs.
The payoff: sharded, single-host, and IVF answers are **bit-identical**
(not merely tie-tolerant), which is what lets the IVF index be tested
for exact equality against the full scan at ``nprobe=K``.

Kernels are pure functions of (Z, ...) so they jit once per shape and
stay valid across versions/epochs — the service just passes its
current Z.
"""
from __future__ import annotations

import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def gather_embeddings(Z, nodes):
    return Z[nodes]


def normalize_rows(X, eps=1e-9):
    return X / jnp.maximum(jnp.linalg.norm(X, axis=-1, keepdims=True), eps)


@functools.partial(jax.jit, static_argnames=("K",))
def class_sums(Z_rows, Y_rows, *, K: int):
    """Per-class (sums (K, K), counts (K,)) over a row slice — the
    shard-local half of `class_centroids`; sum across shards and divide
    once to get the global centroids."""
    labeled = (Y_rows >= 0).astype(Z_rows.dtype)
    onehot = jax.nn.one_hot(jnp.maximum(Y_rows, 0), K, dtype=Z_rows.dtype)
    onehot = onehot * labeled[:, None]
    return onehot.T @ Z_rows, onehot.sum(0)


@functools.partial(jax.jit, static_argnames=("K",))
def class_centroids(Z, Y, *, K: int):
    """Mean embedding of each class's labeled nodes (K, K-dim).  THE
    one copy of the masking/one-hot math is `class_sums`, so the
    sharded merge (sum partials, divide once) cannot drift from the
    single-host answer."""
    sums, counts = class_sums(Z, Y, K=K)
    return sums / jnp.maximum(counts[:, None], 1.0)


@jax.jit
def predict_rows(rows, centroids):
    """Label = argmax cosine(row, centroid_k) for already-gathered rows.
    Returns (pred, score)."""
    q = normalize_rows(rows)
    c = normalize_rows(centroids)
    sims = q @ c.T
    return jnp.argmax(sims, 1).astype(jnp.int32), jnp.max(sims, 1)


@jax.jit
def predict_labels(Z, centroids, nodes):
    """Label = argmax cosine(Z[node], centroid_k).  Returns (pred, score)."""
    return predict_rows(Z[nodes], centroids)


@functools.partial(jax.jit, static_argnames=("k", "exclude_self"))
def _topk_block(vals, idxs, q, block, gidx, qnodes, *,
                exclude_self: bool, k: int):
    """Merge one candidate block into the running (vals, idxs) top-k.

    `gidx` carries each block row's global id (-1 for padding rows,
    which are masked out).  The running candidates are concatenated
    BEFORE the block: with blocks presented in ascending-id order and
    ``lax.top_k``'s lower-position-wins tie rule, score ties resolve to
    the ascending global id (see the module tie-breaking contract)."""
    scores = q @ block.T                                   # (q, B)
    mask = gidx[None, :] < 0                               # padding rows
    if exclude_self:
        mask = mask | (gidx[None, :] == qnodes[:, None])
    scores = jnp.where(mask, -jnp.inf, scores)
    cat_v = jnp.concatenate([vals, scores], 1)
    cat_i = jnp.concatenate(
        [idxs, jnp.broadcast_to(gidx, scores.shape)], 1)
    v, sel = jax.lax.top_k(cat_v, k)
    return v, jnp.take_along_axis(cat_i, sel, 1)


#: reusable per-thread host buffer for the blocked scan's padded tail
#: block ids — the tail is rebuilt every scan but its shape recurs, so
#: the scan fills one buffer in place instead of allocating a fresh
#: np.concatenate result per call (the jnp conversion at the call site
#: copies, so reuse can never alias a pending device computation)
_TAIL = threading.local()


def _padded_tail(gidx: np.ndarray, bucket: int) -> np.ndarray:
    buf = getattr(_TAIL, "buf", None)
    if buf is None or buf.shape[0] != bucket:
        buf = np.empty(bucket, np.int32)
        _TAIL.buf = buf
    t = gidx.shape[0]
    buf[:t] = gidx
    buf[t:] = -1
    return buf


def _bucket_rows(m: int, block_rows: int) -> int:
    """The blocked scan's static block size: single-block inputs pad to
    a power-of-two bucket (one compile per bucket for the IVF path's
    varying cell sizes); multi-block scans use the fixed block shape
    and pad only the tail."""
    return block_rows if m > block_rows else \
        min(block_rows, _pow2(max(m, 1)))


def _topk_blocked(Zn_rows, ids, q, qnodes, *, k: int, block_rows: int,
                  exclude_self: bool):
    """Shared blocked scan: score `q` against candidate rows carrying
    global ids `ids` (ascending), k+block at a time."""
    m = Zn_rows.shape[0]
    qnodes = jnp.asarray(np.asarray(qnodes, np.int32))
    nq = q.shape[0]
    vals = jnp.full((nq, k), -jnp.inf, Zn_rows.dtype)
    idxs = jnp.full((nq, k), -1, jnp.int32)
    bucket = _bucket_rows(m, block_rows)
    for base in range(0, max(m, 1), bucket):
        block = Zn_rows[base:min(base + bucket, m)]
        gidx = ids[base:min(base + bucket, m)]
        if block.shape[0] < bucket:
            block = jnp.pad(block, ((0, bucket - block.shape[0]),
                                    (0, 0)))
            gidx = _padded_tail(gidx, bucket)
        vals, idxs = _topk_block(vals, idxs, q, block,
                                 jnp.array(gidx), qnodes,
                                 exclude_self=exclude_self, k=k)
    # entries never filled (k > candidate count) keep idx -1 / -inf
    valid = jnp.isfinite(vals)
    idxs = jnp.where(valid, idxs, -1)
    return np.asarray(idxs), np.asarray(vals)


def _pow2(size: int) -> int:
    b = 1
    while b < size:
        b <<= 1
    return b


@functools.lru_cache(maxsize=64)
def _id_ramp(row_offset: int, m: int) -> np.ndarray:
    """Cached global-id ramp [row_offset, row_offset + m) — every query
    against a shard's slice needs the same O(n/p) ramp, so it is built
    once per (row_offset, m) instead of per call.  Read-only: callers
    share the cached array."""
    ids = (row_offset + np.arange(m)).astype(np.int32)
    ids.setflags(write=False)
    return ids


def topk_cosine_q(Zn_rows, q, qnodes, *, k: int = 10,
                  block_rows: int = 1 << 14, exclude_self: bool = True,
                  row_offset: int = 0):
    """Top-k of unit-norm query vectors `q` against the unit-norm
    candidate rows `Zn_rows`, which live at global indices
    [row_offset, row_offset + len(Zn_rows)).

    The sharded engine's scatter half: each shard scores the SAME query
    vectors against its owned slice, results carry global ids, and a
    per-query merge over the concatenated per-shard candidates is
    exactly the global answer.  `qnodes` are global query node ids
    for self-exclusion (pass exclude_self=False to keep them).  Score
    ties break by ascending global id (bit-stable across shard counts);
    when k exceeds the candidate count the tail is clamped to
    idx -1 / score -inf.  Returns (indices (q, k) int32,
    scores (q, k) float32) as numpy."""
    m = Zn_rows.shape[0]
    ids = _id_ramp(int(row_offset), int(m))
    return _topk_blocked(Zn_rows, ids, q, qnodes, k=k,
                         block_rows=block_rows,
                         exclude_self=exclude_self)


def _fused_clamp(vals, idxs):
    """Shared unfilled-slot clamp (k > candidate count keeps
    idx -1 / -inf), identical to the blocked scan's post-pass."""
    valid = jnp.isfinite(vals)
    return np.asarray(jnp.where(valid, idxs, -1)), np.asarray(vals)


def topk_cosine_fused(Zn_rows, q, qnodes, *, k: int = 10,
                      block_rows: int = 1 << 14,
                      exclude_self: bool = True, row_offset: int = 0):
    """`topk_cosine_q` as ONE fused pallas scan
    (`kernels.query_fused.topk_fused`): same blocking policy, same
    tie-breaking contract, bit-identical (idx, vals) — but the whole
    blocked merge is a single dispatch with the running top-k resident
    on-chip.  Candidate rows must be unit-norm (a shard's cached Zn);
    use `topk_cosine_fused_norm` on raw rows."""
    from repro.kernels.query_fused import topk_fused
    m = Zn_rows.shape[0]
    vals, idxs = topk_fused(
        Zn_rows, q, qnodes, k=k, bucket=_bucket_rows(m, block_rows),
        row_offset=int(row_offset), exclude_self=exclude_self,
        normalize=False)
    return _fused_clamp(vals, idxs)


def topk_cosine_fused_norm(Z_rows, q, qnodes, *, k: int = 10,
                           block_rows: int = 1 << 14,
                           exclude_self: bool = True,
                           row_offset: int = 0):
    """Fused normalize+cosine+top-k over RAW candidate rows — the cold
    path of a pallas shard, where Zn has not been materialized yet: the
    kernel normalizes each block in-flight and emits the normalized
    slice alongside the answer, so one pass over Z yields both the
    query result and the shard's Zn cache.  Returns (idx, vals, Zn);
    (idx, vals) are bit-identical to
    ``topk_cosine_q(normalize_rows(Z_rows), ...)``."""
    from repro.kernels.query_fused import topk_fused
    m = Z_rows.shape[0]
    vals, idxs, Zn = topk_fused(
        Z_rows, q, qnodes, k=k, bucket=_bucket_rows(m, block_rows),
        row_offset=int(row_offset), exclude_self=exclude_self,
        normalize=True)
    idx, v = _fused_clamp(vals, idxs)
    return idx, v, Zn


def topk_cosine_ids(Zn_rows, ids, q, qnodes, *, k: int = 10,
                    block_rows: int = 1 << 14,
                    exclude_self: bool = True):
    """Top-k of unit-norm queries `q` against GATHERED candidate rows
    `Zn_rows` whose global ids are `ids` — the IVF index's per-cell
    scorer, where a cell's member rows are scattered through the owned
    slice.  `ids` must be sorted ascending (cells store sorted member
    lists) so score ties resolve to the ascending global id, exactly as
    the contiguous scan does — that id-order invariant is what makes
    probing all cells bit-identical to the full scan."""
    ids = np.asarray(ids, np.int32)
    return _topk_blocked(Zn_rows, ids, q, qnodes, k=k,
                         block_rows=block_rows,
                         exclude_self=exclude_self)


def merge_topk(idx_parts, val_parts, *, k: int):
    """Merge per-part (idx, val) top-k candidate lists into the global
    top-k (the gather half of the scatter/gather query, and the IVF
    index's cross-cell merge).  Candidates are ordered lexicographically
    by ``(-score, ascending global id)`` via a stable double argsort,
    so the result is bit-stable and INVARIANT in the part order —
    shards and probed cells can arrive however they like.  Unfilled
    slots (idx -1, -inf) lose to any real candidate; a merge with fewer
    than k real candidates keeps the -1 / -inf clamp in its tail."""
    cat_v = jnp.concatenate([jnp.asarray(v) for v in val_parts], 1)
    cat_i = jnp.concatenate([jnp.asarray(i) for i in idx_parts], 1)
    order = jnp.argsort(cat_i, axis=1)            # secondary: id asc
    v = jnp.take_along_axis(cat_v, order, 1)
    i = jnp.take_along_axis(cat_i, order, 1)
    order = jnp.argsort(-v, axis=1)               # primary: score desc
    v = jnp.take_along_axis(v, order, 1)[:, :k]   # (stable: ties keep
    i = jnp.take_along_axis(i, order, 1)[:, :k]   # the id order)
    valid = jnp.isfinite(v)
    return (np.asarray(jnp.where(valid, i, -1)), np.asarray(v))


def topk_cosine(Z, nodes, *, k: int = 10, block_rows: int = 1 << 14,
                exclude_self: bool = True, pre_normalized: bool = False):
    """Top-k cosine neighbors of Z[nodes] over all rows of Z.

    Pass pre_normalized=True when Z rows are already unit-norm (the
    service caches `normalize_rows(Z)` per version so repeated queries
    skip the O(n*K) pass).  Returns (indices (q, k) int32,
    scores (q, k) float32) as numpy."""
    nodes = np.asarray(nodes, np.int32)
    Zn = Z if pre_normalized else normalize_rows(Z)
    q = Zn[jnp.asarray(nodes)]
    return topk_cosine_q(Zn, q, nodes, k=k, block_rows=block_rows,
                         exclude_self=exclude_self)
