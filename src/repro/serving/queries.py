"""Jitted query kernels over a served embedding Z (n, K).

Three read paths, each shaped for microbatching (`batcher.py` stacks
many user requests into one kernel call):

* ``gather_embeddings``  — Z rows for a node batch.
* ``predict_labels``     — nearest-class-centroid label prediction in
  cosine space (centroids from the epoch's labeled nodes).
* ``topk_cosine``        — blocked top-k cosine nearest neighbors over
  all n rows; the candidate matrix is processed ``block_rows`` rows at
  a time so peak memory is O(q · block_rows), not O(q · n), and the
  running top-k is merged with ``lax.top_k`` per block.

Kernels are pure functions of (Z, ...) so they jit once per shape and
stay valid across versions/epochs — the service just passes its
current Z.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def gather_embeddings(Z, nodes):
    return Z[nodes]


def normalize_rows(X, eps=1e-9):
    return X / jnp.maximum(jnp.linalg.norm(X, axis=-1, keepdims=True), eps)


@functools.partial(jax.jit, static_argnames=("K",))
def class_centroids(Z, Y, *, K: int):
    """Mean embedding of each class's labeled nodes (K, K-dim)."""
    labeled = (Y >= 0).astype(Z.dtype)
    onehot = jax.nn.one_hot(jnp.maximum(Y, 0), K, dtype=Z.dtype)
    onehot = onehot * labeled[:, None]
    sums = onehot.T @ Z
    counts = onehot.sum(0)[:, None]
    return sums / jnp.maximum(counts, 1.0)


@jax.jit
def predict_labels(Z, centroids, nodes):
    """Label = argmax cosine(Z[node], centroid_k).  Returns (pred, score)."""
    q = normalize_rows(Z[nodes])
    c = normalize_rows(centroids)
    sims = q @ c.T
    return jnp.argmax(sims, 1).astype(jnp.int32), jnp.max(sims, 1)


@functools.partial(jax.jit, static_argnames=("k", "exclude_self"))
def _topk_block(vals, idxs, q, block, base, n, qnodes, *,
                exclude_self: bool, k: int):
    """Merge one candidate block into the running (vals, idxs) top-k."""
    scores = q @ block.T                                   # (q, B)
    gidx = base + jnp.arange(block.shape[0])               # (B,)
    mask = gidx[None, :] >= n                              # zero-padded tail
    if exclude_self:
        mask = mask | (gidx[None, :] == qnodes[:, None])
    scores = jnp.where(mask, -jnp.inf, scores)
    cat_v = jnp.concatenate([vals, scores], 1)
    cat_i = jnp.concatenate(
        [idxs, jnp.broadcast_to(gidx, scores.shape)], 1)
    v, sel = jax.lax.top_k(cat_v, k)
    return v, jnp.take_along_axis(cat_i, sel, 1)


def topk_cosine(Z, nodes, *, k: int = 10, block_rows: int = 1 << 14,
                exclude_self: bool = True, pre_normalized: bool = False):
    """Top-k cosine neighbors of Z[nodes] over all rows of Z.

    Pass pre_normalized=True when Z rows are already unit-norm (the
    service caches `normalize_rows(Z)` per version so repeated queries
    skip the O(n*K) pass).  Returns (indices (q, k) int32,
    scores (q, k) float32) as numpy."""
    n = Z.shape[0]
    nodes = jnp.asarray(np.asarray(nodes, np.int32))
    Zn = Z if pre_normalized else normalize_rows(Z)
    q = Zn[nodes]
    nq = q.shape[0]
    vals = jnp.full((nq, k), -jnp.inf, Z.dtype)
    idxs = jnp.full((nq, k), -1, jnp.int32)
    for base in range(0, n, block_rows):
        block = Zn[base:min(base + block_rows, n)]
        if block.shape[0] < block_rows and base > 0:
            # pad the tail block so the jitted kernel sees one shape
            pad = block_rows - block.shape[0]
            block = jnp.pad(block, ((0, pad), (0, 0)))
        vals, idxs = _topk_block(vals, idxs, q, block, base, n, nodes,
                                 exclude_self=exclude_self, k=k)
    # entries never filled (k > candidate count) keep idx -1 / -inf
    valid = jnp.isfinite(vals)
    idxs = jnp.where(valid, idxs, -1)
    return np.asarray(idxs), np.asarray(vals)
