"""Serving driver: synthetic SBM workload of mixed reads and writes.

Builds an SBM graph, stands up GraphStore -> ServingEngine ->
MicroBatcher, then runs `--steps` workload ticks.  Each tick enqueues a
mix of reads (embedding gathers, centroid label predictions, top-k
neighbor lookups) and writes (edge insert batches, deletions of
previously inserted batches, label reveals).  With `--sync-flush` the
driver flushes after each tick; by default the engine's background
flush loop drains the queue asynchronously (the driver just joins the
tickets at the end of each tick).  Periodic compaction restarts the
epoch.

`--shards N` runs the row-partitioned scatter/gather path;
`--data-dir` makes the engine durable (WAL + snapshots) and finishes
with a crash-recovery self-check: reopen the deployment from disk and
verify the exact `(version, epoch, fingerprint)` triple plus Z — and a
held-back top-k answer — against the live engine.

Multi-process deployment (`repro.transport`):

* `--serve-shard HOST:PORT --shard-id I` turns THIS process into shard
  worker I of the workload's row partition (`RowPartition(n, shards)`)
  and serves until shut down — the manual way to stand up workers that
  a router later `--connect`s to;
* `--transport socket` spawns the shard workers as subprocesses;
  `--connect addr0,addr1,...` connects to externally-launched ones
  instead (shard count follows the address list);
* `--replicas N` (durable runs) adds WAL-tail read replicas that serve
  version-pinned reads with owner fallback on lag;
* `--fsync` + `--group-commit-ms/--group-commit-bytes` batch the WAL's
  power-loss barriers (group commit);
* `--shutdown-workers` tears down remote workers at exit — including
  `--connect`ed ones (the `make serve-multiproc` teardown).

With `--data-dir`, socket deployments extend the recovery self-check
to a full reconnect: the router closes, reopens from disk against
fresh (or surviving `--connect`) workers, and must answer the same
top-k queries identically to the pre-crash engine.  `--index ivf [--nprobe N]` serves top-k through the
delta-maintained IVF index (`repro.index`) and adds two self-checks:
ivf@nprobe=K must equal the exact scan bit-for-bit, and (durable runs)
recovery must restore the same quantizer; `--obs-dump` then also
reports per-shard cell occupancy.

Exit criteria printed at the end: per-kind throughput/latency stats,
the version/epoch counters, and a self-check that the delta-maintained
Z matches a from-scratch rebuild (max |dZ|).

    PYTHONPATH=src python -m repro.serving.server --n 2000 --edges 40000 \
        --steps 30 --shards 4
"""
from __future__ import annotations

import argparse

import numpy as np

from repro import obs
from repro.core.gee import gee
from repro.graph.edges import make_labels
from repro.graph.generators import sbm
from repro.serving.batcher import MicroBatcher
from repro.serving.engine import ServingEngine
from repro.serving.store import GraphStore

import jax.numpy as jnp


def _self_check(engine: ServingEngine) -> float:
    """Max |delta-maintained Z - from-scratch Z| under epoch labels."""
    g = engine.store.edges()
    Z = gee(jnp.asarray(g.u), jnp.asarray(g.v), jnp.asarray(g.w),
            jnp.asarray(engine.Y_epoch), K=engine.store.K, n=g.n)
    return float(jnp.max(jnp.abs(Z - engine.Z)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--k", type=int, default=8, help="communities/classes")
    ap.add_argument("--edges", type=int, default=40_000)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--shards", type=int, default=1,
                    help="row-partition Z across N shard workers")
    ap.add_argument("--data-dir", default=None,
                    help="durable deployment dir (WAL + snapshots); "
                         "adds a crash-recovery self-check at the end")
    ap.add_argument("--sync-flush", action="store_true",
                    help="flush the batcher inline instead of running "
                         "the engine's background flush loop")
    ap.add_argument("--reads-per-step", type=int, default=8)
    ap.add_argument("--read-nodes", type=int, default=64)
    ap.add_argument("--write-batch", type=int, default=200)
    ap.add_argument("--label-frac", type=float, default=0.1)
    ap.add_argument("--compact-every", type=int, default=10)
    ap.add_argument("--rebuild-churn", type=float, default=0.05)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--index", choices=["ivf"], default=None,
                    help="serve top-k through the delta-maintained IVF "
                         "index (repro.index) instead of full scans")
    ap.add_argument("--nprobe", type=int, default=None,
                    help="IVF cells probed per query (default: "
                         "repro.index.DEFAULT_NPROBE)")
    ap.add_argument("--index-churn", type=float, default=0.25,
                    help="re-quantize the index past this moved-rows "
                         "fraction")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs-dump", action="store_true",
                    help="print the metrics registry (Prometheus text "
                         "format) and health state at the end")
    ap.add_argument("--transport", choices=["local", "socket"],
                    default="local",
                    help="'socket' runs each shard in its own worker "
                         "process (spawned unless --connect)")
    ap.add_argument("--connect", default=None, metavar="ADDR,ADDR,...",
                    help="connect to externally-launched shard workers "
                         "instead of spawning (implies socket; shard "
                         "count follows the list)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="WAL-tail read replica workers (needs "
                         "--data-dir)")
    ap.add_argument("--serve-shard", default=None, metavar="HOST:PORT",
                    help="be shard worker --shard-id of this "
                         "workload's row partition and serve forever")
    ap.add_argument("--shard-id", type=int, default=0,
                    help="which shard --serve-shard hosts")
    ap.add_argument("--fsync", action="store_true",
                    help="fsync WAL appends (power-loss durability)")
    ap.add_argument("--group-commit-ms", type=float, default=None,
                    help="batch WAL fsync barriers: max age of an "
                         "uncovered append")
    ap.add_argument("--group-commit-bytes", type=int, default=None,
                    help="batch WAL fsync barriers: bytes per group")
    ap.add_argument("--shutdown-workers", action="store_true",
                    help="shut down remote workers at exit, including "
                         "--connect'ed ones")
    args = ap.parse_args(argv)

    if args.serve_shard is not None:
        # become worker `--shard-id` of the (n, shards) row partition:
        # same partition math as the router, so `--connect` lines up
        from repro.graph.partition import RowPartition
        from repro.transport import worker as transport_worker
        lo, hi = RowPartition(args.n, args.shards).slice(args.shard_id)
        return transport_worker.main([
            "--role", "shard", "--addr", args.serve_shard,
            "--shard-id", str(args.shard_id), "--lo", str(lo),
            "--hi", str(hi), "--classes", str(args.k),
            "--nodes", str(args.n)])

    shard_addrs = ([a for a in args.connect.split(",") if a]
                   if args.connect else None)
    transport = ("socket" if (shard_addrs or
                              args.transport == "socket") else "local")
    if shard_addrs:
        args.shards = len(shard_addrs)

    rng = np.random.default_rng(args.seed)
    g, truth = sbm(args.n, args.k, args.edges, p_in=0.85, seed=args.seed)
    Y = make_labels(args.n, args.k, args.label_frac, rng, true_labels=truth)

    store = GraphStore(g, Y, args.k)
    engine = ServingEngine(store, num_shards=args.shards,
                           rebuild_churn=args.rebuild_churn,
                           data_dir=args.data_dir,
                           index=args.index, nprobe=args.nprobe,
                           index_churn=args.index_churn,
                           transport=transport,
                           shard_addrs=shard_addrs,
                           replicas=args.replicas,
                           fsync=args.fsync,
                           group_commit_ms=args.group_commit_ms,
                           group_commit_bytes=args.group_commit_bytes)
    batcher = MicroBatcher(engine, topk=args.topk,
                           topk_mode=args.index or "exact",
                           topk_nprobe=args.nprobe)
    if not args.sync_flush:
        engine.start(batcher)
    print(f"[serve-gee] n={args.n} K={args.k} edges={args.edges:,} "
          f"labeled={int((Y >= 0).sum())} shards={args.shards} "
          f"durable={bool(args.data_dir)} transport={transport}"
          + (f" replicas={args.replicas}" if args.replicas else ""))
    if transport == "socket":
        for row in engine.stats()["transport"]["shard_addrs"]:
            print(f"[serve-gee] shard worker @ {row}")

    inserted: list[tuple] = []     # batches eligible for later deletion
    for step in range(args.steps):
        tickets = []
        for _ in range(args.reads_per_step):
            kind = rng.choice(["embed", "predict", "topk"])
            nodes = rng.integers(0, args.n, size=args.read_nodes)
            tickets.append(batcher.submit(str(kind), nodes))
        b = args.write_batch
        u = rng.integers(0, args.n, size=b).astype(np.int32)
        v = rng.integers(0, args.n, size=b).astype(np.int32)
        w = rng.random(b).astype(np.float32) + 0.5
        tickets.append(batcher.submit("insert", (u, v, w)))
        inserted.append((u, v, w))
        if len(inserted) > 3 and rng.random() < 0.4:
            tickets.append(batcher.submit(
                "delete", inserted.pop(rng.integers(0, len(inserted)))))
        if rng.random() < 0.3:
            nodes = rng.integers(0, args.n, size=args.n // 100 + 1)
            tickets.append(batcher.submit("labels", (nodes, truth[nodes])))
        if args.sync_flush:
            batcher.flush()
        else:                          # async loop drains; join the tick
            for t in tickets:
                t.result(timeout=60)
        if args.compact_every and (step + 1) % args.compact_every == 0:
            info = (engine.checkpoint() if args.data_dir
                    else engine.compact())
            print(f"[serve-gee] step {step + 1}: compacted "
                  f"{info['edges_before']:,} -> {info['edges_after']:,} "
                  f"edges, epoch={engine.epoch}")
    if not args.sync_flush:
        engine.stop()

    print(f"[serve-gee] final version={engine.version} "
          f"epoch={engine.epoch} rebuilds={engine.rebuilds} "
          f"churn={engine.churn:.3f}")
    for kind, row in batcher.stats().items():
        print(f"[serve-gee] {kind:8s} req={row['requests']:5d} "
              f"batches={row['batches']:4d} "
              f"mean_batch={row['mean_batch']:7.1f} "
              f"lat={row['mean_latency_ms']:8.2f} ms "
              f"thru={row['items_per_s']:10.0f} items/s")
    err = _self_check(engine)
    print(f"[serve-gee] self-check max|Z_delta - Z_rebuild| = {err:.2e}")
    assert err < 1e-3, "delta-maintained Z diverged from rebuild"
    if args.index:
        # probing every cell must reproduce the exact scan bit-for-bit
        nodes = rng.integers(0, args.n, size=64).astype(np.int32)
        ei, ev = engine.query_topk(nodes, k=args.topk, mode="exact")
        ii, iv = engine.query_topk(nodes, k=args.topk, mode="ivf",
                                   nprobe=args.k)
        assert np.array_equal(ei, ii) and np.array_equal(ev, iv), \
            "ivf@nprobe=K diverged from the exact scan"
        istats = engine.stats()["index"]
        print(f"[serve-gee] index: nprobe={istats['nprobe']} "
              f"requantizes={istats['requantizes']} "
              f"moved={istats['moved_rows']} "
              f"(ivf@nprobe=K == exact ✓)")
    if args.obs_dump:
        print(f"[serve-gee] health: {engine.health()}")
        if engine.index_mode is not None:
            for sid, cells in enumerate(
                    engine.stats()["index"]["cell_sizes"]):
                print(f"[serve-gee] index occupancy shard {sid}: "
                      f"{cells} (rows/cell)")
        print(obs.render_prometheus(), end="")

    if args.data_dir:
        # capture everything BEFORE close: a socket engine's shards die
        # with it, and the reconnected deployment must answer the same
        qnodes = rng.integers(0, args.n, size=64).astype(np.int32)
        pre = engine.query_topk(qnodes, k=args.topk, mode="exact")
        pre_ivf = (engine.query_topk(qnodes, k=args.topk, mode="ivf",
                                     nprobe=args.nprobe)
                   if args.index else None)
        triple = (engine.version, engine.epoch, engine.fingerprint())
        Z_live = np.asarray(engine.Z)
        engine.close()
        recovered = ServingEngine.open(args.data_dir,
                                       transport=transport,
                                       shard_addrs=shard_addrs)
        rtriple = (recovered.version, recovered.epoch,
                   recovered.fingerprint())
        dz = float(jnp.max(jnp.abs(recovered.Z - Z_live)))
        print(f"[serve-gee] recovery: {rtriple} vs live {triple}, "
              f"max|dZ|={dz:.2e}")
        assert rtriple == triple, "recovered state diverged"
        assert dz < 1e-3, "recovered Z diverged"
        # indices exact; values to the same tolerance as dZ (the
        # recovered Z is rebuilt, the live one delta-maintained)
        post = recovered.query_topk(qnodes, k=args.topk, mode="exact")
        assert (np.array_equal(pre[0], post[0])
                and np.allclose(pre[1], post[1], atol=1e-4)), \
            "reconnected deployment's top-k diverged from pre-crash"
        print("[serve-gee] recovery: reconnected top-k identical ✓")
        if args.index:
            assert recovered.index_mode == engine.index_mode
            assert np.array_equal(recovered._index_centroids,
                                  engine._index_centroids), \
                "recovered index quantizer diverged"
            post_ivf = recovered.query_topk(qnodes, k=args.topk,
                                            mode="ivf",
                                            nprobe=args.nprobe)
            assert (np.array_equal(pre_ivf[0], post_ivf[0])
                    and np.allclose(pre_ivf[1], post_ivf[1],
                                    atol=1e-4)), \
                "reconnected deployment's ivf top-k diverged"
            print("[serve-gee] recovery: index quantizer restored ✓")
        if transport == "socket":
            # socket == in-process: an in-process twin recovered from
            # the same snapshot+WAL must answer bit-for-bit equal
            twin = ServingEngine.open(args.data_dir)
            ti, tv = twin.query_topk(qnodes, k=args.topk, mode="exact")
            assert (np.array_equal(post[0], ti)
                    and np.array_equal(post[1], tv)), \
                "socket deployment diverged from in-process twin"
            if args.index:
                xi, xv = twin.query_topk(qnodes, k=args.topk,
                                         mode="ivf", nprobe=args.nprobe)
                assert (np.array_equal(post_ivf[0], xi)
                        and np.array_equal(post_ivf[1], xv)), \
                    "socket ivf top-k diverged from in-process twin"
            twin.close()
            print("[serve-gee] socket deployment == in-process ✓")
        if args.shutdown_workers:
            recovered.shutdown_workers()
        recovered.close()
    else:
        if args.shutdown_workers:
            engine.shutdown_workers()
        engine.close()
    return err


if __name__ == "__main__":
    main()
