"""Distributed GEE demo: embed a multi-million-edge graph with the
edge-parallel SPMD pipeline on 8 (placeholder) devices — the exact code
path the 512-chip dry-run lowers, at laptop scale.

    PYTHONPATH=src python examples/distributed_gee.py
"""
import json
import os
import subprocess
import sys

WORKER = r"""
import time
import numpy as np, jax, jax.numpy as jnp
from repro.graph.generators import sbm
from repro.graph.edges import make_labels
from repro.graph.partition import shuffle_edges
from repro.core.distributed import gee_distributed, edge_mesh
from repro.core.ref_python import gee_numpy

n, K, s = 200_000, 50, 8_000_000
g, truth = sbm(n, K, s, p_in=0.85, seed=0)
g = shuffle_edges(g, seed=1)
Y = make_labels(n, K, 0.10, np.random.default_rng(0), true_labels=truth)
mesh = edge_mesh()
P = len(jax.devices())
from repro.core.distributed import exact_capacity_factor
cf = exact_capacity_factor(g, P)
print(f"devices={P} edges={s:,} capacity_factor={cf:.2f} (auto)")

for mode in ("ring", "a2a", "reduce_scatter"):
    Z, dropped = gee_distributed(g, Y, K=K, mode=mode, mesh=mesh,
                                 capacity_factor=cf)   # warm + compile
    t0 = time.perf_counter()
    Z, dropped = gee_distributed(g, Y, K=K, mode=mode, mesh=mesh,
                                 capacity_factor=cf)
    dt = time.perf_counter() - t0
    pred = Z.argmax(1)
    mask = Y < 0
    acc = (pred[mask] == truth[mask]).mean()
    print(f"mode={mode:14s} {dt*1e3:9.1f} ms  "
          f"({s/dt/1e6:6.1f} M edges/s)  dropped={dropped}  "
          f"unlabeled-acc={acc:.3f}")
"""


def main():
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(here, "src")
    r = subprocess.run([sys.executable, "-c", WORKER], env=env, text=True)
    sys.exit(r.returncode)


if __name__ == "__main__":
    main()
