"""Distributed GEE demo: embed a multi-million-edge graph through the
unified Embedder API's `distributed:*` backends on 8 (placeholder)
devices — the exact code path the 512-chip dry-run lowers, at laptop
scale.  The plan (padding + exact capacity measurement) is built once
per backend; the timed fit reuses it.

    python examples/distributed_gee.py
"""
import os
import subprocess
import sys

WORKER = r"""
import time
import numpy as np, jax
from repro.graph.generators import sbm
from repro.graph.edges import make_labels
from repro.graph.partition import shuffle_edges
from repro.encoder import Embedder, EncoderConfig

n, K, s = 200_000, 50, 8_000_000
g, truth = sbm(n, K, s, p_in=0.85, seed=0)
g = shuffle_edges(g, seed=1)
Y = make_labels(n, K, 0.10, np.random.default_rng(0), true_labels=truth)
P = len(jax.devices())
print(f"devices={P} edges={s:,} (capacity factor measured in plan)")

for mode in ("ring", "a2a", "reduce_scatter"):
    emb = Embedder(EncoderConfig(K=K), backend=f"distributed:{mode}")
    emb.fit(g, Y)                                  # plan + warm compile
    t0 = time.perf_counter()
    emb.refit(Y)                                   # cached plan
    jax.block_until_ready(emb.Z_)
    dt = time.perf_counter() - t0
    pred = emb.predict()
    mask = Y < 0
    acc = (pred[mask] == truth[mask]).mean()
    print(f"mode={mode:14s} {dt*1e3:9.1f} ms  "
          f"({s/dt/1e6:6.1f} M edges/s)  "
          f"dropped={emb.last_info_['dropped']}  "
          f"plan={emb.plan_stats}  unlabeled-acc={acc:.3f}")
"""


def main():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run([sys.executable, "-c", WORKER], env=env, text=True)
    sys.exit(r.returncode)


if __name__ == "__main__":
    main()
