"""Batched serving example: prefill a mixed batch of prompts, decode
greedily with the shared donated KV cache (the decode_32k dry-run cells
run exactly this step function at production shapes).

    python examples/serve_lm.py --arch zamba2-1.2b
"""
import argparse
from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--reduced",
                "--batch", str(args.batch),
                "--prompt-len", str(args.prompt_len),
                "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
