"""GEE <-> LM bridge: initialize an LM embedding table from a GEE
embedding of the token co-occurrence graph and compare early training
against random init.

    python examples/gee_embedding_init.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.encoder.bridge import gee_embedding_init
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import model as M
from repro.training.optimizer import AdamW
from repro.training.train_loop import make_train_step


def run(use_gee_init: bool, steps: int = 60):
    cfg = ModelConfig(name="bridge-demo", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=4, d_ff=512,
                      vocab=512, vocab_pad=8, param_dtype="float32",
                      compute_dtype="float32", remat=False)
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=64,
                                      global_batch=8, seed=0))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    if use_gee_init:
        stream = np.concatenate([data.batch(s).reshape(-1)
                                 for s in range(1000, 1008)])
        table = gee_embedding_init(stream, cfg.padded_vocab, cfg.d_model,
                                   K=32, refine_iters=4)
        params["embed"]["tokens"] = jnp.asarray(table)
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    state = opt.init(params)
    losses = []
    for s in range(steps):
        batch = {"tokens": jnp.asarray(data.batch(s))}
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    return losses


def main():
    base = run(False)
    geed = run(True)
    print(f"{'step':>6} {'random-init':>12} {'gee-init':>12}")
    for s in (0, 10, 20, 40, 59):
        print(f"{s:>6} {base[s]:>12.4f} {geed[s]:>12.4f}")
    a, b = np.mean(base[-10:]), np.mean(geed[-10:])
    print(f"\nmean last-10 loss: random {a:.4f} vs GEE-init {b:.4f} "
          f"({'GEE better' if b < a else 'random better'} by {abs(a-b):.4f})")


if __name__ == "__main__":
    main()
