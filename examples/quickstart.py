"""Quickstart: embed a graph with the unified Embedder API.

    pip install -e .          # once, from the repo root
    python examples/quickstart.py

One config + one Embedder front door; the execution strategy (XLA
scatter, Pallas kernel, SPMD collectives, streaming chunks, numpy
oracle) is just the `backend=` string — or `"auto"` (the default),
resolved from the graph size and device topology.  Graphs enter
through a `GraphSource` (here: a deterministic synthetic source whose
content fingerprint keys the persistent plan cache — rerun this script
and the second process's plan comes off disk).
"""
import itertools
import time

import jax
import numpy as np

from repro.encoder import Embedder, EncoderConfig
from repro.graph.edges import make_labels
from repro.graph.sources import SyntheticSource


def main():
    # --- 1. a community graph with 5 planted blocks --------------------
    n, K, s = 20_000, 5, 400_000
    src = SyntheticSource("sbm", n=n, K=K, s=s, p_in=0.9, seed=0)
    g, truth = src.graph(), src.labels
    Y = make_labels(n, K, 0.10, np.random.default_rng(0),
                    true_labels=truth)
    print(f"graph: n={n:,} s={s:,} K={K}, 10% labeled "
          f"(fingerprint {src.fingerprint()[:12]}…)")

    # --- 2. one-pass semi-supervised embedding -------------------------
    cfg = EncoderConfig(K=K)                     # backend="auto"
    emb = Embedder(cfg).fit(src, Y)              # plan + embed
    print(f"backend=auto resolved to {emb.backend.name!r}; "
          f"plan {emb.plan_stats}")
    t0 = time.perf_counter()
    emb.refit(Y)                   # cached plan: no host re-packing
    jax.block_until_ready(emb.Z_)
    t_xla = time.perf_counter() - t0

    ref = Embedder(cfg, backend="numpy")
    t0 = time.perf_counter()
    ref.fit(g, Y)
    t_np = time.perf_counter() - t0
    diff = np.abs(emb.transform() - ref.transform()).max()
    print(f"gee (XLA jit): {t_xla*1e3:8.2f} ms   "
          f"({s/t_xla/1e6:.1f} M edges/s)")
    print(f"gee (numpy)  : {t_np*1e3:8.2f} ms   speedup "
          f"{t_np/t_xla:.1f}x, max|diff| {diff:.2e}")

    # --- 3. classify unlabeled nodes by argmax --------------------------
    pred = emb.predict()
    mask = Y < 0
    acc = (pred[mask] == truth[mask]).mean()
    print(f"unlabeled-node accuracy (argmax Z): {acc:.3f}")

    # --- 4. fully unsupervised refinement --------------------------------
    emb2 = Embedder(EncoderConfig(K=K, refine_iters=6), backend="xla")
    emb2.fit(g, np.full(n, -1, np.int32))
    emb2.refine(jax.random.PRNGKey(0))
    labels = emb2.labels_
    best = max((labels == np.asarray(p)[truth]).mean()
               for p in itertools.permutations(range(K)))
    print(f"unsupervised refinement purity:     {best:.3f}")


if __name__ == "__main__":
    main()
