"""Quickstart: embed a graph with GEE in three lines, verify quality.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import time

import numpy as np
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.core.gee import gee, gee_refine           # noqa: E402
from repro.core.ref_python import gee_numpy          # noqa: E402
from repro.graph.edges import make_labels            # noqa: E402
from repro.graph.generators import sbm               # noqa: E402
import jax                                           # noqa: E402


def main():
    # --- 1. a community graph with 5 planted blocks --------------------
    n, K, s = 20_000, 5, 400_000
    g, truth = sbm(n, K, s, p_in=0.9, seed=0)
    Y = make_labels(n, K, 0.10, np.random.default_rng(0),
                    true_labels=truth)
    print(f"graph: n={n:,} s={s:,} K={K}, 10% labeled")

    # --- 2. one-pass semi-supervised embedding -------------------------
    uj, vj, wj, Yj = map(jnp.asarray, (g.u, g.v, g.w, Y))
    Z = gee(uj, vj, wj, Yj, K=K, n=n)              # (n, K)
    Z.block_until_ready()
    t0 = time.perf_counter()
    Z = gee(uj, vj, wj, Yj, K=K, n=n)
    Z.block_until_ready()
    t_xla = time.perf_counter() - t0

    t0 = time.perf_counter()
    Z_np = gee_numpy(g.u, g.v, g.w, Y, K, n)
    t_np = time.perf_counter() - t0
    print(f"gee (XLA jit): {t_xla*1e3:8.2f} ms   "
          f"({s/t_xla/1e6:.1f} M edges/s)")
    print(f"gee (numpy)  : {t_np*1e3:8.2f} ms   speedup "
          f"{t_np/t_xla:.1f}x, max|diff| "
          f"{np.abs(np.asarray(Z)-Z_np).max():.2e}")

    # --- 3. classify unlabeled nodes by argmax --------------------------
    pred = np.asarray(Z).argmax(1)
    mask = Y < 0
    acc = (pred[mask] == truth[mask]).mean()
    print(f"unlabeled-node accuracy (argmax Z): {acc:.3f}")

    # --- 4. fully unsupervised refinement --------------------------------
    Y0 = jnp.full((n,), -1, jnp.int32)
    Z2, labels = gee_refine(uj, vj, wj, Y0, jax.random.PRNGKey(0),
                            K=K, n=n, iters=6)
    import itertools
    labels = np.asarray(labels)
    best = max((labels == np.asarray(p)[truth]).mean()
               for p in itertools.permutations(range(K)))
    print(f"unsupervised refinement purity:     {best:.3f}")


if __name__ == "__main__":
    main()
