"""End-to-end LM training: a ~100M-param dense model for a few hundred
steps through the full production loop (sharded step, checkpoints,
heartbeats, data pipeline).

    python examples/train_lm.py               # ~25M demo
    python examples/train_lm.py --full-100m   # the real one

The 25M default finishes on this single-core CPU container in minutes;
--full-100m is the deliverable configuration (same code path, bigger
dims) — on TPU it is a per-chip triviality, on 1 CPU core budget ~1 hr.
"""
import argparse

from repro.configs.base import ModelConfig
from repro.configs import _REGISTRY
from repro.models import model as M


def demo_config(full: bool) -> ModelConfig:
    if full:
        return ModelConfig(
            name="demo-100m", family="dense", n_layers=12, d_model=512,
            n_heads=8, n_kv_heads=4, d_ff=2048, vocab=32000,
            param_dtype="float32", compute_dtype="float32",
            remat=False, attn_chunk=256)
    return ModelConfig(
        name="demo-25m", family="dense", n_layers=6, d_model=320,
        n_heads=8, n_kv_heads=4, d_ff=1280, vocab=16000,
        param_dtype="float32", compute_dtype="float32",
        remat=False, attn_chunk=256)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_example")
    args = ap.parse_args()

    cfg = demo_config(args.full_100m)
    print(f"[example] {cfg.name}: "
          f"{M.count_params_analytic(cfg)/1e6:.1f}M params")

    # register so the production trainer can find it, then run the real
    # trainer (checkpoints + heartbeat + straggler monitor included)
    import repro.configs.yi_6b as template
    mod = type(template)("repro.configs._demo")
    mod.CONFIG = cfg
    sys.modules["repro.configs._demo"] = mod
    _REGISTRY[cfg.name] = "repro.configs._demo"

    from repro.launch.train import main as train_main
    losses = train_main([
        "--arch", cfg.name, "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--lr", "1e-3", "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100", "--log-every", "20"])
    import numpy as np
    print(f"[example] loss {np.mean(losses[:5]):.3f} -> "
          f"{np.mean(losses[-10:]):.3f} over {len(losses)} steps")


if __name__ == "__main__":
    main()
