"""Online GEE walkthrough: stand up the embedding service, mutate the
graph live, query it, and watch the version/epoch model in action.

    python examples/serve_gee.py

Story line:
  1. Build an SBM graph, reveal 10% of the true labels, start the
     service — Z is embedded once from scratch (epoch 1).
  2. Fold in live edge inserts/deletes with O(batch) delta updates —
     the version counter advances, the epoch does not.
  3. Query through the microbatcher: gathers, label predictions,
     top-k cosine neighbors — all coalesced into single kernel calls.
  4. Reveal more labels: below the churn threshold the service keeps
     serving epoch-1 Z; past it, a rebuild starts epoch 2.
  5. Compact: the delta log folds into the base multiset and the
     embedding is rebuilt fresh.
"""
import numpy as np
import jax.numpy as jnp

from repro.encoder import Embedder, EncoderConfig
from repro.graph.edges import make_labels
from repro.graph.generators import sbm
from repro.serving import EmbeddingService, GraphStore, MicroBatcher

n, K, s = 1500, 6, 30_000
rng = np.random.default_rng(0)
g, truth = sbm(n, K, s, p_in=0.9, seed=0)
Y = make_labels(n, K, 0.10, rng, true_labels=truth)

# -- 1. boot --------------------------------------------------------------
store = GraphStore(g, Y, K)
service = EmbeddingService(store, rebuild_churn=0.05)
batcher = MicroBatcher(service, topk=5)
print(f"boot: n={n} edges={s:,} -> epoch={service.epoch} "
      f"version={service.version} "
      f"fingerprint={store.fingerprint()[:12]}… "
      f"plan={service.embedder.plan_stats}")
# (the store maintains that fingerprint incrementally per delta; a
# second replica booting from the same snapshot+deltas finds this
# boot's plan in the persistent cache and skips host preprocessing)

# -- 2. live edge churn ---------------------------------------------------
b = 500
u = rng.integers(0, n, size=b).astype(np.int32)
v = rng.integers(0, n, size=b).astype(np.int32)
w = np.ones(b, np.float32)
service.apply_edge_delta(u, v, w)                  # insert
service.apply_edge_delta(u[:200], v[:200], w[:200], delete=True)
print(f"after 2 edge deltas: version={service.version} "
      f"epoch={service.epoch} (no rebuild — deltas are exact)")

# prove exactness: from-scratch embed of the live multiset
scratch = Embedder(EncoderConfig(K=K), backend="xla")
scratch.fit(store.edges(), service.Y_epoch)
print(f"max|Z_delta - Z_scratch| = "
      f"{float(jnp.max(jnp.abs(scratch.Z_ - service.Z))):.2e}")

# -- 3. batched queries ---------------------------------------------------
t_embed = batcher.submit("embed", rng.integers(0, n, 32))
t_pred = batcher.submit("predict", rng.integers(0, n, 64))
t_topk = batcher.submit("topk", rng.integers(0, n, 8))
batcher.flush()
pred, score = t_pred.result()
nbr_idx, nbr_val = t_topk.result()
print(f"queries: embed {t_embed.result().shape}, "
      f"predict acc vs truth = "
      f"{(pred == truth[np.asarray(t_pred.payload)]).mean():.2f}, "
      f"top-5 neighbor sample = {nbr_idx[0].tolist()}")

# -- 4. label churn and the rebuild threshold -----------------------------
few = rng.choice(n, size=int(0.02 * n), replace=False)
service.apply_label_delta(few, truth[few])
print(f"2% label reveal: churn={service.churn:.3f} "
      f"epoch={service.epoch} (below threshold, epoch kept)")
many = rng.choice(n, size=int(0.10 * n), replace=False)
service.apply_label_delta(many, truth[many])
print(f"10% label reveal: churn={service.churn:.3f} "
      f"epoch={service.epoch} (threshold crossed -> rebuilt)")

# -- 5. compaction --------------------------------------------------------
info = service.compact()
print(f"compaction: {info['edges_before']:,} -> {info['edges_after']:,} "
      f"edges, epoch={service.epoch}, log_edges={store.log_edges}")
for kind, row in batcher.stats().items():
    print(f"stats[{kind}]: {row['requests']} req in {row['batches']} "
          f"batch(es), mean latency {row['mean_latency_ms']:.1f} ms")
