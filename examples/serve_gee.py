"""Online GEE walkthrough: stand up a sharded, durable serving
deployment, mutate the graph live, query it, crash it, and recover.

    python examples/serve_gee.py

Story line:
  1. Build an SBM graph, reveal 10% of the true labels, start a
     `ServingEngine` with 2 shards and a durable data dir — Z rows are
     partitioned across shard workers, generation 0 is snapshotted,
     and a write-ahead log opens (epoch 1).
  2. Fold in live edge inserts/deletes with O(batch) delta updates —
     each batch is WAL-appended first, then fans out only to the
     shards owning its endpoint rows.  version advances, epoch does
     not.
  3. Query through the microbatcher driven by the engine's background
     flush loop: gathers, label predictions, top-k cosine neighbors —
     coalesced into single scatter/gather passes across the shards.
  4. Reveal more labels: below the churn threshold the engine keeps
     serving epoch-1 Z; past it, every shard rebuilds (a plan-cache
     hit per shard) and epoch 2 begins.
  5. "Crash" (abandon the engine without a checkpoint), then
     `ServingEngine.open` the same directory: the WAL replays onto the
     generation-0 snapshot and reconstructs the exact
     (version, epoch, fingerprint) state.

`EmbeddingService` still exists as the 1-shard volatile special case
(`EmbeddingService(store) == ServingEngine(store, num_shards=1)`);
new code should construct the engine directly.
"""
import shutil
import tempfile

import numpy as np
import jax.numpy as jnp

from repro.encoder import Embedder, EncoderConfig
from repro.graph.edges import make_labels
from repro.graph.generators import sbm
from repro.serving import GraphStore, ServingEngine

n, K, s = 1500, 6, 30_000
rng = np.random.default_rng(0)
g, truth = sbm(n, K, s, p_in=0.9, seed=0)
Y = make_labels(n, K, 0.10, rng, true_labels=truth)
data_dir = tempfile.mkdtemp(prefix="gee-deployment-")

# -- 1. boot a durable, sharded deployment --------------------------------
store = GraphStore(g, Y, K)
engine = ServingEngine(store, num_shards=2, data_dir=data_dir,
                       rebuild_churn=0.05)
batcher = engine.start()           # background flush loop + microbatcher
print(f"boot: n={n} edges={s:,} shards={engine.num_shards} -> "
      f"epoch={engine.epoch} version={engine.version} "
      f"generation={engine.generation} "
      f"fingerprint={engine.fingerprint()[:12]}…")

# -- 2. live edge churn (WAL-append, then fan out to owning shards) -------
b = 500
u = rng.integers(0, n, size=b).astype(np.int32)
v = rng.integers(0, n, size=b).astype(np.int32)
w = np.ones(b, np.float32)
engine.apply_edge_delta(u, v, w)                  # insert
engine.apply_edge_delta(u[:200], v[:200], w[:200], delete=True)
print(f"after 2 edge deltas: version={engine.version} "
      f"epoch={engine.epoch} (no rebuild — deltas are exact), "
      f"wal_records={engine.stats()['durability']['wal_records']}")

# prove exactness: from-scratch embed of the live multiset
scratch = Embedder(EncoderConfig(K=K), backend="xla")
scratch.fit(store.edges(), engine.Y_epoch)
print(f"max|Z_delta - Z_scratch| = "
      f"{float(jnp.max(jnp.abs(scratch.Z_ - engine.Z))):.2e}")

# -- 3. batched queries through the background loop -----------------------
t_embed = batcher.submit("embed", rng.integers(0, n, 32))
t_pred = batcher.submit("predict", rng.integers(0, n, 64))
t_topk = batcher.submit("topk", rng.integers(0, n, 8))
pred, score = t_pred.result(timeout=60)
nbr_idx, nbr_val = t_topk.result(timeout=60)
print(f"queries: embed {t_embed.result(timeout=60).shape}, "
      f"predict acc vs truth = "
      f"{(pred == truth[np.asarray(t_pred.payload)]).mean():.2f}, "
      f"top-5 neighbor sample = {nbr_idx[0][:5].tolist()}")

# -- 4. label churn and the rebuild threshold -----------------------------
few = rng.choice(n, size=int(0.02 * n), replace=False)
engine.apply_label_delta(few, truth[few])
print(f"2% label reveal: churn={engine.churn:.3f} "
      f"epoch={engine.epoch} (below threshold, epoch kept)")
many = rng.choice(n, size=int(0.10 * n), replace=False)
engine.apply_label_delta(many, truth[many])
print(f"10% label reveal: churn={engine.churn:.3f} "
      f"epoch={engine.epoch} (threshold crossed -> all shards rebuilt)")
engine.stop()                      # drain the loop; leave WAL un-rotated

# -- 5. crash + recovery --------------------------------------------------
triple = (engine.version, engine.epoch, engine.fingerprint())
Z_live = np.asarray(engine.Z)
del engine                         # "crash": no checkpoint, no close
recovered = ServingEngine.open(data_dir)
print(f"recovered: (version, epoch, fingerprint[:12]) = "
      f"({recovered.version}, {recovered.epoch}, "
      f"{recovered.fingerprint()[:12]}…) — exact match: "
      f"{(recovered.version, recovered.epoch, recovered.fingerprint()) == triple}")
print(f"max|Z_recovered - Z_live| = "
      f"{np.abs(np.asarray(recovered.Z) - Z_live).max():.2e}")
info = recovered.checkpoint()      # durable compaction: snapshot + rotate
print(f"checkpoint: {info['edges_before']:,} -> {info['edges_after']:,} "
      f"edges, generation={info['generation']}, "
      f"epoch={recovered.epoch}")
recovered.close()
shutil.rmtree(data_dir)
