# One-command entry points (see ROADMAP.md for the tier-1 contract).
PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-slow lint lint-static install install-dev \
	serve-demo serve-multiproc bench-serving bench-encoder bench-smoke \
	obs-gate obs-snapshot

# Tier-1 verify: the whole suite, fail-fast.
test:
	$(PY) -m pytest -x -q

# CI fast lane: everything not marked `slow` (no subprocess compiles,
# no crash-recovery/fuzz loops) — the quick local signal.
test-fast:
	$(PY) -m pytest -x -q -m "not slow"

# CI slow lane: only the `slow`-marked subprocess / plan-cache /
# recovery / fuzz tests.  fast + slow together == `make test`.
test-slow:
	$(PY) -m pytest -x -q -m slow

# Style/defect gate (ruff; `make install-dev` provides it) + the
# repo-specific analysis suite.
lint: lint-static
	$(PY) -m ruff check src tests benchmarks examples

# Repo-specific static analysis (repro.analysis): lock discipline,
# RPC retry safety, metric/span names, JAX tracer safety, WAL/codec
# exhaustiveness.  Stdlib-only — needs neither jax nor ruff.
lint-static:
	$(PY) -m repro.analysis src

# Editable install of the package itself. --no-build-isolation so it
# works offline (jax/numpy are baked into dev containers; the build
# needs only the preinstalled setuptools).
install:
	$(PY) -m pip install -e . --no-build-isolation

# Editable install + test/lint extras (hypothesis, ruff) — needs network.
install-dev:
	$(PY) -m pip install -e ".[test,lint]"

# Smoke the online serving engine on a small SBM workload (sharded).
serve-demo:
	$(PY) -m repro.serving.server --n 1000 --edges 20000 --steps 12 \
		--shards $(SHARDS)

# Multi-process smoke: 2 spawned shard worker processes + 1 WAL-tail
# read replica over the socket transport, with WAL group commit.  The
# driver's self-checks cover delta-vs-rebuild Z, crash-recovery
# reconnect (fresh workers answer the pre-crash top-k), and
# socket == in-process bit-equality; --shutdown-workers tears every
# worker down at exit.
serve-multiproc:
	d=$$(mktemp -d) && \
	$(PY) -m repro.serving.server --n 400 --k 4 --edges 3000 \
		--steps 3 --shards 2 --transport socket --replicas 1 \
		--data-dir $$d --sync-flush --fsync --group-commit-ms 20 \
		--shutdown-workers; rc=$$?; rm -rf $$d; exit $$rc

# Update-latency vs full re-embed + query throughput (>=1M edges),
# plus the sharded ServingEngine path (delta fan-out, scatter/gather
# top-k, WAL overhead, recovery).  `make bench-serving SHARDS=4` for
# more shards, `QUICK=1` for the tiny-graph smoke variant.
SHARDS ?= 2
bench-serving:
	$(PY) -m benchmarks.run --only serving --shards $(SHARDS) \
		$(if $(QUICK),--quick)

# Unified Embedder API: per-backend edges/s + plan-cache effect.
bench-encoder:
	$(PY) -m benchmarks.run --only encoder

# CI rot canary: every benchmark driver end-to-end on tiny graphs,
# then the observability overhead gate (instrumented fit within 3% of
# REPRO_OBS=off, and the disabled path a functional no-op).
# (fig3 spawns a device-sweep subprocess matrix and roofline needs
# dry-run artifacts; both have their own entry points.)
bench-smoke:
	$(PY) -m benchmarks.run --quick --only table1,fig4,kernels,encoder,serving,index
	$(PY) -m benchmarks.obs_gate --quick
	XLA_FLAGS=--xla_force_host_platform_device_count=1 \
		$(PY) -m repro.launch.hillclimb --quick \
		gee-scatter-tune gee-topk-tune

# IVF index: QPS + recall@10 vs the exact scan at n in {1e5, 1e6}.
bench-index:
	$(PY) -m benchmarks.run --only index

# The obs overhead gate alone, at full size.
obs-gate:
	$(PY) -m benchmarks.obs_gate

# Live registry snapshot off a tiny end-to-end serving demo.
obs-snapshot:
	$(PY) -m repro.obs --snapshot
