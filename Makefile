# One-command entry points (see ROADMAP.md for the tier-1 contract).
PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test serve-demo bench-serving

# Tier-1 verify: the whole suite, fail-fast.
test:
	$(PY) -m pytest -x -q

# Smoke the online embedding service on a small SBM workload.
serve-demo:
	$(PY) -m repro.serving.server --n 1000 --edges 20000 --steps 12

# Update-latency vs full re-embed + query throughput (>=1M edges).
bench-serving:
	$(PY) -m benchmarks.run --only serving
