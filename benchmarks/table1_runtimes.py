"""Paper Table I analog: GEE runtime across implementations and graphs.

The paper's columns map to ours as backends of the unified Embedder:
    GEE-Python (interpreted loop)    -> gee_python      (tiny graphs only)
    Numba serial (compiled scatter)  -> backend="numpy" (np.add.at)
    GEE-Ligra serial                 -> backend="xla"   (single device)
    GEE-Ligra parallel               -> distributed:* backends (fig3
                                        bench; this CPU container has 1
                                        core, so the parallel column
                                        lives in fig3_scaling.py's
                                        subprocess device sweep)

Graphs are scaled-down ER versions of the paper's sizes (CPU container);
the speedup STRUCTURE (interpreted -> compiled -> engine) is the claim
under test (C2): paper saw 30-50x Python->Numba; we report ours.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import emit, time_it
from repro.core import ref_python as R
from repro.encoder import Embedder, EncoderConfig
from repro.graph.edges import make_labels
from repro.graph.generators import erdos_renyi

GRAPHS = [
    # (name, n, s)  — scaled ~1000x down from Table I
    ("twitch-s", 1_700, 68_000),
    ("pokec-s", 16_000, 300_000),
    ("livejournal-s", 64_000, 690_000),
    ("orkut-s", 30_000, 1_170_000),
]
QUICK_GRAPHS = [("tiny", 400, 4_000)]
K = 50


def expected_keys() -> list:
    """Schema for `benchmarks.run`'s silently-empty-driver check."""
    keys = []
    for name, _n, s in common.pick(GRAPHS, QUICK_GRAPHS):
        if s <= 100_000:
            keys.append(f"table1/{name}/python_loop")
        keys += [f"table1/{name}/numpy_compiled",
                 f"table1/{name}/gee_xla",
                 f"table1/{name}/allclose"]
    return keys


def run() -> None:
    rng = np.random.default_rng(0)
    K_ = common.pick(K, 8)
    cfg = EncoderConfig(K=K_)
    for name, n, s in common.pick(GRAPHS, QUICK_GRAPHS):
        g = erdos_renyi(n, s, seed=1, weighted=True)
        Y = make_labels(n, K_, 0.10, rng)

        # interpreted python loop — only on the smallest graph (paper's
        # GEE-Python column took 56 min on Friendster; same reason)
        if s <= 100_000:
            t_py = time_it(lambda g=g, Y=Y, n=n:
                           R.gee_python(g.u, g.v, g.w, Y, K_, n),
                           warmup=0, iters=1)
            emit(f"table1/{name}/python_loop", t_py, f"s={s}")
        else:
            t_py = None

        # the numpy column measures the compiled serial scatter ITSELF
        # (the paper's Numba analog), not Embedder round-trip overhead —
        # time the backend internal directly
        t_np = time_it(lambda g=g, Y=Y, n=n:
                       R.gee_numpy(g.u, g.v, g.w, Y, K_, n),
                       warmup=1, iters=3)
        emit(f"table1/{name}/numpy_compiled", t_np, f"s={s}")

        emb = Embedder(cfg, backend="xla").fit(g, Y)
        t_jax = time_it(lambda emb=emb, Y=Y: emb.refit(Y).Z_,
                        warmup=1, iters=3)
        d = f"s={s};speedup_vs_numpy={t_np / t_jax:.2f}"
        if t_py:
            d += f";speedup_vs_python={t_py / t_jax:.1f}"
        emit(f"table1/{name}/gee_xla", t_jax, d)

        # correctness tie-in (C1): all columns agree (through the
        # conformance-tested numpy backend)
        emb_np = Embedder(cfg, backend="numpy").fit(g, Y)
        err = float(np.abs(emb_np.transform() - emb.transform()).max())
        emit(f"table1/{name}/allclose", 0.0, f"C1;max_abs_err={err:.2e}")


if __name__ == "__main__":
    run()
