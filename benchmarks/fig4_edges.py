"""Paper Figure 4 analog: GEE runtime vs. edge count on Erdős–Rényi
graphs — the linearity claim (C4).  We fit runtime = a*s + b and report
R^2 of the linear fit plus the per-edge cost stability."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, time_it
from repro.core import gee as G
from repro.graph.edges import make_labels
from repro.graph.generators import erdos_renyi

SIZES = [250_000, 500_000, 1_000_000, 2_000_000, 4_000_000]
QUICK_SIZES = [2_000, 4_000, 8_000]
K = 50
N = 200_000


def expected_keys() -> list:
    """Schema for `benchmarks.run`'s silently-empty-driver check."""
    return ([f"fig4/edges{s}" for s in common.pick(SIZES, QUICK_SIZES)]
            + ["fig4/linear_fit"])


def run() -> None:
    rng = np.random.default_rng(0)
    n = common.pick(N, 1_000)
    k = common.pick(K, 8)
    Y = make_labels(n, k, 0.10, rng)
    Yj = jnp.asarray(Y)
    xs, ts = [], []
    for s in common.pick(SIZES, QUICK_SIZES):
        g = erdos_renyi(n, s, seed=s, weighted=True)
        uj, vj, wj = map(jnp.asarray, (g.u, g.v, g.w))
        t = time_it(lambda uj=uj, vj=vj, wj=wj:
                    G.gee(uj, vj, wj, Yj, K=k, n=n),
                    warmup=1, iters=3)
        xs.append(s)
        ts.append(t)
        emit(f"fig4/edges{s}", t, f"ns_per_edge={t / s * 1e9:.2f}")
    A = np.vstack([np.asarray(xs, float), np.ones(len(xs))]).T
    coef, res, *_ = np.linalg.lstsq(A, np.asarray(ts), rcond=None)
    pred = A @ coef
    ss_tot = np.sum((np.asarray(ts) - np.mean(ts)) ** 2)
    r2 = 1.0 - float(np.sum((pred - ts) ** 2)) / max(ss_tot, 1e-18)
    emit("fig4/linear_fit", 0.0,
         f"C4;r2={r2:.4f};slope_ns_per_edge={coef[0] * 1e9:.2f}")


if __name__ == "__main__":
    run()
