"""Serving-path benchmark: incremental update latency vs. full re-embed,
query-kernel throughput, and the sharded `ServingEngine` deployment
path (delta fan-out, scatter/gather top-k, WAL append overhead, crash
recovery) on a >=1M-edge synthetic graph.

The headline row is `serving_speedup`: how much cheaper folding a
1%-sized edge delta into Z (`gee_apply_delta`, padded to a power-of-two
bucket exactly as the engine does) is than re-embedding the whole
graph — the reason the online service exists.  The sharded rows run at
1 and `--shards N` shards (`make bench-serving SHARDS=N`; the CI
bench-smoke job runs them in `--quick` mode so the partitioned path
cannot silently rot).
"""
from __future__ import annotations

import shutil
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, time_it
from repro.core.gee import gee, gee_apply_delta, make_w
from repro.graph.edges import Graph, make_labels
from repro.graph.generators import erdos_renyi
from repro.serving.queries import (class_centroids, gather_embeddings,
                                   predict_labels, topk_cosine)
from repro.serving.store import bucket_size

N, K, S = 100_000, 10, 1_500_000
DELTA_FRAC = 0.01


def expected_keys() -> list:
    """Schema for `benchmarks.run`'s silently-empty-driver check."""
    keys = ["serving_full_rebuild", "serving_delta_1pct",
            "serving_gather_8192", "serving_predict_4096",
            "serving_topk_256", "serving_engine_delta_wal",
            "serving_recovery_open", "serving_wal_fsync_each",
            "serving_wal_group_commit"]
    for p in sorted({1, max(1, common.SHARDS)}):
        keys += [f"serving_engine_delta_p{p}",
                 f"serving_engine_topk256_p{p}",
                 f"serving_engine_shard_mem_p{p}"]
    return keys


def run() -> None:
    global N, S
    N = common.pick(N, 2_000)
    S = common.pick(S, 30_000)
    rng = np.random.default_rng(0)
    g = erdos_renyi(N, S, seed=0, weighted=True)
    Y = make_labels(N, K, 0.1, rng)
    u, v, w = jnp.asarray(g.u), jnp.asarray(g.v), jnp.asarray(g.w)
    Yj = jnp.asarray(Y)

    # -- full re-embed (the rebuild path) ---------------------------------
    t_full = time_it(lambda: gee(u, v, w, Yj, K=K, n=N))
    emit("serving_full_rebuild", t_full, f"s={S}")

    # -- 1% delta via the incremental kernel (padded like the service) ----
    b = int(S * DELTA_FRAC)
    batch = Graph(rng.integers(0, N, b).astype(np.int32),
                  rng.integers(0, N, b).astype(np.int32),
                  (rng.random(b, dtype=np.float32) + 0.5),
                  N).pad_to(bucket_size(b))
    Wv = make_w(Yj, K)
    Z = gee(u, v, w, Yj, K=K, n=N)
    du, dv, dw = (jnp.asarray(batch.u), jnp.asarray(batch.v),
                  jnp.asarray(batch.w))
    t_delta = time_it(
        lambda: gee_apply_delta(Z, du, dv, dw, Yj, Wv, K=K))
    speedup = t_full / t_delta
    emit("serving_delta_1pct", t_delta, f"batch={b} speedup={speedup:.1f}x")
    if speedup < 10:
        print(f"# WARN serving delta speedup {speedup:.1f}x < 10x target")

    # -- query kernels ----------------------------------------------------
    nodes = jnp.asarray(rng.integers(0, N, 8192).astype(np.int32))
    t = time_it(lambda: gather_embeddings(Z, nodes))
    emit("serving_gather_8192", t, f"{8192 / t:,.0f}/s")

    cent = class_centroids(Z, Yj, K=K)
    pnodes = jnp.asarray(rng.integers(0, N, 4096).astype(np.int32))
    t = time_it(lambda: predict_labels(Z, cent, pnodes))
    emit("serving_predict_4096", t, f"{4096 / t:,.0f}/s")

    qnodes = rng.integers(0, N, 256).astype(np.int32)
    t = time_it(lambda: topk_cosine(Z, qnodes, k=10, block_rows=1 << 15),
                iters=2)
    emit("serving_topk_256", t, f"{256 / t:,.0f}/s")

    _sharded_engine_section(rng, g, Y, batch)
    _wal_group_section(rng)


def _sharded_engine_section(rng, g, Y, batch) -> None:
    """The deployment path: per-shard-count delta fan-out + top-k
    scatter/gather, per-shard accumulator memory (the owned-rows
    O(n/p) contract, measured rather than asserted), WAL
    append-before-apply overhead, and cold recovery (snapshot load +
    WAL replay + rebuild)."""
    from repro.serving import GraphStore, ServingEngine

    du, dv, dw = batch.u, batch.v, batch.w     # pre-padded 1% delta
    qnodes = rng.integers(0, N, 256).astype(np.int32)
    full_bytes = N * K * 4                     # one float32 (n, K) Z
    for p in sorted({1, max(1, common.SHARDS)}):
        eng = ServingEngine(GraphStore(g, Y, K), num_shards=p,
                            plan_cache=None)
        t = time_it(lambda eng=eng: eng.apply_edge_delta(du, dv, dw))
        emit(f"serving_engine_delta_p{p}", t,
             f"batch={du.shape[0]};edges_per_s={du.shape[0] / t:,.0f}")
        t = time_it(lambda eng=eng: eng.query_topk(qnodes, k=10,
                                                   block_rows=1 << 15),
                    iters=2)
        emit(f"serving_engine_topk256_p{p}", t, f"{256 / t:,.0f}/s")
        # owned-rows memory win: peak per-shard accumulator bytes
        # should track ceil(n/p)*K*4, i.e. ~1/p of the full Z
        peak = eng.stats()["peak_shard_accumulator_bytes"]
        emit(f"serving_engine_shard_mem_p{p}", 0.0,
             f"peak_accumulator_bytes={peak};full_z_bytes={full_bytes};"
             f"frac_of_full={peak / full_bytes:.3f};"
             f"expected_frac={-(-N // p) / N:.3f}")

    d = tempfile.mkdtemp(prefix="gee-bench-dep-")
    try:
        eng = ServingEngine(GraphStore(g, Y, K),
                            num_shards=max(1, common.SHARDS),
                            data_dir=d, plan_cache=None)
        t = time_it(lambda: eng.apply_edge_delta(du, dv, dw))
        emit("serving_engine_delta_wal", t,
             f"batch={du.shape[0]} append-before-apply")
        eng.close()
        t0 = time.perf_counter()
        rec = ServingEngine.open(d, plan_cache=None)
        t = time.perf_counter() - t0
        emit("serving_recovery_open", t,
             f"wal_records={eng.stats()['durability']['wal_records']}")
        rec.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)


def _wal_group_section(rng) -> None:
    """WAL group commit: append throughput with one fsync per record
    vs. batched fsync barriers (`group_commit_bytes`).  The target is
    >=5x — the whole point of batching the power-loss barrier."""
    from repro.serving.wal import WriteAheadLog

    appends = common.pick(400, 60)
    b = 64                                   # edges per append
    u = rng.integers(0, N, b).astype(np.int32)
    v = rng.integers(0, N, b).astype(np.int32)
    w = rng.random(b, dtype=np.float32) + 0.5

    def drive(wal: WriteAheadLog) -> float:
        wal.open()
        t0 = time.perf_counter()
        for i in range(appends):
            wal.append_edges(i + 1, u, v, w)
        wal.sync()                           # cover the final group
        t = time.perf_counter() - t0
        wal.close()
        return t / appends

    d = tempfile.mkdtemp(prefix="gee-bench-wal-")
    try:
        t_each = drive(WriteAheadLog(f"{d}/each.wal", fsync=True))
        emit("serving_wal_fsync_each", t_each,
             f"appends_per_s={1 / t_each:,.0f}")
        # group bytes sized for ~32 appends per barrier
        group = WriteAheadLog(f"{d}/group.wal", fsync=True,
                              group_commit_bytes=32 * (b * 12 + 32))
        t_group = drive(group)
        speedup = t_each / t_group
        emit("serving_wal_group_commit", t_group,
             f"appends_per_s={1 / t_group:,.0f};"
             f"appends_per_fsync={group.appends_per_fsync:.1f};"
             f"speedup={speedup:.1f}x")
        if speedup < 5:
            print(f"# WARN wal group commit speedup {speedup:.1f}x "
                  f"< 5x target")
    finally:
        shutil.rmtree(d, ignore_errors=True)
