# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig4,...]

Suites:
    table1   — paper Table I analog (python/numpy/XLA GEE runtimes)
    fig3     — strong scaling (subprocess device sweep)
    fig4     — Erdős–Rényi edge-count linearity
    kernels  — kernel-path microbenches
    encoder  — unified Embedder API: per-backend edges/s side by side
               + plan-cache (host packing removed on refit)
    serving  — online-service update latency vs full re-embed + queries
    roofline — per-cell roofline terms from dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys
import traceback

SUITES = ("table1", "fig4", "kernels", "encoder", "serving", "fig3",
          "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    ap.add_argument("--quick", action="store_true",
                    help="tiny graphs, minimal iters: exercises every "
                         "chosen driver end-to-end in seconds (the "
                         "`make bench-smoke` CI gate), numbers are NOT "
                         "meaningful measurements")
    ap.add_argument("--shards", type=int, default=None,
                    help="shard count for the serving suite's "
                         "partitioned-engine rows (default 2)")
    args = ap.parse_args()
    from benchmarks import common
    if args.quick:
        common.QUICK = True
    if args.shards is not None:
        common.SHARDS = max(1, args.shards)
    chosen = args.only.split(",") if args.only else list(SUITES)

    print("name,us_per_call,derived")
    failures = []
    for suite in chosen:
        try:
            if suite == "table1":
                from benchmarks.table1_runtimes import run
            elif suite == "fig3":
                from benchmarks.fig3_scaling import run
            elif suite == "fig4":
                from benchmarks.fig4_edges import run
            elif suite == "kernels":
                from benchmarks.kernels_bench import run
            elif suite == "encoder":
                from benchmarks.encoder_bench import run
            elif suite == "serving":
                from benchmarks.serving_bench import run
            elif suite == "roofline":
                from benchmarks.roofline_report import run
            else:
                raise ValueError(f"unknown suite {suite}")
            run()
        except Exception:
            traceback.print_exc()
            failures.append(suite)
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
